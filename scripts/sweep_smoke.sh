#!/usr/bin/env bash
# Sweep-sharding smoke: run the same smoke grid twice — once in-process,
# once as 1 driver + 2 localhost worker processes — and require the two
# result CSVs to be byte-identical (the sharding determinism contract;
# see EXPERIMENTS.md §Sharded sweeps). CI runs this as the `sweep-smoke`
# job.
#
# Usage: scripts/sweep_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found in PATH — install a Rust toolchain" \
         "(see rust-toolchain.toml) before running the sweep smoke" >&2
    exit 1
fi

cargo build --release --bin quickswap
BIN=target/release/quickswap
OUT=results
mkdir -p "$OUT"

# The smoke grid: small enough to finish in seconds, big enough to give
# every worker several units (2 λ × 3 policies × 3 reps = 18 units).
GRID=(--workload one_or_all --k 8 --p1 0.9 --lambdas 2.0,3.0
      --policies msf,msfq:7,fcfs --completions 6000 --seed 42 --reps 3)

echo "== in-process reference run =="
"$BIN" sweep "${GRID[@]}" --out "$OUT/sweep_inproc.csv"

echo "== sharded run: driver + 2 workers =="
rm -f "$OUT/sweep_driver.log"
"$BIN" sweep "${GRID[@]}" --driver 127.0.0.1:0 \
    --out "$OUT/sweep_sharded.csv" 2> "$OUT/sweep_driver.log" &
DRIVER_PID=$!
cleanup() { kill "$DRIVER_PID" 2>/dev/null || true; }
trap cleanup EXIT

# The driver prints its bound address to stderr; wait for it.
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's/.*listening on //p' "$OUT/sweep_driver.log" | head -n 1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$DRIVER_PID" 2>/dev/null; then
        echo "error: driver exited before binding" >&2
        cat "$OUT/sweep_driver.log" >&2
        exit 1
    fi
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "error: driver never reported a bound address" >&2
    cat "$OUT/sweep_driver.log" >&2
    exit 1
fi
echo "driver at $ADDR"

"$BIN" sweep --worker "$ADDR" &
W1=$!
"$BIN" sweep --worker "$ADDR" &
W2=$!
wait "$W1"
wait "$W2"
wait "$DRIVER_PID"
trap - EXIT

echo "== diff =="
if cmp "$OUT/sweep_inproc.csv" "$OUT/sweep_sharded.csv"; then
    echo "sweep smoke OK: sharded (2 workers) == in-process, byte-identical"
else
    echo "error: sharded and in-process sweep CSVs differ" >&2
    exit 1
fi
