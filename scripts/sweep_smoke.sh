#!/usr/bin/env bash
# Sweep-sharding smoke: run the same smoke grid twice — once in-process,
# once as 1 driver + 2 localhost worker processes — and require the two
# result CSVs to be byte-identical (the sharding determinism contract;
# see EXPERIMENTS.md §Sharded sweeps). A second leg repeats the exercise
# in paired (CRN) mode with `--paired --baseline msf`: the marginal CSV
# and the derived Δ CSV (`*.diff.csv`) must both be byte-identical
# between the in-process and sharded runs. CI runs this as the
# `sweep-smoke` job.
#
# Usage: scripts/sweep_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found in PATH — install a Rust toolchain" \
         "(see rust-toolchain.toml) before running the sweep smoke" >&2
    exit 1
fi

cargo build --release --bin quickswap
BIN=target/release/quickswap
OUT=results
mkdir -p "$OUT"

# The smoke grid: small enough to finish in seconds, big enough to give
# every worker several units (unpaired: 2 λ × 3 policies × 3 reps = 18
# units; paired: 2 λ × 3 reps = 6 units of 3 policies each).
GRID=(--workload one_or_all --k 8 --p1 0.9 --lambdas 2.0,3.0
      --policies msf,msfq:7,fcfs --completions 6000 --seed 42 --reps 3)

DRIVER_PID=""
cleanup() { [ -n "$DRIVER_PID" ] && kill "$DRIVER_PID" 2>/dev/null || true; }
trap cleanup EXIT

# Wait for a backgrounded driver to print its bound address to its log.
wait_for_addr() {
    local log=$1 pid=$2 addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/.*listening on //p' "$log" | head -n 1)
        [ -n "$addr" ] && break
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "error: driver exited before binding" >&2
            cat "$log" >&2
            return 1
        fi
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "error: driver never reported a bound address" >&2
        cat "$log" >&2
        return 1
    fi
    echo "$addr"
}

# Run the sharded twin of an in-process run: driver + 2 workers.
# $1 = log file, remaining args = the full sweep command line.
run_sharded() {
    local log=$1
    shift
    rm -f "$log"
    "$@" 2> "$log" &
    DRIVER_PID=$!
    local addr
    addr=$(wait_for_addr "$log" "$DRIVER_PID")
    echo "driver at $addr"
    "$BIN" sweep --worker "$addr" &
    local w1=$!
    "$BIN" sweep --worker "$addr" &
    local w2=$!
    wait "$w1"
    wait "$w2"
    wait "$DRIVER_PID"
    DRIVER_PID=""
}

require_identical() {
    if cmp "$1" "$2"; then
        echo "ok: $2 == $1, byte-identical"
    else
        echo "error: $1 and $2 differ" >&2
        exit 1
    fi
}

echo "== in-process reference run =="
"$BIN" sweep "${GRID[@]}" --out "$OUT/sweep_inproc.csv"

echo "== sharded run: driver + 2 workers =="
run_sharded "$OUT/sweep_driver.log" \
    "$BIN" sweep "${GRID[@]}" --driver 127.0.0.1:0 --out "$OUT/sweep_sharded.csv"

echo "== diff =="
require_identical "$OUT/sweep_inproc.csv" "$OUT/sweep_sharded.csv"

echo "== paired (CRN) in-process reference run =="
"$BIN" sweep "${GRID[@]}" --paired --baseline msf --out "$OUT/sweep_paired_inproc.csv"

echo "== paired (CRN) sharded run: driver + 2 workers =="
run_sharded "$OUT/sweep_paired_driver.log" \
    "$BIN" sweep "${GRID[@]}" --paired --baseline msf --driver 127.0.0.1:0 \
    --out "$OUT/sweep_paired_sharded.csv"

echo "== paired diff =="
require_identical "$OUT/sweep_paired_inproc.csv" "$OUT/sweep_paired_sharded.csv"
require_identical "$OUT/sweep_paired_inproc.diff.csv" "$OUT/sweep_paired_sharded.diff.csv"

trap - EXIT
echo "sweep smoke OK: sharded (2 workers) == in-process for the plain grid" \
     "and the paired (CRN) grid, marginal + Δ CSVs byte-identical"
