#!/usr/bin/env bash
# Sweep-service smoke: exercises the elastic sweep service end to end
# (see EXPERIMENTS.md §Elastic sweep service).
#
#   1. Sharding determinism: the same smoke grid in-process (`sweep run`)
#      and as 1 driver + 2 localhost workers (`sweep drive` / `sweep
#      work`) must produce byte-identical CSVs.
#   2. Paired (CRN) leg: the same exercise with `--paired --baseline
#      msf`, marginal + Δ CSVs both byte-identical.
#   3. Kill-and-resume leg: a journaled driver is SIGKILLed after ≥5 of
#      72 units, then restarted on the same journal with 2 workers; the
#      resumed CSV must be byte-identical to an uninterrupted run and
#      the resume log must show units served from the journal. The
#      `sweep status` endpoint is probed for totals and used to pace the
#      kill.
#   4. Trace leg: generate a 1M-job four_class trace, convert it to the
#      columnar `.qst` format, probe the footer-only `trace stats`, then
#      replay it as a 4-shard sweep — in-process under a < 64 MiB
#      resident-set assertion (the streaming source never materializes
#      the trace), and as driver + 2 workers with a byte-identical CSV.
#   5. Chaos leg: a fsync'd journaled driver serves two workers running
#      seeded fault plans (QS_FAULT_PLAN) — one crashes mid-sweep, one
#      loses its connection and self-heals via reconnect/resend — and
#      the surviving fabric must still converge to a CSV byte-identical
#      to the undisturbed in-process run.
#
# CI runs this as the `sweep-smoke` job, and the chaos leg alone as the
# `chaos-smoke` job (QS_CHAOS_ONLY=1 skips legs 1–3).
#
# Usage: scripts/sweep_smoke.sh          # all legs
#        QS_CHAOS_ONLY=1 scripts/sweep_smoke.sh   # chaos leg only
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found in PATH — install a Rust toolchain" \
         "(see rust-toolchain.toml) before running the sweep smoke" >&2
    exit 1
fi

cargo build --release --bin quickswap
BIN=target/release/quickswap
OUT=results
mkdir -p "$OUT"

# The smoke grid: small enough to finish in seconds, big enough to give
# every worker several units (unpaired: 2 λ × 3 policies × 3 reps = 18
# units; paired: 2 λ × 3 reps = 6 units of 3 policies each).
GRID=(--workload one_or_all --k 8 --p1 0.9 --lambdas 2.0,3.0
      --policies msf,msfq:7,fcfs --completions 6000 --seed 42 --reps 3)

# The kill-and-resume grid: same shape at 12 replications (72 units)
# and a 10× unit budget, so a single worker reliably stays mid-sweep
# long enough for the status-paced kill to land.
KGRID=(--workload one_or_all --k 8 --p1 0.9 --lambdas 2.0,3.0
       --policies msf,msfq:7,fcfs --completions 60000 --seed 42 --reps 12)

DRIVER_PID=""
WORKER_PID=""
cleanup() {
    [ -n "$WORKER_PID" ] && kill "$WORKER_PID" 2>/dev/null || true
    [ -n "$DRIVER_PID" ] && kill "$DRIVER_PID" 2>/dev/null || true
}
trap cleanup EXIT

# Wait for a backgrounded driver to print its bound address to its log.
wait_for_addr() {
    local log=$1 pid=$2 addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/.*listening on //p' "$log" | head -n 1)
        [ -n "$addr" ] && break
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "error: driver exited before binding" >&2
            cat "$log" >&2
            return 1
        fi
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "error: driver never reported a bound address" >&2
        cat "$log" >&2
        return 1
    fi
    echo "$addr"
}

# Run the sharded twin of an in-process run: driver + 2 workers.
# $1 = log file, remaining args = the full driver command line. If the
# driver's journal is already complete it exits before the workers can
# connect, so worker failures are tolerated.
run_sharded() {
    local log=$1
    shift
    rm -f "$log"
    "$@" 2> "$log" &
    DRIVER_PID=$!
    local addr
    addr=$(wait_for_addr "$log" "$DRIVER_PID")
    echo "driver at $addr"
    "$BIN" sweep work --addr "$addr" &
    local w1=$!
    "$BIN" sweep work --addr "$addr" &
    local w2=$!
    wait "$w1" || true
    wait "$w2" || true
    wait "$DRIVER_PID"
    DRIVER_PID=""
}

require_identical() {
    if cmp "$1" "$2"; then
        echo "ok: $2 == $1, byte-identical"
    else
        echo "error: $1 and $2 differ" >&2
        exit 1
    fi
}

if [ "${QS_CHAOS_ONLY:-0}" != "1" ]; then

echo "== in-process reference run =="
"$BIN" sweep run "${GRID[@]}" --out "$OUT/sweep_inproc.csv"

echo "== sharded run: driver + 2 workers =="
run_sharded "$OUT/sweep_driver.log" \
    "$BIN" sweep drive "${GRID[@]}" --addr 127.0.0.1:0 --out "$OUT/sweep_sharded.csv"

echo "== diff =="
require_identical "$OUT/sweep_inproc.csv" "$OUT/sweep_sharded.csv"

echo "== paired (CRN) in-process reference run =="
"$BIN" sweep run "${GRID[@]}" --paired --baseline msf --out "$OUT/sweep_paired_inproc.csv"

echo "== paired (CRN) sharded run: driver + 2 workers =="
run_sharded "$OUT/sweep_paired_driver.log" \
    "$BIN" sweep drive "${GRID[@]}" --paired --baseline msf --addr 127.0.0.1:0 \
    --out "$OUT/sweep_paired_sharded.csv"

echo "== paired diff =="
require_identical "$OUT/sweep_paired_inproc.csv" "$OUT/sweep_paired_sharded.csv"
require_identical "$OUT/sweep_paired_inproc.diff.csv" "$OUT/sweep_paired_sharded.diff.csv"

echo "== multiresource MSR leg: sweep run on the 2-dimension workload =="
"$BIN" sweep run --workload multires --k 16 --mem 64 --lambdas 2.0,3.0 \
    --policies msr-seq,msr-rand:50 --completions 4000 --seed 7 --reps 2 \
    --out "$OUT/sweep_msr_multires.csv"
grep -q 'msr-seq' "$OUT/sweep_msr_multires.csv"
grep -q 'msr-rand:50' "$OUT/sweep_msr_multires.csv"
echo "ok: MSR-Seq and MSR-Rand swept the multires workload to CSV"

echo "== kill-and-resume leg: uninterrupted reference =="
"$BIN" sweep run "${KGRID[@]}" --out "$OUT/sweep_kill_ref.csv"

echo "== kill-and-resume leg: journaled driver, SIGKILL mid-sweep =="
JOURNAL=$OUT/sweep_resume.journal
rm -f "$JOURNAL" "$OUT/sweep_kill_driver.log"
"$BIN" sweep drive "${KGRID[@]}" --addr 127.0.0.1:0 --journal "$JOURNAL" \
    --out "$OUT/sweep_resumed.csv" 2> "$OUT/sweep_kill_driver.log" &
DRIVER_PID=$!
ADDR=$(wait_for_addr "$OUT/sweep_kill_driver.log" "$DRIVER_PID")
echo "driver at $ADDR"

# Status probe: totals are visible before any unit completes.
"$BIN" sweep status --addr "$ADDR" | tee "$OUT/sweep_status.json"
grep -q '"units_total":72' "$OUT/sweep_status.json"
echo "ok: status endpoint reports 72 total units"

# One worker chews through the grid; poll status until ≥5 units are
# done, then SIGKILL the driver mid-sweep. Every acked unit is already
# journaled, so ≥5 records survive the kill.
"$BIN" sweep work --addr "$ADDR" 2>/dev/null &
WORKER_PID=$!
DONE=""
for _ in $(seq 1 400); do
    kill -0 "$DRIVER_PID" 2>/dev/null || break
    DONE=$("$BIN" sweep status --addr "$ADDR" 2>/dev/null \
        | sed -n 's/.*"units_done":\([0-9]*\).*/\1/p') || DONE=""
    [ -n "$DONE" ] && [ "$DONE" -ge 5 ] && break
    sleep 0.05
done
if kill -9 "$DRIVER_PID" 2>/dev/null; then
    echo "SIGKILLed driver at ${DONE:-?} completed units"
else
    # The worker outran the poll loop: the journal is complete, which
    # still exercises resume (everything served from disk).
    echo "driver finished before the kill; resuming from a complete journal"
fi
wait "$DRIVER_PID" 2>/dev/null || true
DRIVER_PID=""
kill "$WORKER_PID" 2>/dev/null || true
wait "$WORKER_PID" 2>/dev/null || true
WORKER_PID=""

echo "== kill-and-resume leg: restart on the journal, driver + 2 workers =="
run_sharded "$OUT/sweep_resume_driver.log" \
    "$BIN" sweep drive "${KGRID[@]}" --addr 127.0.0.1:0 --journal "$JOURNAL" \
    --out "$OUT/sweep_resumed.csv"

echo "== kill-and-resume diff =="
require_identical "$OUT/sweep_kill_ref.csv" "$OUT/sweep_resumed.csv"
FROM_JOURNAL=$(sed -n 's/.*, \([0-9]*\) from journal.*/\1/p' "$OUT/sweep_resume_driver.log")
if [ -z "$FROM_JOURNAL" ] || [ "$FROM_JOURNAL" -lt 5 ]; then
    echo "error: resume served ${FROM_JOURNAL:-0} units from the journal (expected >=5)" >&2
    cat "$OUT/sweep_resume_driver.log" >&2
    exit 1
fi
echo "ok: resume served $FROM_JOURNAL units from the journal without rerunning them"

echo "== trace leg: generate -> convert -> stats =="
TRACE_CSV=$OUT/trace_smoke.csv
TRACE_QST=$OUT/trace_smoke.qst
"$BIN" trace generate --workload four_class --lambda 4.0 --n 1000000 --seed 42 \
    --out "$TRACE_CSV"
"$BIN" trace convert --in "$TRACE_CSV" --out "$TRACE_QST" --workload four_class
"$BIN" trace stats "$TRACE_QST" | tee "$OUT/trace_stats.txt"
grep -q '1000000 arrivals' "$OUT/trace_stats.txt"
echo "ok: footer-only stats report the full trace"

# The trace grid: 1 λ × 3 policies × 4 shards = 12 units, each replaying
# its block-aligned quarter of the 1M-job trace to exhaustion.
TGRID=(--workload four_class --lambdas 4.0 --policies msf,msfq:7,fcfs
       --seed 42 --trace "$TRACE_QST" --shards 4)

echo "== trace leg: in-process streaming replay (RSS-bounded) =="
if /usr/bin/time -v true >/dev/null 2>&1; then
    /usr/bin/time -v "$BIN" sweep run "${TGRID[@]}" --out "$OUT/trace_inproc.csv" \
        2> "$OUT/trace_time.log"
    RSS_KB=$(sed -n 's/.*Maximum resident set size (kbytes): //p' "$OUT/trace_time.log")
    if [ -z "$RSS_KB" ] || [ "$RSS_KB" -ge 65536 ]; then
        echo "error: 1M-job streaming replay peaked at ${RSS_KB:-?} kB resident (>= 64 MiB)" >&2
        cat "$OUT/trace_time.log" >&2
        exit 1
    fi
    echo "ok: 1M-job streaming replay peaked at $RSS_KB kB resident (< 64 MiB)"
else
    echo "warning: GNU time unavailable — streaming-replay RSS bound not asserted"
    "$BIN" sweep run "${TGRID[@]}" --out "$OUT/trace_inproc.csv"
fi

echo "== trace leg: sharded run, driver + 2 workers =="
run_sharded "$OUT/trace_driver.log" \
    "$BIN" sweep drive "${TGRID[@]}" --addr 127.0.0.1:0 --out "$OUT/trace_sharded.csv"

echo "== trace diff =="
require_identical "$OUT/trace_inproc.csv" "$OUT/trace_sharded.csv"
rm -f "$TRACE_CSV"

fi # QS_CHAOS_ONLY

# The chaos grid: 2 λ × 3 policies × 4 reps = 24 units with enough work
# per unit that both fault plans fire while the sweep is genuinely
# mid-flight.
CGRID=(--workload one_or_all --k 8 --p1 0.9 --lambdas 2.0,3.0
       --policies msf,msfq:7,fcfs --completions 20000 --seed 42 --reps 4)

echo "== chaos leg: uninterrupted in-process reference =="
"$BIN" sweep run "${CGRID[@]}" --out "$OUT/chaos_ref.csv"

echo "== chaos leg: fsync'd journaled driver + crash worker + flaky worker =="
CJOURNAL=$OUT/chaos.journal
rm -f "$CJOURNAL" "$OUT/chaos_driver.log" "$OUT/chaos_w1.log" "$OUT/chaos_w2.log"
"$BIN" sweep drive "${CGRID[@]}" --addr 127.0.0.1:0 --journal "$CJOURNAL" --fsync \
    --out "$OUT/chaos_sharded.csv" 2> "$OUT/chaos_driver.log" &
DRIVER_PID=$!
ADDR=$(wait_for_addr "$OUT/chaos_driver.log" "$DRIVER_PID")
echo "driver at $ADDR"
# Worker 1 dies by injected crash while holding its 3rd claimed unit
# (the driver requeues it); worker 2 reads in 7-byte fragments and loses
# its connection on message 5 (its first result send), then reconnects
# with backoff and resends. Plans are per-process env so the driver's
# own QS_FAULT_PLAN stays unset.
QS_FAULT_PLAN="seed=9;crash@3" "$BIN" sweep work --addr "$ADDR" \
    2> "$OUT/chaos_w1.log" &
W1_PID=$!
QS_FAULT_PLAN="seed=9;short-read@7;disconnect@5" "$BIN" sweep work --addr "$ADDR" \
    2> "$OUT/chaos_w2.log" &
W2_PID=$!
wait "$W1_PID" || true
wait "$W2_PID" || true
wait "$DRIVER_PID"
DRIVER_PID=""

echo "== chaos leg: fault evidence and convergence =="
grep -q "injected crash" "$OUT/chaos_w1.log"
echo "ok: worker 1 crashed by plan"
grep -q "reconnected" "$OUT/chaos_w2.log"
echo "ok: worker 2 reconnected after its injected disconnect"
require_identical "$OUT/chaos_ref.csv" "$OUT/chaos_sharded.csv"
LIVENESS=$(grep "liveness" "$OUT/chaos_driver.log" || true)
echo "driver ${LIVENESS:-liveness line missing}"
rm -f "$CJOURNAL"

if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
    {
        echo "### Chaos smoke"
        echo ""
        echo '```'
        echo "plan w1: seed=9;crash@3"
        echo "plan w2: seed=9;short-read@7;disconnect@5"
        echo "${LIVENESS:-no liveness line}"
        echo '```'
        echo ""
        echo "Crash + disconnect fault plans converged to a CSV" \
             "byte-identical to the undisturbed run."
    } >> "$GITHUB_STEP_SUMMARY"
fi

trap - EXIT
if [ "${QS_CHAOS_ONLY:-0}" = "1" ]; then
    echo "chaos smoke OK: crashed and reconnecting workers converged" \
         "to a byte-identical CSV"
else
    echo "sweep smoke OK: sharded (2 workers) == in-process for the plain grid," \
         "the paired (CRN) grid, and the 1M-job sharded trace replay" \
         "(< 64 MiB resident); a SIGKILLed journaled driver resumed" \
         "to a byte-identical CSV, and the chaos leg converged under faults"
fi
