#!/usr/bin/env bash
# Compare a fresh perf-smoke artifact against the committed events/s
# trajectory (BENCH_perf_engine.json at the repo root).
#
#   scripts/bench_compare.sh <committed.json> <fresh.json>
#
# Gate: the headline targets (`sim_msfq:31`, `sim_borg_adaptive_qs`,
# `sim_server_filling`, the ladder-schedule twins `sim_fcfs:ladder` /
# `sim_borg_adaptive_qs:ladder`, the CRN shared-stream target
# `sim_paired_shared_stream`, the streaming `.qst` replay target
# `sim_trace_replay`, and the unitless `paired_ci_width_ratio`)
# fail the run when they regress >30% below the committed baseline, or
# when they are missing from the fresh artifact entirely (a dropped
# scenario must not pass silently); `sim_trace_replay` additionally
# carries an absolute >= 2M events/s acceptance floor independent of the
# committed baseline; everything else — and the
# [0.70, 1.0) band on the gated targets — is warn-only, because
# smoke-scale numbers on shared CI runners jitter. The committed
# baseline carries measured rates from a CI artifact, so the band is
# real headroom, not padding on an estimate. A committed stub (empty
# results) or a scale mismatch skips the gate with a note rather than
# failing.
set -euo pipefail

if [ $# -ne 2 ]; then
    echo "usage: $0 <committed.json> <fresh.json>" >&2
    exit 2
fi
if ! command -v python3 >/dev/null 2>&1; then
    echo "note: python3 unavailable — skipping bench trajectory compare" >&2
    exit 0
fi

python3 - "$1" "$2" <<'PYEOF'
import json, sys

committed = json.load(open(sys.argv[1]))
fresh = json.load(open(sys.argv[2]))
base = committed.get("results") or {}
new = fresh.get("results") or {}
if not new:
    sys.exit("error: fresh bench artifact has an empty 'results' object")
if not base:
    print("note: committed baseline is an empty stub - nothing to compare")
    sys.exit(0)
if committed.get("scale") != fresh.get("scale"):
    print(f"note: scale mismatch (committed {committed.get('scale')!r} vs "
          f"fresh {fresh.get('scale')!r}) - comparison skipped")
    sys.exit(0)

GATED = ("sim_msfq:31", "sim_borg_adaptive_qs", "sim_server_filling",
         "sim_fcfs:ladder", "sim_borg_adaptive_qs:ladder",
         "sim_paired_shared_stream", "sim_trace_replay",
         "paired_ci_width_ratio")
# Absolute floors (same unit as the artifact), enforced on top of the
# ratio gate: the streaming replay target has a hard acceptance number
# from the trace-pipeline PR, not just a no-regression requirement.
FLOORS = {"sim_trace_replay": 2.0e6}
missing = [g for g in GATED if g not in new]
if missing:
    sys.exit("error: gated bench target(s) missing from the fresh artifact: "
             + ", ".join(missing)
             + " - the bench binary dropped a scenario (or wrote a truncated"
             " JSON); refusing to compare without them")
failures = []
print(f"events/s trajectory vs committed baseline ({committed.get('scale')} scale):")
for name in sorted(set(base) | set(new)):
    if name not in base:
        print(f"  {name:<32} NEW: {new[name]:.3e}")
        continue
    if name not in new:
        print(f"  {name:<32} missing from fresh run (warn only)")
        continue
    ratio = new[name] / base[name]
    flag = ""
    if name in GATED and ratio < 0.70:
        flag = "  <-- FAIL: >30% regression"
        failures.append(f"{name} at {ratio:.2f}x of baseline")
    elif ratio < 1.0:
        flag = "  (below baseline - warn only)"
    if name in FLOORS and new[name] < FLOORS[name]:
        flag = f"  <-- FAIL: below the {FLOORS[name]:.1e} absolute floor"
        failures.append(f"{name} at {new[name]:.3e} (floor {FLOORS[name]:.1e})")
    print(f"  {name:<32} {new[name]:.3e} vs {base[name]:.3e}  ({ratio:.2f}x){flag}")
if failures:
    sys.exit("error: perf trajectory regression: " + "; ".join(failures))
print("bench trajectory OK")
PYEOF
