#!/usr/bin/env bash
# Perf smoke: run the engine-throughput bench at QS_SCALE=smoke and emit
# BENCH_perf_engine.json (events/s per policy) at the repo root, so every
# PR has a perf trajectory to compare against.
#
# Usage: scripts/bench_smoke.sh            # smoke scale, fast budgets
#        QS_SCALE=bench scripts/bench_smoke.sh   # heavier, steadier numbers
set -euo pipefail
cd "$(dirname "$0")/.."

export QS_SCALE="${QS_SCALE:-smoke}"
export QS_BENCH_FAST="${QS_BENCH_FAST:-1}"
export QS_BENCH_OUT="${QS_BENCH_OUT:-$PWD/BENCH_perf_engine.json}"

cargo bench --bench perf_engine

echo
echo "== $QS_BENCH_OUT =="
cat "$QS_BENCH_OUT"
