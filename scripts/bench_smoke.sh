#!/usr/bin/env bash
# Perf smoke: run the engine-throughput bench at QS_SCALE=smoke and emit
# BENCH_perf_engine.json (events/s per policy) at the repo root, so every
# PR has a perf trajectory to compare against. CI runs this as the
# `bench-smoke` job and uploads the JSON as an artifact.
#
# Usage: scripts/bench_smoke.sh            # smoke scale, fast budgets
#        QS_SCALE=bench scripts/bench_smoke.sh   # heavier, steadier numbers
#
# Fails loudly (no silent stub output) when:
#   * cargo is missing,
#   * the bench binary fails or writes no JSON,
#   * any bench target reports 0 events/s,
#   * the consult cache or the CRN shared-stream replay is a net
#     slowdown, or CRN pairing widens the Δ CI (paired_ci_width_ratio
#     below 1.0 — the acceptance value is asserted at 3.0 by
#     rust/tests/integration_paired.rs),
#   * the streaming .qst replay (sim_trace_replay) falls below its
#     absolute 2M events/s acceptance floor.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found in PATH — install a Rust toolchain" \
         "(see rust-toolchain.toml) before running the perf smoke" >&2
    exit 1
fi

export QS_SCALE="${QS_SCALE:-smoke}"
export QS_BENCH_FAST="${QS_BENCH_FAST:-1}"
export QS_BENCH_OUT="${QS_BENCH_OUT:-$PWD/BENCH_perf_engine.json}"

# Clear any previous output first: the bench binary exits 0 even when it
# cannot write the JSON, so a stale file must not be able to pass the
# checks below as if freshly measured.
rm -f "$QS_BENCH_OUT"

cargo bench --bench perf_engine

if [ ! -s "$QS_BENCH_OUT" ]; then
    echo "error: bench completed but wrote no output at $QS_BENCH_OUT" >&2
    exit 1
fi

echo
echo "== $QS_BENCH_OUT =="
cat "$QS_BENCH_OUT"

# Validate the artifact: a populated result set with strictly positive
# events/s everywhere, and the consult-cache targets at or above their
# uncached baselines (with a noise margin: < 0.9x fails the run, the
# [0.9, 1.0) band only warns — smoke-scale numbers jitter).
if command -v python3 >/dev/null 2>&1; then
    python3 - "$QS_BENCH_OUT" <<'PYEOF'
import json, sys

doc = json.load(open(sys.argv[1]))
results = doc.get("results") or {}
if not results:
    sys.exit("error: bench JSON has an empty 'results' object")
zeros = [name for name, rate in results.items() if not rate > 0.0]
if zeros:
    sys.exit(f"error: bench targets report 0 events/s: {zeros}")
failures = []
for cached, baseline in [
    ("sim_msfq:31", "sim_msfq:31_nocache"),
    ("sim_borg_adaptive_qs", "sim_borg_adaptive_qs_nocache"),
]:
    if cached in results and baseline in results:
        ratio = results[cached] / results[baseline]
        marker = "" if ratio >= 1.0 else "  <-- WARNING: below uncached baseline"
        print(f"consult-cache speedup {cached}: {ratio:.3f}x{marker}")
        if ratio < 0.9:
            failures.append(f"{cached} at {ratio:.3f}x of its uncached baseline")
# CRN paired replications: replaying one shared stream across the 4-policy
# set must beat 4 independent live-source runs (same noise margin as the
# consult-cache gate), and pairing must narrow — never widen — the Δ CI.
if "sim_paired_shared_stream" in results and "sim_independent_4policy" in results:
    ratio = results["sim_paired_shared_stream"] / results["sim_independent_4policy"]
    marker = "" if ratio >= 1.0 else "  <-- WARNING: replay slower than live sampling"
    print(f"shared-stream speedup (CRN replay, 4 policies): {ratio:.3f}x{marker}")
    if ratio < 0.9:
        failures.append(f"sim_paired_shared_stream at {ratio:.3f}x of the independent runs")
crn = results.get("paired_ci_width_ratio")
if crn is not None:
    print(f"paired_ci_width_ratio (unpaired / paired Δ CI, fig2 frontier): {crn:.2f}x")
    if crn < 1.0:
        failures.append(f"paired_ci_width_ratio {crn:.2f}x - CRN pairing widened the Δ CI")
# Streaming .qst replay: the acceptance floor is absolute (>= 2M
# events/s), independent of the committed trajectory baseline.
replay = results.get("sim_trace_replay")
if replay is not None:
    marker = "" if replay >= 2.0e6 else "  <-- below the 2M events/s floor"
    print(f"sim_trace_replay (streaming .qst, fcfs): {replay / 1e6:.2f} M events/s{marker}")
    if replay < 2.0e6:
        failures.append(f"sim_trace_replay at {replay:.3e} events/s (floor 2.0e6)")
if failures:
    sys.exit("error: perf smoke gate: " + "; ".join(failures))
PYEOF
else
    # Fallback without python3: reject the empty-results stub.
    if grep -q '"results":{}' "$QS_BENCH_OUT"; then
        echo "error: bench JSON has an empty 'results' object" >&2
        exit 1
    fi
    echo "note: python3 unavailable — skipped per-target zero-rate check" >&2
fi
