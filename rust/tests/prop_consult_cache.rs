//! Differential property tests for the incremental consult layer: a
//! policy with its consult cache enabled (fed the engine's `on_arrival`
//! / `on_departure` / `on_swap_epoch` delta notifications) must make
//! **bit-identical decisions** — and leave bit-identical system state —
//! to an uncached twin recomputing every consult from scratch, on
//! arbitrary event sequences. This is the correctness contract that
//! makes the cached fast paths legal (see `policy/mod.rs` module docs).

use quickswap::dist::Dist;
use quickswap::policy::test_support::Harness;
use quickswap::policy::{build, JobId, Policy, PolicyId};
use quickswap::util::proptest::check;
use quickswap::util::rng::Rng;
use quickswap::workload::{ClassSpec, Workload};

/// Parse-then-build, the typed replacement for the old `by_name`.
fn by_name(name: &str, wl: &Workload) -> anyhow::Result<Box<dyn Policy + Send>> {
    build(&name.parse::<PolicyId>()?, wl)
}

/// One step of a replayed schedule.
#[derive(Debug, Clone, Copy)]
enum Step {
    /// Arrival of the given class (index modulo the class count).
    Arrive(usize),
    /// Complete a random running job (no-op if none).
    Complete,
    /// Fire the policy timer (models the engine's `PolicyTimer`).
    Timer,
}

#[derive(Debug, Clone)]
struct Scenario {
    k: u32,
    needs: Vec<u32>,
    script: Vec<Step>,
    seed: u64,
}

fn gen_steps(r: &mut Rng, n: usize, nclasses: usize) -> Vec<Step> {
    (0..n)
        .map(|_| {
            let x = r.f64();
            if x < 0.55 {
                Step::Arrive(r.index(nclasses.max(8)))
            } else if x < 0.95 {
                Step::Complete
            } else {
                Step::Timer
            }
        })
        .collect()
}

fn gen_scenario(r: &mut Rng) -> Scenario {
    let k = 2 + r.below(15) as u32; // 2..=16
    let nclasses = 1 + r.index(4);
    let mut needs: Vec<u32> = (0..nclasses)
        .map(|_| 1 + r.below(k as u64) as u32)
        .collect();
    needs.sort_unstable();
    needs.dedup();
    let script = gen_steps(r, 160, needs.len());
    Scenario {
        k,
        needs,
        script,
        seed: r.next_u64(),
    }
}

/// One-or-all scenarios (the paper's core setting) so MSFQ — which
/// rejects other shapes — gets differential coverage too.
fn gen_one_or_all(r: &mut Rng) -> Scenario {
    let k = 2 + r.below(15) as u32;
    Scenario {
        k,
        needs: vec![1, k],
        script: gen_steps(r, 160, 2),
        seed: r.next_u64(),
    }
}

/// The Fig-5 multiclass workload shape: k=15, needs {1, 3, 5, 15}.
fn gen_fig5(r: &mut Rng) -> Scenario {
    Scenario {
        k: 15,
        needs: vec![1, 3, 5, 15],
        script: gen_steps(r, 200, 4),
        seed: r.next_u64(),
    }
}

/// The Fig-6 Borg-derived shape: k=2048 with all 26 trace classes —
/// the widest need spread the paper runs, exercising the Fenwick walk
/// over the full rank range.
fn gen_fig6(r: &mut Rng) -> Scenario {
    let needs = quickswap::workload::borg::BORG_NEEDS.to_vec();
    let script = gen_steps(r, 220, needs.len());
    Scenario {
        k: 2048,
        needs,
        script,
        seed: r.next_u64(),
    }
}

/// Fig-6-scale one-or-all (k=2048) so MSFQ gets coverage at the Borg
/// server count too (it rejects multiclass shapes by construction).
fn gen_fig6_one_or_all(r: &mut Rng) -> Scenario {
    Scenario {
        k: 2048,
        needs: vec![1, 2048],
        script: gen_steps(r, 200, 2),
        seed: r.next_u64(),
    }
}

/// The queue-index queries behind the new exact skip predicates must
/// agree with a from-scratch recompute of the same quantities — this is
/// what makes the Fenwick-backed consults and exact watermarks legal.
fn assert_index_exact(h: &Harness, step: usize) -> Result<(), String> {
    let sys = h.view();
    let idx = sys.queue_index();
    let brute_min = (0..h.needs.len())
        .filter(|&c| h.queued[c] > 0)
        .map(|c| h.needs[c])
        .min()
        .unwrap_or(u32::MAX);
    if idx.min_queued_need() != brute_min {
        return Err(format!(
            "step {step}: index min_queued_need {} != brute {brute_min}",
            idx.min_queued_need()
        ));
    }
    let starving = (0..h.needs.len()).any(|c| h.queued[c] > 0 && h.running[c] == 0);
    let backlogged = (0..h.needs.len()).any(|c| h.queued[c] > 0 && h.running[c] > 0);
    if idx.swap_trigger() != (starving && !backlogged) {
        return Err(format!("step {step}: index swap_trigger diverged"));
    }
    for free in [0, h.k / 2, h.k] {
        let brute = (0..h.needs.len())
            .filter(|&c| h.queued[c] > 0 && h.needs[c] <= free)
            .max_by_key(|&c| (h.needs[c], std::cmp::Reverse(c)));
        let fast = idx
            .max_fitting_rank_below(idx.num_ranks(), free)
            .map(|r| idx.class_at_rank(r));
        if fast != brute {
            return Err(format!(
                "step {step}: max_fitting({free}) index {fast:?} != brute {brute:?}"
            ));
        }
    }
    Ok(())
}

/// Drive cached and uncached twins of `policy` through the scenario in
/// lockstep; error out on the first divergence in decisions or state.
fn run_differential(sc: &Scenario, policy: &str) -> Result<(), String> {
    let wl = Workload::new(
        sc.k,
        sc.needs
            .iter()
            .map(|&n| ClassSpec::new(n, 1.0, Dist::exp_mean(1.0)))
            .collect(),
    );
    // Every policy in the test lists accepts these workload shapes, so a
    // construction failure is a real regression, not a shape mismatch —
    // never silently skip (that would make the property vacuous).
    let mut cached = by_name(policy, &wl)
        .map_err(|e| format!("by_name({policy}) failed: {e}"))?;
    let mut fresh = by_name(policy, &wl).expect("second construction must match the first");
    cached.set_consult_cache(true);
    fresh.set_consult_cache(false);
    let mut ha = Harness::new(sc.k, &sc.needs);
    let mut hb = Harness::new(sc.k, &sc.needs);
    let mut rng = Rng::new(sc.seed);
    let mut running: Vec<JobId> = Vec::new();
    let mut t = 0.0;
    for (i, &step) in sc.script.iter().enumerate() {
        t += 0.1;
        match step {
            Step::Arrive(c) => {
                let c = c % sc.needs.len();
                ha.arrive_notified(cached.as_mut(), c, t);
                hb.arrive_notified(fresh.as_mut(), c, t);
            }
            Step::Complete => {
                if running.is_empty() {
                    continue;
                }
                let id = running.swap_remove(rng.index(running.len()));
                if !ha.jobs.is_running(id) {
                    continue; // preempted since admission (ServerFilling)
                }
                ha.complete_notified(cached.as_mut(), id, t);
                hb.complete_notified(fresh.as_mut(), id, t);
            }
            Step::Timer => {
                cached.on_timer(t);
                fresh.on_timer(t);
            }
        }
        // The incremental admissible set must equal the from-scratch
        // recompute after every event.
        let adm_a = ha.consult(cached.as_mut());
        let adm_b = hb.consult(fresh.as_mut());
        if adm_a != adm_b {
            return Err(format!(
                "step {i}: cached admitted {adm_a:?}, uncached {adm_b:?}"
            ));
        }
        if ha.queued != hb.queued || ha.running != hb.running || ha.used() != hb.used() {
            return Err(format!(
                "step {i}: state diverged (queued {:?} vs {:?}, running {:?} vs {:?}, used {} vs {})",
                ha.queued,
                hb.queued,
                ha.running,
                hb.running,
                ha.used(),
                hb.used()
            ));
        }
        let la = cached.phase_label(&ha.view());
        let lb = fresh.phase_label(&hb.view());
        if la != lb {
            return Err(format!("step {i}: phase label diverged ({la} vs {lb})"));
        }
        assert_index_exact(&ha, i)?;
        running.extend(adm_a);
        running.retain(|&id| ha.jobs.is_running(id));
    }
    Ok(())
}

#[test]
fn prop_cached_equals_uncached_all_policies() {
    for policy in [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "static-qs:3",
        "adaptive-qs",
        "nmsr",
        "nmsr:5",
        "server-filling",
    ] {
        check(&format!("consult_cache/{policy}"), gen_scenario, |sc| {
            run_differential(sc, policy)
        });
    }
}

#[test]
fn prop_cached_equals_uncached_one_or_all() {
    for policy in [
        "msfq:0",
        "msfq:1",
        "msfq",
        "fcfs",
        "msf",
        "first-fit",
        "adaptive-qs",
        "static-qs",
        "nmsr",
        "server-filling",
    ] {
        check(
            &format!("consult_cache_one_or_all/{policy}"),
            gen_one_or_all,
            |sc| run_differential(sc, policy),
        );
    }
}

/// All policies that accept multiclass workloads on the Fig-5 shape
/// (k=15, needs {1,3,5,15}): the index-backed consults and exact
/// watermarks must be bit-identical to the uncached recompute, and the
/// index queries themselves must match brute force after every event.
#[test]
fn prop_cached_equals_uncached_fig5_multiclass() {
    for policy in [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "static-qs:7",
        "adaptive-qs",
        "nmsr",
        "server-filling",
    ] {
        check(&format!("consult_cache_fig5/{policy}"), gen_fig5, |sc| {
            run_differential(sc, policy)
        });
    }
}

/// Same contract on the Fig-6 Borg shape (k=2048, 26 classes) — the
/// widest rank range the Fenwick walk sees in the paper's experiments.
/// MSFQ rejects multiclass shapes, so it runs the k=2048 one-or-all
/// variant instead.
#[test]
fn prop_cached_equals_uncached_fig6_borg() {
    for policy in [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "adaptive-qs",
        "nmsr",
        "server-filling",
    ] {
        check(&format!("consult_cache_fig6/{policy}"), gen_fig6, |sc| {
            run_differential(sc, policy)
        });
    }
    for policy in ["msfq", "msfq:1024", "msfq:0"] {
        check(
            &format!("consult_cache_fig6_one_or_all/{policy}"),
            gen_fig6_one_or_all,
            |sc| run_differential(sc, policy),
        );
    }
}
