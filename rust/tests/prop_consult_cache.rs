//! Differential property tests for the incremental consult layer: a
//! policy with its consult cache enabled (fed the engine's `on_arrival`
//! / `on_departure` / `on_swap_epoch` delta notifications) must make
//! **bit-identical decisions** — and leave bit-identical system state —
//! to an uncached twin recomputing every consult from scratch, on
//! arbitrary event sequences. This is the correctness contract that
//! makes the cached fast paths legal (see `policy/mod.rs` module docs).

use quickswap::dist::Dist;
use quickswap::policy::test_support::Harness;
use quickswap::policy::{by_name, JobId, Policy};
use quickswap::util::proptest::check;
use quickswap::util::rng::Rng;
use quickswap::workload::{ClassSpec, Workload};

/// One step of a replayed schedule.
#[derive(Debug, Clone, Copy)]
enum Step {
    /// Arrival of the given class (index modulo the class count).
    Arrive(usize),
    /// Complete a random running job (no-op if none).
    Complete,
    /// Fire the policy timer (models the engine's `PolicyTimer`).
    Timer,
}

#[derive(Debug, Clone)]
struct Scenario {
    k: u32,
    needs: Vec<u32>,
    script: Vec<Step>,
    seed: u64,
}

fn gen_steps(r: &mut Rng, n: usize) -> Vec<Step> {
    (0..n)
        .map(|_| {
            let x = r.f64();
            if x < 0.55 {
                Step::Arrive(r.index(8))
            } else if x < 0.95 {
                Step::Complete
            } else {
                Step::Timer
            }
        })
        .collect()
}

fn gen_scenario(r: &mut Rng) -> Scenario {
    let k = 2 + r.below(15) as u32; // 2..=16
    let nclasses = 1 + r.index(4);
    let mut needs: Vec<u32> = (0..nclasses)
        .map(|_| 1 + r.below(k as u64) as u32)
        .collect();
    needs.sort_unstable();
    needs.dedup();
    Scenario {
        k,
        needs,
        script: gen_steps(r, 160),
        seed: r.next_u64(),
    }
}

/// One-or-all scenarios (the paper's core setting) so MSFQ — which
/// rejects other shapes — gets differential coverage too.
fn gen_one_or_all(r: &mut Rng) -> Scenario {
    let k = 2 + r.below(15) as u32;
    Scenario {
        k,
        needs: vec![1, k],
        script: gen_steps(r, 160),
        seed: r.next_u64(),
    }
}

/// Drive cached and uncached twins of `policy` through the scenario in
/// lockstep; error out on the first divergence in decisions or state.
fn run_differential(sc: &Scenario, policy: &str) -> Result<(), String> {
    let wl = Workload::new(
        sc.k,
        sc.needs
            .iter()
            .map(|&n| ClassSpec::new(n, 1.0, Dist::exp_mean(1.0)))
            .collect(),
    );
    // Every policy in the test lists accepts these workload shapes, so a
    // construction failure is a real regression, not a shape mismatch —
    // never silently skip (that would make the property vacuous).
    let mut cached = by_name(policy, &wl)
        .map_err(|e| format!("by_name({policy}) failed: {e}"))?;
    let mut fresh = by_name(policy, &wl).expect("second construction must match the first");
    cached.set_consult_cache(true);
    fresh.set_consult_cache(false);
    let mut ha = Harness::new(sc.k, &sc.needs);
    let mut hb = Harness::new(sc.k, &sc.needs);
    let mut rng = Rng::new(sc.seed);
    let mut running: Vec<JobId> = Vec::new();
    let mut t = 0.0;
    for (i, &step) in sc.script.iter().enumerate() {
        t += 0.1;
        match step {
            Step::Arrive(c) => {
                let c = c % sc.needs.len();
                ha.arrive_notified(cached.as_mut(), c, t);
                hb.arrive_notified(fresh.as_mut(), c, t);
            }
            Step::Complete => {
                if running.is_empty() {
                    continue;
                }
                let id = running.swap_remove(rng.index(running.len()));
                if !ha.jobs.is_running(id) {
                    continue; // preempted since admission (ServerFilling)
                }
                ha.complete_notified(cached.as_mut(), id, t);
                hb.complete_notified(fresh.as_mut(), id, t);
            }
            Step::Timer => {
                cached.on_timer(t);
                fresh.on_timer(t);
            }
        }
        // The incremental admissible set must equal the from-scratch
        // recompute after every event.
        let adm_a = ha.consult(cached.as_mut());
        let adm_b = hb.consult(fresh.as_mut());
        if adm_a != adm_b {
            return Err(format!(
                "step {i}: cached admitted {adm_a:?}, uncached {adm_b:?}"
            ));
        }
        if ha.queued != hb.queued || ha.running != hb.running || ha.used() != hb.used() {
            return Err(format!(
                "step {i}: state diverged (queued {:?} vs {:?}, running {:?} vs {:?}, used {} vs {})",
                ha.queued,
                hb.queued,
                ha.running,
                hb.running,
                ha.used(),
                hb.used()
            ));
        }
        let la = cached.phase_label(&ha.view());
        let lb = fresh.phase_label(&hb.view());
        if la != lb {
            return Err(format!("step {i}: phase label diverged ({la} vs {lb})"));
        }
        running.extend(adm_a);
        running.retain(|&id| ha.jobs.is_running(id));
    }
    Ok(())
}

#[test]
fn prop_cached_equals_uncached_all_policies() {
    for policy in [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "static-qs:3",
        "adaptive-qs",
        "nmsr",
        "nmsr:5",
        "server-filling",
    ] {
        check(&format!("consult_cache/{policy}"), gen_scenario, |sc| {
            run_differential(sc, policy)
        });
    }
}

#[test]
fn prop_cached_equals_uncached_one_or_all() {
    for policy in [
        "msfq:0",
        "msfq:1",
        "msfq",
        "fcfs",
        "msf",
        "first-fit",
        "adaptive-qs",
        "static-qs",
        "nmsr",
        "server-filling",
    ] {
        check(
            &format!("consult_cache_one_or_all/{policy}"),
            gen_one_or_all,
            |sc| run_differential(sc, policy),
        );
    }
}
