//! Integration: the three analysis paths (Theorem-2 calculator, sparse
//! CTMC, DES simulation) must tell one consistent story.

use quickswap::analysis::{analyze, best_threshold, MsfqCtmc, MsfqParams};
use quickswap::sim::{run_policy, SimConfig, SimResult};
use quickswap::workload::Workload;

/// Parse-then-run, the typed replacement for the old `run_named`.
fn run_named(
    wl: &Workload,
    policy: &str,
    cfg: &SimConfig,
    seed: u64,
) -> quickswap::Result<SimResult> {
    run_policy(wl, &policy.parse()?, cfg, seed)
}

/// Calculator vs near-exact CTMC at k=8 across loads: the Theorem-2
/// approximation is accurate at moderate-to-high load (paper §5.2 notes
/// it is an approximation; tolerances widen at low load).
#[test]
fn calculator_tracks_ctmc() {
    // Tolerances reflect that Theorem 2 is an approximation (§5.2); at
    // k=8 and ρ→1 the k-light phase-2 start assumption costs ~12%.
    for (lambda, tol) in [(3.2, 0.25), (4.0, 0.12), (4.4, 0.15)] {
        let p = MsfqParams::standard(8, 7, lambda, 0.9);
        let a = analyze(&p).unwrap();
        let c = MsfqCtmc::new(&p, 256, 64).solve(300_000, 1e-11);
        let rel = (a.et - c.et).abs() / c.et;
        assert!(
            rel < tol,
            "λ={lambda}: calculator {} vs CTMC {} (rel {rel:.3})",
            a.et,
            c.et
        );
    }
}

/// Phase-fraction agreement: m1 (time serving heavies) from the
/// calculator matches the CTMC's stationary fraction.
#[test]
fn phase_fractions_agree() {
    let p = MsfqParams::standard(8, 7, 4.2, 0.9);
    let a = analyze(&p).unwrap();
    let c = MsfqCtmc::new(&p, 256, 64).solve(300_000, 1e-11);
    // CTMC m1 excludes idle; calculator's m1 is per busy-cycle. At high
    // load idle ≈ 0 and the two coincide.
    let m1_ctmc = c.m1 / (1.0 - c.idle);
    assert!(
        (a.m[1] - m1_ctmc).abs() < 0.05,
        "m1: calculator {} vs CTMC {}",
        a.m[1],
        m1_ctmc
    );
}

/// The calculator's threshold ranking is borne out by simulation:
/// simulate at the calculator's best ℓ and at ℓ=0 (MSF).
#[test]
fn threshold_choice_validates_in_simulation() {
    let (k, lambda) = (16u32, 3.8); // rho ≈ 0.93... (0.9·3.8/16 + 0.1·3.8)
    let p = MsfqParams::standard(k, 0, lambda, 0.9);
    let (best_ell, predicted) = best_threshold(k, p.lam1, p.lamk, p.mu1, p.muk, false).unwrap();
    assert!(best_ell > 0);
    let wl = Workload::one_or_all(k, lambda, 0.9, 1.0, 1.0);
    let cfg = SimConfig {
        target_completions: 300_000,
        warmup_completions: 60_000,
        ..Default::default()
    };
    let best = run_named(&wl, &format!("msfq:{best_ell}"), &cfg, 31).unwrap();
    let msf = run_named(&wl, "msf", &cfg, 31).unwrap();
    assert!(
        best.mean_t_all < msf.mean_t_all,
        "chosen ℓ={best_ell} ({}) must beat MSF ({})",
        best.mean_t_all,
        msf.mean_t_all
    );
    let rel = (best.mean_t_all - predicted).abs() / predicted;
    assert!(rel < 0.25, "prediction {predicted} vs sim {} (rel {rel})", best.mean_t_all);
}

/// Stability boundaries (Theorems 3/4): just inside the region the
/// calculator succeeds; outside it must refuse.
#[test]
fn stability_region_boundaries() {
    // k=32, p1=0.9: λ* = 1/(0.9/32 + 0.1) ≈ 7.805.
    let lam_star = 1.0 / (0.9 / 32.0 + 0.1);
    assert!(analyze(&MsfqParams::standard(32, 31, lam_star * 0.99, 0.9)).is_ok());
    assert!(analyze(&MsfqParams::standard(32, 31, lam_star * 1.01, 0.9)).is_err());
    assert!(analyze(&MsfqParams::standard(32, 0, lam_star * 1.01, 0.9)).is_err());
}

/// MSF phase blowup (§4.1): phase durations explode with load under
/// MSF but stay moderate under MSFQ — the calculator shows the same
/// contrast the simulation does (Fig 4).
#[test]
fn msf_phase_blowup_vs_msfq() {
    let lo = analyze(&MsfqParams::standard(32, 0, 6.0, 0.9)).unwrap();
    let hi = analyze(&MsfqParams::standard(32, 0, 7.5, 0.9)).unwrap();
    let q = analyze(&MsfqParams::standard(32, 31, 7.5, 0.9)).unwrap();
    // MSF's light-serving phase grows superlinearly in load.
    assert!(hi.eh[2] > 4.0 * lo.eh[2]);
    // MSFQ keeps the cycle short.
    assert!(q.eh[2] < hi.eh[2] / 4.0, "q={} msf={}", q.eh[2], hi.eh[2]);
    // And the resulting E[T] gap is large (two orders per the paper; we
    // assert one order conservatively at this λ).
    assert!(q.et * 10.0 < hi.et, "MSFQ {} vs MSF {}", q.et, hi.et);
}
