//! Shard-count invariance for the sweep sharding subsystem: a sharded
//! run must be bit-identical to the in-process run at equal (seed, R),
//! regardless of worker count, transport, unit reissue after a worker
//! death, or duplicate results.

use quickswap::experiments::{run_unit, sweep_with, Point, SweepOpts};
use quickswap::sweep::{
    proto, run_spec_local, run_worker, run_worker_with_token, Driver, DriverBuilder, SpecOutcome,
    SweepSpec, WorkloadSpec,
};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Serve a single-marginal-spec driver to completion and unwrap its
/// points (the common shape of these tests).
fn serve_marginal(driver: Driver) -> Vec<Point> {
    let report = driver.serve().unwrap();
    assert_eq!(
        report.units_total,
        report.units_from_journal + report.units_executed
    );
    match report.outcomes.into_iter().next() {
        Some(SpecOutcome::Marginal(pts)) => pts,
        _ => panic!("expected one marginal outcome"),
    }
}

fn smoke_spec() -> SweepSpec {
    SweepSpec {
        workload: WorkloadSpec::OneOrAll {
            k: 8,
            p1: 0.9,
            mu1: 1.0,
            muk: 1.0,
        },
        lambdas: vec![2.0, 3.0],
        policies: vec![
            quickswap::policy::PolicyId::Msf,
            quickswap::policy::PolicyId::Msfq(Some(7)),
        ],
        target_completions: 6_000,
        warmup_completions: 1_200,
        batch: 1000,
        seed: 42,
        replications: 3,
        paired: false,
        baseline: None,
        trace: None,
    }
}

/// Every statistic the CSV writer and reports read must match to the bit.
fn assert_points_bit_identical(a: &[Point], b: &[Point]) {
    assert_eq!(a.len(), b.len(), "point count differs");
    for (x, y) in a.iter().zip(b) {
        let tag = format!("({}, {})", x.lambda, x.policy);
        assert_eq!(x.policy, y.policy, "{tag}");
        assert_eq!(x.lambda.to_bits(), y.lambda.to_bits(), "{tag}");
        assert_eq!(x.result.policy, y.result.policy, "{tag}");
        assert_eq!(x.result.completed, y.result.completed, "{tag}");
        assert_eq!(x.result.events, y.result.events, "{tag}");
        assert_eq!(
            x.result.mean_t_all.to_bits(),
            y.result.mean_t_all.to_bits(),
            "{tag}"
        );
        assert_eq!(x.result.ci95.to_bits(), y.result.ci95.to_bits(), "{tag}");
        assert_eq!(
            x.result.weighted_t.to_bits(),
            y.result.weighted_t.to_bits(),
            "{tag}"
        );
        assert_eq!(x.result.jain.to_bits(), y.result.jain.to_bits(), "{tag}");
        assert_eq!(
            x.result.utilization.to_bits(),
            y.result.utilization.to_bits(),
            "{tag}"
        );
        assert_eq!(
            x.result.sim_time.to_bits(),
            y.result.sim_time.to_bits(),
            "{tag}"
        );
        for c in 0..x.result.mean_t.len() {
            assert_eq!(
                x.result.mean_t[c].to_bits(),
                y.result.mean_t[c].to_bits(),
                "{tag} class {c}"
            );
            assert_eq!(
                x.result.mean_n[c].to_bits(),
                y.result.mean_n[c].to_bits(),
                "{tag} class {c}"
            );
            assert_eq!(x.result.count[c], y.result.count[c], "{tag} class {c}");
        }
    }
}

/// The spec path and the original closure-based local path agree: the
/// figure refactor (closures → shardable descriptions) changed nothing.
#[test]
fn spec_local_matches_closure_sweep() {
    let spec = smoke_spec();
    let via_spec = run_spec_local(&spec, 4);
    let wl_at = |l: f64| quickswap::workload::Workload::one_or_all(8, l, 0.9, 1.0, 1.0);
    let via_closure = sweep_with(
        &wl_at,
        &spec.lambdas,
        &[
            quickswap::policy::PolicyId::Msf,
            quickswap::policy::PolicyId::Msfq(Some(7)),
        ],
        &spec.config(),
        spec.seed,
        &SweepOpts {
            replications: 3,
            threads: 2,
        },
    );
    assert_points_bit_identical(&via_spec, &via_closure);
}

/// In-process vs 1 remote worker vs 3 remote workers (threads in this
/// process speaking real TCP): bit-identical pooled means/CIs.
#[test]
fn sharded_matches_inprocess_across_worker_counts() {
    let spec = smoke_spec();
    let base = run_spec_local(&spec, 4);
    assert_eq!(base.len(), 4);
    for n_workers in [1usize, 3] {
        let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
        let addr = driver.local_addr().to_string();
        let dh = std::thread::spawn(move || serve_marginal(driver));
        let workers: Vec<_> = (0..n_workers)
            .map(|_| {
                let a = addr.clone();
                std::thread::spawn(move || run_worker(&a).unwrap())
            })
            .collect();
        let pts = dh.join().unwrap();
        let served: usize = workers
            .into_iter()
            .map(|w| w.join().unwrap().completed)
            .sum();
        assert!(served >= 1, "workers served nothing");
        assert_points_bit_identical(&base, &pts);
    }
}

/// One spawned-subprocess worker (the real `quickswap sweep work`
/// binary) against an in-process driver.
#[test]
fn subprocess_worker_matches_inprocess() {
    let spec = smoke_spec();
    let base = run_spec_local(&spec, 4);
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || serve_marginal(driver));
    let child = std::process::Command::new(env!("CARGO_BIN_EXE_quickswap"))
        .args(["sweep", "work", "--addr", &addr])
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn worker subprocess");
    let pts = dh.join().unwrap();
    let status = child.wait_with_output().expect("worker subprocess exit");
    assert!(status.status.success(), "worker subprocess failed");
    assert_points_bit_identical(&base, &pts);
}

/// A worker that claims a unit and dies mid-assignment: the unit is
/// reissued and the sweep still converges to the identical result.
#[test]
fn killed_worker_units_are_reissued() {
    let spec = smoke_spec();
    let base = run_spec_local(&spec, 4);
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || serve_marginal(driver));

    // Fake worker: handshake, claim one unit, vanish without a result.
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        writeln!(w, "{}", proto::msg_hello(None)).unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        proto::parse_specs(&proto::parse_line(&line).unwrap()).unwrap();
        writeln!(w, "{}", proto::msg_next()).unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        let msg = proto::parse_line(&line).unwrap();
        assert_eq!(proto::op_of(&msg), Some("unit"));
        // Dropping both halves closes the connection with the unit
        // claimed and unreported.
    }

    let served = run_worker(&addr).unwrap();
    let pts = dh.join().unwrap();
    // The real worker ran the whole grid, including the reissued unit.
    assert_eq!(served.completed, spec.grid().n_units());
    assert_points_bit_identical(&base, &pts);
}

/// A hung-but-connected worker holding a claimed unit past the
/// assignment deadline (`QS_UNIT_TIMEOUT_SECS` /
/// `DriverBuilder::unit_timeout`): the unit is requeued to the next
/// `next` request and the sweep converges bit-identically — the
/// heterogeneous-pacing fault model.
#[test]
fn timed_out_units_are_reissued() {
    let spec = smoke_spec();
    let base = run_spec_local(&spec, 4);
    let driver = DriverBuilder::new()
        .spec(&spec)
        .unit_timeout(Some(std::time::Duration::from_millis(50)))
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || serve_marginal(driver));

    // Stalling worker: handshake, claim one unit, then hold the
    // connection open forever without reporting.
    let stall = TcpStream::connect(&addr).unwrap();
    let mut w = stall.try_clone().unwrap();
    let mut r = BufReader::new(stall.try_clone().unwrap());
    writeln!(w, "{}", proto::msg_hello(None)).unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    proto::parse_specs(&proto::parse_line(&line).unwrap()).unwrap();
    writeln!(w, "{}", proto::msg_next()).unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert_eq!(
        proto::op_of(&proto::parse_line(&line).unwrap()),
        Some("unit")
    );

    // A healthy worker drains the rest; once the deadline passes, its
    // polling (`next` → `wait` → `next`) picks up the reissued unit, so
    // it ends up serving the whole grid.
    let served = run_worker(&addr).unwrap();
    assert_eq!(served.completed, spec.grid().n_units());
    let pts = dh.join().unwrap();
    assert_points_bit_identical(&base, &pts);
    drop((w, r, stall));
}

/// Duplicate results for a unit id are deduped: sending the same unit's
/// result twice must neither corrupt the pool nor terminate the sweep
/// early with units missing.
#[test]
fn duplicate_results_are_deduped() {
    let spec = smoke_spec();
    let base = run_spec_local(&spec, 4);
    let grid = spec.grid();
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || serve_marginal(driver));

    // Rogue client: computes unit 0 honestly but reports it twice,
    // without ever claiming it via `next`.
    {
        let wl = spec.workload.build(grid.pts[0].0);
        let mut cache = None;
        let run = run_unit(&grid, &wl, 0, &mut cache).unwrap();
        let stream = TcpStream::connect(&addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        writeln!(w, "{}", proto::msg_hello(None)).unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap(); // spec
        for _ in 0..2 {
            writeln!(w, "{}", proto::msg_result(0, &run)).unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            let ack = proto::parse_line(&line).unwrap();
            assert_eq!(proto::op_of(&ack), Some("ok"));
        }
    }

    // A real worker finishes the rest; its own unit-0 result (unit 0 is
    // still in the pending queue) is the duplicate on the other side.
    run_worker(&addr).unwrap();
    let pts = dh.join().unwrap();
    assert_points_bit_identical(&base, &pts);
}

/// With a shared secret armed (`QS_SWEEP_TOKEN` /
/// `DriverBuilder::auth_token`), workers presenting the wrong token —
/// or none — are rejected before the spec queue is revealed, while a
/// matching-token worker completes the sweep bit-identically.
#[test]
fn auth_token_gates_workers() {
    let spec = smoke_spec();
    let base = run_spec_local(&spec, 4);
    let driver = DriverBuilder::new()
        .spec(&spec)
        .auth_token(Some("sesame".into()))
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || serve_marginal(driver));

    // Wrong token: rejected with an err line, no spec leaked.
    let err = run_worker_with_token(&addr, Some("wrong")).unwrap_err();
    assert!(
        err.to_string().contains("rejected"),
        "unexpected error: {err}"
    );
    // No token at all: also rejected.
    let err = run_worker_with_token(&addr, None).unwrap_err();
    assert!(err.to_string().contains("rejected"), "{err}");
    // Raw peek: the rejection line is an `err`, not the spec.
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        writeln!(w, "{}", proto::msg_hello(Some("still-wrong"))).unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let reply = proto::parse_line(&line).unwrap();
        assert_eq!(proto::err_of(&reply), Some("auth failed"));
        assert!(proto::parse_specs(&reply).is_err(), "specs must not leak");
    }

    // The right token serves the whole grid, bit-identical as ever.
    let served = run_worker_with_token(&addr, Some("sesame")).unwrap();
    assert_eq!(served.completed, spec.grid().n_units());
    let pts = dh.join().unwrap();
    assert_points_bit_identical(&base, &pts);
}

/// An open (tokenless) driver still accepts token-bearing workers: the
/// hello's token is simply ignored, so a fleet can roll the secret out
/// worker-first.
#[test]
fn open_driver_accepts_token_bearing_worker() {
    let spec = smoke_spec();
    let base = run_spec_local(&spec, 4);
    let driver = DriverBuilder::new()
        .spec(&spec)
        .auth_token(None)
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || serve_marginal(driver));
    let served = run_worker_with_token(&addr, Some("surplus-secret")).unwrap();
    assert_eq!(served.completed, spec.grid().n_units());
    let pts = dh.join().unwrap();
    assert_points_bit_identical(&base, &pts);
}
