//! Integration: PJRT runtime × AOT artifacts × native oracles.
//!
//! Requires the `pjrt` cargo feature (the stub runtime reports
//! "unavailable" by design) and `make artifacts` (the Makefile's `test`
//! target guarantees this ordering).
#![cfg(feature = "pjrt")]

use quickswap::analysis::{MsfqCtmc, MsfqParams};
use quickswap::runtime::solver::SweepArtifact;
use quickswap::runtime::{Runtime, SolverArtifact};

fn runtime() -> Runtime {
    Runtime::new(Runtime::default_dir()).expect("PJRT CPU client")
}

#[test]
fn loads_and_reports_platform() {
    let rt = runtime();
    assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
}

#[test]
fn solver_artifact_executes_and_conserves_mass() {
    let rt = runtime();
    let solver = SolverArtifact::load(&rt, 8).expect("load msfq_solver_k8");
    let m = solver.solve(7, 1.8, 0.1, 1.0, 1.0, 4000).unwrap();
    assert!((m.mass - 1.0).abs() < 1e-3, "mass = {}", m.mass);
    assert!(m.et.is_finite() && m.et > 0.0);
    assert!(m.trustworthy(), "{m:?}");
}

/// The artifact must agree with the native sparse CTMC solver — the
/// three-layer stack and the Rust oracle implement the same chain.
#[test]
fn artifact_matches_native_ctmc() {
    let rt = runtime();
    let solver = SolverArtifact::load(&rt, 8).expect("load msfq_solver_k8");
    let (lam1, lamk) = (2.7, 0.3); // rho = 2.7/8 + 0.3 = 0.6375
    let art = solver.solve(7, lam1, lamk, 1.0, 1.0, 20_000).unwrap();
    // Same truncation as the artifact (aot.py: (128, 32, 9)).
    let p = MsfqParams {
        k: 8,
        ell: 7,
        lam1,
        lamk,
        mu1: 1.0,
        muk: 1.0,
    };
    let native = MsfqCtmc::new(&p, 127, 31).solve(60_000, 1e-12);
    let rel = (art.et - native.et).abs() / native.et;
    assert!(
        rel < 0.02,
        "artifact E[T]={} vs native E[T]={} (rel {rel})",
        art.et,
        native.et
    );
    let rel1 = (art.et1 - native.et1).abs() / native.et1;
    assert!(rel1 < 0.02, "light: {} vs {}", art.et1, native.et1);
}

#[test]
fn autotune_picks_nonzero_threshold_at_high_load() {
    let rt = runtime();
    let solver = SolverArtifact::load(&rt, 8).expect("load msfq_solver_k8");
    // rho = 0.9: quickswap should clearly beat MSF.
    let (ell, m) = solver.autotune(4.0, 0.4, 1.0, 1.0, 30_000, false).unwrap();
    assert!(ell > 0, "autotuner chose MSF (ell=0) at high load");
    assert!(m.trustworthy());
    let msf = solver.solve(0, 4.0, 0.4, 1.0, 1.0, 30_000).unwrap();
    assert!(m.et <= msf.et + 1e-6);
}

#[test]
fn sweep_artifact_orders_thresholds() {
    let rt = runtime();
    let sweep = SweepArtifact::load(&rt, 8).expect("load msfq_sweep_k8");
    let (metrics, best_et, _best_etw) = sweep.sweep(4.0, 0.4, 1.0, 1.0, 20_000).unwrap();
    assert_eq!(metrics.len(), 8);
    assert!(best_et < 8);
    // The argmin returned by the artifact really is the minimum.
    let min_idx = metrics
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.et.partial_cmp(&b.1.et).unwrap())
        .unwrap()
        .0;
    assert_eq!(best_et as usize, min_idx);
}
