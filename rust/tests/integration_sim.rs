//! Integration: engine × policies × workloads — queueing-theoretic
//! ground truths and cross-layer consistency.

use quickswap::analysis::mmk;
use quickswap::dist::Dist;
use quickswap::sim::{run_policy, SimConfig, SimResult};
use quickswap::workload::{ClassSpec, Workload};

/// Parse-then-run, the typed replacement for the old `run_named`.
fn run_named(
    wl: &Workload,
    policy: &str,
    cfg: &SimConfig,
    seed: u64,
) -> quickswap::Result<SimResult> {
    run_policy(wl, &policy.parse()?, cfg, seed)
}

fn quick() -> SimConfig {
    SimConfig {
        target_completions: 150_000,
        warmup_completions: 30_000,
        ..Default::default()
    }
}

/// Under a single 1-server class, every nonpreemptive policy is work-
/// conserving and must match M/M/k exactly.
#[test]
fn all_policies_reduce_to_mmk_single_class() {
    let (k, lam, mu) = (8u32, 6.0, 1.0);
    let wl = Workload::new(k, vec![ClassSpec::new(1, lam, Dist::Exp { mu })]);
    let expect = mmk::mean_response_time(k, lam, mu);
    for policy in ["fcfs", "first-fit", "msf", "adaptive-qs"] {
        let r = run_named(&wl, policy, &quick(), 5).unwrap();
        let rel = (r.mean_t_all - expect).abs() / expect;
        assert!(
            rel < 0.04,
            "{policy}: E[T]={} vs M/M/k={expect} (rel {rel})",
            r.mean_t_all
        );
    }
}

/// Little's law holds per class for every policy on a 2-class workload.
#[test]
fn littles_law_all_policies() {
    let wl = Workload::one_or_all(16, 3.0, 0.9, 1.0, 1.0);
    for policy in ["fcfs", "first-fit", "msf", "msfq:15", "adaptive-qs", "static-qs", "nmsr"] {
        let r = run_named(&wl, policy, &quick(), 11).unwrap();
        for (c, cl) in wl.classes.iter().enumerate() {
            if r.count[c] < 1000 {
                continue;
            }
            let lam_eff = r.count[c] as f64 / r.sim_time;
            let expect_n = lam_eff * r.mean_t[c];
            let rel = (r.mean_n[c] - expect_n).abs() / expect_n.max(1e-9);
            assert!(
                rel < 0.08,
                "{policy}/class {}: E[N]={} vs λE[T]={} (rel {rel})",
                cl.name,
                r.mean_n[c],
                expect_n
            );
        }
    }
}

/// MSFQ with ℓ=0 must equal MSF in distribution: with identical seeds the
/// two simulations produce identical statistics (decision-equivalence).
#[test]
fn msfq_ell0_equals_msf() {
    let wl = Workload::one_or_all(8, 3.5, 0.9, 1.0, 1.0);
    let a = run_named(&wl, "msf", &quick(), 99).unwrap();
    let b = run_named(&wl, "msfq:0", &quick(), 99).unwrap();
    assert_eq!(a.completed, b.completed);
    assert!(
        (a.mean_t_all - b.mean_t_all).abs() < 1e-9,
        "MSF {} vs MSFQ(0) {}",
        a.mean_t_all,
        b.mean_t_all
    );
    assert!((a.mean_t[0] - b.mean_t[0]).abs() < 1e-9);
    assert!((a.mean_t[1] - b.mean_t[1]).abs() < 1e-9);
}

/// Simulation agrees with the Theorem-2 calculator for MSFQ (the paper's
/// analysis-accuracy claim, Fig 3).
#[test]
fn sim_matches_calculator_msfq() {
    // §5.2: the analysis is an approximation; measured gap is ~7% at
    // λ=6 (phase-2 start assumption) and shrinks as load rises.
    for (lambda, tol) in [(6.0, 0.09), (7.25, 0.10)] {
        let wl = Workload::one_or_all(32, lambda, 0.9, 1.0, 1.0);
        let cfg = SimConfig {
            target_completions: 400_000,
            warmup_completions: 80_000,
            ..Default::default()
        };
        let r = run_named(&wl, "msfq:31", &cfg, 21).unwrap();
        let a = quickswap::analysis::analyze(&quickswap::analysis::MsfqParams::standard(
            32, 31, lambda, 0.9,
        ))
        .unwrap();
        let rel = (r.mean_t_all - a.et).abs() / a.et;
        assert!(
            rel < tol,
            "λ={lambda}: sim {} vs analysis {} (rel {rel})",
            r.mean_t_all,
            a.et
        );
    }
}

/// Deterministic replay: same seed ⇒ identical results.
#[test]
fn deterministic_across_runs() {
    let wl = Workload::four_class(4.0);
    let a = run_named(&wl, "adaptive-qs", &quick(), 3).unwrap();
    let b = run_named(&wl, "adaptive-qs", &quick(), 3).unwrap();
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.events, b.events);
    assert!((a.mean_t_all - b.mean_t_all).abs() < 1e-12);
}

/// Utilization can never exceed 1 and matches offered load for stable
/// work-conserving single-class systems.
#[test]
fn utilization_bounds() {
    let wl = Workload::one_or_all(16, 3.0, 0.9, 1.0, 1.0);
    for policy in ["msf", "msfq:15", "first-fit", "server-filling"] {
        let r = run_named(&wl, policy, &quick(), 17).unwrap();
        assert!(r.utilization <= 1.0 + 1e-9, "{policy} util {}", r.utilization);
        assert!(r.utilization > 0.1);
    }
}

/// Preemptive ServerFilling beats every nonpreemptive policy on a
/// one-or-all workload at high load (Appendix D's headline).
#[test]
fn server_filling_dominates_nonpreemptive() {
    let wl = Workload::one_or_all(16, 4.2, 0.9, 1.0, 1.0); // rho ≈ 0.945
    let sf = run_named(&wl, "server-filling", &quick(), 7).unwrap();
    for policy in ["msf", "msfq:15", "fcfs"] {
        let r = run_named(&wl, policy, &quick(), 7).unwrap();
        assert!(
            sf.mean_t_all < r.mean_t_all,
            "ServerFilling {} !< {policy} {}",
            sf.mean_t_all,
            r.mean_t_all
        );
    }
}

/// General (non-exponential) sizes: engine + policies stay consistent
/// (Little's law) with hyperexponential and deterministic sizes.
#[test]
fn non_exponential_sizes_work() {
    let wl = Workload::new(
        8,
        vec![
            ClassSpec::new(1, 3.0, Dist::hyper2_mean_scv(1.0, 4.0)),
            ClassSpec::new(8, 0.05, Dist::Det { v: 2.0 }),
        ],
    );
    let r = run_named(&wl, "msfq:7", &quick(), 13).unwrap();
    assert!(r.mean_t_all.is_finite() && r.mean_t_all > 0.0);
    let lam_eff = r.count[0] as f64 / r.sim_time;
    let rel = (r.mean_n[0] - lam_eff * r.mean_t[0]).abs() / r.mean_n[0];
    assert!(rel < 0.08, "Little violated: rel={rel}");
}
