//! Sharded trace-replay sweeps: a `.qst`-backed sweep distributed over
//! TCP workers must be bit-identical to the in-process run, and the
//! trace field must ride the spec wire format additively (pre-trace
//! drivers and workers never see it).

use quickswap::experiments::{Point, TraceShards};
use quickswap::sweep::{
    run_spec_local, run_worker, Driver, DriverBuilder, SpecOutcome, SweepSpec, WorkloadSpec,
};
use quickswap::util::json::Value;
use quickswap::workload::trace::Trace;
use quickswap::workload::Workload;

fn serve_marginal(driver: Driver) -> Vec<Point> {
    let report = driver.serve().unwrap();
    match report.outcomes.into_iter().next() {
        Some(SpecOutcome::Marginal(pts)) => pts,
        _ => panic!("expected one marginal outcome"),
    }
}

/// A four_class trace on disk plus a spec that replays it in 2 shards.
fn trace_spec(dir: &std::path::Path) -> SweepSpec {
    let wl = Workload::four_class(4.0);
    let tr = Trace::generate(&wl, 1_200, 11);
    let path = dir.join("sweep.qst");
    tr.write_qst(&path, wl.num_classes(), 64).unwrap();
    SweepSpec {
        workload: WorkloadSpec::FourClass,
        lambdas: vec![4.0],
        policies: vec![
            quickswap::policy::PolicyId::Msf,
            quickswap::policy::PolicyId::Msfq(Some(7)),
            quickswap::policy::PolicyId::Fcfs,
        ],
        target_completions: 6_000,
        warmup_completions: 0,
        batch: 1000,
        seed: 42,
        replications: 3, // ignored: the shard axis takes over
        paired: false,
        baseline: None,
        trace: Some(TraceShards {
            path: path.to_string_lossy().into_owned(),
            shards: 2,
        }),
    }
}

fn tmp_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("qs_trace_sweep_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_points_bit_identical(a: &[Point], b: &[Point]) {
    assert_eq!(a.len(), b.len(), "point count differs");
    for (x, y) in a.iter().zip(b) {
        let tag = format!("({}, {})", x.lambda, x.policy);
        assert_eq!(x.policy, y.policy, "{tag}");
        assert_eq!(x.result.completed, y.result.completed, "{tag}");
        assert_eq!(x.result.events, y.result.events, "{tag}");
        assert_eq!(x.result.mean_t_all.to_bits(), y.result.mean_t_all.to_bits(), "{tag}");
        assert_eq!(x.result.ci95.to_bits(), y.result.ci95.to_bits(), "{tag}");
        assert_eq!(x.result.weighted_t.to_bits(), y.result.weighted_t.to_bits(), "{tag}");
        assert_eq!(x.result.sim_time.to_bits(), y.result.sim_time.to_bits(), "{tag}");
        for c in 0..x.result.mean_t.len() {
            assert_eq!(
                x.result.mean_t[c].to_bits(),
                y.result.mean_t[c].to_bits(),
                "{tag} class {c}"
            );
            assert_eq!(x.result.count[c], y.result.count[c], "{tag} class {c}");
        }
    }
}

/// The acceptance invariant: driver + 2 TCP workers replaying a sharded
/// trace produce exactly the in-process results — the shard grid is
/// rebuilt identically from the spec on both sides.
#[test]
fn sharded_trace_sweep_is_bit_identical_to_local() {
    let dir = tmp_dir();
    let spec = trace_spec(&dir);
    let base = run_spec_local(&spec, 4);
    assert_eq!(base.len(), 3, "one pooled point per policy");
    // Every unit replayed real trace jobs (1200 jobs over 2 shards, all
    // of which complete).
    for p in &base {
        assert_eq!(p.result.completed, 1_200, "({}, {})", p.lambda, p.policy);
    }
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || serve_marginal(driver));
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let a = addr.clone();
            std::thread::spawn(move || run_worker(&a).unwrap())
        })
        .collect();
    let pts = dh.join().unwrap();
    let served: usize = workers.into_iter().map(|w| w.join().unwrap().completed).sum();
    assert_eq!(served, spec.grid().n_units());
    assert_points_bit_identical(&base, &pts);
}

/// Wire compatibility: the trace field round-trips when present, is
/// absent from traceless wires, and a paired spec refuses to carry one.
#[test]
fn trace_spec_wire_roundtrip_and_grid() {
    let dir = tmp_dir();
    let mut spec = trace_spec(&dir);
    let wire = spec.to_json().to_string();
    assert!(wire.contains("trace"), "trace object missing from wire");
    let back = SweepSpec::from_json(&Value::parse(&wire).unwrap()).unwrap();
    assert_eq!(back.trace, spec.trace);
    // The shard axis replaces the replication axis, and units run to
    // trace exhaustion, not to the completion target.
    let grid = back.grid();
    assert_eq!(grid.reps, 2);
    assert_eq!(grid.rep_cfg.target_completions, u64::MAX / 2);
    assert_eq!(grid.trace, spec.trace);
    // Pre-trace wire (no trace field) parses to a traceless spec.
    let legacy = Value::parse(&wire).unwrap().without("trace");
    assert!(SweepSpec::from_json(&legacy).unwrap().trace.is_none());
    // CRN pairing and trace replay are mutually exclusive.
    spec.paired = true;
    assert!(spec.paired_grid().is_err());
}
