//! Integration: policy semantics under adversarial event sequences,
//! exercised through the mini-harness (no stochastic noise).

use quickswap::dist::Dist;
use quickswap::policy::test_support::Harness;
use quickswap::policy::{build, Policy, PolicyId};
use quickswap::workload::{ClassSpec, Workload};

fn one_or_all(k: u32) -> Workload {
    Workload::one_or_all(k, 1.0, 0.9, 1.0, 1.0)
}

/// Parse-then-build, the typed replacement for the old `by_name`.
fn mk(name: &str, wl: &Workload) -> anyhow::Result<Box<dyn Policy + Send>> {
    build(&name.parse::<PolicyId>()?, wl)
}

/// MSFQ never serves lights and heavies simultaneously (one-or-all
/// exclusivity, the structural invariant behind the phase analysis).
#[test]
fn msfq_never_mixes_classes() {
    let k = 6;
    let wl = one_or_all(k);
    let mut p = mk("msfq:5", &wl).unwrap();
    let mut h = Harness::new(k, &[1, k]);
    let mut running = Vec::new();
    // Deterministic stress: bursts of arrivals interleaved with
    // completions in FIFO order.
    let mut t = 0.0;
    for round in 0..200 {
        t += 0.1;
        let class = usize::from(round % 7 == 0);
        h.arrive(class, t);
        running.extend(h.consult(p.as_mut()));
        assert!(
            h.running[0] == 0 || h.running[1] == 0,
            "lights and heavies in service together at round {round}"
        );
        if round % 3 == 0 && !running.is_empty() {
            let id = running.remove(0);
            if h.jobs.is_running(id) {
                t += 0.05;
                h.complete(id, t);
                running.extend(h.consult(p.as_mut()));
            }
        }
    }
}

/// Drain-phase invariant: once MSFQ stops admitting lights, no light
/// enters service until the drain empties — even under heavy arrivals.
#[test]
fn msfq_drain_is_sealed() {
    let k = 4;
    let wl = one_or_all(k);
    let mut p = mk("msfq:2", &wl).unwrap();
    let mut h = Harness::new(k, &[1, k]);
    let l: Vec<_> = (0..4).map(|i| h.arrive(0, i as f64 * 0.01)).collect();
    h.consult(p.as_mut());
    // Complete down to the threshold (n1 = 2 ⇒ drain).
    h.complete(l[0], 1.0);
    h.consult(p.as_mut());
    h.complete(l[1], 1.1);
    h.consult(p.as_mut());
    // Flood with arrivals of both classes: nothing may start.
    for i in 0..10 {
        h.arrive(0, 1.2 + i as f64 * 0.01);
        h.arrive(1, 1.25 + i as f64 * 0.01);
        assert!(h.consult(p.as_mut()).is_empty(), "drain leaked at i={i}");
    }
    assert_eq!(h.running[0], 2);
}

/// Static Quickswap serves exactly one class at a time, in cycle order.
#[test]
fn static_qs_exclusivity() {
    let wl = Workload::four_class(1.0);
    let mut p = mk("static-qs", &wl).unwrap();
    let mut h = Harness::new(15, &[1, 3, 5, 15]);
    for i in 0..5 {
        h.arrive(0, 0.01 * i as f64);
        h.arrive(1, 0.02 * i as f64);
        h.arrive(2, 0.03 * i as f64);
    }
    h.consult(p.as_mut());
    let classes_running = (0..4).filter(|&c| h.running[c] > 0).count();
    assert_eq!(classes_running, 1, "StaticQS must serve one class");
}

/// nMSR ignores queue state: with jobs of an inactive class queued and
/// servers idle, it still refuses to serve them (the paper's critique).
#[test]
fn nmsr_wastes_capacity_by_design() {
    let wl = Workload::new(
        4,
        vec![
            ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
            ClassSpec::new(4, 0.2, Dist::exp_mean(1.0)),
        ],
    );
    let mut p = mk("nmsr:1000", &wl).unwrap();
    let mut h = Harness::new(4, &[1, 4]);
    // Schedule 0 (class 0) is active for ~the whole long cycle; a heavy
    // arrives and must wait despite 4 idle servers.
    h.arrive(1, 0.0);
    assert!(h.consult(p.as_mut()).is_empty(), "nMSR served inactive class");
    // A light arrival is admitted immediately.
    let l = h.arrive(0, 0.1);
    assert_eq!(h.consult(p.as_mut()), vec![l]);
}

/// FCFS head-of-line blocking vs First-Fit backfilling on the same
/// deterministic sequence (the §1.1 motivating example).
#[test]
fn fcfs_blocks_first_fit_backfills() {
    let k = 4;
    let seq = |p: &mut dyn Policy| {
        let mut h = Harness::new(k, &[1, k]);
        h.arrive(0, 0.0);
        h.arrive(1, 0.1); // heavy cannot fit
        h.arrive(0, 0.2);
        h.arrive(0, 0.3);
        h.consult(p);
        h.running[0]
    };
    let wl = one_or_all(k);
    let mut fcfs = mk("fcfs", &wl).unwrap();
    let mut ff = mk("first-fit", &wl).unwrap();
    assert_eq!(seq(fcfs.as_mut()), 1, "FCFS must block at the heavy");
    assert_eq!(seq(ff.as_mut()), 3, "First-Fit must backfill the lights");
}

/// ServerFilling keeps all k servers busy whenever total queued demand
/// ≥ k with power-of-two needs (the [22] guarantee).
#[test]
fn server_filling_full_utilization() {
    let k = 16;
    let wl = Workload::new(
        k,
        vec![
            ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
            ClassSpec::new(2, 1.0, Dist::exp_mean(1.0)),
            ClassSpec::new(4, 1.0, Dist::exp_mean(1.0)),
            ClassSpec::new(8, 1.0, Dist::exp_mean(1.0)),
        ],
    );
    let mut p = mk("server-filling", &wl).unwrap();
    let mut h = Harness::new(k, &[1, 2, 4, 8]);
    let mut rng = quickswap::util::rng::Rng::new(5);
    let mut in_service: Vec<quickswap::policy::JobId> = Vec::new();
    for step in 0..300 {
        let class = rng.index(4);
        h.arrive(class, step as f64);
        in_service.extend(h.consult(p.as_mut()));
        in_service.retain(|&id| h.jobs.is_running(id));
        let demand: u32 = (0..4)
            .map(|c| (h.queued[c] + h.running[c]) * h.needs[c])
            .sum();
        if demand >= k {
            assert_eq!(h.used(), k, "not fully packed at step {step}");
        }
        // Random completion.
        if !in_service.is_empty() && rng.chance(0.7) {
            let id = in_service.swap_remove(rng.index(in_service.len()));
            h.complete(id, step as f64 + 0.5);
            in_service.extend(h.consult(p.as_mut()));
            in_service.retain(|&id| h.jobs.is_running(id));
        }
    }
}

/// Policy construction errors: bad names, bad thresholds, wrong
/// workload shapes.
#[test]
fn constructor_validation() {
    let wl = one_or_all(8);
    let unknown = "bogus".parse::<PolicyId>().unwrap_err().to_string();
    assert!(
        unknown.contains("unknown policy") && unknown.contains("msfq"),
        "unknown-policy error must list the valid names, got: {unknown}"
    );
    assert!(mk("msfq:8", &wl).is_err()); // ell must be < k
    assert!(mk("msfq:abc", &wl).is_err());
    let multi = Workload::four_class(1.0);
    assert!(mk("msfq:3", &multi).is_err()); // not one-or-all
    assert!(mk("msfq:7", &wl).is_ok());
    // MSFQ requires the scalar model; the MSR family accepts vectors.
    let vec2 = Workload::multires(16, 64, 1.0);
    assert!(mk("msfq:7", &vec2).is_err());
    assert!(mk("msr-seq", &vec2).is_ok());
    assert!(mk("msr-rand:25", &vec2).is_ok());
    // Canonical Display round-trips through parse.
    for id in [
        PolicyId::Fcfs,
        PolicyId::FirstFit,
        PolicyId::Msf,
        PolicyId::Msfq(Some(31)),
        PolicyId::StaticQs(None),
        PolicyId::AdaptiveQs,
        PolicyId::Nmsr(Some(50.0)),
        PolicyId::ServerFilling,
        PolicyId::MsrSeq(None),
        PolicyId::MsrRand(Some(25.0)),
    ] {
        let back: PolicyId = id.to_string().parse().unwrap();
        assert_eq!(back, id);
    }
}

/// MSR-Seq and MSR-Rand serve only their active configuration on the
/// 2-resource workload, sized by vector packing (not servers alone).
#[test]
fn msr_family_vector_configurations() {
    let wl = Workload::multires(16, 64, 1.0);
    // Class 1 ("cpu") demands [8, 8] into capacity [16, 64] → 2 slots.
    for name in ["msr-seq", "msr-rand"] {
        let mut p = mk(name, &wl).unwrap();
        let mut h = Harness::with_capacity(wl.capacity, &wl.demands());
        // Active configuration is class 0 (small, [1,1]): its jobs are
        // admitted, the queued cpu job is not.
        let s = h.arrive(0, 0.0);
        h.arrive(1, 0.1);
        let adm = h.consult(p.as_mut());
        assert_eq!(adm, vec![s], "{name} must serve only the active class");
        assert_eq!(h.running[1], 0);
    }
}
