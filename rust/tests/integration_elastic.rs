//! Elastic sweep service integration: multi-spec queueing,
//! checkpoint/resume durability, worker churn, and the status endpoint.
//!
//! The determinism contract under test: a driver SIGKILLed mid-sweep
//! and restarted on the same journal emits byte-identical CSVs to an
//! uninterrupted run at equal (seed, R) — with finished units served
//! from the journal, never rerun (asserted via the
//! [`ServeReport`] unit accounting) — across 1- and 2-worker resume
//! topologies, for marginal and paired (CRN) specs alike. Corrupted
//! journals must fail loudly rather than silently rerunning; a torn
//! (no-newline) tail is the one legitimate crash artifact and is
//! dropped.

use quickswap::experiments::{
    run_paired_unit, run_unit, write_diff_csv, write_sweep_csv, PairedSweep, Point,
};
use quickswap::sweep::{
    proto, run_spec_local, run_spec_paired_local, run_worker, DriverBuilder, SpecOutcome, SweepSpec,
    WorkloadSpec,
};
use quickswap::policy::PolicyId;
use quickswap::util::json::Value;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};

/// The sweep-smoke grid (12 units): must stay in sync with
/// [`GRID_ARGS`] so the subprocess driver serves the same spec, byte
/// for byte, as the in-process resume.
fn marginal_spec() -> SweepSpec {
    SweepSpec {
        workload: WorkloadSpec::OneOrAll {
            k: 8,
            p1: 0.9,
            mu1: 1.0,
            muk: 1.0,
        },
        lambdas: vec![2.0, 3.0],
        policies: vec![PolicyId::Msf, PolicyId::Msfq(Some(7))],
        target_completions: 6_000,
        warmup_completions: 1_200,
        batch: 1000,
        seed: 42,
        replications: 3,
        paired: false,
        baseline: None,
        trace: None,
    }
}

/// CLI spelling of [`marginal_spec`] for `quickswap sweep drive`.
const GRID_ARGS: [&str; 16] = [
    "--workload",
    "one_or_all",
    "--k",
    "8",
    "--p1",
    "0.9",
    "--lambdas",
    "2.0,3.0",
    "--policies",
    "msf,msfq:7",
    "--completions",
    "6000",
    "--seed",
    "42",
    "--reps",
    "3",
];

/// The paired (CRN) variant (6 shared-stream units, 3 policies each).
fn paired_spec() -> SweepSpec {
    SweepSpec {
        policies: vec![PolicyId::Msf, PolicyId::Msfq(Some(7)), PolicyId::Fcfs],
        paired: true,
        baseline: Some(PolicyId::Msf),
        ..marginal_spec()
    }
}

/// CLI spelling of [`paired_spec`] (`--baseline` implies `--paired`).
const PAIRED_GRID_ARGS: [&str; 18] = [
    "--workload",
    "one_or_all",
    "--k",
    "8",
    "--p1",
    "0.9",
    "--lambdas",
    "2.0,3.0",
    "--policies",
    "msf,msfq:7,fcfs",
    "--completions",
    "6000",
    "--seed",
    "42",
    "--reps",
    "3",
    "--baseline",
    "msf",
];

fn tmp_path(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("qs-elastic-{}-{name}", std::process::id()));
    p
}

/// Render marginal points exactly as `--out` would and return the bytes
/// (the acceptance criterion is CSV byte-identity, so the comparison
/// goes through the real writer).
fn csv_bytes_marginal(spec: &SweepSpec, pts: &[Point], name: &str) -> Vec<u8> {
    let p = tmp_path(name);
    write_sweep_csv(p.to_str().unwrap(), pts, &spec.class_names()).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    let _ = std::fs::remove_file(&p);
    bytes
}

/// Render a paired sweep's marginal and Δ CSVs and return both byte
/// vectors.
fn csv_bytes_paired(spec: &SweepSpec, sweep: &PairedSweep, name: &str) -> (Vec<u8>, Vec<u8>) {
    let p = tmp_path(name);
    let d = tmp_path(&format!("{name}.diff"));
    write_sweep_csv(p.to_str().unwrap(), &sweep.points, &spec.class_names()).unwrap();
    write_diff_csv(d.to_str().unwrap(), &sweep.diffs, &spec.class_names()).unwrap();
    let bytes = (std::fs::read(&p).unwrap(), std::fs::read(&d).unwrap());
    let _ = std::fs::remove_file(&p);
    let _ = std::fs::remove_file(&d);
    bytes
}

/// Spawn the real `quickswap sweep drive` binary with a journal and
/// read the bound address off its stderr announcement line. The stderr
/// reader is returned so the pipe stays open for the driver's lifetime
/// (the 64 KiB pipe buffer absorbs its later messages unread).
fn spawn_driver(
    grid_args: &[&str],
    journal: &Path,
) -> (std::process::Child, String, BufReader<std::process::ChildStderr>) {
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_quickswap"));
    cmd.args(["sweep", "drive", "--addr", "127.0.0.1:0", "--journal"])
        .arg(journal)
        .args(grid_args)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::piped());
    let mut child = cmd.spawn().expect("spawn driver subprocess");
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if stderr.read_line(&mut line).unwrap() == 0 {
            panic!("driver exited before announcing its address");
        }
        if let Some(rest) = line.trim().strip_prefix("qs-sweep driver listening on ") {
            break rest.to_string();
        }
    };
    (child, addr, stderr)
}

/// Raw-proto worker: claim and honestly complete exactly `k` units of a
/// single-spec queue, then disconnect. Each ack arrives only after the
/// driver journaled the unit, so `k` acks ⟹ exactly `k` records on
/// disk when the driver is killed right after.
fn complete_k_units(addr: &str, spec: &SweepSpec, k: usize) {
    let grid = spec.grid();
    let paired = spec.paired_grid().unwrap();
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    writeln!(w, "{}", proto::msg_hello(None)).unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    proto::parse_specs(&proto::parse_line(&line).unwrap()).unwrap();
    let mut cache = None;
    for _ in 0..k {
        writeln!(w, "{}", proto::msg_next()).unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        let msg = proto::parse_line(&line).unwrap();
        assert_eq!(proto::op_of(&msg), Some("unit"));
        let u = proto::id_of(&msg).unwrap();
        let reply = match &paired {
            Some(pg) => {
                let (li, _) = pg.point_rep(u);
                let wl = spec.workload.build(pg.lambdas[li]);
                let run = run_paired_unit(pg, &wl, u, &mut cache);
                proto::msg_paired_result(u, &run)
            }
            None => {
                let (p, _) = grid.point_rep(u);
                let wl = spec.workload.build(grid.pts[p].0);
                let run = run_unit(&grid, &wl, u, &mut cache).unwrap();
                proto::msg_result(u, &run)
            }
        };
        writeln!(w, "{reply}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(proto::op_of(&proto::parse_line(&line).unwrap()), Some("ok"));
    }
}

/// Resume a single-spec journal with `n_workers` in-thread workers and
/// return the finished report.
fn resume(spec: &SweepSpec, journal: &Path, n_workers: usize) -> quickswap::sweep::ServeReport {
    let driver = DriverBuilder::new()
        .spec(spec)
        .journal(journal)
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let a = addr.clone();
            std::thread::spawn(move || run_worker(&a).unwrap())
        })
        .collect();
    let report = dh.join().unwrap();
    for wkr in workers {
        wkr.join().unwrap();
    }
    report
}

/// SIGKILL a marginal driver after 5 of 12 units, restart on the same
/// journal, and require byte-identical CSVs to an uninterrupted run —
/// with the 5 finished units served from disk, not rerun — for 1- and
/// 2-worker resume topologies.
#[test]
fn sigkilled_driver_resumes_marginal_sweep_bit_identically() {
    let spec = marginal_spec();
    let total = spec.grid().n_units();
    let reference = run_spec_local(&spec, 4);
    let ref_csv = csv_bytes_marginal(&spec, &reference, "ref-marginal.csv");

    let journal = tmp_path("kill-marginal.journal");
    let _ = std::fs::remove_file(&journal);
    let (mut child, addr, _stderr) = spawn_driver(&GRID_ARGS, &journal);
    let k = 5;
    complete_k_units(&addr, &spec, k);
    child.kill().unwrap();
    child.wait().unwrap();

    // Snapshot the k-record journal so both resume topologies start
    // from the same checkpoint.
    let snapshot = tmp_path("kill-marginal.journal.copy");
    std::fs::copy(&journal, &snapshot).unwrap();

    for (n_workers, path) in [(1usize, &journal), (2usize, &snapshot)] {
        let report = resume(&spec, path, n_workers);
        assert_eq!(report.units_total, total);
        assert_eq!(report.units_from_journal, k, "finished units must come from disk");
        assert_eq!(report.units_executed, total - k, "journaled units must not rerun");
        let pts = match report.outcomes.into_iter().next() {
            Some(SpecOutcome::Marginal(pts)) => pts,
            _ => panic!("expected a marginal outcome"),
        };
        let resumed = csv_bytes_marginal(&spec, &pts, "resumed-marginal.csv");
        assert_eq!(ref_csv, resumed, "resumed CSV differs from uninterrupted run");
    }
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&snapshot);
}

/// The paired (CRN) variant of the kill/resume contract: both the
/// marginal and the Δ CSVs must be byte-identical after a SIGKILL +
/// journal resume, across both resume topologies.
#[test]
fn sigkilled_driver_resumes_paired_sweep_bit_identically() {
    let spec = paired_spec();
    let reference = run_spec_paired_local(&spec, 4).unwrap();
    let (ref_csv, ref_diff) = csv_bytes_paired(&spec, &reference, "ref-paired.csv");
    let total = 6; // 2 λ × 3 shared-stream replications

    let journal = tmp_path("kill-paired.journal");
    let _ = std::fs::remove_file(&journal);
    let (mut child, addr, _stderr) = spawn_driver(&PAIRED_GRID_ARGS, &journal);
    let k = 3;
    complete_k_units(&addr, &spec, k);
    child.kill().unwrap();
    child.wait().unwrap();

    let snapshot = tmp_path("kill-paired.journal.copy");
    std::fs::copy(&journal, &snapshot).unwrap();

    for (n_workers, path) in [(1usize, &journal), (2usize, &snapshot)] {
        let report = resume(&spec, path, n_workers);
        assert_eq!(report.units_total, total);
        assert_eq!(report.units_from_journal, k, "finished units must come from disk");
        assert_eq!(report.units_executed, total - k, "journaled units must not rerun");
        let sweep = match report.outcomes.into_iter().next() {
            Some(SpecOutcome::Paired(sweep)) => sweep,
            _ => panic!("expected a paired outcome"),
        };
        let (csv, diff) = csv_bytes_paired(&spec, &sweep, "resumed-paired.csv");
        assert_eq!(ref_csv, csv, "resumed marginal CSV differs");
        assert_eq!(ref_diff, diff, "resumed diff CSV differs");
    }
    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&snapshot);
}

/// A queue of mixed specs (marginal + paired) served concurrently from
/// one pooled unit scheduler by two elastic workers: each outcome is
/// byte-identical to its single-spec local run. Then the finished
/// journal — with a torn garbage tail appended, as a crash would leave
/// — resumes with NO workers at all: every unit is served from disk,
/// the torn tail is dropped, and the outputs are byte-identical again.
#[test]
fn multi_spec_queue_serves_and_resumes_fully_from_journal() {
    let m = marginal_spec();
    let p = paired_spec();
    let ref_m = csv_bytes_marginal(&m, &run_spec_local(&m, 4), "ref-multi-m.csv");
    let (ref_p, ref_pd) =
        csv_bytes_paired(&p, &run_spec_paired_local(&p, 4).unwrap(), "ref-multi-p.csv");

    let journal = tmp_path("multi.journal");
    let _ = std::fs::remove_file(&journal);
    let driver = DriverBuilder::new()
        .spec(&m)
        .spec(&p)
        .journal(&journal)
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let a = addr.clone();
            std::thread::spawn(move || run_worker(&a).unwrap())
        })
        .collect();
    let report = dh.join().unwrap();
    let served: usize = workers
        .into_iter()
        .map(|w| w.join().unwrap().completed)
        .sum();
    assert_eq!(served, 18, "12 marginal + 6 paired units, each acked once");
    assert_eq!(report.units_total, 18);
    assert_eq!(report.units_executed, 18);

    let check = |outcomes: Vec<SpecOutcome>| {
        let mut it = outcomes.into_iter();
        match it.next() {
            Some(SpecOutcome::Marginal(pts)) => {
                assert_eq!(ref_m, csv_bytes_marginal(&m, &pts, "multi-m.csv"));
            }
            _ => panic!("spec 0 must pool as marginal"),
        }
        match it.next() {
            Some(SpecOutcome::Paired(sweep)) => {
                let (csv, diff) = csv_bytes_paired(&p, &sweep, "multi-p.csv");
                assert_eq!(ref_p, csv);
                assert_eq!(ref_pd, diff);
            }
            _ => panic!("spec 1 must pool as paired"),
        }
    };
    check(report.outcomes);

    // Crash artifact: a torn, newline-less tail after the last record.
    let clean = std::fs::read(&journal).unwrap();
    let mut torn = clean.clone();
    torn.extend_from_slice(b"{\"n\":18,\"torn");
    std::fs::write(&journal, &torn).unwrap();

    let driver = DriverBuilder::new()
        .spec(&m)
        .spec(&p)
        .journal(&journal)
        .bind()
        .unwrap();
    let report = driver.serve().unwrap();
    assert_eq!(report.units_from_journal, 18, "everything replays from disk");
    assert_eq!(report.units_executed, 0, "no unit may rerun");
    check(report.outcomes);
    // The torn tail was truncated away on open.
    assert_eq!(std::fs::read(&journal).unwrap(), clean);
    let _ = std::fs::remove_file(&journal);
}

/// A worker joining after >50% of the grid is done picks up the
/// remainder; the pooled result is byte-identical.
#[test]
fn late_joining_worker_finishes_the_sweep() {
    let spec = marginal_spec();
    let total = spec.grid().n_units();
    let ref_csv = csv_bytes_marginal(&spec, &run_spec_local(&spec, 4), "ref-late.csv");
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());

    // First worker: completes half the grid, then leaves.
    let half = total.div_ceil(2);
    complete_k_units(&addr, &spec, half);

    // Fresh worker joins mid-life and drains the rest.
    let served = run_worker(&addr).unwrap();
    let report = dh.join().unwrap();
    assert_eq!(served.completed, total - half);
    assert_eq!(report.units_executed, total);
    let pts = match report.outcomes.into_iter().next() {
        Some(SpecOutcome::Marginal(pts)) => pts,
        _ => panic!("expected a marginal outcome"),
    };
    assert_eq!(ref_csv, csv_bytes_marginal(&spec, &pts, "late.csv"));
}

/// Corruption is loud: a mangled record or a journal from a different
/// sweep must fail with a clear "journal" error, never silently rerun;
/// only the torn no-newline tail is forgiven (and truncated).
#[test]
fn journal_corruption_is_detected() {
    let spec = marginal_spec();
    let journal = tmp_path("corrupt.journal");
    let _ = std::fs::remove_file(&journal);
    // Produce a complete journal with an in-process drive.
    {
        let report = resume(&spec, &journal, 1);
        assert_eq!(report.units_executed, report.units_total);
    }
    let clean = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(clean.lines().count(), 13, "header + 12 records");

    // (a) A mangled mid-file record.
    let corrupted: String = clean
        .lines()
        .enumerate()
        .map(|(i, l)| {
            let line = if i == 5 { "{\"not\":\"a record\"}" } else { l };
            format!("{line}\n")
        })
        .collect();
    std::fs::write(&journal, corrupted).unwrap();
    let driver = DriverBuilder::new()
        .spec(&spec)
        .journal(&journal)
        .bind()
        .unwrap();
    let err = driver.serve().unwrap_err();
    assert!(err.to_string().contains("journal"), "unexpected error: {err}");

    // (b) A journal belonging to a different sweep (same shape,
    // different seed): byte-compared header ⇒ refused.
    std::fs::write(&journal, &clean).unwrap();
    let mut other = marginal_spec();
    other.seed = 43;
    let driver = DriverBuilder::new()
        .spec(&other)
        .journal(&journal)
        .bind()
        .unwrap();
    let err = driver.serve().unwrap_err();
    assert!(err.to_string().contains("journal"), "unexpected error: {err}");
    let _ = std::fs::remove_file(&journal);
}

fn poll_status(w: &mut TcpStream, r: &mut BufReader<TcpStream>) -> Value {
    writeln!(w, "{}", proto::msg_status_req()).unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    proto::parse_line(&line).unwrap()
}

/// The read-only status endpoint: per-spec progress counters plus
/// pooled rows for every fully-replicated point, streamed over a
/// persistent connection while the sweep runs.
#[test]
fn status_endpoint_reports_progress_and_pooled_rows() {
    let spec = marginal_spec();
    let reference = run_spec_local(&spec, 4);
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());

    // Monitor: handshakes like a worker, then polls `status` — the
    // reply leaves the connection open, so one socket polls repeatedly.
    let stream = TcpStream::connect(&addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    writeln!(w, "{}", proto::msg_hello(None)).unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    proto::parse_specs(&proto::parse_line(&line).unwrap()).unwrap();

    let s0 = poll_status(&mut w, &mut r);
    assert_eq!(s0.get("op").and_then(|x| x.as_str()), Some("status"));
    assert_eq!(s0.get("units_total").and_then(|x| x.as_u64()), Some(12));
    assert_eq!(s0.get("units_done").and_then(|x| x.as_u64()), Some(0));
    let specs0 = s0.get("specs").and_then(|x| x.as_arr()).unwrap();
    assert_eq!(specs0.len(), 1);
    assert_eq!(specs0[0].get("done").and_then(|x| x.as_u64()), Some(0));
    assert_eq!(specs0[0].get("paired"), Some(&Value::Bool(false)));
    let rows0 = specs0[0].get("rows").and_then(|x| x.as_arr()).unwrap();
    assert!(rows0.is_empty(), "no point is fully replicated yet");

    // Complete point 0's three replications (global units 0..3).
    {
        let grid = spec.grid();
        let wl = spec.workload.build(grid.pts[0].0);
        let mut cache = None;
        let stream = TcpStream::connect(&addr).unwrap();
        let mut rw = stream.try_clone().unwrap();
        let mut rr = BufReader::new(stream);
        writeln!(rw, "{}", proto::msg_hello(None)).unwrap();
        let mut l = String::new();
        rr.read_line(&mut l).unwrap();
        for u in 0..3 {
            let run = run_unit(&grid, &wl, u, &mut cache).unwrap();
            writeln!(rw, "{}", proto::msg_result(u, &run)).unwrap();
            l.clear();
            rr.read_line(&mut l).unwrap();
            assert_eq!(proto::op_of(&proto::parse_line(&l).unwrap()), Some("ok"));
        }
    }

    let s1 = poll_status(&mut w, &mut r);
    assert_eq!(s1.get("units_done").and_then(|x| x.as_u64()), Some(3));
    assert_eq!(s1.get("units_executed").and_then(|x| x.as_u64()), Some(3));
    assert_eq!(s1.get("units_from_journal").and_then(|x| x.as_u64()), Some(0));
    let specs1 = s1.get("specs").and_then(|x| x.as_arr()).unwrap();
    assert_eq!(specs1[0].get("done").and_then(|x| x.as_u64()), Some(3));
    let rows = specs1[0].get("rows").and_then(|x| x.as_arr()).unwrap();
    assert_eq!(rows.len(), 1, "exactly point 0 is fully pooled");
    assert_eq!(rows[0].get("policy").and_then(|x| x.as_str()), Some("msf"));
    assert_eq!(rows[0].get("reps").and_then(|x| x.as_u64()), Some(3));
    // The mid-sweep row uses the same replication-order pooling as the
    // final CSV: E[T] round-trips to the reference bits (shortest-
    // roundtrip f64 formatting).
    let et = rows[0].get("et").and_then(|x| x.as_f64()).unwrap();
    assert_eq!(et.to_bits(), reference[0].result.mean_t_all.to_bits());
    drop((w, r));

    // Drain the sweep so the driver exits cleanly.
    run_worker(&addr).unwrap();
    let report = dh.join().unwrap();
    assert_eq!(report.units_total, 12);
}
