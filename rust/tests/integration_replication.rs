//! Determinism golden tests for the engine refactor and the replication
//! runner: identical seeds must give bit-identical statistics, engine
//! reuse must be indistinguishable from fresh construction, and pooled
//! batch-means CIs must agree with the single-run path.

use quickswap::experiments::{sweep_with, SweepOpts};
use quickswap::sim::{run_policy, Engine, SimConfig, SimResult};
use quickswap::util::rng::Rng;
use quickswap::workload::{SyntheticSource, Workload};

/// Parse-then-run, the typed replacement for the old `run_named`.
fn run_named(
    wl: &Workload,
    policy: &str,
    cfg: &SimConfig,
    seed: u64,
) -> quickswap::Result<SimResult> {
    run_policy(wl, &policy.parse()?, cfg, seed)
}

fn quick(target: u64) -> SimConfig {
    SimConfig {
        target_completions: target,
        warmup_completions: target / 5,
        ..Default::default()
    }
}

/// Golden determinism: the same (workload, policy, seed) produces
/// bit-identical per-class mean response times, CI, event and completion
/// counts on every run — including under preemption and policy timers.
#[test]
fn golden_same_seed_bit_identical() {
    let wl = Workload::one_or_all(16, 3.8, 0.9, 1.0, 1.0);
    for policy in ["msfq:15", "adaptive-qs", "server-filling", "nmsr"] {
        let a = run_named(&wl, policy, &quick(40_000), 12345).unwrap();
        let b = run_named(&wl, policy, &quick(40_000), 12345).unwrap();
        assert_eq!(a.completed, b.completed, "{policy}");
        assert_eq!(a.events, b.events, "{policy}");
        assert_eq!(a.mean_t_all.to_bits(), b.mean_t_all.to_bits(), "{policy}");
        assert_eq!(a.ci95.to_bits(), b.ci95.to_bits(), "{policy}");
        for c in 0..a.mean_t.len() {
            assert_eq!(
                a.mean_t[c].to_bits(),
                b.mean_t[c].to_bits(),
                "{policy} class {c}"
            );
        }
    }
}

/// The incremental consult layer is a pure optimization: full runs with
/// the consult cache forced ON must be bit-identical (events,
/// completions, every statistic) to runs with it forced OFF — the
/// `QS_NO_CONSULT_CACHE` differential contract, engine edition, for
/// every policy on both a one-or-all and a multiclass workload.
#[test]
fn golden_consult_cache_on_off_bit_identical() {
    let one_or_all = Workload::one_or_all(16, 3.8, 0.9, 1.0, 1.0);
    let four = Workload::four_class(4.0);
    let cases: &[(&Workload, &[&str])] = &[
        (
            &one_or_all,
            &[
                "fcfs",
                "first-fit",
                "msf",
                "msfq:15",
                "msfq:0",
                "static-qs",
                "adaptive-qs",
                "nmsr",
                "server-filling",
            ],
        ),
        (
            &four,
            &[
                "fcfs",
                "first-fit",
                "msf",
                "static-qs",
                "adaptive-qs",
                "nmsr",
                "server-filling",
            ],
        ),
    ];
    for &(wl, policies) in cases {
        for &policy in policies {
            let run = |cache: bool| {
                let cfg = SimConfig {
                    consult_cache: Some(cache),
                    ..quick(30_000)
                };
                run_named(wl, policy, &cfg, 4242).unwrap()
            };
            let on = run(true);
            let off = run(false);
            assert_eq!(on.completed, off.completed, "{policy}");
            assert_eq!(on.events, off.events, "{policy}");
            assert_eq!(
                on.mean_t_all.to_bits(),
                off.mean_t_all.to_bits(),
                "{policy}"
            );
            assert_eq!(on.ci95.to_bits(), off.ci95.to_bits(), "{policy}");
            assert_eq!(
                on.utilization.to_bits(),
                off.utilization.to_bits(),
                "{policy}"
            );
            for c in 0..on.mean_t.len() {
                assert_eq!(
                    on.mean_t[c].to_bits(),
                    off.mean_t[c].to_bits(),
                    "{policy} class {c}"
                );
                assert_eq!(
                    on.mean_n[c].to_bits(),
                    off.mean_n[c].to_bits(),
                    "{policy} class {c}"
                );
            }
        }
    }
}

/// Engine reuse: reset() after an unrelated run must reproduce a fresh
/// engine's trajectory bit for bit (the replication runner depends on
/// this to recycle allocations safely).
#[test]
fn engine_reuse_bit_identical_to_fresh() {
    let wl = Workload::four_class(4.0);
    let cfg = quick(30_000);
    let fresh = run_named(&wl, "adaptive-qs", &cfg, 77).unwrap();

    let mut engine = Engine::new(&wl, cfg);
    {
        // Dirty the engine with a different policy/seed first.
        let mut p = quickswap::policy::build(&"msf".parse().unwrap(), &wl).unwrap();
        let mut src = SyntheticSource::new(wl.clone());
        let mut rng = Rng::new(5);
        let _ = engine.run(&mut src, p.as_mut(), &mut rng);
    }
    engine.reset();
    let mut p = quickswap::policy::build(&"adaptive-qs".parse().unwrap(), &wl).unwrap();
    let mut src = SyntheticSource::new(wl.clone());
    let mut rng = Rng::new(77);
    let reused = engine.run(&mut src, p.as_mut(), &mut rng);

    assert_eq!(fresh.completed, reused.completed);
    assert_eq!(fresh.events, reused.events);
    assert_eq!(fresh.mean_t_all.to_bits(), reused.mean_t_all.to_bits());
    for c in 0..fresh.mean_t.len() {
        assert_eq!(fresh.mean_t[c].to_bits(), reused.mean_t[c].to_bits());
    }
}

/// The parallel replication runner is deterministic in its inputs (not
/// in thread schedule), pools CIs from every replication, and produces
/// sane statistics.
#[test]
fn replicated_sweep_deterministic_and_pooled() {
    let cfg = SimConfig {
        target_completions: 9_000,
        warmup_completions: 1_800,
        ..Default::default()
    };
    let wl_at = |l: f64| Workload::one_or_all(8, l, 0.9, 1.0, 1.0);
    let opts_par = SweepOpts {
        replications: 3,
        threads: 4,
    };
    let opts_serial = SweepOpts {
        replications: 3,
        threads: 1,
    };
    let pols = [
        quickswap::policy::PolicyId::Msf,
        quickswap::policy::PolicyId::Msfq(Some(7)),
    ];
    let a = sweep_with(&wl_at, &[2.0, 3.0], &pols, &cfg, 42, &opts_par);
    let b = sweep_with(&wl_at, &[2.0, 3.0], &pols, &cfg, 42, &opts_serial);
    assert_eq!(a.len(), 4);
    assert_eq!(b.len(), 4);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.policy, y.policy);
        assert_eq!(x.lambda, y.lambda);
        // Thread count must not change any statistic.
        assert_eq!(x.result.completed, y.result.completed);
        assert_eq!(x.result.events, y.result.events);
        assert_eq!(x.result.mean_t_all.to_bits(), y.result.mean_t_all.to_bits());
        assert_eq!(x.result.ci95.to_bits(), y.result.ci95.to_bits());
        // Pooled stats are sane.
        assert!(x.result.mean_t_all.is_finite() && x.result.mean_t_all > 0.0);
        assert!(
            x.result.ci95.is_finite() && x.result.ci95 > 0.0,
            "pooled CI missing: {}",
            x.result.ci95
        );
        assert!(x.result.utilization > 0.0 && x.result.utilization <= 1.0 + 1e-9);
        assert!(x.result.completed >= 9_000);
    }
}

/// Replications must be genuinely different streams: two replications of
/// the same point see different arrival processes (else the pooled CI
/// would be a lie).
#[test]
fn replications_use_distinct_streams() {
    let cfg = SimConfig {
        target_completions: 5_000,
        warmup_completions: 1_000,
        ..Default::default()
    };
    let wl_at = |l: f64| Workload::one_or_all(8, l, 0.9, 1.0, 1.0);
    let one = |reps: u32| {
        let opts = SweepOpts {
            replications: reps,
            threads: 2,
        };
        sweep_with(&wl_at, &[3.0], &[quickswap::policy::PolicyId::Msf], &cfg, 9, &opts)
            .pop()
            .unwrap()
            .result
    };
    let r1 = one(1);
    let r2 = one(2);
    // Same total measured completions (budget split), different sample
    // paths ⇒ means differ (they'd be bitwise equal if streams repeated).
    assert_eq!(r1.completed, 5_000);
    assert_eq!(r2.completed, 5_000);
    assert_ne!(
        r1.mean_t_all.to_bits(),
        r2.mean_t_all.to_bits(),
        "replications reused the same RNG stream"
    );
}
