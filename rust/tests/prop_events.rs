//! Property tests for the pluggable event schedules: random
//! interleavings of push / pop / cancel checked against a naive sorted
//! reference model — for **both** the indexed 4-ary heap and the ladder
//! queue — plus a lockstep heap-vs-ladder differential (the two must
//! agree operation by operation), a heavy-tail script that provably
//! exercises the ladder's rung-spill path, an all-ties script that
//! provably exercises the seq-keyed tie sub-buckets (giant equal-time
//! clusters with interleaved cancels), and full fig5/fig6-shaped engine
//! runs byte-compared across schedules.

use quickswap::sim::events::{EventKind, EventQueue};
use quickswap::sim::ladder::LadderQueue;
use quickswap::sim::schedule::EventSchedule;
use quickswap::sim::{EventScheduleKind, SimConfig};
use quickswap::util::proptest::check;
use quickswap::util::rng::Rng;
use quickswap::workload::{borg::borg_workload, Workload};

/// A reference entry mirroring one queued event.
#[derive(Clone, Debug, PartialEq)]
struct RefEv {
    t: f64,
    seq: u64,
    job: Option<u64>,
}

/// Time shape of a script's pushes.
#[derive(Clone, Copy, Debug)]
enum Shape {
    /// Coarse grid: ties are frequent but clusters stay small.
    Coarse,
    /// Heavy-tailed: wide dynamic range, rare far-future outliers — the
    /// shape that forces ladder re-seeds and rung spills.
    Heavy,
    /// All-ties: almost every push lands on one of two times, building
    /// equal-time clusters far larger than one ladder bucket — the
    /// shape that forces the seq-keyed tie sub-buckets.
    Ties,
}

#[derive(Clone, Debug)]
struct Script {
    /// (opcode selector, payload selector) pairs.
    ops: Vec<(u64, u64)>,
    shape: Shape,
}

fn gen_script(r: &mut Rng) -> Script {
    Script {
        ops: (0..300).map(|_| (r.below(100), r.below(1 << 20))).collect(),
        shape: Shape::Coarse,
    }
}

fn gen_script_heavy(r: &mut Rng) -> Script {
    Script {
        ops: (0..400).map(|_| (r.below(100), r.below(1 << 20))).collect(),
        shape: Shape::Heavy,
    }
}

/// Phase-structured: a long push-dominated build phase (with cancels
/// sprinkled in) grows giant equal-time clusters before the full op mix
/// churns them down — a uniform op mix would drain clusters as fast as
/// they form and never reach tie-rung size.
fn gen_script_ties(r: &mut Rng) -> Script {
    let build = (0..400).map(|_| {
        let op = if r.below(10) == 0 { 8 } else { r.below(6) };
        (op, r.below(1 << 20))
    });
    let churn = (0..250).map(|_| (r.below(100), r.below(1 << 20)));
    Script {
        ops: build.chain(churn).collect(),
        shape: Shape::Ties,
    }
}

fn time_of(sc: &Script, payload: u64) -> f64 {
    match sc.shape {
        Shape::Heavy => {
            // Dense cluster with rare outliers several orders of
            // magnitude out — Borg-like service-time spread.
            let base = (payload % 512) as f64 * 1e-4;
            match payload % 23 {
                0 => base * 1.0e6,
                1 => base * 1.0e3 + 50.0,
                _ => base,
            }
        }
        Shape::Coarse => {
            // Coarse grid so ties are frequent.
            (payload % 64) as f64 * 0.25
        }
        Shape::Ties => {
            // Two tie times plus rare strays (the strays keep the
            // re-seed span nonzero, routing clusters through the
            // bucket-spill arm as well as the overflow arm).
            match payload % 16 {
                0 => (payload % 8) as f64 + 100.0,
                1..=3 => 9.0,
                _ => 3.0,
            }
        }
    }
}

fn min_index(model: &[RefEv]) -> usize {
    let mut best = 0;
    for i in 1..model.len() {
        let a = &model[i];
        let b = &model[best];
        if (a.t, a.seq) < (b.t, b.seq) {
            best = i;
        }
    }
    best
}

/// Drive one schedule implementation through the script, checking every
/// observable against the reference model.
fn run_script<Q: EventSchedule>(sc: &Script, q: &mut Q) -> Result<(), String> {
    let mut model: Vec<RefEv> = Vec::new();
    let mut next_seq = 0u64;
    let mut next_job = 0u64;

    for &(op, payload) in &sc.ops {
        let t = time_of(sc, payload);
        match op % 10 {
            // 0..=2: push a non-departure event.
            0..=2 => {
                q.push(t, EventKind::Arrival);
                model.push(RefEv {
                    t,
                    seq: next_seq,
                    job: None,
                });
                next_seq += 1;
            }
            // 3..=5: push a departure for a fresh job id.
            3..=5 => {
                let job = next_job;
                next_job += 1;
                q.push(t, EventKind::Departure { job });
                model.push(RefEv {
                    t,
                    seq: next_seq,
                    job: Some(job),
                });
                next_seq += 1;
            }
            // 6..=7: pop and compare against the model minimum.
            6..=7 => {
                let got = q.pop();
                if model.is_empty() {
                    if got.is_some() {
                        return Err("pop from empty returned an event".into());
                    }
                } else {
                    let i = min_index(&model);
                    let want = model.remove(i);
                    let Some(e) = got else {
                        return Err("pop returned None with events queued".into());
                    };
                    let job = match e.kind {
                        EventKind::Departure { job } => Some(job),
                        _ => None,
                    };
                    if e.t != want.t || e.seq != want.seq || job != want.job {
                        return Err(format!("pop mismatch: got {e:?}, want {want:?}"));
                    }
                    if let Some(j) = job {
                        if q.has_departure(j) {
                            return Err(format!("popped departure {j} still mapped"));
                        }
                    }
                }
            }
            // 8: cancel a scheduled departure chosen from the model.
            8 => {
                let scheduled: Vec<usize> = model
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.job.is_some())
                    .map(|(i, _)| i)
                    .collect();
                if scheduled.is_empty() {
                    continue;
                }
                let i = scheduled[(payload as usize) % scheduled.len()];
                let job = model[i].job.expect("filtered to departures");
                if !q.cancel_departure(job) {
                    return Err(format!("cancel of scheduled job {job} failed"));
                }
                if q.has_departure(job) {
                    return Err(format!("cancelled job {job} still mapped"));
                }
                model.remove(i);
            }
            // 9: cancel of a never-scheduled job must fail cleanly.
            _ => {
                if q.cancel_departure(next_job + 1_000_000) {
                    return Err("cancel of unknown job succeeded".into());
                }
            }
        }
        if q.len() != model.len() {
            return Err(format!("len drift: queue {} vs model {}", q.len(), model.len()));
        }
        // peek must agree with the model minimum (and not consume it).
        let want_peek = if model.is_empty() {
            None
        } else {
            Some(model[min_index(&model)].t)
        };
        if q.peek_t() != want_peek {
            return Err(format!("peek {:?} vs model {want_peek:?}", q.peek_t()));
        }
    }

    // Drain: strict (t, seq) order, exact multiset match with the model.
    let mut last: Option<(f64, u64)> = None;
    while let Some(e) = q.pop() {
        if let Some(prev) = last {
            if (e.t, e.seq) <= prev {
                return Err(format!("drain out of order: {prev:?} then ({}, {})", e.t, e.seq));
            }
        }
        last = Some((e.t, e.seq));
        let i = min_index(&model);
        let want = model.remove(i);
        if e.t != want.t || e.seq != want.seq {
            return Err(format!("drain mismatch: got {e:?}, want {want:?}"));
        }
    }
    if !model.is_empty() {
        return Err(format!("queue drained but model has {} left", model.len()));
    }
    Ok(())
}

#[test]
fn prop_indexed_heap_matches_reference() {
    check("indexed_heap_vs_reference", gen_script, |sc| {
        run_script(sc, &mut EventQueue::new())
    });
}

#[test]
fn prop_ladder_matches_reference() {
    check("ladder_vs_reference", gen_script, |sc| {
        run_script(sc, &mut LadderQueue::new())
    });
}

#[test]
fn prop_ladder_matches_reference_heavy_tail() {
    check("ladder_vs_reference_heavy", gen_script_heavy, |sc| {
        run_script(sc, &mut LadderQueue::new())
    });
}

#[test]
fn prop_ladder_matches_reference_all_ties() {
    check("ladder_vs_reference_ties", gen_script_ties, |sc| {
        run_script(sc, &mut LadderQueue::new())
    });
}

/// Lockstep differential: heap and ladder fed the identical op stream
/// must agree on every observable after every operation — pop results
/// (full events: time, sequence, kind), peek, length, and departure
/// membership. This is the bit-identity contract stated in
/// `sim/schedule.rs`, checked structure-against-structure with no model
/// in between.
fn run_lockstep(sc: &Script) -> Result<(), String> {
    let mut heap = EventQueue::new();
    let mut ladder = LadderQueue::new();
    let mut next_job = 0u64;
    let mut live_jobs: Vec<u64> = Vec::new();
    for (step, &(op, payload)) in sc.ops.iter().enumerate() {
        let t = time_of(sc, payload);
        match op % 10 {
            0..=2 => {
                heap.push(t, EventKind::Arrival);
                ladder.push(t, EventKind::Arrival);
            }
            3..=5 => {
                let job = next_job;
                next_job += 1;
                live_jobs.push(job);
                heap.push(t, EventKind::Departure { job });
                ladder.push(t, EventKind::Departure { job });
            }
            6..=7 => {
                let (a, b) = (heap.pop(), ladder.pop());
                if a != b {
                    return Err(format!("step {step}: pop diverged: heap {a:?}, ladder {b:?}"));
                }
                if let Some(e) = a {
                    if let EventKind::Departure { job } = e.kind {
                        live_jobs.retain(|&j| j != job);
                    }
                }
            }
            8 => {
                if live_jobs.is_empty() {
                    continue;
                }
                let job = live_jobs.remove((payload as usize) % live_jobs.len());
                let (a, b) = (heap.cancel_departure(job), ladder.cancel_departure(job));
                if a != b {
                    return Err(format!("step {step}: cancel({job}) diverged: {a} vs {b}"));
                }
            }
            _ => {
                let probe = next_job + 1_000_000;
                if heap.cancel_departure(probe) || ladder.cancel_departure(probe) {
                    return Err("cancel of unknown job succeeded".into());
                }
            }
        }
        if heap.len() != ladder.len() {
            return Err(format!(
                "step {step}: len diverged: heap {} vs ladder {}",
                heap.len(),
                ladder.len()
            ));
        }
        if heap.peek_t() != ladder.peek_t() {
            return Err(format!(
                "step {step}: peek diverged: heap {:?} vs ladder {:?}",
                heap.peek_t(),
                ladder.peek_t()
            ));
        }
        for &j in &live_jobs {
            if heap.has_departure(j) != ladder.has_departure(j) {
                return Err(format!("step {step}: has_departure({j}) diverged"));
            }
        }
    }
    loop {
        let (a, b) = (heap.pop(), ladder.pop());
        if a != b {
            return Err(format!("drain diverged: heap {a:?}, ladder {b:?}"));
        }
        if a.is_none() {
            return Ok(());
        }
    }
}

#[test]
fn prop_heap_ladder_lockstep_differential() {
    check("heap_vs_ladder_lockstep", gen_script, run_lockstep);
    check("heap_vs_ladder_lockstep_heavy", gen_script_heavy, run_lockstep);
    check("heap_vs_ladder_lockstep_ties", gen_script_ties, run_lockstep);
}

/// Deterministic giant all-ties cluster, churned against the heap in
/// lockstep: builds a cluster far larger than one bottom-tier bucket,
/// asserts the ladder actually took the seq-keyed tie path (so this
/// test cannot silently stop covering it), then interleaves cancels,
/// pops and peeks — the pattern whose cancels used to cost O(cluster).
#[test]
fn ladder_giant_tie_cluster_lockstep_with_cancels() {
    let mut heap = EventQueue::new();
    let mut ladder = LadderQueue::new();
    for job in 0..1200u64 {
        heap.push(42.0, EventKind::Departure { job });
        ladder.push(42.0, EventKind::Departure { job });
    }
    assert_eq!(heap.pop(), ladder.pop());
    assert!(ladder.tie_spills() > 0, "cluster must take the seq-keyed tie path");
    let mut rng = Rng::new(11);
    let mut live: Vec<u64> = (1..1200).collect();
    for step in 0..900 {
        if live.is_empty() {
            break;
        }
        match rng.below(3) {
            0 => {
                let job = live.remove(rng.index(live.len()));
                assert_eq!(
                    heap.cancel_departure(job),
                    ladder.cancel_departure(job),
                    "step {step}: cancel({job}) diverged"
                );
            }
            1 => {
                let (a, b) = (heap.pop(), ladder.pop());
                assert_eq!(a, b, "step {step}: pop diverged");
                if let Some(e) = a {
                    if let EventKind::Departure { job } = e.kind {
                        live.retain(|&j| j != job);
                    }
                }
            }
            _ => {
                assert_eq!(heap.peek_t(), ladder.peek_t(), "step {step}: peek diverged");
            }
        }
        assert_eq!(heap.len(), ladder.len(), "step {step}: len diverged");
    }
    loop {
        let (a, b) = (heap.pop(), ladder.pop());
        assert_eq!(a, b, "drain diverged");
        if a.is_none() {
            break;
        }
    }
}

/// Rung-spill / bucket-resize property: a dense cluster with far
/// outliers must (a) actually take the spill path — asserted via the
/// spill counter, so this test cannot silently stop covering it — and
/// (b) still pop in exact (t, seq) order; and clearing mid-flight must
/// reset to a fresh-equivalent structure (bucket widths re-derive from
/// the next observed span, not stale tuning state).
#[test]
fn prop_ladder_rung_spill_and_reset() {
    check(
        "ladder_rung_spill",
        |r| {
            let n = 200 + r.index(400);
            (0..n)
                .map(|_| (r.below(1 << 16), r.below(100)))
                .collect::<Vec<(u64, u64)>>()
        },
        |input| {
            let mut q = LadderQueue::new();
            let mut times: Vec<(f64, u64)> = Vec::new();
            for (i, &(tsel, shape)) in input.iter().enumerate() {
                // ~1/8 of events are far-future outliers: the observed
                // span is huge, the cluster lands in few buckets, and
                // the ladder must re-bucket (spill) to stay sorted-small.
                let t = if shape < 12 {
                    1.0e7 + (tsel as f64)
                } else {
                    (tsel as f64) * 1e-3
                };
                q.push(t, EventKind::Departure { job: i as u64 });
                times.push((t, i as u64));
            }
            // First pop forces the re-seed + first drains.
            let first = q.pop().ok_or("empty pop")?;
            times.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            if (first.t, first.seq) != times[0] {
                return Err(format!("first pop {first:?} != {:?}", times[0]));
            }
            if q.spills() == 0 {
                return Err(format!(
                    "cluster+outlier input (n={}) did not exercise the spill path",
                    input.len()
                ));
            }
            // Drain half in order, then clear and verify fresh behavior.
            let mut last = (first.t, first.seq);
            for _ in 0..input.len() / 2 {
                let e = q.pop().ok_or("early empty")?;
                if (e.t, e.seq) <= last {
                    return Err("out of order after spill".into());
                }
                last = (e.t, e.seq);
            }
            q.clear();
            if !q.is_empty() || q.spills() != 0 || q.reseeds() != 0 {
                return Err("clear did not reset the ladder".into());
            }
            q.push(1.0, EventKind::Arrival);
            let e = q.pop().ok_or("post-clear pop")?;
            if e.seq != 0 {
                return Err("sequence did not restart after clear".into());
            }
            Ok(())
        },
    );
}

/// Cancel/reschedule churn: repeatedly cancel and re-push the same job's
/// departure (the preemptive-policy pattern) and verify the final pop —
/// on both schedule implementations.
#[test]
fn prop_cancel_reschedule_churn() {
    fn churn<Q: EventSchedule>(times: &[u64], q: &mut Q) -> Result<(), String> {
        // Background noise events.
        for (i, &t) in times.iter().enumerate() {
            q.push(t as f64, EventKind::PolicyTimer { seq: i as u64 });
        }
        let job = 3u64;
        for &t in times {
            q.push(t as f64 + 0.5, EventKind::Departure { job });
            if times.len() % 2 == 0 {
                // cancel and push once more at a shifted time
                if !q.cancel_departure(job) {
                    return Err("cancel failed".into());
                }
                q.push(t as f64 + 0.25, EventKind::Departure { job });
            }
            // Exactly one departure must be live now.
            if !q.has_departure(job) {
                return Err("departure lost".into());
            }
            if !q.cancel_departure(job) {
                return Err("cancel failed".into());
            }
        }
        // All departures cancelled: drain must see timers only.
        while let Some(e) = q.pop() {
            if matches!(e.kind, EventKind::Departure { .. }) {
                return Err("cancelled departure survived".into());
            }
        }
        Ok(())
    }
    check(
        "cancel_reschedule_churn",
        |r| {
            let n = 1 + r.index(40);
            (0..n).map(|_| r.below(1000)).collect::<Vec<u64>>()
        },
        |times| {
            churn(times, &mut EventQueue::new())?;
            churn(times, &mut LadderQueue::new())
        },
    );
}

// ---- full engine runs: heap vs ladder must be bit-identical ----

fn run_engine(
    kind: EventScheduleKind,
    wl: &Workload,
    policy: &str,
    target: u64,
    seed: u64,
) -> quickswap::sim::SimResult {
    let cfg = SimConfig {
        target_completions: target,
        warmup_completions: target / 5,
        event_schedule: Some(kind),
        ..Default::default()
    };
    quickswap::sim::run_policy(wl, &policy.parse().unwrap(), &cfg, seed).unwrap()
}

fn assert_bit_identical(
    policy: &str,
    tag: &str,
    h: &quickswap::sim::SimResult,
    l: &quickswap::sim::SimResult,
) {
    assert_eq!(h.completed, l.completed, "{tag}/{policy}");
    assert_eq!(h.events, l.events, "{tag}/{policy}");
    assert_eq!(h.mean_t_all.to_bits(), l.mean_t_all.to_bits(), "{tag}/{policy}");
    assert_eq!(h.ci95.to_bits(), l.ci95.to_bits(), "{tag}/{policy}");
    assert_eq!(h.utilization.to_bits(), l.utilization.to_bits(), "{tag}/{policy}");
    assert_eq!(h.sim_time.to_bits(), l.sim_time.to_bits(), "{tag}/{policy}");
    for c in 0..h.mean_t.len() {
        assert_eq!(h.mean_t[c].to_bits(), l.mean_t[c].to_bits(), "{tag}/{policy} class {c}");
        assert_eq!(h.mean_n[c].to_bits(), l.mean_n[c].to_bits(), "{tag}/{policy} class {c}");
        assert_eq!(h.count[c], l.count[c], "{tag}/{policy} class {c}");
    }
}

/// The tentpole contract at engine scale: full runs on the fig5
/// multiclass shape (k=15, needs {1,3,5,15}) and the fig6 Borg shape
/// (k=2048, 26 classes) produce bit-identical statistics under the heap
/// and the ladder, for every multiclass policy; MSFQ (which rejects
/// multiclass shapes) runs the fig6-scale one-or-all variant.
#[test]
fn ladder_engine_runs_bit_identical_to_heap() {
    let fig5 = Workload::four_class(4.0);
    let fig6 = borg_workload(4.0);
    let multiclass = [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "adaptive-qs",
        "nmsr",
        "server-filling",
    ];
    for policy in multiclass {
        let h = run_engine(EventScheduleKind::Heap, &fig5, policy, 30_000, 7);
        let l = run_engine(EventScheduleKind::Ladder, &fig5, policy, 30_000, 7);
        assert_bit_identical(policy, "fig5", &h, &l);
    }
    for policy in multiclass {
        let h = run_engine(EventScheduleKind::Heap, &fig6, policy, 8_000, 7);
        let l = run_engine(EventScheduleKind::Ladder, &fig6, policy, 8_000, 7);
        assert_bit_identical(policy, "fig6", &h, &l);
    }
    let ooa = Workload::one_or_all(2048, 8.0, 0.9, 1.0, 1.0);
    for policy in ["msfq", "msfq:1024", "msfq:0"] {
        let h = run_engine(EventScheduleKind::Heap, &ooa, policy, 12_000, 7);
        let l = run_engine(EventScheduleKind::Ladder, &ooa, policy, 12_000, 7);
        assert_bit_identical(policy, "fig6-one-or-all", &h, &l);
    }
}

/// The `QS_EVENT_SCHEDULE` escape hatch: `heap` selects the heap,
/// `ladder`/unset select the ladder, and an engine built under either
/// env default produces the same bits as one with the kind pinned
/// (pop-order identity makes the knob observable only in throughput).
#[test]
fn event_schedule_env_escape_hatch() {
    // Note: env vars are process-global; this test only ever sets valid
    // values, and every other test in this binary pins the kind
    // explicitly, so a concurrent read is harmless either way.
    std::env::set_var("QS_EVENT_SCHEDULE", "heap");
    assert_eq!(EventScheduleKind::from_env(), EventScheduleKind::Heap);
    std::env::set_var("QS_EVENT_SCHEDULE", "ladder");
    assert_eq!(EventScheduleKind::from_env(), EventScheduleKind::Ladder);
    std::env::remove_var("QS_EVENT_SCHEDULE");
    assert_eq!(EventScheduleKind::from_env(), EventScheduleKind::Ladder);

    let wl = Workload::four_class(3.0);
    let pinned = run_engine(EventScheduleKind::Heap, &wl, "msf", 10_000, 3);
    std::env::set_var("QS_EVENT_SCHEDULE", "heap");
    let cfg = SimConfig {
        target_completions: 10_000,
        warmup_completions: 2_000,
        event_schedule: None, // follow the env default
        ..Default::default()
    };
    let via_env = quickswap::sim::run_policy(&wl, &"msf".parse().unwrap(), &cfg, 3).unwrap();
    std::env::remove_var("QS_EVENT_SCHEDULE");
    assert_bit_identical("msf", "env-hatch", &pinned, &via_env);
}
