//! Property tests for the indexed 4-ary event heap: random interleavings
//! of push / pop / cancel checked against a naive sorted reference model.

use quickswap::sim::events::{EventKind, EventQueue};
use quickswap::util::proptest::check;
use quickswap::util::rng::Rng;

/// A reference entry mirroring one queued event.
#[derive(Clone, Debug, PartialEq)]
struct RefEv {
    t: f64,
    seq: u64,
    job: Option<u64>,
}

#[derive(Clone, Debug)]
struct Script {
    /// (opcode selector, payload selector) pairs.
    ops: Vec<(u64, u64)>,
}

fn gen_script(r: &mut Rng) -> Script {
    Script {
        ops: (0..300).map(|_| (r.below(100), r.below(1 << 20))).collect(),
    }
}

fn min_index(model: &[RefEv]) -> usize {
    let mut best = 0;
    for i in 1..model.len() {
        let a = &model[i];
        let b = &model[best];
        if (a.t, a.seq) < (b.t, b.seq) {
            best = i;
        }
    }
    best
}

fn run_script(sc: &Script) -> Result<(), String> {
    let mut q = EventQueue::new();
    let mut model: Vec<RefEv> = Vec::new();
    let mut next_seq = 0u64;
    let mut next_job = 0u64;

    for &(op, payload) in &sc.ops {
        // Quantize times to a coarse grid so ties are frequent.
        let t = (payload % 64) as f64 * 0.25;
        match op % 10 {
            // 0..=2: push a non-departure event.
            0..=2 => {
                q.push(t, EventKind::Arrival);
                model.push(RefEv {
                    t,
                    seq: next_seq,
                    job: None,
                });
                next_seq += 1;
            }
            // 3..=5: push a departure for a fresh job id.
            3..=5 => {
                let job = next_job;
                next_job += 1;
                q.push(t, EventKind::Departure { job });
                model.push(RefEv {
                    t,
                    seq: next_seq,
                    job: Some(job),
                });
                next_seq += 1;
            }
            // 6..=7: pop and compare against the model minimum.
            6..=7 => {
                let got = q.pop();
                if model.is_empty() {
                    if got.is_some() {
                        return Err("pop from empty returned an event".into());
                    }
                } else {
                    let i = min_index(&model);
                    let want = model.remove(i);
                    let Some(e) = got else {
                        return Err("pop returned None with events queued".into());
                    };
                    let job = match e.kind {
                        EventKind::Departure { job } => Some(job),
                        _ => None,
                    };
                    if e.t != want.t || e.seq != want.seq || job != want.job {
                        return Err(format!("pop mismatch: got {e:?}, want {want:?}"));
                    }
                    if let Some(j) = job {
                        if q.has_departure(j) {
                            return Err(format!("popped departure {j} still mapped"));
                        }
                    }
                }
            }
            // 8: cancel a scheduled departure chosen from the model.
            8 => {
                let scheduled: Vec<usize> = model
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.job.is_some())
                    .map(|(i, _)| i)
                    .collect();
                if scheduled.is_empty() {
                    continue;
                }
                let i = scheduled[(payload as usize) % scheduled.len()];
                let job = model[i].job.expect("filtered to departures");
                if !q.cancel_departure(job) {
                    return Err(format!("cancel of scheduled job {job} failed"));
                }
                if q.has_departure(job) {
                    return Err(format!("cancelled job {job} still mapped"));
                }
                model.remove(i);
            }
            // 9: cancel of a never-scheduled job must fail cleanly.
            _ => {
                if q.cancel_departure(next_job + 1_000_000) {
                    return Err("cancel of unknown job succeeded".into());
                }
            }
        }
        if q.len() != model.len() {
            return Err(format!("len drift: queue {} vs model {}", q.len(), model.len()));
        }
    }

    // Drain: strict (t, seq) order, exact multiset match with the model.
    let mut last: Option<(f64, u64)> = None;
    while let Some(e) = q.pop() {
        if let Some(prev) = last {
            if (e.t, e.seq) <= prev {
                return Err(format!("drain out of order: {prev:?} then ({}, {})", e.t, e.seq));
            }
        }
        last = Some((e.t, e.seq));
        let i = min_index(&model);
        let want = model.remove(i);
        if e.t != want.t || e.seq != want.seq {
            return Err(format!("drain mismatch: got {e:?}, want {want:?}"));
        }
    }
    if !model.is_empty() {
        return Err(format!("queue drained but model has {} left", model.len()));
    }
    Ok(())
}

#[test]
fn prop_indexed_heap_matches_reference() {
    check("indexed_heap_vs_reference", gen_script, run_script);
}

/// Cancel/reschedule churn: repeatedly cancel and re-push the same job's
/// departure (the preemptive-policy pattern) and verify the final pop.
#[test]
fn prop_cancel_reschedule_churn() {
    check(
        "cancel_reschedule_churn",
        |r| {
            let n = 1 + r.index(40);
            (0..n).map(|_| r.below(1000)).collect::<Vec<u64>>()
        },
        |times| {
            let mut q = EventQueue::new();
            // Background noise events.
            for (i, &t) in times.iter().enumerate() {
                q.push(t as f64, EventKind::PolicyTimer { seq: i as u64 });
            }
            let job = 3u64;
            let mut final_t = None;
            for &t in times {
                q.push(t as f64 + 0.5, EventKind::Departure { job });
                final_t = Some(t as f64 + 0.5);
                if times.len() % 2 == 0 {
                    // cancel and push once more at a shifted time
                    if !q.cancel_departure(job) {
                        return Err("cancel failed".into());
                    }
                    q.push(t as f64 + 0.25, EventKind::Departure { job });
                    final_t = Some(t as f64 + 0.25);
                }
                // Exactly one departure must be live now.
                if !q.has_departure(job) {
                    return Err("departure lost".into());
                }
                if !q.cancel_departure(job) {
                    return Err("cancel failed".into());
                }
            }
            let _ = final_t;
            // All departures cancelled: drain must see timers only.
            while let Some(e) = q.pop() {
                if matches!(e.kind, EventKind::Departure { .. }) {
                    return Err("cancelled departure survived".into());
                }
            }
            Ok(())
        },
    );
}
