//! Property and differential tests for the dominance index — the
//! vector-fit query layer that keeps the exact consult-skip predicates
//! legal under the multiresource model.
//!
//! Three contracts:
//! 1. Every vector-fit query (`queued_demand_fits`,
//!    `min_queued_dominated`, `queued_mass_fitting`,
//!    `max_dominated_rank_below`, `can_admit_vec`,
//!    `dim_queued_fitting`) equals a naive scan over the class table,
//!    at every dimension count, on arbitrary enqueue/admit/depart
//!    sequences.
//! 2. A d=1 `QueueIndex` built from demand vectors answers every query
//!    bit-identically to the scalar constructor, and a d=2 index padded
//!    with a never-binding dimension answers identically to the scalar
//!    index on the fig5 and fig6 (Borg) class shapes.
//! 3. Engine-level differential goldens: on the fig5/fig6/fig2 shapes,
//!    a run over the scalar workload and a run over the same workload
//!    padded to d=2 (demand 1 into capacity k on the extra dimension —
//!    binding-equivalent, since at most k jobs can ever run) produce
//!    bit-identical statistics for every vector-capable policy. MSFQ is
//!    scalar-only by constructor contract, so its d=1 bit-identity is
//!    the scalar path itself (covered by the existing golden tests).

use quickswap::sim::{QueueIndex, SimConfig};
use quickswap::util::proptest::check;
use quickswap::util::rng::Rng;
use quickswap::workload::{borg::borg_workload, ClassSpec, ResourceVec, Workload};

// ---- 1. brute-force: every query vs a naive scan ----

/// A random index scenario: class demand vectors under a capacity, and
/// a script of (enqueue | admit | depart) ops with query probes.
#[derive(Debug, Clone)]
struct Scenario {
    capacity: ResourceVec,
    demands: Vec<ResourceVec>,
    /// op ∈ {0: enqueue, 1: admit, 2: depart}, per-step class pick and
    /// a free-vector probe drawn as per-dimension fractions of capacity.
    script: Vec<(u8, usize, [u64; 4])>,
}

fn gen_scenario(r: &mut Rng) -> Scenario {
    let dims = 1 + r.index(3); // 1..=3
    let cap_vals: Vec<u32> = (0..dims).map(|_| 2 + r.below(30) as u32).collect();
    let capacity = ResourceVec::new(&cap_vals);
    let nclasses = 1 + r.index(6);
    let demands: Vec<ResourceVec> = (0..nclasses)
        .map(|_| {
            let v: Vec<u32> = cap_vals
                .iter()
                .map(|&c| 1 + r.below(c as u64) as u32)
                .collect();
            ResourceVec::new(&v)
        })
        .collect();
    let script = (0..120)
        .map(|_| {
            (
                r.below(3) as u8,
                r.index(nclasses),
                [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
            )
        })
        .collect();
    Scenario {
        capacity,
        demands,
        script,
    }
}

/// Naive reference model: plain per-class queued/running counts.
struct Naive {
    queued: Vec<u32>,
    running: Vec<u32>,
}

fn check_queries(
    ix: &QueueIndex,
    n: &Naive,
    demands: &[ResourceVec],
    free: &ResourceVec,
) -> Result<(), String> {
    let fits = |c: usize| n.queued[c] > 0 && demands[c].fits_in(free);
    let expect_fits = (0..demands.len()).any(fits);
    if ix.queued_demand_fits(free) != expect_fits {
        return Err(format!(
            "queued_demand_fits({free}) = {}, naive {expect_fits}",
            ix.queued_demand_fits(free)
        ));
    }
    let expect_min = (0..demands.len())
        .filter(|&c| fits(c))
        .map(|c| demands[c].servers())
        .min();
    if ix.min_queued_dominated(free) != expect_min {
        return Err(format!(
            "min_queued_dominated({free}) = {:?}, naive {expect_min:?}",
            ix.min_queued_dominated(free)
        ));
    }
    let expect_mass: u64 = (0..demands.len())
        .filter(|&c| fits(c))
        .map(|c| demands[c].servers() as u64 * n.queued[c] as u64)
        .sum();
    if ix.queued_mass_fitting(free) != expect_mass {
        return Err(format!(
            "queued_mass_fitting({free}) = {}, naive {expect_mass}",
            ix.queued_mass_fitting(free)
        ));
    }
    // Rank walk: naive descending scan over the index's own rank order.
    for bound in [demands.len(), demands.len() / 2 + 1] {
        let expect_rank = (0..bound.min(ix.num_ranks()))
            .rev()
            .find(|&r| fits(ix.class_at_rank(r)));
        if ix.max_dominated_rank_below(bound, free) != expect_rank {
            return Err(format!(
                "max_dominated_rank_below({bound}, {free}) = {:?}, naive {expect_rank:?}",
                ix.max_dominated_rank_below(bound, free)
            ));
        }
    }
    for c in 0..demands.len() {
        if ix.can_admit_vec(c, free) != fits(c) {
            return Err(format!("can_admit_vec({c}, {free}) diverged"));
        }
    }
    // Per-dimension prefix counts (the rejection certificates).
    for j in 0..free.dims() {
        let expect: u32 = (0..demands.len())
            .filter(|&c| demands[c].get(j) <= free.get(j))
            .map(|c| n.queued[c])
            .sum();
        if ix.dim_queued_fitting(j, free.get(j)) != expect {
            return Err(format!(
                "dim_queued_fitting({j}, {}) = {}, naive {expect}",
                free.get(j),
                ix.dim_queued_fitting(j, free.get(j))
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_vector_queries_match_naive_scan() {
    check("dominance_vs_naive", gen_scenario, |sc| {
        let mut ix = QueueIndex::with_demands(&sc.demands);
        let mut n = Naive {
            queued: vec![0; sc.demands.len()],
            running: vec![0; sc.demands.len()],
        };
        for &(op, c, probe) in &sc.script {
            match op {
                0 => {
                    ix.on_enqueue(c);
                    n.queued[c] += 1;
                }
                1 if n.queued[c] > 0 => {
                    ix.on_admit(c);
                    n.queued[c] -= 1;
                    n.running[c] += 1;
                }
                2 if n.running[c] > 0 => {
                    ix.on_depart(c);
                    n.running[c] -= 1;
                }
                _ => {}
            }
            let free_vals: Vec<u32> = (0..sc.capacity.dims())
                .map(|j| (probe[j] % (sc.capacity.get(j) as u64 + 1)) as u32)
                .collect();
            let free = ResourceVec::new(&free_vals);
            check_queries(&ix, &n, &sc.demands, &free)?;
        }
        Ok(())
    });
}

// ---- 2. d=1 / padded-d2 differential replay on fig5 + fig6 shapes ----

/// Replay one op script on (a) the scalar index, (b) the d=1 vector
/// index, (c) a d=2 index padded with a never-binding dimension, and
/// assert every query agrees at every step.
fn replay_differential(k: u32, needs: &[u32], seed: u64) {
    let d1: Vec<ResourceVec> = needs.iter().map(|&n| ResourceVec::scalar(n)).collect();
    let d2: Vec<ResourceVec> = needs.iter().map(|&n| ResourceVec::new(&[n, 1])).collect();
    let mut scalar = QueueIndex::new(needs);
    let mut vec1 = QueueIndex::with_demands(&d1);
    let mut vec2 = QueueIndex::with_demands(&d2);
    let mut queued = vec![0u32; needs.len()];
    let mut running = vec![0u32; needs.len()];
    let mut r = Rng::new(seed);
    for step in 0..400 {
        let c = r.index(needs.len());
        match r.below(3) {
            0 => {
                scalar.on_enqueue(c);
                vec1.on_enqueue(c);
                vec2.on_enqueue(c);
                queued[c] += 1;
            }
            1 if queued[c] > 0 => {
                scalar.on_admit(c);
                vec1.on_admit(c);
                vec2.on_admit(c);
                queued[c] -= 1;
                running[c] += 1;
            }
            2 if running[c] > 0 => {
                scalar.on_depart(c);
                vec1.on_depart(c);
                vec2.on_depart(c);
                running[c] -= 1;
            }
            _ => {}
        }
        let f = r.below(k as u64 + 1) as u32;
        let f1 = ResourceVec::scalar(f);
        // Padding never binds: dimension 1 holds k units and every job
        // takes 1, so with ≤ k jobs runnable the probe carries full k.
        let f2 = ResourceVec::new(&[f, k]);
        assert_eq!(
            scalar.queued_demand_fits(&f1),
            vec2.queued_demand_fits(&f2),
            "fits diverged at step {step} (free {f})"
        );
        assert_eq!(
            scalar.min_queued_dominated(&f1),
            vec2.min_queued_dominated(&f2),
            "min diverged at step {step} (free {f})"
        );
        assert_eq!(
            scalar.queued_need_fitting(f),
            vec2.queued_mass_fitting(&f2),
            "mass diverged at step {step} (free {f})"
        );
        for bound in [needs.len(), needs.len() / 2 + 1] {
            assert_eq!(
                scalar.max_fitting_rank_below(bound, f),
                vec2.max_dominated_rank_below(bound, &f2),
                "rank walk diverged at step {step} (bound {bound}, free {f})"
            );
        }
        for c in 0..needs.len() {
            assert_eq!(scalar.can_admit(c, f), vec2.can_admit_vec(c, &f2), "step {step}");
            assert_eq!(scalar.can_admit(c, f), vec1.can_admit_vec(c, &f1), "step {step}");
        }
        // The d=1 vector index is the scalar index, query for query.
        assert_eq!(scalar.queued_demand_fits(&f1), vec1.queued_demand_fits(&f1));
        assert_eq!(scalar.min_queued_need(), vec1.min_queued_need());
        assert_eq!(scalar.queued_need_fitting(f), vec1.queued_mass_fitting(&f1));
    }
}

#[test]
fn d1_and_padded_d2_index_replay_fig5_shape() {
    // fig5: k=15, needs {1,3,5,15}.
    replay_differential(15, &[1, 3, 5, 15], 0xF165);
}

#[test]
fn d1_and_padded_d2_index_replay_fig6_shape() {
    // fig6: the Borg shape (k=2048, 26 classes).
    let wl = borg_workload(4.0);
    let needs: Vec<u32> = wl.classes.iter().map(|c| c.need()).collect();
    replay_differential(wl.k, &needs, 0xF166);
}

// ---- 3. engine-level differential goldens: scalar vs padded d=2 ----

/// The scalar workload padded to d=2 with a never-binding dimension:
/// every class demands 1 unit of a size-k resource. Since every job
/// needs ≥ 1 server, at most k jobs run concurrently and the extra
/// dimension can never reject an admission the scalar model allows.
fn pad_to_d2(wl: &Workload) -> Workload {
    let classes = wl
        .classes
        .iter()
        .map(|c| ClassSpec {
            demand: ResourceVec::new(&[c.need(), 1]),
            rate: c.rate,
            size: c.size.clone(),
            name: c.name.clone(),
        })
        .collect();
    Workload::with_capacity(ResourceVec::new(&[wl.k, wl.k]), classes)
}

fn assert_runs_bit_identical(policy: &str, tag: &str, scalar: &Workload, target: u64, seed: u64) {
    let cfg = SimConfig {
        target_completions: target,
        warmup_completions: target / 5,
        ..Default::default()
    };
    let id = policy.parse().unwrap();
    let a = quickswap::sim::run_policy(scalar, &id, &cfg, seed).unwrap();
    let b = quickswap::sim::run_policy(&pad_to_d2(scalar), &id, &cfg, seed).unwrap();
    assert_eq!(a.completed, b.completed, "{tag}/{policy}");
    assert_eq!(a.events, b.events, "{tag}/{policy}");
    assert_eq!(a.mean_t_all.to_bits(), b.mean_t_all.to_bits(), "{tag}/{policy}");
    assert_eq!(a.ci95.to_bits(), b.ci95.to_bits(), "{tag}/{policy}");
    assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{tag}/{policy}");
    assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{tag}/{policy}");
    for c in 0..a.mean_t.len() {
        assert_eq!(a.mean_t[c].to_bits(), b.mean_t[c].to_bits(), "{tag}/{policy} class {c}");
        assert_eq!(a.mean_n[c].to_bits(), b.mean_n[c].to_bits(), "{tag}/{policy} class {c}");
        assert_eq!(a.count[c], b.count[c], "{tag}/{policy} class {c}");
    }
}

/// Every vector-capable policy, fig5/fig6/fig2 shapes: padding the
/// workload with a never-binding dimension changes no statistic bit.
/// (MSFQ rejects d > 1 by contract — its d=1 path is the scalar path.)
#[test]
fn padded_d2_runs_bit_identical_to_scalar() {
    let multiclass = [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "adaptive-qs",
        "nmsr",
        "server-filling",
    ];
    let fig5 = Workload::four_class(4.0);
    for policy in multiclass {
        assert_runs_bit_identical(policy, "fig5", &fig5, 12_000, 1234);
    }
    let fig6 = borg_workload(4.0);
    for policy in multiclass {
        assert_runs_bit_identical(policy, "fig6", &fig6, 4_000, 77);
    }
    let fig2 = Workload::one_or_all(32, 7.5, 0.9, 1.0, 1.0);
    for policy in ["fcfs", "first-fit", "msf", "server-filling"] {
        assert_runs_bit_identical(policy, "fig2-one-or-all", &fig2, 10_000, 7);
    }
}

/// The MSR family runs end-to-end on the genuinely 2-dimensional
/// workload: both policies complete jobs of every class and produce
/// finite, reproducible statistics.
#[test]
fn msr_policies_run_on_multires_workload() {
    let wl = Workload::multires(16, 64, 3.0);
    let cfg = SimConfig {
        target_completions: 20_000,
        warmup_completions: 4_000,
        ..Default::default()
    };
    for policy in ["msr-seq", "msr-rand", "msr-seq:25", "msr-rand:100"] {
        let id = policy.parse().unwrap();
        let a = quickswap::sim::run_policy(&wl, &id, &cfg, 11).unwrap();
        assert!(
            a.mean_t_all.is_finite() && a.mean_t_all > 0.0,
            "{policy}: E[T] = {}",
            a.mean_t_all
        );
        assert!(a.count.iter().all(|&c| c > 0), "{policy}: starved a class: {:?}", a.count);
        let b = quickswap::sim::run_policy(&wl, &id, &cfg, 11).unwrap();
        assert_eq!(a.events, b.events, "{policy} must be deterministic");
        assert_eq!(a.mean_t_all.to_bits(), b.mean_t_all.to_bits(), "{policy}");
    }
}
