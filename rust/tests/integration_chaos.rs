//! Seeded fault-injection matrix for the self-healing sweep fabric:
//! every plan here disturbs a sharded sweep — disconnects at exact
//! message ordinals, worker crashes and hangs, torn journal appends,
//! fsync-dropped tails, overload sheds — and every test's acceptance
//! bar is the same: the run (after reconnects, requeues, and resumes)
//! converges to CSV bytes identical to an undisturbed in-process run.
//! Faults are deterministic, replayable functions of their plan seed
//! (see `sweep::faultline`), so a failure here reproduces locally from
//! the plan string alone.

use quickswap::experiments::write_sweep_csv;
use quickswap::sweep::faultline::{backoff_delay, AtomicFile, FaultDurable, FaultPlan, PlanState};
use quickswap::sweep::{
    run_spec_local, run_worker_with, DriverBuilder, ServeReport, SpecOutcome, SweepSpec,
    WorkerConfig, WorkerOutcome, WorkerReport, WorkloadSpec,
};
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The shared grid: 2 λ × 3 policies × 2 replications = 12 units, small
/// enough that every chaos scenario runs in well under a second of
/// simulated work.
fn chaos_spec() -> SweepSpec {
    SweepSpec {
        workload: WorkloadSpec::OneOrAll {
            k: 8,
            p1: 0.9,
            mu1: 1.0,
            muk: 1.0,
        },
        lambdas: vec![2.0, 3.0],
        policies: vec![
            quickswap::policy::PolicyId::Msf,
            quickswap::policy::PolicyId::Msfq(Some(7)),
            quickswap::policy::PolicyId::Fcfs,
        ],
        target_completions: 3_000,
        warmup_completions: 600,
        batch: 500,
        seed: 42,
        replications: 2,
        paired: false,
        baseline: None,
        trace: None,
    }
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("qs-chaos-{}-{name}", std::process::id()))
}

/// Byte-compare a served outcome's CSV against the undisturbed
/// in-process reference — the paper-facing artifact is the CSV, so the
/// contract is stated (and checked) at the byte level, not the struct
/// level.
fn assert_csv_bytes_identical(spec: &SweepSpec, report: &ServeReport, tag: &str) {
    let reference = run_spec_local(spec, 4);
    let ref_csv = tmp_path(&format!("{tag}-ref.csv"));
    let got_csv = tmp_path(&format!("{tag}-got.csv"));
    write_sweep_csv(ref_csv.to_str().unwrap(), &reference, &spec.class_names()).unwrap();
    let pts = match &report.outcomes[0] {
        SpecOutcome::Marginal(pts) => pts,
        _ => panic!("expected a marginal outcome"),
    };
    write_sweep_csv(got_csv.to_str().unwrap(), pts, &spec.class_names()).unwrap();
    let a = std::fs::read(&ref_csv).unwrap();
    let b = std::fs::read(&got_csv).unwrap();
    assert!(!a.is_empty(), "{tag}: reference CSV is empty");
    assert_eq!(a, b, "{tag}: CSV bytes differ from the undisturbed run");
    let _ = std::fs::remove_file(&ref_csv);
    let _ = std::fs::remove_file(&got_csv);
}

/// Run one worker with `plan` against a plain driver and require full
/// convergence: the worker must self-heal (exactly `reconnects`
/// reconnects), finish every unit, and the CSV must match the
/// undisturbed bytes.
fn run_one_worker_plan(plan: FaultPlan, want_reconnects: u32, tag: &str) {
    let spec = chaos_spec();
    let total = spec.grid().n_units();
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    let cfg = WorkerConfig {
        plan: Some(plan),
        ..WorkerConfig::default()
    };
    let report = run_worker_with(&addr, &cfg).unwrap();
    let serve = dh.join().unwrap();
    assert_eq!(report.outcome, WorkerOutcome::Done, "{tag}");
    assert_eq!(report.reconnects, want_reconnects, "{tag}");
    assert_eq!(report.completed, total, "{tag}: every unit acked to this worker");
    assert_eq!(serve.units_executed, total, "{tag}");
    assert_csv_bytes_identical(&spec, &serve, tag);
}

/// Plan 1 — transport loss mid-result: the connection dies on the very
/// send carrying unit 0's result (message ordinal 5 = hello, specs,
/// next, unit, then this send). The worker reconnects, *resends* the
/// unacked result (the driver never saw it — it journals/delivers it
/// now), and drains the sweep. `short-read@3` rides along so every
/// recv also exercises the fragmented-read path.
#[test]
fn disconnect_during_result_send_resends_and_converges() {
    let plan = FaultPlan::new(101).short_read_cap(3).disconnect_at(5);
    run_one_worker_plan(plan, 1, "disconnect@result-send");
}

/// Plan 2 — transport loss on the ack: the result reached the driver
/// but the `ok` never reached the worker (ordinal 6). On reconnect the
/// resent result is a *duplicate*; the driver dedupes, acks, and the
/// unit counts exactly once.
#[test]
fn disconnect_during_ack_recv_dedupes_resend() {
    let plan = FaultPlan::new(102).disconnect_at(6);
    let spec = chaos_spec();
    let total = spec.grid().n_units();
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    let cfg = WorkerConfig {
        plan: Some(plan),
        ..WorkerConfig::default()
    };
    let report = run_worker_with(&addr, &cfg).unwrap();
    let serve = dh.join().unwrap();
    assert_eq!(report.outcome, WorkerOutcome::Done);
    assert_eq!(report.reconnects, 1);
    assert_eq!(report.completed, total);
    assert_eq!(serve.units_executed, total, "the duplicate must not double-count");
    assert_eq!(serve.liveness.duplicates, 1, "the resend is seen and deduped");
    assert_csv_bytes_identical(&spec, &serve, "disconnect@ack-recv");
}

/// Plan 3 — injected worker crash while holding a unit: the driver
/// requeues it on disconnect and a fresh worker (modeling a restarted
/// process) finishes the sweep bit-identically.
#[test]
fn crashed_worker_unit_is_reissued_to_replacement() {
    let spec = chaos_spec();
    let total = spec.grid().n_units();
    let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    let cfg = WorkerConfig {
        plan: Some(FaultPlan::new(103).crash_on_unit(3)),
        ..WorkerConfig::default()
    };
    let crashed = run_worker_with(&addr, &cfg).unwrap();
    assert_eq!(crashed.outcome, WorkerOutcome::Crashed);
    assert_eq!(crashed.completed, 2, "crashed holding its 3rd claimed unit");
    let replacement = run_worker_with(&addr, &WorkerConfig::default()).unwrap();
    let serve = dh.join().unwrap();
    assert_eq!(replacement.outcome, WorkerOutcome::Done);
    assert_eq!(crashed.completed + replacement.completed, total);
    assert!(serve.liveness.disconnect_requeues >= 1, "the held unit was requeued");
    assert_csv_bytes_identical(&spec, &serve, "crash@3");
}

/// Plan 4 — torn journal append (simulated power cut mid-write, with
/// fsync on): the 4th record is written only partially, followed by
/// garbage. The serve aborts fatally WITHOUT acking the unit; a fresh
/// driver on the same journal truncates the torn tail (3 intact records
/// survive), reruns only the lost units, and the final CSV is
/// byte-identical.
#[test]
fn torn_journal_append_aborts_then_resumes_truncated() {
    let spec = chaos_spec();
    let total = spec.grid().n_units();
    let journal = tmp_path("torn.journal");
    let _ = std::fs::remove_file(&journal);
    {
        let driver = DriverBuilder::new()
            .spec(&spec)
            .journal(&journal)
            .fsync(true)
            .fault_plan(Some(FaultPlan::new(104).torn_append(4, 0.5)))
            .bind()
            .unwrap();
        let addr = driver.local_addr().to_string();
        let wh = std::thread::spawn({
            let addr = addr.clone();
            move || run_worker_with(&addr, &WorkerConfig::default())
        });
        let err = driver.serve().unwrap_err();
        assert!(
            err.to_string().contains("journal write failed"),
            "unexpected error: {err}"
        );
        wh.join().unwrap().unwrap(); // the worker must exit, not hang
    }
    // Resume on the torn journal: the broken final record is dropped,
    // the 3 intact ones are served from disk, the rest rerun.
    let driver = DriverBuilder::new()
        .spec(&spec)
        .journal(&journal)
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    run_worker_with(&addr, &WorkerConfig::default()).unwrap();
    let serve = dh.join().unwrap();
    assert_eq!(serve.units_from_journal, 3, "intact prefix served from disk");
    assert_eq!(serve.units_executed, total - 3, "only lost units rerun");
    assert_csv_bytes_identical(&spec, &serve, "torn-append");
    let _ = std::fs::remove_file(&journal);
}

/// Plan 5 — fsync-dropped tail: the 6th append dies with its bytes
/// dropped back to the last synced offset (the classic
/// power-cut-after-write-before-sync artifact). Five durable records
/// survive; the resume picks them up exactly.
#[test]
fn fsync_dropped_tail_resumes_from_synced_prefix() {
    let spec = chaos_spec();
    let total = spec.grid().n_units();
    let journal = tmp_path("dropsync.journal");
    let _ = std::fs::remove_file(&journal);
    {
        let driver = DriverBuilder::new()
            .spec(&spec)
            .journal(&journal)
            .fsync(true)
            .fault_plan(Some(FaultPlan::new(105).drop_sync(6)))
            .bind()
            .unwrap();
        let addr = driver.local_addr().to_string();
        let wh = std::thread::spawn({
            let addr = addr.clone();
            move || run_worker_with(&addr, &WorkerConfig::default())
        });
        let err = driver.serve().unwrap_err();
        assert!(err.to_string().contains("journal write failed"), "{err}");
        wh.join().unwrap().unwrap();
    }
    let driver = DriverBuilder::new()
        .spec(&spec)
        .journal(&journal)
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    run_worker_with(&addr, &WorkerConfig::default()).unwrap();
    let serve = dh.join().unwrap();
    assert_eq!(serve.units_from_journal, 5, "synced prefix served from disk");
    assert_eq!(serve.units_executed, total - 5);
    assert_csv_bytes_identical(&spec, &serve, "drop-sync");
    let _ = std::fs::remove_file(&journal);
}

/// Plan 6 — hung-but-connected worker: `hang@2` goes silent (heartbeats
/// suppressed) for 1.5 s while holding its 2nd unit. The driver's
/// heartbeat detector (deadline 200 ms, well under the 400 ms idle
/// drop) requeues the unit to the healthy worker long before any unit
/// timeout could, and the sweep converges bit-identically.
#[test]
fn hung_worker_unit_is_requeued_by_heartbeat_detector() {
    let spec = chaos_spec();
    let driver = DriverBuilder::new()
        .spec(&spec)
        .heartbeat_timeout(Some(Duration::from_millis(200)))
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    let hung_cfg = WorkerConfig {
        plan: Some(FaultPlan::new(106).hang_on_unit(2, 1500)),
        heartbeat: Some(Duration::from_millis(50)),
        ..WorkerConfig::default()
    };
    let hw = std::thread::spawn({
        let addr = addr.clone();
        move || run_worker_with(&addr, &hung_cfg)
    });
    // Give the hung worker first claim, then let the healthy one drain.
    std::thread::sleep(Duration::from_millis(30));
    let healthy_cfg = WorkerConfig {
        heartbeat: Some(Duration::from_millis(50)),
        ..WorkerConfig::default()
    };
    let healthy = run_worker_with(&addr, &healthy_cfg).unwrap();
    let serve = dh.join().unwrap();
    // The hung worker wakes into a torn-down sweep; any of its terminal
    // outcomes is fine — the determinism contract is on the results.
    let _: anyhow::Result<WorkerReport> = hw.join().unwrap();
    assert_eq!(healthy.outcome, WorkerOutcome::Done);
    assert!(
        serve.liveness.heartbeat_requeues >= 1,
        "the hung unit must be reclaimed by the heartbeat detector, \
         liveness: {:?}",
        serve.liveness
    );
    assert_csv_bytes_identical(&spec, &serve, "hang-heartbeat");
}

/// Plan 7 — overload shedding: with the connection cap at 1 and the
/// only slot held by a half-open peer, a late worker is shed with a
/// typed `busy`, backs off on its own schedule, and completes the
/// whole sweep once the slot frees. Shedding is observable (counters)
/// but not result-affecting.
#[test]
fn shed_worker_retries_after_busy_and_converges() {
    let spec = chaos_spec();
    let total = spec.grid().n_units();
    let driver = DriverBuilder::new()
        .spec(&spec)
        .max_conns(1)
        .bind()
        .unwrap();
    let addr = driver.local_addr().to_string();
    let dh = std::thread::spawn(move || driver.serve().unwrap());
    // Squatter: occupies the single slot without ever completing the
    // handshake (the driver's handshake deadline would evict it in 10 s;
    // we release it much sooner).
    let squatter = std::net::TcpStream::connect(&addr).unwrap();
    std::thread::sleep(Duration::from_millis(100));
    let wh = std::thread::spawn({
        let addr = addr.clone();
        move || {
            let cfg = WorkerConfig {
                max_retries: 40,
                backoff_base: Duration::from_millis(20),
                backoff_cap: Duration::from_millis(60),
                ..WorkerConfig::default()
            };
            run_worker_with(&addr, &cfg)
        }
    });
    std::thread::sleep(Duration::from_millis(250));
    drop(squatter);
    let report = wh.join().unwrap().unwrap();
    let serve = dh.join().unwrap();
    assert_eq!(report.outcome, WorkerOutcome::Done);
    assert!(report.busy_retries >= 1, "the worker was shed at least once");
    assert_eq!(report.completed, total);
    assert!(serve.liveness.conns_shed >= 1, "liveness: {:?}", serve.liveness);
    assert_csv_bytes_identical(&spec, &serve, "overload-shed");
}

/// Plan 8 — atomic CSV publish: a fault mid-rewrite (torn append on the
/// temp file) must leave the previously published CSV untouched at its
/// final name, clean up its temp file, and a clean retry must produce
/// the identical bytes.
#[test]
fn atomic_csv_survives_torn_rewrite() {
    let spec = chaos_spec();
    let pts = run_spec_local(&spec, 4);
    let dest = tmp_path("atomic.csv");
    let _ = std::fs::remove_file(&dest);
    write_sweep_csv(dest.to_str().unwrap(), &pts, &spec.class_names()).unwrap();
    let published = std::fs::read(&dest).unwrap();
    assert!(!published.is_empty());

    // Faulty rewrite: the second append to the temp file tears.
    let state = Arc::new(Mutex::new(PlanState::new(
        FaultPlan::new(107).torn_append(2, 0.6),
    )));
    let mut atomic = AtomicFile::create_with(&dest, move |f| {
        Box::new(FaultDurable::new(f, state).unwrap())
    })
    .unwrap();
    atomic.write_all(b"lambda,policy\n").unwrap();
    let err = atomic.write_all(b"2,msf\n").unwrap_err();
    assert!(err.to_string().contains("torn"), "unexpected error: {err}");
    drop(atomic); // abandoned, not committed

    // The published file is untouched and no temp litter remains.
    assert_eq!(std::fs::read(&dest).unwrap(), published, "dest must be intact");
    let dir = dest.parent().unwrap();
    let tmp_litter = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let n = e.file_name().to_string_lossy().to_string();
            n.starts_with("qs-chaos") && n.contains("atomic.csv") && n.ends_with(".tmp")
        })
        .count();
    assert_eq!(tmp_litter, 0, "abandoned temp files must be cleaned up");

    // A clean retry converges to the same bytes.
    write_sweep_csv(dest.to_str().unwrap(), &pts, &spec.class_names()).unwrap();
    assert_eq!(std::fs::read(&dest).unwrap(), published);
    let _ = std::fs::remove_file(&dest);
}

/// The reconnect backoff schedule is a pure function of its seed:
/// deterministic, capped, and jittered within [0.5, 1.0] of the nominal
/// doubling curve — replayable chaos requires replayable waits.
#[test]
fn backoff_schedule_is_deterministic_capped_and_jittered() {
    let base = Duration::from_millis(50);
    let cap = Duration::from_secs(1);
    let schedule = |seed: u64| -> Vec<Duration> {
        let mut rng = quickswap::util::rng::Rng::new(seed);
        (1..=12).map(|a| backoff_delay(a, base, cap, &mut rng)).collect()
    };
    assert_eq!(schedule(7), schedule(7), "same seed, same schedule");
    assert_ne!(schedule(7), schedule(8), "different seeds must jitter apart");
    for (i, d) in schedule(7).iter().enumerate() {
        let nominal = std::cmp::min(cap, base * 2u32.saturating_pow(i as u32));
        assert!(*d <= nominal, "attempt {i} exceeds its nominal ceiling");
        assert!(
            *d >= nominal / 2,
            "attempt {i} jittered below half the nominal ({d:?} < {nominal:?}/2)"
        );
    }
}
