//! Integration: the coordinator daemon end-to-end — TCP API, real-time
//! execution, statistics, and autotuning.

use quickswap::coordinator::{serve_tcp, Coordinator, CoordinatorConfig};
use quickswap::util::json::Value;
use quickswap::workload::Workload;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn spawn(policy: &str, wl: &Workload, scale: f64) -> Coordinator {
    let pol = quickswap::policy::build(&policy.parse().unwrap(), wl).unwrap();
    Coordinator::spawn(
        wl,
        pol,
        CoordinatorConfig {
            time_scale: scale,
            autotune_every: 0,
            use_artifact: true,
            solver_iters: 20_000,
        },
    )
}

#[test]
fn submit_drain_stats_roundtrip() {
    let wl = Workload::one_or_all(4, 1.0, 0.9, 1.0, 1.0);
    let coord = spawn("msfq:3", &wl, 2e-4);
    let h = coord.handle();
    for i in 0..120 {
        h.submit(usize::from(i % 10 == 0), 1.0);
    }
    assert!(h.drain(Duration::from_secs(30)));
    let s = h.stats().unwrap();
    assert_eq!(s.submitted, 120);
    assert_eq!(s.completed, 120);
    assert_eq!(s.used_servers, 0);
    assert!(s.mean_t >= 1.0, "E[T] = {} below service time", s.mean_t);
    coord.join();
}

#[test]
fn tcp_api_full_protocol() {
    let wl = Workload::one_or_all(4, 1.0, 0.9, 1.0, 1.0);
    let coord = spawn("msf", &wl, 1e-4);
    let addr = serve_tcp("127.0.0.1:0", coord.handle()).unwrap();
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut line = String::new();

    let mut rpc = |req: &str, line: &mut String| -> Value {
        writeln!(w, "{req}").unwrap();
        line.clear();
        r.read_line(line).unwrap();
        Value::parse(line.trim()).unwrap()
    };

    let pong = rpc(r#"{"op":"ping"}"#, &mut line);
    assert_eq!(pong.get("pong").unwrap().as_bool(), Some(true));

    for _ in 0..30 {
        let resp = rpc(r#"{"op":"submit","class":0,"size":0.5}"#, &mut line);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp}");
    }
    // Malformed requests keep the connection alive.
    let bad = rpc(r#"{"op":"submit"}"#, &mut line);
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    let bad2 = rpc("not json", &mut line);
    assert_eq!(bad2.get("ok").unwrap().as_bool(), Some(false));

    assert!(coord.handle().drain(Duration::from_secs(30)));
    let stats = rpc(r#"{"op":"stats"}"#, &mut line);
    assert_eq!(stats.get("completed").unwrap().as_u64(), Some(30));
    assert_eq!(stats.get("in_system").unwrap().as_u64(), Some(0));
    coord.join();
}

/// The autotuner swaps MSF for MSFQ(ℓ*>0) using the PJRT artifact (or
/// the native calculator fallback) from observed rates.
#[test]
fn autotune_swaps_policy_online() {
    // Burst submission: the estimated arrival rates blow past the
    // stability region, so the tuner clamps to ρ = 0.95 while keeping
    // the observed 9:1 class mix — decisively in the regime where
    // Quickswap (ℓ > 0) beats MSF. (Paced submission would depend on
    // sub-millisecond sleep accuracy; the clamp path is deterministic.)
    let wl = Workload::one_or_all(8, 4.5, 0.9, 1.0, 1.0);
    let coord = spawn("msf", &wl, 1e-4);
    let h = coord.handle();
    for i in 0..200 {
        h.submit(usize::from(i % 10 == 0), 1.0);
    }
    let ell = h.autotune();
    assert!(ell.is_some(), "autotune produced no threshold");
    let ell = ell.unwrap();
    assert!(ell > 0, "high-load autotune must pick ell > 0");
    let s = h.stats().unwrap();
    assert!(s.policy.contains("MSFQ"), "policy now {}", s.policy);
    assert_eq!(s.current_ell, Some(ell));
    assert_eq!(s.retunes, 1);
    assert!(h.drain(Duration::from_secs(60)));
    coord.join();
}

/// Multiclass coordinator run under Adaptive Quickswap.
#[test]
fn multiclass_coordinator_run() {
    let wl = Workload::four_class(3.0);
    let coord = spawn("adaptive-qs", &wl, 1e-4);
    let h = coord.handle();
    let mut rng = quickswap::util::rng::Rng::new(9);
    for _ in 0..200 {
        let class = rng.discrete(&[0.5, 0.25, 0.2, 0.05]);
        h.submit(class, rng.exp(1.0));
    }
    assert!(h.drain(Duration::from_secs(60)));
    let s = h.stats().unwrap();
    assert_eq!(s.completed, 200);
    // All classes that got jobs report finite response times.
    for (count, mean_t, _) in s.per_class.iter() {
        if *count > 0 {
            assert!(mean_t.is_finite() && *mean_t > 0.0);
        }
    }
    coord.join();
}
