//! Property tests for the analysis layer: Taylor arithmetic, calculator
//! sanity across the stable parameter space, CTMC consistency.

use quickswap::analysis::taylor::T2;
use quickswap::analysis::{analyze, MsfqCtmc, MsfqParams};
use quickswap::util::proptest::check;
use quickswap::util::rng::Rng;

/// Random stable one-or-all parameters (ρ bounded away from 1).
fn gen_params(r: &mut Rng) -> MsfqParams {
    let k = 2 + r.below(31) as u32; // 2..=32
    let ell = r.below(k as u64) as u32;
    let mu1 = 0.5 + r.f64() * 2.0;
    let muk = 0.5 + r.f64() * 2.0;
    let rho = 0.2 + r.f64() * 0.65; // 0.2..0.85
    let p1 = 0.5 + r.f64() * 0.45;
    // Split load: rho = lam1/(k mu1) + lamk/muk with job fraction p1.
    // Choose lam so the class-arrival fractions match p1.
    let denom = p1 / (k as f64 * mu1) + (1.0 - p1) / muk;
    let lam = rho / denom;
    MsfqParams {
        k,
        ell,
        lam1: lam * p1,
        lamk: lam * (1.0 - p1),
        mu1,
        muk,
    }
}

#[test]
fn prop_calculator_always_sane_on_stable_params() {
    check("calculator_sane", gen_params, |p| {
        let a = match analyze(p) {
            Ok(a) => a,
            Err(e) => return Err(format!("analyze failed: {e}")),
        };
        for i in 1..=4 {
            if !(a.eh[i] >= -1e-9) {
                return Err(format!("E[H{i}] = {} < 0", a.eh[i]));
            }
            // Jensen: E[H²] ≥ E[H]².
            if a.eh2[i] + 1e-9 < a.eh[i] * a.eh[i] {
                return Err(format!(
                    "E[H{i}²]={} < E[H{i}]²={}",
                    a.eh2[i],
                    a.eh[i] * a.eh[i]
                ));
            }
        }
        let msum: f64 = (1..=4).map(|i| a.m[i]).sum();
        if (msum - 1.0).abs() > 1e-6 {
            return Err(format!("phase fractions sum to {msum}"));
        }
        // Response times exceed a bare service time.
        if a.et_light < 0.99 / p.mu1 || a.et_heavy < 0.99 / p.muk {
            return Err(format!(
                "E[T] below service time: light {} heavy {}",
                a.et_light, a.et_heavy
            ));
        }
        if !a.et.is_finite() || !a.etw.is_finite() {
            return Err("non-finite E[T]".into());
        }
        Ok(())
    });
}

/// N moments consistency: E[N1H] must equal λk·E[H2+H3+H4] (arrivals
/// during the non-heavy phases — the defining relation of Lemma 6).
#[test]
fn prop_n1h_consistent_with_phase_means() {
    check("n1h_consistency", gen_params, |p| {
        let a = match analyze(p) {
            Ok(a) => a,
            Err(_) => return Ok(()),
        };
        let expect = p.lamk * (a.eh[2] + a.eh[3] + a.eh[4]);
        let rel = (a.en1h.0 - expect).abs() / expect.max(1e-12);
        if rel > 1e-6 {
            return Err(format!("E[N1H]={} vs λk·E[H234]={expect}", a.en1h.0));
        }
        Ok(())
    });
}

/// Taylor arithmetic: (a·b)/b == a and exp(ln(x)) == x over random
/// coefficient vectors.
#[test]
fn prop_taylor_field_identities() {
    check(
        "taylor_identities",
        |r| {
            let g = |r: &mut Rng| 0.2 + r.f64() * 3.0;
            (
                T2::new(g(r), r.f64() - 0.5, r.f64() - 0.5),
                T2::new(g(r), r.f64() - 0.5, r.f64() - 0.5),
            )
        },
        |(a, b)| {
            let close = |x: f64, y: f64| (x - y).abs() < 1e-8 * (1.0 + x.abs().max(y.abs()));
            let q = a.mul(*b).div(*b);
            if !(close(q.c0, a.c0) && close(q.c1, a.c1) && close(q.c2, a.c2)) {
                return Err(format!("(a*b)/b != a: {q:?} vs {a:?}"));
            }
            let e = a.ln().exp();
            if !(close(e.c0, a.c0) && close(e.c1, a.c1) && close(e.c2, a.c2)) {
                return Err(format!("exp(ln(a)) != a: {e:?} vs {a:?}"));
            }
            Ok(())
        },
    );
}

/// CTMC solver mass conservation over random small systems.
#[test]
fn prop_ctmc_conserves_mass() {
    check(
        "ctmc_mass",
        |r| {
            let mut p = gen_params(r);
            p.k = 2 + r.below(5) as u32; // keep the state space small
            p.ell = p.ell.min(p.k - 1);
            p
        },
        |p| {
            let sol = MsfqCtmc::new(p, 48, 24).solve(4000, 1e-9);
            let total = sol.m1 + sol.m23 + sol.m4 + sol.idle;
            if (total - 1.0).abs() > 1e-3 {
                return Err(format!("fractions sum to {total}"));
            }
            if sol.en1 < -1e-9 || sol.enk < -1e-9 {
                return Err("negative occupancy".into());
            }
            Ok(())
        },
    );
}
