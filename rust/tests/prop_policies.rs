//! Property tests: policy invariants under randomized event sequences.
//!
//! Uses the in-tree generate-and-check harness (util::proptest). Each
//! property drives a random arrival/completion schedule through a policy
//! and asserts the structural invariants the analysis relies on.

use quickswap::policy::test_support::Harness;
use quickswap::policy::{build, JobId, Policy, PolicyId};
use quickswap::util::proptest::check;
use quickswap::util::rng::Rng;
use quickswap::workload::Workload;

/// Parse-then-build, the typed replacement for the old `by_name`.
fn by_name(name: &str, wl: &Workload) -> anyhow::Result<Box<dyn Policy + Send>> {
    build(&name.parse::<PolicyId>()?, wl)
}

/// A random scenario: class needs, arrival pattern, completion order.
#[derive(Debug, Clone)]
struct Scenario {
    k: u32,
    needs: Vec<u32>,
    /// (event, class): true = arrival of class, false = completion.
    script: Vec<(bool, usize)>,
    seed: u64,
}

fn gen_scenario(r: &mut Rng) -> Scenario {
    let k = 2 + r.below(15) as u32; // 2..=16
    let nclasses = 1 + r.index(4);
    let mut needs: Vec<u32> = (0..nclasses)
        .map(|_| 1 + r.below(k as u64) as u32)
        .collect();
    needs.dedup();
    let script = (0..200)
        .map(|_| (r.chance(0.6), r.index(needs.len())))
        .collect();
    Scenario {
        k,
        needs,
        script,
        seed: r.next_u64(),
    }
}

/// Drive the scenario; panics inside Harness::consult enforce capacity
/// and queued-state correctness. Extra invariants checked per event.
fn run_scenario(sc: &Scenario, policy: &str) -> Result<(), String> {
    let wl = Workload::new(
        sc.k,
        sc.needs
            .iter()
            .map(|&n| {
                quickswap::workload::ClassSpec::new(n, 1.0, quickswap::dist::Dist::exp_mean(1.0))
            })
            .collect(),
    );
    let mut pol = match by_name(policy, &wl) {
        Ok(p) => p,
        Err(_) => return Ok(()), // policy not applicable (e.g. msfq on multiclass)
    };
    let mut h = Harness::new(sc.k, &sc.needs);
    let mut rng = Rng::new(sc.seed);
    let mut running: Vec<JobId> = Vec::new();
    let mut t = 0.0;
    for &(arrive, class) in &sc.script {
        t += 0.1;
        if arrive {
            h.arrive(class, t);
        } else if !running.is_empty() {
            let id = running.swap_remove(rng.index(running.len()));
            if h.jobs.is_running(id) {
                h.complete(id, t);
            }
        }
        running.extend(h.consult(pol.as_mut()));
        running.retain(|&id| h.jobs.is_running(id));

        // Capacity invariant (also asserted inside consult).
        let used: u32 = (0..sc.needs.len())
            .map(|c| h.running[c] * h.needs[c])
            .sum();
        if used != h.used() {
            return Err(format!("used-counter drift: {} vs {}", used, h.used()));
        }
        if used > sc.k {
            return Err(format!("capacity violated: {used} > {}", sc.k));
        }
        // Non-preemptive policies must never shrink the running set
        // except via completions — captured by Harness (it panics if a
        // nonpreemptive policy emits preempts).
    }
    Ok(())
}

#[test]
fn prop_capacity_and_state_all_policies() {
    for policy in [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "adaptive-qs",
        "nmsr",
        "server-filling",
    ] {
        check(
            &format!("capacity/{policy}"),
            gen_scenario,
            |sc| run_scenario(sc, policy),
        );
    }
}

/// MSF admission is maximal in descending-need order: after consult, no
/// queued job of any class fits in the free servers *unless* a larger
/// class was (correctly) preferred and exhausted the space.
#[test]
fn prop_msf_greedy_maximal() {
    check("msf_maximal", gen_scenario, |sc| {
        let wl = Workload::new(
            sc.k,
            sc.needs
                .iter()
                .map(|&n| {
                    quickswap::workload::ClassSpec::new(
                        n,
                        1.0,
                        quickswap::dist::Dist::exp_mean(1.0),
                    )
                })
                .collect(),
        );
        let mut pol = by_name("msf", &wl).unwrap();
        let mut h = Harness::new(sc.k, &sc.needs);
        let mut rng = Rng::new(sc.seed);
        let mut running: Vec<JobId> = Vec::new();
        let mut t = 0.0;
        for &(arrive, class) in &sc.script {
            t += 0.1;
            if arrive {
                h.arrive(class, t);
            } else if !running.is_empty() {
                let id = running.swap_remove(rng.index(running.len()));
                if h.jobs.is_running(id) {
                    h.complete(id, t);
                }
            }
            running.extend(h.consult(pol.as_mut()));
            running.retain(|&id| h.jobs.is_running(id));
            // Maximality: no queued job fits into the remaining space.
            let free = sc.k - h.used();
            for c in 0..sc.needs.len() {
                if h.queued[c] > 0 && sc.needs[c] <= free {
                    return Err(format!(
                        "MSF left class {c} (need {}) waiting with {free} free",
                        sc.needs[c]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// One-or-all MSFQ: threshold semantics — whenever lights are in service
/// and their in-system count exceeds ℓ, no server may idle (phases 2/3
/// are work-conserving for lights).
#[test]
fn prop_msfq_no_idle_above_threshold() {
    check(
        "msfq_work_conserving",
        |r| {
            let k = 2 + r.below(10) as u32;
            let ell = r.below(k as u64) as u32;
            let script: Vec<(bool, usize)> = (0..200)
                .map(|_| (r.chance(0.65), usize::from(r.chance(0.15))))
                .collect();
            (k, ell, script, r.next_u64())
        },
        |(k, ell, script, seed)| {
            let wl = Workload::one_or_all(*k, 1.0, 0.9, 1.0, 1.0);
            let mut pol = by_name(&format!("msfq:{ell}"), &wl).unwrap();
            let mut h = Harness::new(*k, &[1, *k]);
            let mut rng = Rng::new(*seed);
            let mut running: Vec<JobId> = Vec::new();
            let mut t = 0.0;
            for &(arrive, class) in script {
                t += 0.1;
                if arrive {
                    h.arrive(class, t);
                } else if !running.is_empty() {
                    let id = running.swap_remove(rng.index(running.len()));
                    if h.jobs.is_running(id) {
                        h.complete(id, t);
                    }
                }
                running.extend(h.consult(pol.as_mut()));
                running.retain(|&id| h.jobs.is_running(id));
                // Exclusivity always.
                if h.running[0] > 0 && h.running[1] > 0 {
                    return Err("mixed service".into());
                }
                // Work conservation for lights while above threshold:
                // if lights are being served and more lights are queued
                // and in-system count > ell, no server may be idle
                // (unless we are draining, i.e. queued lights exist but
                // none was admitted this round — detectable as: queued
                // lights > 0, free > 0, in_system > ell, lights running).
                let n1 = h.in_system(0);
                if h.running[0] > 0
                    && h.queued[0] > 0
                    && h.used() < *k
                    && n1 > *ell
                    && h.running[0] + h.queued[0] == n1
                {
                    // Phase 2/3 with spare room and waiting lights, yet
                    // not admitted ⇒ must be the drain phase. The drain
                    // only holds when in-service ≤ ℓ.
                    if h.running[0] > *ell {
                        return Err(format!(
                            "idle servers with {} lights waiting (n1={n1}, ell={ell})",
                            h.queued[0]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
