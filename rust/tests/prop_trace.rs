//! Trace-pipeline properties: the streaming `.qst` replay must be
//! bit-identical to the materialized path across block sizes and every
//! policy in the family, the one-pass CSV converter must reproduce the
//! direct writer's bytes, and torn or corrupted files must hard-error
//! at open — never mid-replay.

use quickswap::policy::PolicyId;
use quickswap::sim::{Engine, SimConfig, SimResult};
use quickswap::util::rng::Rng;
use quickswap::workload::borg::borg_workload;
use quickswap::workload::qst;
use quickswap::workload::trace::{StreamingTraceSource, Trace, TraceError, TraceSource};
use quickswap::workload::{ArrivalSource, RateCurve, Workload};

/// Every named policy in the family (ISSUE: the replay equivalence must
/// hold for all of them, not just the queueing-friendly ones).
const ALL_POLICIES: [&str; 10] = [
    "fcfs",
    "first-fit",
    "msf",
    "msfq:7",
    "static-qs:7",
    "adaptive-qs",
    "nmsr",
    "server-filling",
    "msr-seq",
    "msr-rand",
];

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("qs_prop_trace_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Replay `src` under `id` until the source is exhausted: the trace,
/// not a completion target, ends the run (timer-driven policies rely on
/// the engine's exhaustion break to terminate).
fn replay(wl: &Workload, id: &PolicyId, src: &mut dyn ArrivalSource, seed: u64) -> SimResult {
    let cfg = SimConfig {
        target_completions: u64::MAX / 2,
        warmup_completions: 0,
        ..Default::default()
    };
    let mut pol = quickswap::policy::build(id, wl).unwrap();
    let mut eng = Engine::new(wl, cfg);
    let mut rng = Rng::new(seed);
    eng.run(src, pol.as_mut(), &mut rng)
}

/// Every statistic downstream consumers read, compared to the bit.
fn assert_results_bit_identical(a: &SimResult, b: &SimResult, tag: &str) {
    assert_eq!(a.events, b.events, "{tag}: events");
    assert_eq!(a.completed, b.completed, "{tag}: completed");
    assert_eq!(a.mean_t_all.to_bits(), b.mean_t_all.to_bits(), "{tag}: mean_t_all");
    assert_eq!(a.ci95.to_bits(), b.ci95.to_bits(), "{tag}: ci95");
    assert_eq!(a.weighted_t.to_bits(), b.weighted_t.to_bits(), "{tag}: weighted_t");
    assert_eq!(a.jain.to_bits(), b.jain.to_bits(), "{tag}: jain");
    assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{tag}: utilization");
    assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{tag}: sim_time");
    assert_eq!(a.count, b.count, "{tag}: count");
    for c in 0..a.mean_t.len() {
        assert_eq!(a.mean_t[c].to_bits(), b.mean_t[c].to_bits(), "{tag}: mean_t[{c}]");
        assert_eq!(a.mean_n[c].to_bits(), b.mean_n[c].to_bits(), "{tag}: mean_n[{c}]");
    }
}

/// The tentpole equivalence: streaming mmap-backed replay == the
/// materialized `TraceSource` path, bitwise, for every block size and
/// every policy, on the fig5 (four_class) and fig6 (borg) shapes.
#[test]
fn streaming_replay_is_bit_identical_across_blocks_and_policies() {
    let shapes: [(&str, Workload, usize); 2] = [
        ("four_class", Workload::four_class(4.0), 2_000),
        ("borg", borg_workload(3.0), 1_200),
    ];
    let dir = tmp_dir("bitident");
    let blocks = [1usize, 7, 64, 4096];
    for (name, wl, n) in shapes {
        let tr = Trace::generate(&wl, n, 0x5eed_2026);
        let paths: Vec<_> = blocks
            .iter()
            .map(|&block| {
                let path = dir.join(format!("{name}_{block}.qst"));
                tr.write_qst(&path, wl.num_classes(), block).unwrap();
                (block, path)
            })
            .collect();
        for pstr in ALL_POLICIES {
            let id: PolicyId = pstr.parse().unwrap();
            let mut base_src = TraceSource::new(wl.clone(), tr.clone()).unwrap();
            let base = replay(&wl, &id, &mut base_src, 5);
            assert!(base.completed > 0, "{name}/{pstr}: nothing completed");
            for (block, path) in &paths {
                let mut src = StreamingTraceSource::open(path, wl.clone()).unwrap();
                let got = replay(&wl, &id, &mut src, 5);
                assert_results_bit_identical(&base, &got, &format!("{name}/{pstr}/block={block}"));
            }
        }
        for (_, path) in &paths {
            std::fs::remove_file(path).ok();
        }
    }
}

/// The one-pass CSV converter and the in-memory writer produce the same
/// bytes (CSV round-trips f64s via shortest-round-trip Display, so no
/// precision is lost on the way through text).
#[test]
fn converter_bytes_match_writer_bytes() {
    let wl = Workload::four_class(4.0);
    let tr = Trace::generate(&wl, 1_234, 77);
    let dir = tmp_dir("convert");
    let csv = dir.join("t.csv");
    let direct = dir.join("direct.qst");
    let converted = dir.join("converted.qst");
    tr.write_csv(&csv).unwrap();
    let f1 = tr.write_qst(&direct, wl.num_classes(), 256).unwrap();
    let f2 = qst::convert_csv(&csv, &converted, wl.num_classes(), 256).unwrap();
    assert_eq!(f1, f2, "footers differ");
    assert_eq!(
        std::fs::read(&direct).unwrap(),
        std::fs::read(&converted).unwrap(),
        "converted bytes differ from directly written bytes"
    );
    for p in [&csv, &direct, &converted] {
        std::fs::remove_file(p).ok();
    }
}

/// Corruption is caught at open, with the failing block named; a torn
/// (truncated) file of any cut length also refuses to open. Replay can
/// therefore never observe a bad block.
#[test]
fn corrupted_and_torn_qst_hard_error_at_open() {
    let wl = Workload::four_class(4.0);
    let tr = Trace::generate(&wl, 600, 9);
    let dir = tmp_dir("corrupt");
    let path = dir.join("good.qst");
    let footer = tr.write_qst(&path, wl.num_classes(), 64).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Flip one byte inside block 3's payload.
    let mut bytes = good.clone();
    bytes[footer.blocks[3].offset as usize + 5] ^= 0x40;
    let bad = dir.join("flipped.qst");
    std::fs::write(&bad, &bytes).unwrap();
    let err = StreamingTraceSource::open(&bad, wl.clone())
        .err()
        .expect("corrupted file must not open");
    match err {
        TraceError::Corrupt { block, .. } => assert_eq!(block, 3, "wrong block named"),
        e => panic!("expected Corrupt, got: {e}"),
    }

    // Torn writes: cut through the tail magic, the footer CRC, the
    // footer body, and half the file.
    for cut in [1usize, 13, 21, 40, good.len() / 2] {
        let torn = dir.join(format!("torn_{cut}.qst"));
        std::fs::write(&torn, &good[..good.len() - cut]).unwrap();
        assert!(
            StreamingTraceSource::open(&torn, wl.clone()).is_err(),
            "torn file (cut {cut}) opened"
        );
        std::fs::remove_file(&torn).ok();
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&bad).ok();
}

/// Block-aligned shards drain to natural exhaustion even under a
/// timer-driven policy (the engine breaks the timer re-arm cycle once
/// the shard is spent and the system is empty).
#[test]
fn sharded_replay_with_timer_policy_terminates_and_covers_the_trace() {
    let wl = Workload::four_class(4.0);
    let tr = Trace::generate(&wl, 900, 3);
    let dir = tmp_dir("shards");
    let path = dir.join("sharded.qst");
    tr.write_qst(&path, wl.num_classes(), 32).unwrap();
    let id: PolicyId = "msr-seq".parse().unwrap();
    let mut total = 0;
    for s in 0..3 {
        let mut src = StreamingTraceSource::open_shard(&path, wl.clone(), s, 3).unwrap();
        let expect = src.shard_len();
        let r = replay(&wl, &id, &mut src, 1);
        assert_eq!(r.completed, expect, "shard {s} left jobs behind");
        total += r.completed;
    }
    assert_eq!(total, 900, "shards do not cover the trace");
    std::fs::remove_file(&path).ok();
}

/// A nonstationary (diurnal) arrival stream recorded to `.qst` and
/// replayed gives bit-identical results to simulating the live warped
/// source — the rate curve survives the recording round trip.
#[test]
fn rate_curve_trace_roundtrip_matches_live_source() {
    let wl = Workload::four_class(3.0).with_rate_curve(RateCurve::Diurnal {
        period: 200.0,
        amp: 0.6,
        phase: 0.0,
    });
    let id: PolicyId = "msfq:7".parse().unwrap();
    let cfg = SimConfig {
        target_completions: 1_500,
        warmup_completions: 0,
        ..Default::default()
    };
    let live = quickswap::sim::run_policy(&wl, &id, &cfg, 99).unwrap();
    // Ample trace: the target ends the run before the trace runs dry.
    let tr = Trace::generate(&wl, 12_000, 99);
    let dir = tmp_dir("ratecurve");
    let path = dir.join("diurnal.qst");
    tr.write_qst(&path, wl.num_classes(), 512).unwrap();
    let mut src = StreamingTraceSource::open(&path, wl.clone()).unwrap();
    let mut pol = quickswap::policy::build(&id, &wl).unwrap();
    let mut eng = Engine::new(&wl, cfg);
    let mut rng = Rng::new(99);
    let replayed = eng.run(&mut src, pol.as_mut(), &mut rng);
    assert_results_bit_identical(&live, &replayed, "diurnal live vs replay");
    std::fs::remove_file(&path).ok();
}
