//! CRN paired-replication integration: the shared-stream contract.
//!
//! Three layers of the determinism argument, bottom to top:
//!
//! 1. Replaying a [`MaterializedStream`] through the engine is
//!    bit-identical to a live [`SyntheticSource`] run at the same seed,
//!    for every policy, on the fig5/fig6 shapes — even when the
//!    engine-side RNG is seeded with garbage, because arrivals are the
//!    only consumer of that RNG.
//! 2. A policy's marginal statistics inside a paired unit cannot depend
//!    on which other policies share its stream (solo paired grid vs the
//!    full grid, compared per-field to the bit).
//! 3. A sharded paired sweep (driver + workers over the wire) is
//!    bit-identical to the in-process paired runner at the same
//!    (seed, R) — marginal points and Δ rows both.
//!
//! Plus the acceptance gate: on a fig2 frontier point, the paired
//! Δ(MSFQ − MSF) CI is at least 3× narrower than the unpaired
//! quadrature CI at the same event budget.

use quickswap::experiments::{run_paired_unit, DiffPoint, PairedGrid, Point};
use quickswap::sim::{Engine, SimConfig, SimResult, UnitStats};
use quickswap::sweep::{run_spec_paired_local, run_worker, DriverBuilder, SweepSpec, WorkloadSpec};
use quickswap::util::rng::Rng;
use quickswap::workload::{borg::borg_workload, MaterializedStream, Workload};

/// Standard config shape used across the differentials (warmup = 1/5 of
/// the measured budget, everything else at defaults).
fn cfg(target: u64) -> SimConfig {
    SimConfig {
        target_completions: target,
        warmup_completions: target / 5,
        ..Default::default()
    }
}

/// Run `policy` over a replayed [`MaterializedStream`] at `seed` — the
/// paired runner's engine path — with a deliberately different
/// engine-side RNG seed to prove replay never consumes it.
fn replay_result(wl: &Workload, policy: &str, cfg: &SimConfig, seed: u64) -> SimResult {
    let mut engine = Engine::new(wl, cfg.clone());
    let mut stream = MaterializedStream::new(wl.clone(), seed);
    let mut pol = quickswap::policy::build(&policy.parse().unwrap(), wl).unwrap();
    let mut rng = Rng::new(seed ^ 0xDEAD_BEEF_F00D); // junk on purpose
    let mut cursor = stream.cursor();
    engine.run(&mut cursor, pol.as_mut(), &mut rng)
}

/// Every statistic reports read from a [`SimResult`] must match to the
/// bit (wall-clock excluded — it is the one legitimately nondeterministic
/// field).
fn assert_result_bit_identical(policy: &str, tag: &str, a: &SimResult, b: &SimResult) {
    assert_eq!(a.policy, b.policy, "{tag}/{policy}");
    assert_eq!(a.completed, b.completed, "{tag}/{policy}");
    assert_eq!(a.events, b.events, "{tag}/{policy}");
    assert_eq!(a.mean_t_all.to_bits(), b.mean_t_all.to_bits(), "{tag}/{policy}");
    assert_eq!(a.ci95.to_bits(), b.ci95.to_bits(), "{tag}/{policy}");
    assert_eq!(a.weighted_t.to_bits(), b.weighted_t.to_bits(), "{tag}/{policy}");
    assert_eq!(a.jain.to_bits(), b.jain.to_bits(), "{tag}/{policy}");
    assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{tag}/{policy}");
    assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{tag}/{policy}");
    for c in 0..a.mean_t.len() {
        assert_eq!(a.mean_t[c].to_bits(), b.mean_t[c].to_bits(), "{tag}/{policy} class {c}");
        assert_eq!(a.mean_n[c].to_bits(), b.mean_n[c].to_bits(), "{tag}/{policy} class {c}");
        assert_eq!(a.count[c], b.count[c], "{tag}/{policy} class {c}");
    }
}

/// Replay vs live source, every policy, fig5/fig6 multiclass shapes plus
/// the one-or-all shape MSFQ accepts. This is the foundation the paired
/// runner's "marginals are bit-identical to solo runs" claim rests on.
#[test]
fn replay_is_bit_identical_to_live_source_for_every_policy() {
    let multiclass = [
        "fcfs",
        "first-fit",
        "msf",
        "static-qs",
        "adaptive-qs",
        "nmsr",
        "server-filling",
    ];
    let fig5 = Workload::four_class(4.0);
    let c5 = cfg(15_000);
    for policy in multiclass {
        let live = quickswap::sim::run_policy(&fig5, &policy.parse().unwrap(), &c5, 1234).unwrap();
        let replay = replay_result(&fig5, policy, &c5, 1234);
        assert_result_bit_identical(policy, "fig5", &live, &replay);
    }
    let fig6 = borg_workload(4.0);
    let c6 = cfg(5_000);
    for policy in multiclass {
        let live = quickswap::sim::run_policy(&fig6, &policy.parse().unwrap(), &c6, 77).unwrap();
        let replay = replay_result(&fig6, policy, &c6, 77);
        assert_result_bit_identical(policy, "fig6", &live, &replay);
    }
    let ooa = Workload::one_or_all(32, 7.5, 0.9, 1.0, 1.0);
    let c2 = cfg(12_000);
    for policy in ["fcfs", "first-fit", "msf", "msfq:31", "msfq:0", "server-filling"] {
        let live = quickswap::sim::run_policy(&ooa, &policy.parse().unwrap(), &c2, 7).unwrap();
        let replay = replay_result(&ooa, policy, &c2, 7);
        assert_result_bit_identical(policy, "fig2-one-or-all", &live, &replay);
    }
}

/// Everything a paired unit ships over the wire except wall clock.
fn assert_stats_bit_identical(tag: &str, a: &UnitStats, b: &UnitStats) {
    assert_eq!(a.completed, b.completed, "{tag}");
    assert_eq!(a.events, b.events, "{tag}");
    assert_eq!(a.window.to_bits(), b.window.to_bits(), "{tag}");
    assert_eq!(a.busy_area.to_bits(), b.busy_area.to_bits(), "{tag}");
    assert_eq!(a.n_area.len(), b.n_area.len(), "{tag}");
    for c in 0..a.n_area.len() {
        assert_eq!(a.n_area[c].to_bits(), b.n_area[c].to_bits(), "{tag} class {c}");
    }
    assert_eq!(a.resp.len(), b.resp.len(), "{tag}");
    for c in 0..a.resp.len() {
        let (x, y) = (a.resp[c].to_json().to_string(), b.resp[c].to_json().to_string());
        assert_eq!(x, y, "{tag} resp class {c}");
    }
    let (x, y) = (a.resp_all.to_json().to_string(), b.resp_all.to_json().to_string());
    assert_eq!(x, y, "{tag} resp_all");
}

/// A policy's marginal stats cannot depend on which other policies share
/// its stream: a one-policy paired grid and the full four-policy grid
/// produce bit-identical per-policy stats for every (λ, replication)
/// unit. This is what makes CRN a pure variance optimisation — it can
/// never change what any single policy reports.
#[test]
fn paired_marginals_are_independent_of_the_policy_set() {
    let base = cfg(8_000);
    let lambdas = [3.0, 4.0];
    let all: [&str; 4] = ["msf", "fcfs", "msfq:7", "first-fit"];
    let grid_all = PairedGrid::new(&lambdas, &all, 0, &base, 99, 2);
    for u in 0..grid_all.n_units() {
        let (li, r) = grid_all.point_rep(u);
        let wl = Workload::one_or_all(8, grid_all.lambdas[li], 0.9, 1.0, 1.0);
        let mut cache = None;
        let full = run_paired_unit(&grid_all, &wl, u, &mut cache);
        for (pi, &name) in all.iter().enumerate() {
            let solo_grid = PairedGrid::new(&lambdas, &[name], 0, &base, 99, 2);
            let mut solo_cache = None;
            let solo = run_paired_unit(&solo_grid, &wl, u, &mut solo_cache);
            let tag = format!("λ={} rep={r} policy={name}", grid_all.lambdas[li]);
            let a = full.runs[pi].as_ref().unwrap_or_else(|| panic!("{tag}: full run missing"));
            let b = solo.runs[0].as_ref().unwrap_or_else(|| panic!("{tag}: solo run missing"));
            assert_eq!(a.display, b.display, "{tag}");
            assert_stats_bit_identical(&tag, &a.stats, &b.stats);
        }
    }
}

/// The sweep-smoke grid, paired against an MSF baseline.
fn paired_spec() -> SweepSpec {
    SweepSpec {
        workload: WorkloadSpec::OneOrAll {
            k: 8,
            p1: 0.9,
            mu1: 1.0,
            muk: 1.0,
        },
        lambdas: vec![2.0, 3.0],
        policies: vec![
            quickswap::policy::PolicyId::Msf,
            quickswap::policy::PolicyId::Msfq(Some(7)),
            quickswap::policy::PolicyId::Fcfs,
        ],
        target_completions: 6_000,
        warmup_completions: 1_200,
        batch: 1000,
        seed: 42,
        replications: 3,
        paired: true,
        baseline: Some(quickswap::policy::PolicyId::Msf),
        trace: None,
    }
}

fn assert_points_bit_identical(a: &[Point], b: &[Point]) {
    assert_eq!(a.len(), b.len(), "point count differs");
    for (x, y) in a.iter().zip(b) {
        let tag = format!("({}, {})", x.lambda, x.policy);
        assert_eq!(x.lambda.to_bits(), y.lambda.to_bits(), "{tag}");
        assert_eq!(x.policy, y.policy, "{tag}");
        assert_result_bit_identical(&x.policy.to_string(), "sharded-vs-local", &x.result, &y.result);
    }
}

fn assert_diffs_bit_identical(a: &[DiffPoint], b: &[DiffPoint]) {
    assert_eq!(a.len(), b.len(), "diff count differs");
    for (x, y) in a.iter().zip(b) {
        let tag = format!("({}, {} − {})", x.lambda, x.policy, x.baseline);
        assert_eq!(x.lambda.to_bits(), y.lambda.to_bits(), "{tag}");
        assert_eq!(x.policy, y.policy, "{tag}");
        assert_eq!(x.baseline, y.baseline, "{tag}");
        assert_eq!(x.unpaired_ci95.to_bits(), y.unpaired_ci95.to_bits(), "{tag}");
        assert_eq!(x.diff.to_json().to_string(), y.diff.to_json().to_string(), "{tag}");
    }
}

/// Sharding a paired sweep adds nothing but transport: driver + N
/// in-process workers reproduce the local runner's marginal points and
/// Δ rows to the bit, for 1 and 2 workers (arrival order and unit
/// interleaving vary; the pooled output must not).
#[test]
fn sharded_paired_sweep_is_bit_identical_to_local() {
    let spec = paired_spec();
    let local = run_spec_paired_local(&spec, 4).unwrap();
    assert_eq!(local.points.len(), 6, "2 λ × 3 policies");
    assert_eq!(local.diffs.len(), 4, "2 λ × 2 non-baseline policies");
    for n_workers in [1usize, 2] {
        let driver = DriverBuilder::new().spec(&spec).bind().unwrap();
        let addr = driver.local_addr().to_string();
        let dh = std::thread::spawn(move || {
            let report = driver.serve().unwrap();
            match report.outcomes.into_iter().next() {
                Some(quickswap::sweep::SpecOutcome::Paired(sweep)) => sweep,
                _ => panic!("expected one paired outcome"),
            }
        });
        let workers: Vec<_> = (0..n_workers)
            .map(|_| {
                let a = addr.clone();
                std::thread::spawn(move || run_worker(&a).unwrap())
            })
            .collect();
        let sharded = dh.join().unwrap();
        let served: usize = workers
            .into_iter()
            .map(|w| w.join().unwrap().completed)
            .sum();
        assert_eq!(served, 6, "every (λ, replication) unit acknowledged once");
        assert_points_bit_identical(&local.points, &sharded.points);
        assert_diffs_bit_identical(&local.diffs, &sharded.diffs);
    }
}

/// The acceptance gate on a fig2 frontier point (k=32, λ=7.5, p1=0.9):
/// at a fixed event budget, the paired Δ(MSFQ − MSF) CI must be at
/// least 3× narrower than the unpaired quadrature of the marginal CIs.
/// Fully deterministic at the pinned seed, so this either always passes
/// or always fails — it cannot flake.
#[test]
fn paired_ci_is_at_least_3x_narrower_on_fig2_frontier() {
    let spec = SweepSpec {
        workload: WorkloadSpec::OneOrAll {
            k: 32,
            p1: 0.9,
            mu1: 1.0,
            muk: 1.0,
        },
        lambdas: vec![7.5],
        policies: vec![
            quickswap::policy::PolicyId::Msf,
            quickswap::policy::PolicyId::Msfq(Some(31)),
        ],
        target_completions: 40_000,
        warmup_completions: 8_000,
        batch: 1000,
        seed: 20250710,
        replications: 4,
        paired: true,
        baseline: Some(quickswap::policy::PolicyId::Msf),
        trace: None,
    };
    let sweep = run_spec_paired_local(&spec, 4).unwrap();
    assert_eq!(sweep.diffs.len(), 1);
    let d = &sweep.diffs[0];
    let paired = d.diff.ci95_half_width();
    assert!(paired.is_finite() && paired > 0.0, "degenerate paired CI: {paired}");
    let ratio = d.unpaired_ci95 / paired;
    assert!(
        ratio >= 3.0,
        "CRN variance reduction only {ratio:.2}× (paired ±{paired:.4}, unpaired ±{:.4})",
        d.unpaired_ci95
    );
}
