//! Fig C.7 bench: fairness (Jain index) on the Borg workload.
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig7_fairness").with_budget(std::time::Duration::from_millis(1));
    let mut rows = Vec::new();
    b.bench("borg_fairness", || {
        let pts = figures::fig6(Scale::smoke(), &[4.0], false);
        rows = figures::fig7(&pts);
    });
    let jain = |pol: &str| {
        rows.iter()
            .find(|r| r.policy.to_lowercase().replace('-', "").contains(pol))
            .map(|r| r.jain)
            .unwrap()
    };
    // Paper shape: Quickswap policies are fairer than MSF.
    let (aq, msf) = (jain("adaptiveqs"), jain("msf"));
    assert!(aq > msf, "AdaptiveQS jain {aq} !> MSF {msf}");
    println!("fig7 OK: jain AdaptiveQS={aq:.3} MSF={msf:.3}");
    b.finish();
}
