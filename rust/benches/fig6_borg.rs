//! Fig 6 bench: Borg-derived workload (k=2048, 26 classes), weighted E[T].
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig6_borg").with_budget(std::time::Duration::from_millis(1));
    let mut pts = Vec::new();
    b.bench("borg_sweep", || {
        pts = figures::fig6(Scale::smoke(), &[4.0], false);
    });
    let at = |pol: &str| {
        pts.iter()
            .find(|p| p.policy.to_lowercase().replace('-', "").contains(pol))
            .map(|p| p.result.weighted_t)
            .unwrap()
    };
    let (adaptive, msf) = (at("adaptiveqs"), at("msf"));
    assert!(adaptive < msf, "AdaptiveQS {adaptive} !< MSF {msf}");
    println!("fig6 OK @λ=4.0: AdaptiveQS={adaptive:.1} MSF={msf:.1}");
    b.finish();
}
