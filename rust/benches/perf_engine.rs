//! L3 perf microbenches: DES engine event throughput and policy decision
//! cost. Targets recorded in EXPERIMENTS.md §Perf.
use quickswap::sim::{run_named, SimConfig};
use quickswap::util::bench::{black_box, Bench};
use quickswap::workload::{borg::borg_workload, Workload};

fn events_per_sec(wl: &Workload, policy: &str, completions: u64) -> f64 {
    let cfg = SimConfig {
        target_completions: completions,
        warmup_completions: 0,
        ..Default::default()
    };
    let r = run_named(wl, policy, &cfg, 7).unwrap();
    r.events as f64 / r.wall_s
}

fn main() {
    let mut b = Bench::new("perf_engine");
    let one_or_all = Workload::one_or_all(32, 7.5, 0.9, 1.0, 1.0);
    for policy in ["fcfs", "msf", "msfq:31", "first-fit"] {
        let mut rate = 0.0;
        b.bench(&format!("sim_{policy}"), || {
            rate = events_per_sec(&one_or_all, policy, 100_000);
        });
        println!("  -> {policy}: {:.2} M events/s", rate / 1e6);
    }
    let borg = borg_workload(4.0);
    let mut rate = 0.0;
    b.bench("sim_borg_adaptive_qs", || {
        rate = events_per_sec(&borg, "adaptive-qs", 50_000);
    });
    println!("  -> borg/adaptive-qs: {:.2} M events/s", rate / 1e6);

    // Analytical calculator throughput (the autotuner's native fallback).
    b.bench("theorem2_calculator_k32", || {
        let a = quickswap::analysis::analyze(&quickswap::analysis::MsfqParams::standard(
            32, 31, 7.5, 0.9,
        ))
        .unwrap();
        black_box(a.et);
    });
    b.finish();
}
