//! L3 perf microbenches: DES engine event throughput and policy decision
//! cost. Targets recorded in EXPERIMENTS.md §Perf; machine-readable
//! events/s land in BENCH_perf_engine.json (override with QS_BENCH_OUT)
//! so successive PRs have a perf trajectory to compare against — see
//! scripts/bench_smoke.sh.
//!
//! Engines are constructed once per workload and reset between runs, so
//! the numbers measure the steady-state hot path (indexed event heap +
//! SoA job table), not allocator traffic.
use quickswap::experiments::Scale;
use quickswap::sim::{Engine, SimConfig};
use quickswap::util::bench::{black_box, Bench};
use quickswap::util::json::Value;
use quickswap::util::rng::Rng;
use quickswap::workload::{borg::borg_workload, SyntheticSource, Workload};

/// One replication on a reused engine; returns events per wall second.
fn events_per_sec(engine: &mut Engine, wl: &Workload, policy: &str, seed: u64) -> f64 {
    engine.reset();
    let mut pol = quickswap::policy::by_name(policy, wl).unwrap();
    let mut src = SyntheticSource::new(wl.clone());
    let mut rng = Rng::new(seed);
    let r = engine.run(&mut src, pol.as_mut(), &mut rng);
    r.events as f64 / r.wall_s.max(1e-12)
}

fn write_json(measured: &[(String, f64)], completions: u64) {
    let path =
        std::env::var("QS_BENCH_OUT").unwrap_or_else(|_| "BENCH_perf_engine.json".to_string());
    let mut results = Value::obj();
    for (name, rate) in measured {
        results = results.set(name, *rate);
    }
    let doc = Value::obj()
        .set("bench", "perf_engine")
        .set("unit", "events_per_sec")
        .set("scale", Scale::env_name())
        .set("completions", completions)
        .set("results", results);
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let scale = Scale::from_env();
    // Cap the per-run length: throughput saturates well before this and
    // the Bench harness repeats runs anyway.
    let completions = scale.completions.min(100_000).max(10_000);
    let mut measured: Vec<(String, f64)> = Vec::new();
    let mut b = Bench::new("perf_engine");

    let one_or_all = Workload::one_or_all(32, 7.5, 0.9, 1.0, 1.0);
    let cfg = SimConfig {
        target_completions: completions,
        warmup_completions: 0,
        ..Default::default()
    };
    let mut engine = Engine::new(&one_or_all, cfg.clone());
    for policy in ["fcfs", "msf", "msfq:31", "first-fit"] {
        let mut rate = 0.0;
        b.bench(&format!("sim_{policy}"), || {
            rate = events_per_sec(&mut engine, &one_or_all, policy, 7);
            black_box(rate);
        });
        println!("  -> {policy}: {:.2} M events/s", rate / 1e6);
        measured.push((format!("sim_{policy}"), rate));
    }

    // Uncached-consult baseline for the headline policy: the consult
    // cache must keep `sim_msfq:31` at or above this number.
    let nocache_cfg = SimConfig {
        consult_cache: Some(false),
        ..cfg
    };
    let mut engine_nc = Engine::new(&one_or_all, nocache_cfg);
    let mut rate = 0.0;
    b.bench("sim_msfq:31_nocache", || {
        rate = events_per_sec(&mut engine_nc, &one_or_all, "msfq:31", 7);
        black_box(rate);
    });
    println!("  -> msfq:31 (no consult cache): {:.2} M events/s", rate / 1e6);
    measured.push(("sim_msfq:31_nocache".to_string(), rate));

    let borg = borg_workload(4.0);
    let borg_cfg = SimConfig {
        target_completions: completions / 2,
        warmup_completions: 0,
        ..Default::default()
    };
    let mut borg_engine = Engine::new(&borg, borg_cfg.clone());
    let mut rate = 0.0;
    b.bench("sim_borg_adaptive_qs", || {
        rate = events_per_sec(&mut borg_engine, &borg, "adaptive-qs", 7);
        black_box(rate);
    });
    println!("  -> borg/adaptive-qs: {:.2} M events/s", rate / 1e6);
    measured.push(("sim_borg_adaptive_qs".to_string(), rate));

    // 26-class MSF: stresses the queue index's Fenwick-backed
    // descending-need admission walk (O(log C) per admitted class
    // instead of an O(C) scan per consult).
    let mut rate = 0.0;
    b.bench("sim_borg_msf", || {
        rate = events_per_sec(&mut borg_engine, &borg, "msf", 7);
        black_box(rate);
    });
    println!("  -> borg/msf: {:.2} M events/s", rate / 1e6);
    measured.push(("sim_borg_msf".to_string(), rate));

    let borg_nc_cfg = SimConfig {
        consult_cache: Some(false),
        ..borg_cfg
    };
    let mut borg_engine_nc = Engine::new(&borg, borg_nc_cfg);
    let mut rate = 0.0;
    b.bench("sim_borg_adaptive_qs_nocache", || {
        rate = events_per_sec(&mut borg_engine_nc, &borg, "adaptive-qs", 7);
        black_box(rate);
    });
    println!(
        "  -> borg/adaptive-qs (no consult cache): {:.2} M events/s",
        rate / 1e6
    );
    measured.push(("sim_borg_adaptive_qs_nocache".to_string(), rate));

    // Preemptive policy: stresses departure cancel/reschedule.
    let sf_wl = Workload::one_or_all(16, 4.0, 0.9, 1.0, 1.0);
    let sf_cfg = SimConfig {
        target_completions: completions / 2,
        warmup_completions: 0,
        ..Default::default()
    };
    let mut sf_engine = Engine::new(&sf_wl, sf_cfg);
    let mut rate = 0.0;
    b.bench("sim_server_filling", || {
        rate = events_per_sec(&mut sf_engine, &sf_wl, "server-filling", 7);
        black_box(rate);
    });
    println!("  -> server-filling: {:.2} M events/s", rate / 1e6);
    measured.push(("sim_server_filling".to_string(), rate));

    // Analytical calculator throughput (the autotuner's native fallback).
    b.bench("theorem2_calculator_k32", || {
        let a = quickswap::analysis::analyze(&quickswap::analysis::MsfqParams::standard(
            32, 31, 7.5, 0.9,
        ))
        .unwrap();
        black_box(a.et);
    });
    b.finish();

    write_json(&measured, completions);
}
