//! L3 perf microbenches: DES engine event throughput and policy decision
//! cost. Targets recorded in EXPERIMENTS.md §Perf; machine-readable
//! events/s land in BENCH_perf_engine.json (override with QS_BENCH_OUT)
//! so successive PRs have a perf trajectory to compare against — see
//! scripts/bench_smoke.sh.
//!
//! Engines are constructed once per workload and reset between runs, so
//! the numbers measure the steady-state hot path, not allocator
//! traffic. The `sim_*` targets pin the 4-ary heap event schedule so
//! the committed trajectory keeps comparing like with like; the
//! `sim_*:ladder` twins pin the ladder queue (the engine default), and
//! the `sched_churn_*` microbenchmark races the two structures on a raw
//! push/pop/cancel stream with no engine around them. The
//! `sim_paired_shared_stream` / `sim_independent_4policy` pair measures
//! the CRN replay path against independent live-source runs, and
//! `paired_ci_width_ratio` (unitless, not a rate) records the paired
//! vs unpaired Δ-CI variance-reduction factor on the fig2 frontier.
use quickswap::experiments::Scale;
use quickswap::sim::events::{EventKind, EventQueue};
use quickswap::sim::ladder::LadderQueue;
use quickswap::sim::schedule::EventSchedule;
use quickswap::sim::{Engine, EventScheduleKind, SimConfig};
use quickswap::sweep::{run_spec_paired_local, SweepSpec, WorkloadSpec};
use quickswap::util::bench::{black_box, Bench};
use quickswap::util::json::Value;
use quickswap::util::rng::Rng;
use quickswap::workload::{borg::borg_workload, MaterializedStream, SyntheticSource, Workload};

/// One replication on a reused engine; returns events per wall second.
fn events_per_sec(engine: &mut Engine, wl: &Workload, policy: &str, seed: u64) -> f64 {
    engine.reset();
    let mut pol = quickswap::policy::build(&policy.parse().unwrap(), wl).unwrap();
    let mut src = SyntheticSource::new(wl.clone());
    let mut rng = Rng::new(seed);
    let r = engine.run(&mut src, pol.as_mut(), &mut rng);
    r.events as f64 / r.wall_s.max(1e-12)
}

/// One CRN pass: every policy replays the same materialized arrival
/// stream on a reused engine (the paired-unit hot path). Returns
/// (total events, total wall seconds) across the policy set.
fn paired_pass(
    engine: &mut Engine,
    wl: &Workload,
    stream: &mut MaterializedStream,
    policies: &[&str],
    seed: u64,
) -> (u64, f64) {
    let (mut events, mut wall) = (0u64, 0.0f64);
    for policy in policies {
        engine.reset();
        let mut pol = quickswap::policy::build(&policy.parse().unwrap(), wl).unwrap();
        // Replay never consumes the engine-side RNG; seeded for parity.
        let mut rng = Rng::new(seed);
        let mut cursor = stream.cursor();
        let r = engine.run(&mut cursor, pol.as_mut(), &mut rng);
        events += r.events;
        wall += r.wall_s;
    }
    (events, wall)
}

fn write_json(measured: &[(String, f64)], completions: u64) {
    let path =
        std::env::var("QS_BENCH_OUT").unwrap_or_else(|_| "BENCH_perf_engine.json".to_string());
    let mut results = Value::obj();
    for (name, rate) in measured {
        results = results.set(name, *rate);
    }
    let doc = Value::obj()
        .set("bench", "perf_engine")
        .set("unit", "events_per_sec")
        .set("scale", Scale::env_name())
        .set("completions", completions)
        .set("results", results);
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Raw schedule microbenchmark: a steady-state churn of `JOBS` live
/// departures — pop the earliest, re-push it one service ahead, and
/// every 8th iteration cancel + reschedule a random other job (the
/// preemption pattern). Identical op/RNG stream for every structure;
/// returns pops per wall second.
fn schedule_churn<Q: EventSchedule>(q: &mut Q) -> f64 {
    const JOBS: u64 = 1024;
    const OPS: u64 = 200_000;
    let mut rng = Rng::new(4242);
    for j in 0..JOBS {
        q.push(rng.exp(1.0), EventKind::Departure { job: j });
    }
    let t0 = std::time::Instant::now();
    let mut ops = 0u64;
    while ops < OPS {
        let e = q.pop().expect("churn queue never empties");
        let EventKind::Departure { job } = e.kind else {
            unreachable!("only departures are pushed")
        };
        let now = e.t;
        if ops % 8 == 0 {
            let other = rng.below(JOBS);
            // `other == job` would double-schedule the popped job.
            if other != job && q.cancel_departure(other) {
                q.push(now + rng.exp(0.5), EventKind::Departure { job: other });
            }
        }
        q.push(now + rng.exp(1.0), EventKind::Departure { job });
        ops += 1;
    }
    let rate = ops as f64 / t0.elapsed().as_secs_f64().max(1e-12);
    q.clear();
    rate
}

fn main() {
    let scale = Scale::from_env();
    // Cap the per-run length: throughput saturates well before this and
    // the Bench harness repeats runs anyway.
    let completions = scale.completions.min(100_000).max(10_000);
    let mut measured: Vec<(String, f64)> = Vec::new();
    let mut b = Bench::new("perf_engine");

    let one_or_all = Workload::one_or_all(32, 7.5, 0.9, 1.0, 1.0);
    let cfg = SimConfig {
        target_completions: completions,
        warmup_completions: 0,
        event_schedule: Some(EventScheduleKind::Heap),
        ..Default::default()
    };
    let mut engine = Engine::new(&one_or_all, cfg.clone());
    for policy in ["fcfs", "msf", "msfq:31", "first-fit"] {
        let mut rate = 0.0;
        b.bench(&format!("sim_{policy}"), || {
            rate = events_per_sec(&mut engine, &one_or_all, policy, 7);
            black_box(rate);
        });
        println!("  -> {policy}: {:.2} M events/s", rate / 1e6);
        measured.push((format!("sim_{policy}"), rate));
    }

    // Streaming trace replay: the same one_or_all stream recorded to a
    // columnar `.qst` and replayed through the mmap-backed source under
    // FCFS — block decode plus zero-allocation chunked refills are the
    // only costs on top of the engine. bench_compare.sh holds this at
    // >= 2M events/s absolute in addition to the ratio gate.
    let trace_dir = std::env::temp_dir().join(format!("qs_bench_trace_{}", std::process::id()));
    std::fs::create_dir_all(&trace_dir).expect("bench trace dir");
    let trace_path = trace_dir.join("replay.qst");
    quickswap::workload::trace::Trace::generate(&one_or_all, (completions * 2) as usize, 7)
        .write_qst(
            &trace_path,
            one_or_all.num_classes(),
            quickswap::workload::qst::DEFAULT_BLOCK,
        )
        .expect("write bench trace");
    let mut rate = 0.0;
    b.bench("sim_trace_replay", || {
        engine.reset();
        let mut pol = quickswap::policy::build(&"fcfs".parse().unwrap(), &one_or_all).unwrap();
        let mut src =
            quickswap::workload::trace::StreamingTraceSource::open(&trace_path, one_or_all.clone())
                .expect("open bench trace");
        let mut rng = Rng::new(7);
        let r = engine.run(&mut src, pol.as_mut(), &mut rng);
        rate = r.events as f64 / r.wall_s.max(1e-12);
        black_box(rate);
    });
    println!("  -> trace replay (fcfs, qst): {:.2} M events/s", rate / 1e6);
    measured.push(("sim_trace_replay".to_string(), rate));
    std::fs::remove_file(&trace_path).ok();

    // CRN paired-replication throughput: the same four policies over ONE
    // materialized arrival stream (the paired-unit hot path) vs four
    // independent live-source runs. Replay samples arrivals once instead
    // of once per policy, so the shared-stream rate must stay ahead of
    // the independent rate.
    const CRN_POLICIES: [&str; 4] = ["fcfs", "msf", "msfq:31", "first-fit"];
    let mut stream = MaterializedStream::new(one_or_all.clone(), 7);
    let mut shared_rate = 0.0;
    b.bench("sim_paired_shared_stream", || {
        let (ev, wall) = paired_pass(&mut engine, &one_or_all, &mut stream, &CRN_POLICIES, 7);
        shared_rate = ev as f64 / wall.max(1e-12);
        black_box(shared_rate);
    });
    println!(
        "  -> paired shared-stream (4 policies): {:.2} M events/s",
        shared_rate / 1e6
    );
    measured.push(("sim_paired_shared_stream".to_string(), shared_rate));

    let mut indep_rate = 0.0;
    b.bench("sim_independent_4policy", || {
        let (mut ev, mut wall) = (0u64, 0.0f64);
        for policy in CRN_POLICIES {
            engine.reset();
            let mut pol = quickswap::policy::build(&policy.parse().unwrap(), &one_or_all).unwrap();
            let mut src = SyntheticSource::new(one_or_all.clone());
            let mut rng = Rng::new(7);
            let r = engine.run(&mut src, pol.as_mut(), &mut rng);
            ev += r.events;
            wall += r.wall_s;
        }
        indep_rate = ev as f64 / wall.max(1e-12);
        black_box(indep_rate);
    });
    println!(
        "  -> independent (4 policies): {:.2} M events/s",
        indep_rate / 1e6
    );
    measured.push(("sim_independent_4policy".to_string(), indep_rate));
    println!(
        "  -> shared-stream speedup: {:.2}x",
        shared_rate / indep_rate.max(1e-12)
    );

    // Ladder-schedule twin of the FCFS target: same workload, same
    // seeds, only the timing structure differs (results are
    // bit-identical; only events/s may move).
    let ladder_cfg = SimConfig {
        event_schedule: Some(EventScheduleKind::Ladder),
        ..cfg.clone()
    };
    let mut engine_ladder = Engine::new(&one_or_all, ladder_cfg);
    let mut rate = 0.0;
    b.bench("sim_fcfs:ladder", || {
        rate = events_per_sec(&mut engine_ladder, &one_or_all, "fcfs", 7);
        black_box(rate);
    });
    println!("  -> fcfs (ladder schedule): {:.2} M events/s", rate / 1e6);
    measured.push(("sim_fcfs:ladder".to_string(), rate));

    // Uncached-consult baseline for the headline policy: the consult
    // cache must keep `sim_msfq:31` at or above this number.
    let nocache_cfg = SimConfig {
        consult_cache: Some(false),
        ..cfg
    };
    let mut engine_nc = Engine::new(&one_or_all, nocache_cfg);
    let mut rate = 0.0;
    b.bench("sim_msfq:31_nocache", || {
        rate = events_per_sec(&mut engine_nc, &one_or_all, "msfq:31", 7);
        black_box(rate);
    });
    println!("  -> msfq:31 (no consult cache): {:.2} M events/s", rate / 1e6);
    measured.push(("sim_msfq:31_nocache".to_string(), rate));

    let borg = borg_workload(4.0);
    let borg_cfg = SimConfig {
        target_completions: completions / 2,
        warmup_completions: 0,
        event_schedule: Some(EventScheduleKind::Heap),
        ..Default::default()
    };
    let mut borg_engine = Engine::new(&borg, borg_cfg.clone());
    let mut rate = 0.0;
    b.bench("sim_borg_adaptive_qs", || {
        rate = events_per_sec(&mut borg_engine, &borg, "adaptive-qs", 7);
        black_box(rate);
    });
    println!("  -> borg/adaptive-qs: {:.2} M events/s", rate / 1e6);
    measured.push(("sim_borg_adaptive_qs".to_string(), rate));

    // Ladder twin of the headline Borg target (heavy-tailed service
    // spans: the bucket auto-tuning + rung-spill stress case).
    let borg_ladder_cfg = SimConfig {
        event_schedule: Some(EventScheduleKind::Ladder),
        ..borg_cfg.clone()
    };
    let mut borg_engine_ladder = Engine::new(&borg, borg_ladder_cfg);
    let mut rate = 0.0;
    b.bench("sim_borg_adaptive_qs:ladder", || {
        rate = events_per_sec(&mut borg_engine_ladder, &borg, "adaptive-qs", 7);
        black_box(rate);
    });
    println!("  -> borg/adaptive-qs (ladder): {:.2} M events/s", rate / 1e6);
    measured.push(("sim_borg_adaptive_qs:ladder".to_string(), rate));

    // 26-class MSF: stresses the queue index's Fenwick-backed
    // descending-need admission walk (O(log C) per admitted class
    // instead of an O(C) scan per consult).
    let mut rate = 0.0;
    b.bench("sim_borg_msf", || {
        rate = events_per_sec(&mut borg_engine, &borg, "msf", 7);
        black_box(rate);
    });
    println!("  -> borg/msf: {:.2} M events/s", rate / 1e6);
    measured.push(("sim_borg_msf".to_string(), rate));

    let borg_nc_cfg = SimConfig {
        consult_cache: Some(false),
        ..borg_cfg
    };
    let mut borg_engine_nc = Engine::new(&borg, borg_nc_cfg);
    let mut rate = 0.0;
    b.bench("sim_borg_adaptive_qs_nocache", || {
        rate = events_per_sec(&mut borg_engine_nc, &borg, "adaptive-qs", 7);
        black_box(rate);
    });
    println!(
        "  -> borg/adaptive-qs (no consult cache): {:.2} M events/s",
        rate / 1e6
    );
    measured.push(("sim_borg_adaptive_qs_nocache".to_string(), rate));

    // Raw timing-structure microbenchmark: heap vs ladder on the same
    // synthetic departure churn (no engine, no policy).
    for (name, rate) in [
        ("sched_churn_heap", {
            let mut q = EventQueue::new();
            let mut r = 0.0;
            b.bench("sched_churn_heap", || {
                r = schedule_churn(&mut q);
                black_box(r);
            });
            r
        }),
        ("sched_churn_ladder", {
            let mut q = LadderQueue::new();
            let mut r = 0.0;
            b.bench("sched_churn_ladder", || {
                r = schedule_churn(&mut q);
                black_box(r);
            });
            r
        }),
    ] {
        println!("  -> {name}: {:.2} M pops/s", rate / 1e6);
        measured.push((name.to_string(), rate));
    }

    // Preemptive policy: stresses departure cancel/reschedule.
    let sf_wl = Workload::one_or_all(16, 4.0, 0.9, 1.0, 1.0);
    let sf_cfg = SimConfig {
        target_completions: completions / 2,
        warmup_completions: 0,
        event_schedule: Some(EventScheduleKind::Heap),
        ..Default::default()
    };
    let mut sf_engine = Engine::new(&sf_wl, sf_cfg);
    let mut rate = 0.0;
    b.bench("sim_server_filling", || {
        rate = events_per_sec(&mut sf_engine, &sf_wl, "server-filling", 7);
        black_box(rate);
    });
    println!("  -> server-filling: {:.2} M events/s", rate / 1e6);
    measured.push(("sim_server_filling".to_string(), rate));

    // Analytical calculator throughput (the autotuner's native fallback).
    b.bench("theorem2_calculator_k32", || {
        let a = quickswap::analysis::analyze(&quickswap::analysis::MsfqParams::standard(
            32, 31, 7.5, 0.9,
        ))
        .unwrap();
        black_box(a.et);
    });
    b.finish();

    // CRN variance-reduction factor on the fig2 frontier point: the
    // paired Δ(MSFQ:31 − MSF) CI half-width vs the unpaired quadrature
    // of the marginal CIs, at the same event budget (R = 4). Not a
    // timing — recorded in the trajectory so bench_compare gates the
    // variance reduction alongside throughput.
    let crn_spec = SweepSpec {
        workload: WorkloadSpec::OneOrAll {
            k: 32,
            p1: 0.9,
            mu1: 1.0,
            muk: 1.0,
        },
        lambdas: vec![7.5],
        policies: vec![
            quickswap::policy::PolicyId::Msf,
            quickswap::policy::PolicyId::Msfq(Some(31)),
        ],
        target_completions: completions,
        warmup_completions: completions / 5,
        batch: 1000,
        seed: 20250710,
        replications: 4,
        paired: true,
        baseline: Some(quickswap::policy::PolicyId::Msf),
        trace: None,
    };
    let sweep = run_spec_paired_local(&crn_spec, 1).expect("paired sweep");
    let d = &sweep.diffs[0];
    let paired_hw = d.diff.ci95_half_width();
    let ratio = d.unpaired_ci95 / paired_hw.max(1e-12);
    println!(
        "  -> paired_ci_width_ratio: {ratio:.2}x (paired ±{paired_hw:.4}, unpaired ±{:.4})",
        d.unpaired_ci95
    );
    measured.push(("paired_ci_width_ratio".to_string(), ratio));

    write_json(&measured, completions);
}
