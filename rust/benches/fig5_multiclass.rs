//! Fig 5 bench: 4-class (k=15) weighted E[T] sweep.
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig5_multiclass").with_budget(std::time::Duration::from_millis(1));
    let mut pts = Vec::new();
    b.bench("four_class_sweep", || {
        pts = figures::fig5(Scale::smoke(), &[4.5]);
    });
    let at = |pol: &str| {
        pts.iter()
            .find(|p| p.policy.to_lowercase().replace('-', "").contains(pol))
            .map(|p| p.result.weighted_t)
            .unwrap()
    };
    // Paper shape: both Quickswap generalizations beat MSF (weighted).
    let (adaptive, stat, msf) = (at("adaptiveqs"), at("staticqs"), at("msf"));
    assert!(adaptive < msf, "AdaptiveQS {adaptive} !< MSF {msf}");
    assert!(stat < msf, "StaticQS {stat} !< MSF {msf}");
    println!("fig5 OK @λ=4.5: AdaptiveQS={adaptive:.1} StaticQS={stat:.1} MSF={msf:.1}");
    b.finish();
}
