//! Fig 3 bench: one-or-all λ sweep across all policies + analysis overlay.
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig3_one_or_all").with_budget(std::time::Duration::from_millis(1));
    let mut pts = Vec::new();
    b.bench("lambda_sweep_5_policies", || {
        pts = figures::fig3(Scale::smoke(), &[6.0, 7.25]);
    });
    let at = |pol: &str, l: f64| {
        pts.iter()
            .find(|p| p.policy.to_lowercase().starts_with(pol) && p.lambda == l)
            .map(|p| p.result.mean_t_all)
            .unwrap()
    };
    // Paper shape at high load: MSFQ ≪ MSF and MSFQ ≪ FCFS.
    let (msfq, msf, fcfs) = (at("msfq", 7.25), at("msf", 7.25), at("fcfs", 7.25));
    assert!(msfq < msf / 2.0, "MSFQ {msfq} !< MSF {msf}/2");
    assert!(msfq < fcfs, "MSFQ {msfq} !< FCFS {fcfs}");
    println!("fig3 OK @λ=7.25: MSFQ={msfq:.1} MSF={msf:.1} FCFS={fcfs:.1}");
    b.finish();
}
