//! Fig 1 bench: occupancy time-series, MSF vs MSFQ (k=32, λ=7.5).
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig1_timeseries").with_budget(std::time::Duration::from_millis(1));
    let mut out = Vec::new();
    b.bench("msf_vs_msfq_timeseries", || {
        out = figures::fig1(Scale::smoke());
    });
    // Paper shape: MSF accumulates far more jobs than MSFQ.
    assert!(out[0].mean_n > 2.0 * out[1].mean_n, "Fig 1 shape violated");
    println!(
        "fig1 OK: MSF mean #jobs {:.1} vs MSFQ {:.1}",
        out[0].mean_n, out[1].mean_n
    );
    b.finish();
}
