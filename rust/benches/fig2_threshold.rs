//! Fig 2 bench: E[T] vs quickswap threshold ℓ (sim + analysis).
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig2_threshold").with_budget(std::time::Duration::from_millis(1));
    let mut rows = Vec::new();
    b.bench("ell_sweep_lambda7.5", || {
        rows = figures::fig2(Scale::smoke(), 7.5, &[0, 2, 8, 31]);
    });
    // Paper shape: any ℓ ≫ 0 beats MSF (ℓ=0) dramatically at high load.
    let et0 = rows[0].1;
    let et31 = rows.last().unwrap().1;
    assert!(et31 < et0 / 3.0, "ℓ=31 ({et31}) must beat ℓ=0 ({et0})");
    println!("fig2 OK: E[T](ℓ=0) = {et0:.1}, E[T](ℓ=31) = {et31:.1}");
    b.finish();
}
