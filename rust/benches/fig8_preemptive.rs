//! Fig D.8 bench: preemptive ServerFilling vs nonpreemptive policies.
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig8_preemptive").with_budget(std::time::Duration::from_millis(1));
    let mut pts = Vec::new();
    b.bench("borg_with_serverfilling", || {
        pts = figures::fig6(Scale::smoke(), &[4.0], true);
    });
    let at = |pol: &str| {
        pts.iter()
            .find(|p| p.policy.to_lowercase().replace('-', "").contains(pol))
            .map(|p| p.result.weighted_t)
            .unwrap()
    };
    // Paper shape: free preemption beats every nonpreemptive policy.
    let (sf, aq) = (at("serverfilling"), at("adaptiveqs"));
    assert!(sf < aq, "ServerFilling {sf} !< AdaptiveQS {aq}");
    println!("fig8 OK: ServerFilling={sf:.2} AdaptiveQS={aq:.2}");
    b.finish();
}
