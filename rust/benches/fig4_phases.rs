//! Fig 4 bench: phase durations, MSF vs MSFQ.
use quickswap::experiments::{figures, Scale};
use quickswap::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig4_phases").with_budget(std::time::Duration::from_millis(1));
    let mut rows = Vec::new();
    b.bench("phase_durations", || {
        rows = figures::fig4(Scale::smoke(), &[7.25]);
    });
    // Paper shape: MSFQ's phases 1 and 2 are much shorter than MSF's.
    let msf = rows.iter().find(|r| r.policy == "MSF").unwrap();
    let msfq = rows.iter().find(|r| r.policy.starts_with("MSFQ")).unwrap();
    assert!(msfq.mean[1] < msf.mean[1], "H1: {} !< {}", msfq.mean[1], msf.mean[1]);
    assert!(msfq.mean[2] < msf.mean[2], "H2: {} !< {}", msfq.mean[2], msf.mean[2]);
    println!(
        "fig4 OK: E[H1] {:.1}→{:.1}, E[H2] {:.1}→{:.1}",
        msf.mean[1], msfq.mean[1], msf.mean[2], msfq.mean[2]
    );
    b.finish();
}
