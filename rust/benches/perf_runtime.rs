//! Runtime perf: PJRT artifact load/compile and per-solve latency — the
//! autotuner's hot path. Requires `make artifacts`.
use quickswap::runtime::{Runtime, SolverArtifact};
use quickswap::util::bench::{black_box, Bench};

fn main() {
    let rt = match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping perf_runtime (no PJRT): {e}");
            return;
        }
    };
    let mut b = Bench::new("perf_runtime");
    b.bench("compile_solver_k8", || {
        let a = rt.load("msfq_solver_k8").unwrap();
        black_box(&a);
    });
    let solver = SolverArtifact::load(&rt, 8).unwrap();
    for iters in [1_000, 10_000] {
        b.bench(&format!("solve_k8_iters{iters}"), || {
            let m = solver.solve(7, 3.0, 0.3, 1.0, 1.0, iters).unwrap();
            black_box(m.et);
        });
    }
    b.bench("autotune_k8", || {
        let (ell, m) = solver.autotune(3.0, 0.3, 1.0, 1.0, 5_000, false).unwrap();
        black_box((ell, m.et));
    });
    b.finish();
}
