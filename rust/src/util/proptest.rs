//! Property-based testing harness (proptest substitute): random case
//! generation from a seeded RNG, failure reporting with the seed and case
//! index for reproduction, and greedy input shrinking for integer vectors.

use crate::util::rng::Rng;

/// Number of cases per property (override with QS_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("QS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` on `cases` random inputs produced by `gen`.
/// Panics with seed/case diagnostics on the first failure.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let seed = std::env::var("QS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let cases = default_cases();
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Shrinkable u64-vector property: on failure, greedily tries removing
/// elements and halving values to find a smaller failing input.
pub fn check_vec_u64<P>(name: &str, len_range: (usize, usize), max_val: u64, mut prop: P)
where
    P: FnMut(&[u64]) -> Result<(), String>,
{
    let seed = std::env::var("QS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..default_cases() {
        let len = len_range.0 + rng.index(len_range.1 - len_range.0 + 1);
        let input: Vec<u64> = (0..len).map(|_| rng.below(max_val + 1)).collect();
        if let Err(first_msg) = prop(&input) {
            let (shrunk, msg) = shrink(input, first_msg, &mut prop);
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  shrunk input: {shrunk:?}\n  error: {msg}"
            );
        }
    }
}

fn shrink<P>(mut input: Vec<u64>, mut msg: String, prop: &mut P) -> (Vec<u64>, String)
where
    P: FnMut(&[u64]) -> Result<(), String>,
{
    loop {
        let mut improved = false;
        // Try removing each element.
        let mut i = 0;
        while i < input.len() {
            let mut cand = input.clone();
            cand.remove(i);
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                improved = true;
            } else {
                i += 1;
            }
        }
        // Try halving each value.
        for i in 0..input.len() {
            while input[i] > 0 {
                let mut cand = input.clone();
                cand[i] /= 2;
                if let Err(m) = prop(&cand) {
                    input = cand;
                    msg = m;
                    improved = true;
                } else {
                    break;
                }
            }
        }
        if !improved {
            return (input, msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum_commutes",
            |r| (r.below(100), r.below(100)),
            |(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input")]
    fn failing_property_shrinks() {
        check_vec_u64("no_big_values", (0, 20), 1000, |v| {
            if v.iter().any(|&x| x > 500) {
                Err(format!("found {v:?}"))
            } else {
                Ok(())
            }
        });
    }
}
