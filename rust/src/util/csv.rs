//! CSV writing/reading for experiment outputs and trace files.
//! Quoting is supported on read; experiment writers only emit
//! numeric/simple-identifier cells so writes stay unquoted.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Incremental CSV writer with a fixed header.
pub struct CsvWriter<W: Write> {
    w: W,
    cols: usize,
}

impl CsvWriter<BufWriter<File>> {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = BufWriter::new(File::create(path)?);
        Self::new(f, header)
    }
}

impl<W: Write> CsvWriter<W> {
    pub fn new(mut w: W, header: &[&str]) -> std::io::Result<Self> {
        writeln!(w, "{}", header.join(","))?;
        Ok(Self {
            w,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.cols, "csv row width mismatch");
        writeln!(self.w, "{}", cells.join(","))
    }

    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        let cells: Vec<String> = cells.iter().map(|x| format_g(*x)).collect();
        self.row(&cells)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }

    /// Hand back the underlying writer (e.g. to `commit()` an
    /// [`AtomicFile`](crate::sweep::faultline::AtomicFile)).
    pub fn into_inner(self) -> W {
        self.w
    }
}

/// Compact float formatting (up to 9 significant digits, no trailing zeros).
pub fn format_g(x: f64) -> String {
    if x.is_nan() {
        return "nan".into();
    }
    if x == x.trunc() && x.abs() < 1e15 {
        return format!("{}", x as i64);
    }
    let s = format!("{x:.9}");
    let s = s.trim_end_matches('0').trim_end_matches('.');
    s.to_string()
}

/// Parse a CSV file into (header, rows of string cells).
pub fn read_csv(path: impl AsRef<Path>) -> std::io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let f = BufReader::new(File::open(path)?);
    let mut lines = f.lines();
    let header = match lines.next() {
        Some(h) => split_line(&h?),
        None => return Ok((vec![], vec![])),
    };
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        rows.push(split_line(&line));
    }
    Ok((header, rows))
}

/// Split a CSV line, honoring double-quoted cells with `""` escapes.
pub fn split_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut quoted = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if quoted => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    quoted = false;
                }
            }
            '"' if cur.is_empty() => quoted = true,
            ',' if !quoted => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_and_read_roundtrip() {
        let dir = std::env::temp_dir().join(format!("qs_csv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row_f64(&[1.0, 2.5]).unwrap();
            w.row(&["x".into(), "y".into()]).unwrap();
            w.flush().unwrap();
        }
        let (h, rows) = read_csv(&path).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows, vec![vec!["1", "2.5"], vec!["x", "y"]]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quoted_cells() {
        assert_eq!(
            split_line(r#"a,"b,c","d""e""#),
            vec!["a", "b,c", r#"d"e"#]
        );
    }

    #[test]
    fn format_g_compact() {
        assert_eq!(format_g(3.0), "3");
        assert_eq!(format_g(0.25), "0.25");
        assert_eq!(format_g(f64::NAN), "nan");
    }
}
