//! Tiny declarative CLI parser (clap substitute): subcommands, `--flag`,
//! `--key value` / `--key=value` options with typed accessors and
//! generated `--help` text.

use std::collections::BTreeMap;

/// Declared option for help text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
}

/// Parsed arguments for one (sub)command invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Missing(&'static str),
    Invalid(&'static str, String),
    Usage(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(name) => write!(f, "missing required option --{name}"),
            CliError::Invalid(name, v) => write!(f, "invalid value for --{name}: {v}"),
            CliError::Usage(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse a raw argv tail (after the subcommand name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates options.
                    a.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--") || n.parse::<f64>().is_ok())
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.opts.insert(rest.to_string(), v);
                } else {
                    a.flags.push(rest.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &'static str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name, v.to_string())),
        }
    }

    pub fn u64_or(&self, name: &'static str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name, v.to_string())),
        }
    }

    pub fn usize_or(&self, name: &'static str, default: usize) -> Result<usize, CliError> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn u32_or(&self, name: &'static str, default: u32) -> Result<u32, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name, v.to_string())),
        }
    }

    pub fn required(&self, name: &'static str) -> Result<&str, CliError> {
        self.get(name).ok_or(CliError::Missing(name))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated string list, e.g. `--figs 2,6,8`. Entries are
    /// trimmed and empty segments dropped; `None` when absent.
    pub fn str_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name).map(|v| {
            v.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect()
        })
    }

    /// Comma-separated f64 list, e.g. `--rates 1.0,2.5,7.5`.
    pub fn f64_list(&self, name: &'static str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|_| CliError::Invalid(name, v.to_string()))
                })
                .collect(),
        }
    }
}

/// Render a help screen for a command with subcommands/options.
pub fn render_help(
    program: &str,
    about: &str,
    subcommands: &[(&str, &str)],
    options: &[OptSpec],
) -> String {
    let mut s = format!("{program} — {about}\n\nUSAGE:\n  {program} <command> [options]\n");
    if !subcommands.is_empty() {
        s.push_str("\nCOMMANDS:\n");
        for (name, help) in subcommands {
            s.push_str(&format!("  {name:<14} {help}\n"));
        }
    }
    if !options.is_empty() {
        s.push_str("\nOPTIONS:\n");
        for o in options {
            let d = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<16} {}{}\n", o.name, o.help, d));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn options_flags_positionals() {
        let a = parse(&[
            "run", "--k", "32", "--lambda=7.5", "--verbose", "--out", "x.csv",
        ]);
        assert_eq!(a.positional(), &["run".to_string()]);
        assert_eq!(a.u64_or("k", 0).unwrap(), 32);
        assert_eq!(a.f64_or("lambda", 0.0).unwrap(), 7.5);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--offset", "-3.5"]);
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse(&["--rates", "1,2,3.5"]);
        assert_eq!(a.f64_list("rates", &[]).unwrap(), vec![1.0, 2.0, 3.5]);
        assert_eq!(a.f64_list("other", &[9.0]).unwrap(), vec![9.0]);
        assert_eq!(a.str_or("mode", "sim"), "sim");
    }

    #[test]
    fn string_lists() {
        let a = parse(&["--figs", "2, 6,,8"]);
        assert_eq!(
            a.str_list("figs"),
            Some(vec!["2".to_string(), "6".to_string(), "8".to_string()])
        );
        assert_eq!(a.str_list("absent"), None);
    }

    #[test]
    fn missing_required_errors() {
        let a = parse(&[]);
        assert!(a.required("k").is_err());
        assert!(parse(&["--k", "abc"]).u64_or("k", 1).is_err());
    }
}
