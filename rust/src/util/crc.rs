//! CRC-32 (IEEE 802.3 polynomial, reflected) — used by the sweep
//! journal to detect torn or bit-rotted records. Bitwise, table-free:
//! journal records are short and appended at human cadence, so a
//! 256-entry table would buy nothing measurable.

/// CRC-32/ISO-HDLC of `data` (the common zlib/PNG/Ethernet variant:
/// reflected 0xEDB88320, init and final XOR 0xFFFFFFFF).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_vector() {
        // The standard CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn detects_single_bit_flips() {
        let msg = b"{\"id\":3,\"n\":7,\"op\":\"result\"}";
        let good = crc32(msg);
        let mut bad = msg.to_vec();
        bad[5] ^= 0x01;
        assert_ne!(crc32(&bad), good);
    }
}
