//! Criterion-style benchmark harness (the registry has no criterion).
//!
//! Usage inside a `harness = false` bench target:
//! ```no_run
//! use quickswap::util::bench::Bench;
//! let mut b = Bench::new("fig3_one_or_all");
//! b.bench("msfq_lambda_7.5", || { /* workload */ });
//! b.finish();
//! ```
//! Each benchmark is warmed up, then timed over adaptively-chosen
//! iterations until a wall-time budget is met; reports mean, median, p95
//! and stddev. Results are also appended to `target/bench_results.csv`.

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    budget: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub group: String,
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // QS_BENCH_FAST=1 shrinks budgets for CI runs.
        let fast = std::env::var("QS_BENCH_FAST").ok().as_deref() == Some("1");
        Self {
            group: group.to_string(),
            budget: if fast {
                Duration::from_millis(300)
            } else {
                Duration::from_secs(2)
            },
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Time `f`, printing a criterion-like summary line.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup and estimate per-iteration cost.
        let wstart = Instant::now();
        let mut witers = 0u64;
        while wstart.elapsed() < self.warmup || witers == 0 {
            f();
            witers += 1;
            if witers > 1_000_000 {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_nanos() as f64 / witers as f64;
        // Choose sample batching: aim for ~50 samples within budget.
        let budget_ns = self.budget.as_nanos() as f64;
        let samples = 50usize;
        let iters_per_sample = ((budget_ns / samples as f64 / per_iter.max(1.0)) as u64).max(1);

        let mut times = Vec::with_capacity(samples);
        let start = Instant::now();
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            times.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
            if start.elapsed() > self.budget * 2 {
                break;
            }
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let median = times[n / 2];
        let p95 = times[((n as f64 * 0.95) as usize).min(n - 1)];
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n as f64;
        let result = BenchResult {
            group: self.group.clone(),
            name: name.to_string(),
            iters: iters_per_sample * n as u64,
            mean_ns: mean,
            median_ns: median,
            p95_ns: p95,
            stddev_ns: var.sqrt(),
        };
        println!(
            "{}/{:<40} time: [{} {} {}]  (n={}, sd={})",
            self.group,
            name,
            fmt_ns(median * 0.98),
            fmt_ns(median),
            fmt_ns(p95),
            result.iters,
            fmt_ns(result.stddev_ns),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Append all results to target/bench_results.csv and return them.
    pub fn finish(self) -> Vec<BenchResult> {
        let path = std::path::Path::new("target/bench_results.csv");
        let existed = path.exists();
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
        {
            use std::io::Write;
            if !existed {
                let _ = writeln!(f, "group,name,iters,mean_ns,median_ns,p95_ns,stddev_ns");
            }
            for r in &self.results {
                let _ = writeln!(
                    f,
                    "{},{},{},{:.1},{:.1},{:.1},{:.1}",
                    r.group, r.name, r.iters, r.mean_ns, r.median_ns, r.p95_ns, r.stddev_ns
                );
            }
        }
        self.results
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("QS_BENCH_FAST", "1");
        let mut b = Bench::new("self_test").with_budget(Duration::from_millis(50));
        let r = b
            .bench("sum_1k", || {
                let s: u64 = black_box((0..1000u64).sum());
                black_box(s);
            })
            .clone();
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
    }
}
