//! Minimal JSON: a `Value` tree, a recursive-descent parser, and a
//! serializer. Used by the coordinator wire protocol, the config system,
//! and experiment output. Supports the full JSON grammar except `\u`
//! surrogate pairs outside the BMP (sufficient for this crate's usage).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        if let Value::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// A copy of this object without `key` (non-objects come back
    /// unchanged). The sweep journal uses this to compute a record's
    /// CRC over its canonical serialization minus the `crc` field
    /// itself — sound because `Obj` is a `BTreeMap`, so serialization
    /// is key-sorted and parse → serialize is canonical.
    pub fn without(&self, key: &str) -> Value {
        match self {
            Value::Obj(m) => {
                let mut m = m.clone();
                m.remove(key);
                Value::Obj(m)
            }
            other => other.clone(),
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    /// Non-negative integral number as a usize (index fields in wire
    /// messages and journal records).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u32> for Value {
    fn from(x: u32) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Bit-exact f64 encoding for wire payloads where precision loss is
/// unacceptable (NaN and ±inf included): 16 lowercase hex digits of the
/// IEEE-754 bit pattern, carried as a JSON string. Plain `Value::Num`
/// round-trips finite values exactly too (Rust's shortest-round-trip
/// `Display`), but cannot represent non-finite values at all — the sweep
/// protocol uses this form for every statistic it ships.
pub fn f64_bits(x: f64) -> Value {
    Value::Str(format!("{:016x}", x.to_bits()))
}

/// Inverse of [`f64_bits`]; `None` on anything but a hex-bits string.
pub fn f64_from_bits(v: &Value) -> Option<f64> {
    let s = v.as_str()?;
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub at: usize,
    pub msg: &'static str,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &'static str) -> ParseError {
        ParseError { at: self.i, msg }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or(self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or(self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or(self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    self.i = start + len;
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_f64(),
            Some(-2500.0)
        );
        // Serialize and reparse.
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("1 2").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let s = Value::Str("a\"b\\c\n".into()).to_string();
        assert_eq!(Value::parse(&s).unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn builder_api() {
        let v = Value::obj().set("x", 3u64).set("y", "hi");
        assert_eq!(v.get("x").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("x").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("y").unwrap().as_usize(), None);
        assert_eq!(Value::Num(-1.0).as_usize(), None);
        assert_eq!(Value::Num(2.5).as_usize(), None);
        assert_eq!(v.to_string(), r#"{"x":3,"y":"hi"}"#);
    }

    #[test]
    fn f64_bits_roundtrip_exact() {
        for x in [
            0.0,
            -0.0,
            1.0,
            0.1 + 0.2,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            std::f64::consts::PI,
        ] {
            let v = f64_bits(x);
            // Through the serializer and parser, still bit-exact.
            let v2 = Value::parse(&v.to_string()).unwrap();
            assert_eq!(f64_from_bits(&v2).unwrap().to_bits(), x.to_bits());
        }
        let nan = f64_from_bits(&f64_bits(f64::NAN)).unwrap();
        assert!(nan.is_nan());
        assert!(f64_from_bits(&Value::Str("xyz".into())).is_none());
        assert!(f64_from_bits(&Value::Num(1.0)).is_none());
    }
}
