//! In-tree substrates.
//!
//! The build environment is fully offline and its registry carries only the
//! `xla` crate's transitive closure, so the usual ecosystem crates (rand,
//! serde, clap, criterion, proptest, tokio) are unavailable. Everything a
//! downstream user would expect from those is implemented here with
//! equivalent observable behaviour (documented in DESIGN.md §4).

pub mod bench;
pub mod cli;
pub mod crc;
pub mod csv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
