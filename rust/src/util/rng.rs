//! Deterministic pseudo-random number generation.
//!
//! `Xoshiro256pp` (xoshiro256++ by Blackman & Vigna) is the workhorse
//! generator for the simulator: 256-bit state, sub-nanosecond next(), and
//! `jump()` for constructing independent parallel streams from one seed.
//! `SplitMix64` is used for seeding, per the xoshiro authors' guidance.

/// SplitMix64: used to expand a 64-bit seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the simulator's PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a 64-bit value.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is invalid (fixed point); SplitMix64 cannot emit
        // four zeros in a row, but be defensive anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as an argument to `ln()`.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential with rate `rate` (mean `1/rate`).
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64_open().ln() / rate
    }

    /// Fill `out` with exponential(`rate`) variates — the chunk-fill
    /// twin of [`exp`](Rng::exp): identical per-variate arithmetic and
    /// draw order (so scalar and batched paths are interchangeable
    /// bit-for-bit), but the `-ln(U)/rate` loop stays tight instead of
    /// paying per-call dispatch from the sampling layer.
    #[inline]
    pub fn fill_exp(&mut self, rate: f64, out: &mut [f64]) {
        debug_assert!(rate > 0.0);
        for x in out.iter_mut() {
            *x = -self.f64_open().ln() / rate;
        }
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from a discrete distribution given by `weights`
    /// (need not be normalized).
    pub fn discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= *w;
        }
        weights.len() - 1
    }

    /// The xoshiro256++ jump function: equivalent to 2^128 next() calls.
    /// Used to carve independent streams for parallel replications.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// A new independent stream (jump-ahead clone).
    pub fn split(&mut self) -> Rng {
        let clone = self.clone();
        self.jump();
        clone
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn fill_exp_matches_scalar_stream() {
        let mut a = Rng::new(33);
        let mut b = Rng::new(33);
        let mut buf = [0.0; 64];
        a.fill_exp(2.5, &mut buf);
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(x.to_bits(), b.exp(2.5).to_bits(), "variate {i}");
        }
        // The generators are in the same state afterwards.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn discrete_matches_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[r.discrete(&w)] += 1;
        }
        assert!((counts[2] as f64 / n as f64 - 0.6).abs() < 0.02);
        assert!((counts[1] as f64 / n as f64 - 0.3).abs() < 0.02);
    }

    #[test]
    fn jump_streams_diverge() {
        let mut base = Rng::new(5);
        let mut s1 = base.split();
        let mut s2 = base.split();
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert!(same < 2);
    }
}
