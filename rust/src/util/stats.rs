//! Streaming statistics: Welford accumulators, batch-means confidence
//! intervals, and a fixed-memory streaming histogram for tail metrics.
//!
//! `Welford` and `BatchMeans` serialize to JSON with **bit-exact** f64
//! state ([`crate::util::json::f64_bits`]): remote sweep workers ship
//! their accumulators over the wire, and the driver's merge must be
//! indistinguishable from an in-process merge of the same runs. The
//! sweep journal ([`crate::sweep`]) checkpoints the same wire encoding
//! verbatim, so a resume replayed from disk pools the exact bits a live
//! worker would have delivered.

use crate::util::json::{f64_bits, f64_from_bits, Value};

fn bits_field(v: &Value, key: &str) -> anyhow::Result<f64> {
    v.get(key)
        .and_then(f64_from_bits)
        .ok_or_else(|| anyhow::anyhow!("missing/invalid f64-bits field '{key}'"))
}

fn u64_field(v: &Value, key: &str) -> anyhow::Result<u64> {
    v.get(key)
        .and_then(|x| x.as_u64())
        .ok_or_else(|| anyhow::anyhow!("missing/invalid u64 field '{key}'"))
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Second raw moment E[X²].
    pub fn second_moment(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64 + self.mean * self.mean
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Bit-exact JSON form (counts as numbers, f64 state as hex bits).
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("n", self.n)
            .set("mean", f64_bits(self.mean))
            .set("m2", f64_bits(self.m2))
            .set("min", f64_bits(self.min))
            .set("max", f64_bits(self.max))
    }

    /// Inverse of [`Welford::to_json`] — reconstructs the exact state.
    pub fn from_json(v: &Value) -> anyhow::Result<Welford> {
        Ok(Welford {
            n: u64_field(v, "n")?,
            mean: bits_field(v, "mean")?,
            m2: bits_field(v, "m2")?,
            min: bits_field(v, "min")?,
            max: bits_field(v, "max")?,
        })
    }

    /// Merge another accumulator (parallel replication combine).
    pub fn merge(&mut self, o: &Welford) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64) / n as f64;
        self.mean += d * o.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Batch-means confidence intervals for correlated (steady-state
/// simulation) output: samples are grouped into `batches` consecutive
/// batches, and the batch means are treated as ~i.i.d.
#[derive(Clone, Debug)]
pub struct BatchMeans {
    batch_size: u64,
    current: Welford,
    batch_means: Vec<f64>,
    overall: Welford,
}

impl BatchMeans {
    pub fn new(batch_size: u64) -> Self {
        assert!(batch_size > 0);
        Self {
            batch_size,
            current: Welford::new(),
            batch_means: Vec::new(),
            overall: Welford::new(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.overall.push(x);
        self.current.push(x);
        if self.current.count() == self.batch_size {
            self.batch_means.push(self.current.mean());
            self.current = Welford::new();
        }
    }

    pub fn mean(&self) -> f64 {
        self.overall.mean()
    }

    pub fn count(&self) -> u64 {
        self.overall.count()
    }

    pub fn num_batches(&self) -> usize {
        self.batch_means.len()
    }

    /// The completed batch means.
    pub fn batch_means(&self) -> &[f64] {
        &self.batch_means
    }

    /// Zero all accumulators, retaining the batch-means allocation
    /// (engine reuse across replications).
    pub fn reset(&mut self) {
        self.current = Welford::new();
        self.batch_means.clear();
        self.overall = Welford::new();
    }

    /// Pool another run's batch means into this one (independent
    /// replications ⇒ batch means stay ~i.i.d., so the pooled CI simply
    /// has more batches). The other run's partial batch contributes to
    /// the overall mean but not to the CI. With aligned batch boundaries
    /// (sample counts that are multiples of the batch size) merging
    /// splits of one stream reproduces the single-stream result exactly.
    pub fn merge(&mut self, o: &BatchMeans) {
        debug_assert_eq!(self.batch_size, o.batch_size, "batch sizes differ");
        self.overall.merge(&o.overall);
        self.batch_means.extend_from_slice(&o.batch_means);
    }

    /// Bit-exact JSON form: batch size, the partial current batch, every
    /// completed batch mean, and the overall accumulator. Round-trips
    /// through [`BatchMeans::from_json`] without precision loss, so a
    /// merge of deserialized accumulators is bit-identical to a merge of
    /// the originals.
    pub fn to_json(&self) -> Value {
        let means: Vec<Value> = self.batch_means.iter().map(|&b| f64_bits(b)).collect();
        Value::obj()
            .set("batch_size", self.batch_size)
            .set("current", self.current.to_json())
            .set("means", Value::Arr(means))
            .set("overall", self.overall.to_json())
    }

    /// Inverse of [`BatchMeans::to_json`].
    pub fn from_json(v: &Value) -> anyhow::Result<BatchMeans> {
        let batch_size = u64_field(v, "batch_size")?;
        if batch_size == 0 {
            anyhow::bail!("batch_size must be positive");
        }
        let means = v
            .get("means")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing 'means' array"))?;
        let batch_means = means
            .iter()
            .map(|m| f64_from_bits(m).ok_or_else(|| anyhow::anyhow!("bad batch mean bits")))
            .collect::<anyhow::Result<Vec<f64>>>()?;
        let current = v
            .get("current")
            .ok_or_else(|| anyhow::anyhow!("missing 'current'"))
            .and_then(Welford::from_json)?;
        let overall = v
            .get("overall")
            .ok_or_else(|| anyhow::anyhow!("missing 'overall'"))
            .and_then(Welford::from_json)?;
        Ok(BatchMeans {
            batch_size,
            current,
            batch_means,
            overall,
        })
    }

    /// 95% CI half-width from the batch means (normal approximation,
    /// z=1.96; requires ≥2 completed batches).
    pub fn ci95_half_width(&self) -> f64 {
        let m = self.batch_means.len();
        if m < 2 {
            return f64::NAN;
        }
        let mut w = Welford::new();
        for &b in &self.batch_means {
            w.push(b);
        }
        1.96 * (w.variance() / m as f64).sqrt()
    }
}

/// Paired-difference accumulator for common-random-number (CRN) policy
/// comparisons: each replication runs policy and baseline over the
/// *same* arrival stream, and only the differences enter the estimator.
///
/// Sign convention: every Δ is `policy − baseline`, so **negative means
/// the policy responds faster than the baseline** (response times: lower
/// is better).
///
/// Two levels of pairing feed in per replication via
/// [`PairedDiff::push_rep`]:
///  * per-class replication deltas — Δ of the class mean response times
///    — into one Welford per class (replication-level CI per class);
///  * batch-mean deltas — the two runs' completed batch means zipped to
///    the shorter run and differenced — pooled into one accumulator
///    across replications. Under CRN the batch deltas are strongly
///    positively-correlated pairs, so `Var(Δ)` collapses relative to
///    the unpaired quadrature `Var(A) + Var(B)` and the Δ CI narrows
///    accordingly.
///
/// Serializes bit-exact over the `f64_bits` wire like [`Welford`] /
/// [`BatchMeans`], so a driver-side merge of shipped accumulators is
/// indistinguishable from an in-process merge.
#[derive(Clone, Debug)]
pub struct PairedDiff {
    /// Per-class Welford over replication-level Δ of class means.
    per_class: Vec<Welford>,
    /// Pooled Welford over per-batch Δ of batch means.
    batches: Welford,
    /// Number of replications pushed.
    reps: u64,
}

impl PairedDiff {
    pub fn new(num_classes: usize) -> PairedDiff {
        PairedDiff {
            per_class: (0..num_classes).map(|_| Welford::new()).collect(),
            batches: Welford::new(),
            reps: 0,
        }
    }

    pub fn num_classes(&self) -> usize {
        self.per_class.len()
    }

    pub fn replications(&self) -> u64 {
        self.reps
    }

    /// Absorb one paired replication: per-class mean response times of
    /// the two runs, plus their completed batch-mean sequences (zipped
    /// to the shorter; a trailing unmatched batch has no pair and is
    /// dropped from the Δ estimator).
    pub fn push_rep(
        &mut self,
        policy_class_means: &[f64],
        baseline_class_means: &[f64],
        policy_batches: &[f64],
        baseline_batches: &[f64],
    ) {
        debug_assert_eq!(policy_class_means.len(), self.per_class.len());
        debug_assert_eq!(baseline_class_means.len(), self.per_class.len());
        for (c, w) in self.per_class.iter_mut().enumerate() {
            w.push(policy_class_means[c] - baseline_class_means[c]);
        }
        for (p, b) in policy_batches.iter().zip(baseline_batches.iter()) {
            self.batches.push(p - b);
        }
        self.reps += 1;
    }

    /// Pooled Δ of batch means (policy − baseline).
    pub fn delta_mean(&self) -> f64 {
        self.batches.mean()
    }

    /// 95% CI half-width of the pooled Δ (normal approximation over the
    /// paired batch deltas; NaN until ≥2 paired batches).
    pub fn ci95_half_width(&self) -> f64 {
        let n = self.batches.count();
        if n < 2 {
            return f64::NAN;
        }
        1.96 * (self.batches.variance() / n as f64).sqrt()
    }

    /// Replication-level Δ of class `c`'s mean response time.
    pub fn class_delta_mean(&self, c: usize) -> f64 {
        self.per_class[c].mean()
    }

    /// Number of paired batch deltas pooled so far.
    pub fn paired_batches(&self) -> u64 {
        self.batches.count()
    }

    /// Merge another accumulator (sharded replication combine).
    pub fn merge(&mut self, o: &PairedDiff) {
        debug_assert_eq!(self.per_class.len(), o.per_class.len());
        for (w, ow) in self.per_class.iter_mut().zip(o.per_class.iter()) {
            w.merge(ow);
        }
        self.batches.merge(&o.batches);
        self.reps += o.reps;
    }

    /// Bit-exact JSON form, following the [`Welford`] wire idiom.
    pub fn to_json(&self) -> Value {
        let classes: Vec<Value> = self.per_class.iter().map(|w| w.to_json()).collect();
        Value::obj()
            .set("classes", Value::Arr(classes))
            .set("batches", self.batches.to_json())
            .set("reps", self.reps)
    }

    /// Inverse of [`PairedDiff::to_json`].
    pub fn from_json(v: &Value) -> anyhow::Result<PairedDiff> {
        let classes = v
            .get("classes")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing 'classes' array"))?;
        let per_class = classes
            .iter()
            .map(Welford::from_json)
            .collect::<anyhow::Result<Vec<Welford>>>()?;
        let batches = v
            .get("batches")
            .ok_or_else(|| anyhow::anyhow!("missing 'batches'"))
            .and_then(Welford::from_json)?;
        Ok(PairedDiff {
            per_class,
            batches,
            reps: u64_field(v, "reps")?,
        })
    }
}

/// Fixed-memory log-scale histogram (bins per decade) for response-time
/// tails. Range: [1e-9, 1e9); out-of-range values clamp to edge bins.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    #[allow(dead_code)]
    per_decade: usize,
    total: u64,
}

const LOG_MIN: f64 = -9.0;
const LOG_MAX: f64 = 9.0;

impl LogHistogram {
    pub fn new(per_decade: usize) -> Self {
        let decades = (LOG_MAX - LOG_MIN) as usize;
        Self {
            counts: vec![0; decades * per_decade],
            per_decade,
            total: 0,
        }
    }

    fn bin_of(&self, x: f64) -> usize {
        let lx = if x <= 0.0 { LOG_MIN } else { x.log10() };
        let pos = (lx - LOG_MIN) / (LOG_MAX - LOG_MIN);
        let b = (pos * self.counts.len() as f64) as isize;
        b.clamp(0, self.counts.len() as isize - 1) as usize
    }

    pub fn push(&mut self, x: f64) {
        let b = self.bin_of(x);
        self.counts[b] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (upper edge of the bin containing it).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                let frac = (i + 1) as f64 / self.counts.len() as f64;
                return 10f64.powf(LOG_MIN + frac * (LOG_MAX - LOG_MIN));
            }
        }
        10f64.powf(LOG_MAX)
    }
}

/// Jain's fairness index over per-class mean response times (Eq. C.1).
pub fn jain_index(values: &[f64]) -> f64 {
    let vals: Vec<f64> = values.iter().copied().filter(|v| v.is_finite()).collect();
    if vals.is_empty() {
        return f64::NAN;
    }
    let s: f64 = vals.iter().sum();
    let s2: f64 = vals.iter().map(|v| v * v).sum();
    if s2 == 0.0 {
        return 1.0;
    }
    s * s / (vals.len() as f64 * s2)
}

/// Time-weighted average of a piecewise-constant process (e.g. number of
/// jobs in system): accumulates `value × dt` between updates.
#[derive(Clone, Debug, Default)]
pub struct TimeAverage {
    last_t: f64,
    last_v: f64,
    area: f64,
    start_t: f64,
    started: bool,
}

impl TimeAverage {
    pub fn new() -> Self {
        Default::default()
    }

    /// Record that the process had value `v` starting at time `t`.
    pub fn update(&mut self, t: f64, v: f64) {
        if !self.started {
            self.start_t = t;
            self.started = true;
        } else {
            self.area += self.last_v * (t - self.last_t);
        }
        self.last_t = t;
        self.last_v = v;
    }

    /// Time average up to time `t_end` (process held at its last value).
    pub fn average(&self, t_end: f64) -> f64 {
        if !self.started || t_end <= self.start_t {
            return f64::NAN;
        }
        let area = self.area + self.last_v * (t_end - self.last_t);
        area / (t_end - self.start_t)
    }

    /// Accumulated ∫v dt up to `t_end` (0 if never updated). Used to pool
    /// time averages across replications with different time axes:
    /// pooled average = Σ area / Σ window length.
    pub fn area(&self, t_end: f64) -> f64 {
        if !self.started {
            return 0.0;
        }
        self.area + self.last_v * (t_end - self.last_t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_basic() {
        let mut w = Welford::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert_eq!(w.count(), 4);
        assert!((w.mean() - 2.5).abs() < 1e-12);
        assert!((w.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert!((w.second_moment() - 7.5).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 4.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn batch_means_ci_shrinks() {
        let mut bm = BatchMeans::new(100);
        let mut r = crate::util::rng::Rng::new(3);
        for _ in 0..100_00 {
            bm.push(r.f64());
        }
        assert!(bm.num_batches() >= 90);
        let hw = bm.ci95_half_width();
        assert!(hw > 0.0 && hw < 0.02, "hw={hw}");
        assert!((bm.mean() - 0.5).abs() < 0.02);
    }

    #[test]
    fn batch_means_merge_matches_single_stream() {
        let mut r = crate::util::rng::Rng::new(21);
        let xs: Vec<f64> = (0..3000).map(|_| r.f64()).collect();
        let mut single = BatchMeans::new(100);
        for &x in &xs {
            single.push(x);
        }
        // Split at a batch boundary: merged result must be identical.
        let mut a = BatchMeans::new(100);
        let mut b = BatchMeans::new(100);
        for &x in &xs[..1200] {
            a.push(x);
        }
        for &x in &xs[1200..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), single.count());
        assert_eq!(a.num_batches(), single.num_batches());
        assert!((a.mean() - single.mean()).abs() < 1e-12);
        assert!((a.ci95_half_width() - single.ci95_half_width()).abs() < 1e-12);
    }

    #[test]
    fn welford_json_roundtrip_bit_exact() {
        let mut w = Welford::new();
        for i in 0..57 {
            w.push((i as f64).sin() * 1e-7 + 3.0);
        }
        let wire = w.to_json().to_string();
        let back = Welford::from_json(&Value::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.n, w.n);
        assert_eq!(back.mean.to_bits(), w.mean.to_bits());
        assert_eq!(back.m2.to_bits(), w.m2.to_bits());
        assert_eq!(back.min.to_bits(), w.min.to_bits());
        assert_eq!(back.max.to_bits(), w.max.to_bits());
        // Empty accumulator carries ±inf min/max — must survive too.
        let wire = Value::parse(&Welford::new().to_json().to_string()).unwrap();
        let empty = Welford::from_json(&wire).unwrap();
        assert_eq!(empty.min, f64::INFINITY);
        assert_eq!(empty.max, f64::NEG_INFINITY);
    }

    #[test]
    fn batch_means_json_roundtrip_merges_identically() {
        let mut r = crate::util::rng::Rng::new(5);
        let mut a = BatchMeans::new(50);
        let mut b = BatchMeans::new(50);
        for _ in 0..730 {
            a.push(r.f64());
        }
        for _ in 0..540 {
            b.push(r.f64());
        }
        let b_wire =
            BatchMeans::from_json(&Value::parse(&b.to_json().to_string()).unwrap()).unwrap();
        let mut direct = a.clone();
        direct.merge(&b);
        let mut via_wire = a.clone();
        via_wire.merge(&b_wire);
        assert_eq!(direct.count(), via_wire.count());
        assert_eq!(direct.num_batches(), via_wire.num_batches());
        assert_eq!(direct.mean().to_bits(), via_wire.mean().to_bits());
        assert_eq!(
            direct.ci95_half_width().to_bits(),
            via_wire.ci95_half_width().to_bits()
        );
    }

    #[test]
    fn time_average_area() {
        let mut ta = TimeAverage::new();
        ta.update(1.0, 2.0); // value 2 on [1,3)
        ta.update(3.0, 4.0); // value 4 on [3,5)
        assert!((ta.area(5.0) - 12.0).abs() < 1e-12);
        let empty = TimeAverage::new();
        assert_eq!(empty.area(10.0), 0.0);
    }

    #[test]
    fn jain_uniform_is_one() {
        assert!((jain_index(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        // Fully skewed → 1/n.
        let j = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12);
    }

    #[test]
    fn time_average_piecewise() {
        let mut ta = TimeAverage::new();
        ta.update(0.0, 1.0); // value 1 on [0,2)
        ta.update(2.0, 3.0); // value 3 on [2,4)
        assert!((ta.average(4.0) - 2.0).abs() < 1e-12);
    }

    /// Build a PairedDiff from synthetic replications where the policy
    /// run is the baseline run shifted by `shift` plus small noise — the
    /// CRN-correlated shape the estimator exists for.
    fn synthetic_paired(reps: std::ops::Range<u64>, shift: f64) -> PairedDiff {
        let mut pd = PairedDiff::new(2);
        for rep in reps {
            let mut r = crate::util::rng::Rng::new(1000 + rep);
            let base: Vec<f64> = (0..20).map(|_| 5.0 + r.f64()).collect();
            let pol: Vec<f64> = base.iter().map(|b| b + shift + 0.01 * r.f64()).collect();
            let bm = [base[0], base[1]];
            let pm = [pol[0], pol[1]];
            pd.push_rep(&pm, &bm, &pol, &base);
        }
        pd
    }

    #[test]
    fn paired_diff_sign_convention() {
        // Policy strictly faster (smaller response times): Δ < 0.
        let faster = synthetic_paired(0..8, -1.0);
        assert!(faster.delta_mean() < 0.0);
        assert!(faster.class_delta_mean(0) < 0.0);
        // Policy slower: Δ > 0, and the CI excludes zero.
        let slower = synthetic_paired(0..8, 1.0);
        assert!(slower.delta_mean() > 0.0);
        assert!(slower.delta_mean() - slower.ci95_half_width() > 0.0);
        assert_eq!(slower.replications(), 8);
        // CRN correlation: the paired CI is far narrower than the
        // unpaired quadrature of the two marginals would be (~0.4 here,
        // the spread of the uniform noise on each side).
        assert!(slower.ci95_half_width() < 0.05);
    }

    #[test]
    fn paired_diff_merge_associative_and_matches_sequential() {
        let all = synthetic_paired(0..12, 0.5);
        let (a, b, c) = (
            synthetic_paired(0..4, 0.5),
            synthetic_paired(4..9, 0.5),
            synthetic_paired(9..12, 0.5),
        );
        // (a ⊕ b) ⊕ c vs a ⊕ (b ⊕ c) vs the sequential accumulator.
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        for m in [&left, &right] {
            assert_eq!(m.replications(), all.replications());
            assert_eq!(m.paired_batches(), all.paired_batches());
            assert!((m.delta_mean() - all.delta_mean()).abs() < 1e-12);
            assert!((m.ci95_half_width() - all.ci95_half_width()).abs() < 1e-12);
            for cidx in 0..2 {
                assert!((m.class_delta_mean(cidx) - all.class_delta_mean(cidx)).abs() < 1e-12);
            }
        }
        assert!((left.delta_mean() - right.delta_mean()).abs() < 1e-14);
    }

    #[test]
    fn paired_diff_json_roundtrip_merges_identically() {
        let a = synthetic_paired(0..5, 0.3);
        let b = synthetic_paired(5..9, 0.3);
        let b_wire =
            PairedDiff::from_json(&Value::parse(&b.to_json().to_string()).unwrap()).unwrap();
        let mut direct = a.clone();
        direct.merge(&b);
        let mut via_wire = a.clone();
        via_wire.merge(&b_wire);
        assert_eq!(direct.replications(), via_wire.replications());
        assert_eq!(direct.delta_mean().to_bits(), via_wire.delta_mean().to_bits());
        assert_eq!(
            direct.ci95_half_width().to_bits(),
            via_wire.ci95_half_width().to_bits()
        );
        for c in 0..2 {
            assert_eq!(
                direct.class_delta_mean(c).to_bits(),
                via_wire.class_delta_mean(c).to_bits()
            );
        }
    }

    #[test]
    fn paired_diff_unequal_batch_counts_zip_to_shorter() {
        let mut pd = PairedDiff::new(1);
        pd.push_rep(&[1.0], &[2.0], &[1.0, 1.0, 1.0], &[2.0, 2.0]);
        assert_eq!(pd.paired_batches(), 2);
        assert!((pd.delta_mean() + 1.0).abs() < 1e-12);
        assert!((pd.class_delta_mean(0) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn log_histogram_quantiles() {
        let mut h = LogHistogram::new(32);
        for i in 1..=1000 {
            h.push(i as f64 / 100.0); // 0.01 .. 10
        }
        let med = h.quantile(0.5);
        assert!(med > 3.0 && med < 8.0, "med={med}");
    }
}
