//! # Quickswap — nonpreemptive multiserver-job scheduling
//!
//! A reproduction of *"Improving Nonpreemptive Multiserver Job Scheduling
//! with Quickswap"* (Chen et al., 2025) as a deployable framework:
//!
//! * [`sim`] — discrete-event simulation engine for multiserver-job (MSJ)
//!   systems with per-class response-time statistics.
//! * [`policy`] — the paper's Quickswap policy family (MSFQ, Static
//!   Quickswap, Adaptive Quickswap) and every baseline it is evaluated
//!   against (FCFS, First-Fit, MSF, nMSR, preemptive ServerFilling).
//! * [`analysis`] — the Theorem-2 analytical calculator (transform moments
//!   via second-order Taylor arithmetic) and a native CTMC solver.
//! * [`workload`] — synthetic and Borg-trace-derived workload generators.
//! * [`coordinator`] — a cluster-scheduler daemon with a TCP JSONL API and
//!   an online Quickswap-threshold autotuner.
//! * [`runtime`] — loads the AOT-compiled JAX/Pallas CTMC solver
//!   (`artifacts/*.hlo.txt`) through PJRT and exposes typed wrappers.
//! * [`experiments`] — one harness per paper figure/table.
//! * [`sweep`] — sharded sweep orchestration: a driver serves the
//!   (point, replication) unit grid to worker processes over TCP JSONL,
//!   bit-identical to the in-process runner at equal (seed, R).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod experiments;
pub mod policy;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
