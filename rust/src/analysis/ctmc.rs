//! Native CTMC solver for the one-or-all MSFQ system — a near-exact
//! oracle (up to state-space truncation) used to validate both the
//! simulator and the Theorem-2 calculator, and mirrored by the JAX/Pallas
//! AOT artifact (python/compile/model.py implements the same chain as a
//! dense tensor; this implementation is sparse).
//!
//! State (n₁, n_k, z) with z = 0: serving a heavy job (or idle),
//! z = 1: light-serving (paper phases 2∪3), z = 1+u: drain phase with u
//! lights in service (paper phase 4). See DESIGN.md §2 for the full
//! transition table. Arrivals at the truncation boundary are deferred
//! (no out-edge) so probability is conserved.

use crate::analysis::msfq_calc::MsfqParams;

/// Sparse uniformized MSFQ chain.
pub struct MsfqCtmc {
    pub p: MsfqParams,
    pub n1max: usize,
    pub nkmax: usize,
    nz: usize,
    /// CSR-ish flat edge list: (src-ordered) ranges into `dst`/`w`.
    row: Vec<u32>,
    dst: Vec<u32>,
    w: Vec<f32>,
    /// Self-loop weight per state (1 − q/Λ).
    selfw: Vec<f32>,
}

/// Stationary-distribution summary.
#[derive(Clone, Copy, Debug)]
pub struct CtmcSolution {
    pub en1: f64,
    pub enk: f64,
    /// Per-class mean response times via Little's law.
    pub et1: f64,
    pub etk: f64,
    pub et: f64,
    pub etw: f64,
    /// Time fractions: phase 1 (serving heavy), phases 2∪3, phase 4, idle.
    pub m1: f64,
    pub m23: f64,
    pub m4: f64,
    pub idle: f64,
    /// Probability mass within 2 states of the truncation boundary —
    /// should be ≪ 1 for a trustworthy solution.
    pub boundary_mass: f64,
    pub iters: usize,
    /// Final L1 step-to-step delta.
    pub residual: f64,
}

impl MsfqCtmc {
    pub fn new(p: &MsfqParams, n1max: usize, nkmax: usize) -> MsfqCtmc {
        let ell = p.ell as usize;
        let nz = ell + 2; // z ∈ {0, 1, 2..=ell+1}
        let mut c = MsfqCtmc {
            p: *p,
            n1max,
            nkmax,
            nz,
            row: Vec::new(),
            dst: Vec::new(),
            w: Vec::new(),
            selfw: Vec::new(),
        };
        c.build();
        c
    }

    #[inline]
    fn idx(&self, a: usize, b: usize, z: usize) -> usize {
        (a * (self.nkmax + 1) + b) * self.nz + z
    }

    pub fn num_states(&self) -> usize {
        (self.n1max + 1) * (self.nkmax + 1) * self.nz
    }

    /// Destination when the system must pick what to serve next with
    /// `a` lights, `b` heavies and nothing currently in service.
    fn dispatch(&self, a: usize, b: usize) -> (usize, usize, usize) {
        let ell = self.p.ell as usize;
        if b >= 1 {
            (a, b, 0) // phase 1: serve a heavy
        } else if a > ell {
            (a, 0, 1) // phases 2/3: light service
        } else if a >= 1 {
            (a, 0, 1 + a) // straight into drain with u = a
        } else {
            (0, 0, 0) // idle
        }
    }

    fn build(&mut self) {
        let MsfqParams {
            k,
            ell,
            lam1,
            lamk,
            mu1,
            muk,
        } = self.p;
        let (kf, ell) = (k as f64, ell as usize);
        let uni = lam1 + lamk + (kf * mu1).max(muk); // uniformization Λ
        let n = self.num_states();
        self.row = Vec::with_capacity(n + 1);
        self.selfw = vec![0.0; n];
        self.row.push(0);

        for a in 0..=self.n1max {
            for b in 0..=self.nkmax {
                for z in 0..self.nz {
                    let mut q = 0.0; // total out-rate
                    let push = |this: &mut Self, dest: (usize, usize, usize), rate: f64| {
                        let di = this.idx(dest.0, dest.1, dest.2);
                        this.dst.push(di as u32);
                        this.w.push((rate / uni) as f32);
                    };
                    // Light arrival.
                    if a < self.n1max {
                        let dest = if z == 0 && b == 0 {
                            // Only the idle state (a=0) is valid here.
                            self.dispatch(a + 1, 0)
                        } else {
                            (a + 1, b, z)
                        };
                        push(self, dest, lam1);
                        q += lam1;
                    }
                    // Heavy arrival (phase unchanged).
                    if b < self.nkmax {
                        push(self, (a, b + 1, z), lamk);
                        q += lamk;
                    }
                    match z {
                        0 => {
                            // Heavy completion.
                            if b >= 1 {
                                let dest = if b - 1 >= 1 {
                                    (a, b - 1, 0)
                                } else {
                                    self.dispatch(a, 0)
                                };
                                push(self, dest, muk);
                                q += muk;
                            }
                        }
                        1 => {
                            // Light completion in M/M/k mode.
                            if a >= 1 {
                                let rate = (a.min(k as usize)) as f64 * mu1;
                                let dest = if a - 1 > ell {
                                    (a - 1, b, 1)
                                } else if ell >= 1 {
                                    (a - 1, b, 1 + ell) // trigger: a−1 == ℓ
                                } else {
                                    // ℓ = 0, a−1 = 0: phase over.
                                    self.dispatch(0, b)
                                };
                                push(self, dest, rate);
                                q += rate;
                            }
                        }
                        zz => {
                            // Drain phase with u = zz−1 lights in service.
                            let u = zz - 1;
                            if a >= 1 {
                                let rate = u as f64 * mu1;
                                let dest = if u - 1 >= 1 {
                                    (a - 1, b, zz - 1)
                                } else {
                                    self.dispatch(a - 1, b)
                                };
                                push(self, dest, rate);
                                q += rate;
                            }
                        }
                    }
                    let i = self.idx(a, b, z);
                    self.selfw[i] = (1.0 - q / uni) as f32;
                    self.row.push(self.dst.len() as u32);
                }
            }
        }
    }

    /// Power-iterate the uniformized chain from the empty state.
    pub fn solve(&self, max_iters: usize, tol: f64) -> CtmcSolution {
        let n = self.num_states();
        let mut p = vec![0.0f32; n];
        let mut p2 = vec![0.0f32; n];
        p[self.idx(0, 0, 0)] = 1.0;

        let mut iters = 0;
        let mut residual = f64::INFINITY;
        let check_every = 100;
        let mut prev = p.clone();
        while iters < max_iters {
            for _ in 0..check_every {
                p2.iter_mut().for_each(|x| *x = 0.0);
                for s in 0..n {
                    let ps = p[s];
                    if ps == 0.0 {
                        continue;
                    }
                    p2[s] += ps * self.selfw[s];
                    let (lo, hi) = (self.row[s] as usize, self.row[s + 1] as usize);
                    for e in lo..hi {
                        p2[self.dst[e] as usize] += ps * self.w[e];
                    }
                }
                std::mem::swap(&mut p, &mut p2);
                iters += 1;
            }
            // Renormalize drift from f32 accumulation.
            let total: f64 = p.iter().map(|&x| x as f64).sum();
            let inv = (1.0 / total) as f32;
            p.iter_mut().for_each(|x| *x *= inv);
            residual = p
                .iter()
                .zip(prev.iter())
                .map(|(&a, &b)| (a as f64 - b as f64).abs())
                .sum::<f64>()
                / check_every as f64;
            if residual < tol {
                break;
            }
            prev.copy_from_slice(&p);
        }
        self.summarize(&p, iters, residual)
    }

    fn summarize(&self, p: &[f32], iters: usize, residual: f64) -> CtmcSolution {
        let MsfqParams {
            k,
            lam1,
            lamk,
            mu1,
            muk,
            ..
        } = self.p;
        let kf = k as f64;
        let (mut en1, mut enk) = (0.0f64, 0.0f64);
        let (mut m1, mut m23, mut m4, mut idle) = (0.0f64, 0.0, 0.0, 0.0);
        let (mut blocked1, mut blockedk, mut boundary) = (0.0f64, 0.0, 0.0);
        for a in 0..=self.n1max {
            for b in 0..=self.nkmax {
                for z in 0..self.nz {
                    let pr = p[self.idx(a, b, z)] as f64;
                    if pr == 0.0 {
                        continue;
                    }
                    en1 += a as f64 * pr;
                    enk += b as f64 * pr;
                    match z {
                        0 if b >= 1 => m1 += pr,
                        0 => idle += pr,
                        1 => m23 += pr,
                        _ => m4 += pr,
                    }
                    if a == self.n1max {
                        blocked1 += pr;
                    }
                    if b == self.nkmax {
                        blockedk += pr;
                    }
                    if a + 2 >= self.n1max || b + 2 >= self.nkmax {
                        boundary += pr;
                    }
                }
            }
        }
        // Effective (admitted) arrival rates for Little's law under the
        // deferred-boundary truncation.
        let l1e = lam1 * (1.0 - blocked1);
        let lke = lamk * (1.0 - blockedk);
        let et1 = en1 / l1e;
        let etk = enk / lke;
        let et = (en1 + enk) / (l1e + lke);
        let rho1 = lam1 / mu1;
        let rhok = kf * lamk / muk;
        let etw = (rho1 * et1 + rhok * etk) / (rho1 + rhok);
        CtmcSolution {
            en1,
            enk,
            et1,
            etk,
            et,
            etw,
            m1,
            m23,
            m4,
            idle,
            boundary_mass: boundary,
            iters,
            residual,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve(k: u32, ell: u32, lambda: f64, n1: usize, nk: usize) -> CtmcSolution {
        let p = MsfqParams::standard(k, ell, lambda, 0.9);
        MsfqCtmc::new(&p, n1, nk).solve(200_000, 1e-10)
    }

    #[test]
    fn probability_conserved_and_sane() {
        let s = solve(4, 3, 1.0, 64, 32);
        let total = s.m1 + s.m23 + s.m4 + s.idle;
        assert!((total - 1.0).abs() < 1e-6, "fractions sum to {total}");
        assert!(s.boundary_mass < 1e-4, "truncation too tight: {}", s.boundary_mass);
        assert!(s.et.is_finite() && s.et1 > 0.9, "light E[T] ≈ 1 at low load: {}", s.et1);
    }

    /// ℓ = 0 (MSF) vs ℓ = k−1 (MSFQ): the Quickswap benefit appears at
    /// high load (at low load the drain phases make MSFQ slightly worse —
    /// consistent with Fig 2, which evaluates λ near capacity).
    #[test]
    fn msfq_beats_msf_small_system_high_load() {
        // λ = 2.9 ⇒ ρ ≈ 0.94; the k=4 crossover sits near ρ ≈ 0.88.
        let msf = solve(4, 0, 2.9, 256, 64);
        let msfq = solve(4, 3, 2.9, 256, 64);
        assert!(
            msfq.boundary_mass < 1e-3 && msf.boundary_mass < 0.05,
            "truncation: msfq={} msf={}",
            msfq.boundary_mass,
            msf.boundary_mass
        );
        assert!(msfq.et < msf.et, "msfq={} msf={}", msfq.et, msf.et);
    }

    /// Cross-check against the DES simulator (the two must agree).
    #[test]
    fn matches_simulation() {
        let k = 4u32;
        let lambda = 1.2;
        let sol = solve(k, 3, lambda, 96, 48);
        let wl = crate::workload::Workload::one_or_all(k, lambda, 0.9, 1.0, 1.0);
        let cfg = crate::sim::SimConfig::quick();
        let r = crate::sim::run_policy(&wl, &"msfq:3".parse().unwrap(), &cfg, 42).unwrap();
        let rel = (r.mean_t_all - sol.et).abs() / sol.et;
        assert!(
            rel < 0.05,
            "sim E[T]={} vs ctmc E[T]={} (rel {rel})",
            r.mean_t_all,
            sol.et
        );
    }
}
