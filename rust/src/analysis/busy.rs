//! M/G/1 busy-period moments (Remark 3) and the EFS system (Remark 2).

use crate::analysis::taylor::T2;

/// First two moments of the busy period of an M/G/1 queue started by a
/// single job, given arrival rate `lam` and job-size moments (es1, es2):
/// E[B] = E[S]/(1−ρ);  E[B²] = E[S²]/(1−ρ)³.
pub fn busy_period_moments(lam: f64, es1: f64, es2: f64) -> (f64, f64) {
    let rho = lam * es1;
    assert!(rho < 1.0, "busy period requires rho < 1 (rho = {rho})");
    let m1 = es1 / (1.0 - rho);
    let m2 = es2 / (1.0 - rho).powi(3);
    (m1, m2)
}

/// Busy-period LST (as a `T2` around s = 0) for exponential sizes Exp(mu).
pub fn busy_period_t2_exp(lam: f64, mu: f64) -> T2 {
    let (m1, m2) = busy_period_moments(lam, 1.0 / mu, 2.0 / (mu * mu));
    T2::from_moments(m1, m2)
}

/// M/G/1 with Exceptional First Service (Remark 2, from Bose 2002).
/// `s` = (E[S], E[S²]) for ordinary jobs, `sp` = (E[S'], E[S'²]) for the
/// job opening each busy period.
pub struct Efs {
    pub lam: f64,
    pub es: (f64, f64),
    pub esp: (f64, f64),
}

impl Efs {
    /// Mean work in system, E[W^{EFS}].
    pub fn mean_work(&self) -> f64 {
        let (es1, es2) = self.es;
        let (ep1, ep2) = self.esp;
        let lam = self.lam;
        let rho = lam * es1;
        assert!(rho < 1.0, "EFS requires lam*E[S] < 1");
        lam * es2 / (2.0 * (1.0 - rho)) + lam * (ep2 - es2) / (2.0 * (1.0 - rho + lam * ep1))
    }

    /// Probability an arrival opens a busy period (gets exceptional svc).
    pub fn p_exceptional(&self) -> f64 {
        let rho = self.lam * self.es.0;
        (1.0 - rho) / (1.0 - rho + self.lam * self.esp.0)
    }

    /// Mean work seen by a *non-exceptional* arrival:
    /// E[W | no exceptional service] = E[W]/(1 − p^{EFS}) in the paper's
    /// Lemma-2 usage.
    pub fn mean_work_non_exceptional(&self) -> f64 {
        self.mean_work() / (1.0 - self.p_exceptional())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_mm1_closed_form() {
        let (m1, m2) = busy_period_moments(0.5, 1.0, 2.0);
        assert!((m1 - 2.0).abs() < 1e-12);
        assert!((m2 - 16.0).abs() < 1e-12);
    }

    /// With S' ≡ S the EFS system is a plain M/G/1: E[W] must equal the
    /// Pollaczek–Khinchine mean workload λE[S²]/(2(1−ρ)).
    #[test]
    fn efs_degenerates_to_pk() {
        let lam = 0.7;
        let es = (1.0, 2.0);
        let efs = Efs {
            lam,
            es,
            esp: es,
        };
        let pk = lam * es.1 / (2.0 * (1.0 - lam * es.0));
        assert!((efs.mean_work() - pk).abs() < 1e-12);
        // p^EFS = P(empty on arrival) = 1 − ρ for M/M/1-like setting.
        assert!((efs.p_exceptional() - (1.0 - 0.7)).abs() < 1e-12);
    }

    /// Larger exceptional first service increases mean work.
    #[test]
    fn efs_monotone_in_exceptional_size() {
        let base = Efs {
            lam: 0.5,
            es: (1.0, 2.0),
            esp: (1.0, 2.0),
        };
        let bigger = Efs {
            lam: 0.5,
            es: (1.0, 2.0),
            esp: (3.0, 18.0),
        };
        assert!(bigger.mean_work() > base.mean_work());
        assert!(bigger.p_exceptional() < base.p_exceptional());
    }
}
