//! The MSFQ mean-response-time calculator — Theorem 2, assembled from
//! Lemmas 1–8 of the paper, for the one-or-all system with parameters
//! (k, ℓ, λ₁, λ_k, μ₁, μ_k). Setting ℓ = 0 analyzes MSF itself.
//!
//! Pipeline (§5.3–§5.4):
//!  1. Closed-form busy-period moments (Remark 3) for heavy and light
//!     M/G/1s → `T2` transforms.
//!  2. H₄ (Lemma 8) and H₃ (Lemma 7, continued-fraction recursion) as T2.
//!  3. Fixed point over H₂: N₁ᴴ and N₂ᴸ (Lemma 6) feed H₁ and H₂
//!     (Lemma 5), which feed back into the N's. Iterated to convergence.
//!  4. Conditional response times: Lemma 2 (EFS coupling), Lemma 3
//!     (age/excess of phase unions), Lemma 4 (Cⱼ visit counts).
//!  5. E[T] via Lemma 1's time fractions and Eq. (1).

use crate::analysis::busy::{busy_period_t2_exp, Efs};
use crate::analysis::taylor::T2;

/// Parameters of the one-or-all MSFQ system.
#[derive(Clone, Copy, Debug)]
pub struct MsfqParams {
    pub k: u32,
    pub ell: u32,
    pub lam1: f64,
    pub lamk: f64,
    pub mu1: f64,
    pub muk: f64,
}

impl MsfqParams {
    /// The paper's standard configuration: total rate λ, light fraction
    /// p1, unit service rates.
    pub fn standard(k: u32, ell: u32, lambda: f64, p1: f64) -> MsfqParams {
        MsfqParams {
            k,
            ell,
            lam1: lambda * p1,
            lamk: lambda * (1.0 - p1),
            mu1: 1.0,
            muk: 1.0,
        }
    }

    /// Normalized system load ρ = λ₁/(kμ₁) + λ_k/μ_k (Theorem 3/4).
    pub fn load(&self) -> f64 {
        self.lam1 / (self.k as f64 * self.mu1) + self.lamk / self.muk
    }
}

/// Calculator output: everything the figures need.
#[derive(Clone, Copy, Debug)]
pub struct MsfqAnalysis {
    /// Overall mean response time E[T] (Eq. 1).
    pub et: f64,
    /// Per-class means.
    pub et_light: f64,
    pub et_heavy: f64,
    /// Load-weighted mean response time (§6.1).
    pub etw: f64,
    /// Mean phase durations E[H₁..H₄] (index 1..=4).
    pub eh: [f64; 5],
    /// Second moments E[H_i²].
    pub eh2: [f64; 5],
    /// Time fraction per phase m₁..m₄ (Lemma 1).
    pub m: [f64; 5],
    /// E[N₁ᴴ], E[(N₁ᴴ)²]: heavies at the start of phase 1.
    pub en1h: (f64, f64),
    /// E[N₂ᴸ], E[(N₂ᴸ)²]: lights at the start of phase 2.
    pub en2l: (f64, f64),
    /// Conditional response times (diagnostics).
    pub t1h: f64,
    pub t234h: f64,
    pub t14l: f64,
    pub t2l: f64,
    pub t3l: f64,
}

#[derive(Debug)]
pub enum CalcError {
    Unstable(f64),
    Invalid(String),
    NoConvergence(usize),
}

impl std::fmt::Display for CalcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CalcError::Unstable(rho) => {
                write!(f, "system unstable: rho = {rho:.4} >= 1 (Theorem 4)")
            }
            CalcError::Invalid(msg) => write!(f, "invalid parameters: {msg}"),
            CalcError::NoConvergence(iters) => {
                write!(f, "fixed point did not converge after {iters} iterations")
            }
        }
    }
}

impl std::error::Error for CalcError {}

/// Compute the Theorem-2 approximation of MSFQ mean response time.
pub fn analyze(p: &MsfqParams) -> Result<MsfqAnalysis, CalcError> {
    let MsfqParams {
        k,
        ell,
        lam1,
        lamk,
        mu1,
        muk,
    } = *p;
    let kf = k as f64;
    if k < 2 || ell >= k {
        return Err(CalcError::Invalid(format!("need k ≥ 2, 0 ≤ ell < k (k={k}, ell={ell})")));
    }
    if lam1 <= 0.0 || lamk <= 0.0 || mu1 <= 0.0 || muk <= 0.0 {
        return Err(CalcError::Invalid(
            "rates must be positive (one-or-all analysis)".into(),
        ));
    }
    let rho = p.load();
    if rho >= 1.0 {
        return Err(CalcError::Unstable(rho));
    }

    // --- Busy periods (Remark 3) ---------------------------------------
    // Heavy: M/M/1 with arrival λk, service Exp(μk).
    let bh = busy_period_t2_exp(lamk, muk);
    // Light: "k-speed" M/M/1 with arrival λ1, service Exp(kμ1) — the
    // phase-2 dynamics of the lights (all k servers busy).
    let bl = busy_period_t2_exp(lam1, kf * mu1);

    // --- H4 (Lemma 8): sum of Exp(jμ1), j = 1..ℓ ------------------------
    let mut h4_m1 = 0.0;
    let mut h4_var = 0.0;
    for j in 1..=ell {
        let r = j as f64 * mu1;
        h4_m1 += 1.0 / r;
        h4_var += 1.0 / (r * r);
    }
    let h4 = T2::from_moments(h4_m1, h4_var + h4_m1 * h4_m1);

    // --- H3 (Lemma 7): transit k−1 → ℓ continued fraction ---------------
    // H̃3,k = B̃ᴸ; H̃3,j = jμ1 / (λ1 + jμ1 + s − λ1·H̃3,j+1).
    let mut h3 = T2::ONE;
    if ell + 1 <= k - 1 {
        let mut t_next = bl; // H̃_{3,k}
        for j in (ell + 1..k).rev() {
            let jf = j as f64;
            let denom = T2::new(lam1 + jf * mu1, 1.0, 0.0).sub(t_next.scale(lam1));
            let t_j = T2::cst(jf * mu1).div(denom);
            h3 = h3.mul(t_j);
            t_next = t_j;
        }
    }

    // --- Fixed point over H2 (Lemmas 5 & 6) ----------------------------
    // Series-variable conventions: LSTs in s; z-transforms in x = z−1.
    let lin = |a: f64| T2::new(0.0, a, 0.0); // the map s/x ↦ a·x
    let beta = bh.compose0(lin(-lam1)); // β(z) = B̃ᴴ(λ1(1−z)), in x
    let arg_a = (T2::ONE.sub(beta)).scale(lamk); // λk(1−β(z)), in x
    let arg_b = arg_a.add(lin(-lam1)); // λk(1−β(z)) + λ1(1−z), in x

    let h3_a = h3.compose0(arg_a);
    let h4_b = h4.compose0(arg_b);
    let h3_sk = h3.compose0(lin(-lamk)); // H̃3(λk(1−z))
    let h4_sk = h4.compose0(lin(-lamk));
    let bl_m1 = bl.sub(T2::ONE); // B̃ᴸ(s) − 1 (inner for compositions)
    let bl_pow = bl.powf(1.0 - kf); // (B̃ᴸ)^{1−k}

    let mut h2 = T2::ONE;
    let mut converged = false;
    const MAX_ITERS: usize = 20_000;
    // §5.2's approximation assumes ≥ k lights at the start of phase 2;
    // when the light load is very low, N₂ᴸ − k + 1 goes negative and the
    // raw transforms leave the valid moment cone. Project back
    // (E[H₂] ≥ 0, E[H₂²] ≥ E[H₂]²) so the calculator always returns a
    // sane — if approximate — answer in that regime.
    let sanitize = |t: T2| -> T2 {
        let m1 = t.mean().max(0.0);
        let m2 = t.second().max(m1 * m1);
        T2::from_moments(m1, m2)
    };
    for _ in 0..MAX_ITERS {
        // N̂2L(z) = H̃2(λk(1−β)) H̃3(λk(1−β)) H̃4(λk(1−β)+λ1(1−z)).
        let n2l = h2.compose0(arg_a).mul(h3_a).mul(h4_b);
        // H̃2(s) = N̂2L(B̃ᴸ(s)) · B̃ᴸ(s)^{1−k}  (Lemma 5).
        let h2_new = sanitize(n2l.compose0(bl_m1).mul(bl_pow));
        let delta = (h2_new.c1 - h2.c1).abs() + (h2_new.c2 - h2.c2).abs();
        h2 = h2_new;
        if delta < 1e-13 * (1.0 + h2.c1.abs() + h2.c2.abs()) {
            converged = true;
            break;
        }
        if !h2.c1.is_finite() || !h2.c2.is_finite() {
            return Err(CalcError::NoConvergence(MAX_ITERS));
        }
    }
    if !converged {
        return Err(CalcError::NoConvergence(MAX_ITERS));
    }

    // N̂1H(z) = H̃2 H̃3 H̃4 all at λk(1−z)  (Lemma 6).
    let n1h = h2.compose0(lin(-lamk)).mul(h3_sk).mul(h4_sk);
    // H̃1(s) = N̂1H(B̃ᴴ(s))  (Lemma 5).
    let h1 = sanitize(n1h.compose0(bh.sub(T2::ONE)));
    let n2l = h2.compose0(arg_a).mul(h3_a).mul(h4_b);

    let eh = [
        f64::NAN,
        h1.mean(),
        h2.mean(),
        h3.mean(),
        h4.mean(),
    ];
    let eh2 = [
        f64::NAN,
        h1.second(),
        h2.second(),
        h3.second(),
        h4.second(),
    ];
    let en1h = (n1h.zt_mean(), n1h.zt_second());
    let en2l = (n2l.zt_mean(), n2l.zt_second());

    // --- Lemma 1: time fractions ---------------------------------------
    let cycle: f64 = eh[1] + eh[2] + eh[3] + eh[4];
    let m = [
        f64::NAN,
        eh[1] / cycle,
        eh[2] / cycle,
        eh[3] / cycle,
        eh[4] / cycle,
    ];

    // --- Lemma 2: EFS couplings ----------------------------------------
    // Heavy arrivals in phase 1.
    let es_h = (1.0 / muk, 2.0 / (muk * muk));
    let sp1 = en1h.0 / muk;
    let sp2 = (en1h.1 + en1h.0) / (muk * muk);
    let efs_h = Efs {
        lam: lamk,
        es: es_h,
        esp: (sp1, sp2),
    };
    let t1h = efs_h.mean_work_non_exceptional() + 1.0 / muk;

    // Light arrivals in phase 2: effective single server of speed k.
    let es_l = (1.0 / (kf * mu1), 2.0 / (kf * mu1).powi(2));
    // Σ(N2L − k + 1, S1/k): the paper's moment formulas; clamp the count
    // at 0 for low loads where E[N2L] < k−1 (approximation regime).
    let cnt1 = (en2l.0 - kf + 1.0).max(0.0);
    let cnt2 = (en2l.1 - (2.0 * kf - 3.0) * en2l.0 + kf * kf - 3.0 * kf + 2.0).max(cnt1 * cnt1);
    let spl = (
        cnt1 / (kf * mu1),
        cnt2 / (kf * mu1).powi(2),
    );
    let efs_l = Efs {
        lam: lam1,
        es: es_l,
        esp: spl,
    };
    let t2l = efs_l.mean_work_non_exceptional() + 1.0 / mu1;

    // --- Lemma 3: age/excess over phase unions -------------------------
    // E[(H2+H3+H4)²] with H2 ⊥ H3 ⊥ H4 (H3, H4 start from fixed states).
    let e234 = eh[2] + eh[3] + eh[4];
    let e234_sq = eh2[2]
        + eh2[3]
        + eh2[4]
        + 2.0 * (eh[2] * eh[3] + eh[2] * eh[4] + eh[3] * eh[4]);
    let t234h = (lamk / muk + 1.0) * e234_sq / (2.0 * e234) + 1.0 / muk;

    // E[(H4+H1)²]: H1 is a busy period started by the heavies that arrive
    // during phases 2–4, so H4 and H1 are positively correlated:
    // E[H4·H1] = E[Bᴴ]·λk·(E[H4](E[H2]+E[H3]) + E[H4²]).
    let e41 = eh[4] + eh[1];
    let cov_h4h1 = bh.mean() * lamk * (eh[4] * (eh[2] + eh[3]) + eh2[4]);
    let e41_sq = eh2[4] + eh2[1] + 2.0 * cov_h4h1;
    let t14l = (lam1 / (kf * mu1) + 1.0) * e41_sq / (2.0 * e41) + 1.0 / mu1;

    // --- Lemma 4: lights arriving during phase 3 ------------------------
    let t3l = lemma4_t3(k, ell, lam1, mu1);

    // --- Eq. (1): assemble ----------------------------------------------
    // A phase with zero duration contributes nothing even if its
    // conditional response time is degenerate (e.g. the clamped
    // low-light-load regime makes E[T₂ᴸ] → ∞ while m₂ = 0).
    let wt = |m: f64, t: f64| if m > 0.0 { m * t } else { 0.0 };
    let lam = lam1 + lamk;
    let (p1f, pkf) = (lam1 / lam, lamk / lam);
    let et_heavy = wt(m[1], t1h) + wt(m[2] + m[3] + m[4], t234h);
    let et_light = wt(m[1] + m[4], t14l) + wt(m[2], t2l) + wt(m[3], t3l);
    let et = pkf * et_heavy + p1f * et_light;
    let rho1 = lam1 / mu1;
    let rhok = kf * lamk / muk;
    let etw = (rho1 * et_light + rhok * et_heavy) / (rho1 + rhok);

    Ok(MsfqAnalysis {
        et,
        et_light,
        et_heavy,
        etw,
        eh,
        eh2,
        m,
        en1h,
        en2l,
        t1h,
        t234h,
        t14l,
        t2l,
        t3l,
    })
}

/// Lemma 4: E[T₃ᴸ] via the Cⱼ visit-count recursion of the absorbing
/// M/M/k on light jobs during phase 3 (from k−1 down to ℓ).
fn lemma4_t3(k: u32, ell: u32, lam1: f64, mu1: f64) -> f64 {
    let kf = k as f64;
    if ell + 1 >= k {
        return 0.0; // phase 3 has zero length when ℓ = k−1
    }
    let resp = |j: f64| (kf + (j - kf + 1.0).max(0.0)) / (kf * mu1);
    let mut num = 0.0;
    let mut den = 0.0;
    // C_{ℓ+1}: the indicator 1{ℓ+1 ≤ k−1} holds here by the guard above.
    let l1 = (ell + 1) as f64;
    let mut c_prev = (lam1 + l1 * mu1) / (l1 * mu1);
    let w = c_prev / (lam1 + l1.min(kf) * mu1);
    num += w * resp(l1);
    den += w;
    // ℓ+1 < j ≤ k.
    for j in (ell + 2)..=k {
        let jf = j as f64;
        let ind = if j <= k - 1 { 1.0 } else { 0.0 };
        let c = c_prev * lam1 * (lam1 + jf * mu1) / (jf * mu1 * (lam1 + (jf - 1.0) * mu1))
            + (lam1 + jf * mu1) / (jf * mu1) * ind;
        let w = c / (lam1 + jf.min(kf) * mu1);
        num += w * resp(jf);
        den += w;
        c_prev = c;
    }
    // Geometric tail j > k: C_j = (λ1/(kμ1))·C_{j−1}.
    let r = lam1 / (kf * mu1);
    debug_assert!(r < 1.0);
    let mut c = c_prev;
    let mut j = kf;
    for _ in 0..1_000_000 {
        j += 1.0;
        c *= r;
        let w = c / (lam1 + kf * mu1);
        let dn = w * resp(j);
        num += dn;
        den += w;
        if dn < 1e-15 * num {
            break;
        }
    }
    num / den
}

/// Sweep all thresholds and return (best ℓ, its E[T]) by the calculator —
/// the native autotuner (mirrors the AOT sweep artifact).
pub fn best_threshold(
    k: u32,
    lam1: f64,
    lamk: f64,
    mu1: f64,
    muk: f64,
    weighted: bool,
) -> Option<(u32, f64)> {
    let mut best: Option<(u32, f64)> = None;
    for ell in 0..k {
        let p = MsfqParams {
            k,
            ell,
            lam1,
            lamk,
            mu1,
            muk,
        };
        if let Ok(a) = analyze(&p) {
            let v = if weighted { a.etw } else { a.et };
            if v.is_finite() && best.map(|(_, b)| v < b).unwrap_or(true) {
                best = Some((ell, v));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(ell: u32, lambda: f64) -> MsfqParams {
        MsfqParams::standard(32, ell, lambda, 0.9)
    }

    #[test]
    fn rejects_unstable() {
        // k=32, p1=0.9: load = λ(0.9/32 + 0.1) → λ* ≈ 7.804.
        assert!(matches!(
            analyze(&params(31, 8.0)),
            Err(CalcError::Unstable(_))
        ));
        assert!(analyze(&params(31, 7.5)).is_ok());
    }

    #[test]
    fn phase_means_sane() {
        let a = analyze(&params(31, 7.5)).unwrap();
        for i in 1..=4 {
            assert!(a.eh[i] >= 0.0, "E[H{i}] = {}", a.eh[i]);
            assert!(a.eh2[i] >= a.eh[i] * a.eh[i] - 1e-9, "Var[H{i}] < 0");
        }
        // ℓ = 31 ⇒ phase 3 is empty.
        assert!(a.eh[3].abs() < 1e-12);
        let msum: f64 = (1..=4).map(|i| a.m[i]).sum();
        assert!((msum - 1.0).abs() < 1e-9);
        assert!(a.et > 0.0 && a.et.is_finite());
    }

    /// The headline claim: MSFQ(k−1) dramatically beats MSF (= ℓ=0) at
    /// high load.
    #[test]
    fn msfq_beats_msf_at_high_load() {
        let msf = analyze(&params(0, 7.5)).unwrap();
        let msfq = analyze(&params(31, 7.5)).unwrap();
        assert!(
            msfq.et < msf.et / 5.0,
            "MSFQ E[T]={} should be ≪ MSF E[T]={}",
            msfq.et,
            msf.et
        );
    }

    /// H4 mean is the harmonic sum Σ 1/(jμ1).
    #[test]
    fn h4_closed_form() {
        let a = analyze(&params(3, 5.0)).unwrap();
        let expect: f64 = (1..=3).map(|j| 1.0 / j as f64).sum();
        assert!((a.eh[4] - expect).abs() < 1e-9);
    }

    /// Lemma 4 in the M/M/k-free corner: when ℓ = k−1, t3 = 0.
    #[test]
    fn t3_zero_at_max_threshold() {
        assert_eq!(lemma4_t3(32, 31, 6.75, 1.0), 0.0);
        // And positive otherwise, larger than a bare service time.
        let t3 = lemma4_t3(32, 16, 6.75, 1.0);
        assert!(t3 >= 1.0 / 1.0, "t3={t3}");
    }

    #[test]
    fn best_threshold_prefers_large_ell() {
        let (ell, _) = best_threshold(32, 6.75, 0.75, 1.0, 1.0, false).unwrap();
        assert!(ell > 8, "best ell = {ell} should be far from 0");
    }

    /// Monotone degradation with load for fixed ℓ.
    #[test]
    fn et_monotone_in_lambda() {
        let a1 = analyze(&params(31, 4.0)).unwrap();
        let a2 = analyze(&params(31, 6.0)).unwrap();
        let a3 = analyze(&params(31, 7.5)).unwrap();
        assert!(a1.et < a2.et && a2.et < a3.et);
    }
}
