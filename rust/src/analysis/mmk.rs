//! M/M/k utilities: Erlang-C waiting probability, mean response time,
//! and busy-period moments — building blocks for the MSFQ calculator
//! (the phase-2/3 dynamics are M/M/k on light jobs).

/// Erlang-C: probability an arrival waits in an M/M/k with arrival rate
/// `lam` and per-server rate `mu`. Requires ρ = λ/(kμ) < 1.
pub fn erlang_c(k: u32, lam: f64, mu: f64) -> f64 {
    let a = lam / mu; // offered load
    let k_f = k as f64;
    let rho = a / k_f;
    assert!(rho < 1.0, "Erlang-C needs rho < 1");
    // Compute iteratively to avoid overflow: term_j = a^j/j!.
    let mut term = 1.0; // j = 0
    let mut sum = 1.0;
    for j in 1..k {
        term *= a / j as f64;
        sum += term;
    }
    let term_k = term * a / k_f; // a^k/k!
    let c = term_k / (1.0 - rho);
    c / (sum + c)
}

/// Mean waiting time E[W] in M/M/k.
pub fn mean_wait(k: u32, lam: f64, mu: f64) -> f64 {
    let pw = erlang_c(k, lam, mu);
    pw / (k as f64 * mu - lam)
}

/// Mean response time E[T] = E[W] + 1/μ in M/M/k.
pub fn mean_response_time(k: u32, lam: f64, mu: f64) -> f64 {
    mean_wait(k, lam, mu) + 1.0 / mu
}

/// Mean number in system (Little).
pub fn mean_number(k: u32, lam: f64, mu: f64) -> f64 {
    lam * mean_response_time(k, lam, mu)
}

/// First two moments of the M/M/1 busy period started by one job:
/// E[B] = 1/(μ−λ), E[B²] = 2/(μ(1−ρ)³) · (1/μ) … standard results
/// (e.g. Harchol-Balter 2013): E[B²] = E[S²]/(1−ρ)³ with S ~ Exp(μ).
pub fn mm1_busy_period_moments(lam: f64, mu: f64) -> (f64, f64) {
    let rho = lam / mu;
    assert!(rho < 1.0);
    let m1 = (1.0 / mu) / (1.0 - rho);
    let es2 = 2.0 / (mu * mu);
    let m2 = es2 / (1.0 - rho).powi(3);
    (m1, m2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erlang_c_reduces_to_mm1() {
        // k=1: C = ρ.
        for rho in [0.1, 0.5, 0.9] {
            assert!((erlang_c(1, rho, 1.0) - rho).abs() < 1e-12);
        }
    }

    #[test]
    fn mm1_response_time() {
        // k=1: E[T] = 1/(μ−λ).
        let t = mean_response_time(1, 0.5, 1.0);
        assert!((t - 2.0).abs() < 1e-12);
    }

    #[test]
    fn erlang_c_known_value() {
        // Classic table value: k=4, a=3 (ρ=0.75) → C ≈ 0.509.
        let c = erlang_c(4, 3.0, 1.0);
        assert!((c - 0.5094).abs() < 5e-4, "c={c}");
    }

    #[test]
    fn busy_period_moments() {
        let (m1, m2) = mm1_busy_period_moments(0.5, 1.0);
        assert!((m1 - 2.0).abs() < 1e-12);
        assert!((m2 - 16.0).abs() < 1e-12);
        // Variance must be positive.
        assert!(m2 > m1 * m1);
    }

    #[test]
    fn mmk_monotone_in_load() {
        let t1 = mean_response_time(8, 2.0, 1.0);
        let t2 = mean_response_time(8, 6.0, 1.0);
        let t3 = mean_response_time(8, 7.5, 1.0);
        assert!(t1 < t2 && t2 < t3);
    }
}
