//! Second-order truncated Taylor arithmetic ("dual numbers, order 2").
//!
//! A Laplace–Stieltjes transform H̃(s) of a nonnegative random variable H
//! is represented by its expansion at s = 0:
//!     H̃(s) ≈ c0 + c1·s + c2·s²,  with c0 = 1, c1 = −E[H], c2 = E[H²]/2.
//! A z-transform N̂(z) is represented in x = z − 1:
//!     N̂ ≈ 1 + E[N]·x + E[N(N−1)]/2·x².
//! All the transform manipulations of Lemmas 5–8 (products, quotients,
//! compositions, powers) then reduce to `T2` arithmetic, which yields
//! exact first and second moments without symbolic differentiation.

/// Truncated Taylor series c0 + c1·x + c2·x².
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct T2 {
    pub c0: f64,
    pub c1: f64,
    pub c2: f64,
}

impl T2 {
    pub const ONE: T2 = T2 {
        c0: 1.0,
        c1: 0.0,
        c2: 0.0,
    };

    pub fn new(c0: f64, c1: f64, c2: f64) -> T2 {
        T2 { c0, c1, c2 }
    }

    /// Constant.
    pub fn cst(c: f64) -> T2 {
        T2::new(c, 0.0, 0.0)
    }

    /// The variable x itself.
    pub fn var() -> T2 {
        T2::new(0.0, 1.0, 0.0)
    }

    /// Build the LST Taylor of a variable with given first two moments.
    pub fn from_moments(m1: f64, m2: f64) -> T2 {
        T2::new(1.0, -m1, m2 / 2.0)
    }

    /// Mean of the underlying variable (LST convention).
    pub fn mean(&self) -> f64 {
        -self.c1
    }

    /// Second raw moment (LST convention).
    pub fn second(&self) -> f64 {
        2.0 * self.c2
    }

    /// z-transform convention: E[N] and E[N(N−1)] from expansion in z−1.
    pub fn zt_mean(&self) -> f64 {
        self.c1
    }

    pub fn zt_factorial2(&self) -> f64 {
        2.0 * self.c2
    }

    /// Second raw moment of N for a z-transform: E[N²] = E[N(N−1)] + E[N].
    pub fn zt_second(&self) -> f64 {
        self.zt_factorial2() + self.zt_mean()
    }

    pub fn add(self, o: T2) -> T2 {
        T2::new(self.c0 + o.c0, self.c1 + o.c1, self.c2 + o.c2)
    }

    pub fn sub(self, o: T2) -> T2 {
        T2::new(self.c0 - o.c0, self.c1 - o.c1, self.c2 - o.c2)
    }

    pub fn scale(self, a: f64) -> T2 {
        T2::new(a * self.c0, a * self.c1, a * self.c2)
    }

    pub fn mul(self, o: T2) -> T2 {
        T2::new(
            self.c0 * o.c0,
            self.c0 * o.c1 + self.c1 * o.c0,
            self.c0 * o.c2 + self.c1 * o.c1 + self.c2 * o.c0,
        )
    }

    pub fn div(self, o: T2) -> T2 {
        debug_assert!(o.c0 != 0.0);
        let c0 = self.c0 / o.c0;
        let c1 = (self.c1 - c0 * o.c1) / o.c0;
        let c2 = (self.c2 - c0 * o.c2 - c1 * o.c1) / o.c0;
        T2::new(c0, c1, c2)
    }

    /// Composition self(g(x)) where g(0) = 0 (i.e. g.c0 == 0): the outer
    /// series is re-expanded through the inner one.
    pub fn compose0(self, g: T2) -> T2 {
        debug_assert!(
            g.c0.abs() < 1e-9,
            "compose0 requires inner value 0 at x=0, got {}",
            g.c0
        );
        T2::new(
            self.c0,
            self.c1 * g.c1,
            self.c1 * g.c2 + self.c2 * g.c1 * g.c1,
        )
    }

    /// Natural log of a series with c0 > 0.
    pub fn ln(self) -> T2 {
        debug_assert!(self.c0 > 0.0);
        let l1 = self.c1 / self.c0;
        let l2 = self.c2 / self.c0 - 0.5 * l1 * l1;
        T2::new(self.c0.ln(), l1, l2)
    }

    /// Exponential of a series.
    pub fn exp(self) -> T2 {
        let e = self.c0.exp();
        T2::new(e, e * self.c1, e * (self.c2 + 0.5 * self.c1 * self.c1))
    }

    /// Real power (via exp(p·ln)).
    pub fn powf(self, p: f64) -> T2 {
        self.ln().scale(p).exp()
    }
}

impl std::ops::Add for T2 {
    type Output = T2;
    fn add(self, o: T2) -> T2 {
        T2::add(self, o)
    }
}
impl std::ops::Sub for T2 {
    type Output = T2;
    fn sub(self, o: T2) -> T2 {
        T2::sub(self, o)
    }
}
impl std::ops::Mul for T2 {
    type Output = T2;
    fn mul(self, o: T2) -> T2 {
        T2::mul(self, o)
    }
}
impl std::ops::Div for T2 {
    type Output = T2;
    fn div(self, o: T2) -> T2 {
        T2::div(self, o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-10 * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn moments_roundtrip() {
        let t = T2::from_moments(3.0, 11.0);
        assert!(close(t.mean(), 3.0) && close(t.second(), 11.0));
    }

    /// Product of independent LSTs = LST of the sum: moments must match
    /// E[X+Y] and E[(X+Y)²].
    #[test]
    fn product_is_sum_of_variables() {
        let x = T2::from_moments(2.0, 6.0); // Var=2
        let y = T2::from_moments(1.0, 3.0); // Var=2
        let s = x.mul(y);
        assert!(close(s.mean(), 3.0));
        // E[(X+Y)²] = E[X²]+2E[X]E[Y]+E[Y²] = 6+4+3 = 13.
        assert!(close(s.second(), 13.0));
    }

    /// Exp(μ) LST is μ/(μ+s): build via div and check moments.
    #[test]
    fn exponential_lst_via_div() {
        let mu = 2.0;
        let denom = T2::new(mu, 1.0, 0.0); // μ + s
        let lst = T2::cst(mu).div(denom);
        assert!(close(lst.mean(), 0.5));
        assert!(close(lst.second(), 2.0 / (mu * mu)));
    }

    /// Geometric-sum composition: N̂(B̃(s)) with N ~ const n gives
    /// moments of n·B.
    #[test]
    fn compose_deterministic_count() {
        let n = 4.0;
        let b = T2::from_moments(2.0, 10.0); // Var = 6
        // N̂(z) = z^n → in x = z−1: 1 + n x + n(n−1)/2 x².
        let nz = T2::new(1.0, n, n * (n - 1.0) / 2.0);
        let inner = b.sub(T2::ONE); // B̃(s) − 1, value 0 at s=0
        let h = nz.compose0(inner);
        assert!(close(h.mean(), n * 2.0));
        // E[(ΣB)²] = n·E[B²] + n(n−1)·E[B]² = 4·10 + 12·4 = 88.
        assert!(close(h.second(), 88.0));
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let b = T2::from_moments(1.5, 4.0);
        let p3 = b.powf(3.0);
        let m3 = b.mul(b).mul(b);
        assert!(close(p3.c0, m3.c0) && close(p3.c1, m3.c1) && close(p3.c2, m3.c2));
        // Negative powers invert.
        let inv = b.powf(-1.0).mul(b);
        assert!(close(inv.c0, 1.0) && inv.c1.abs() < 1e-12);
    }

    #[test]
    fn div_is_mul_inverse() {
        let a = T2::new(2.0, 3.0, 4.0);
        let b = T2::new(1.5, -0.5, 0.25);
        let q = a.div(b);
        let back = q.mul(b);
        assert!(close(back.c0, a.c0) && close(back.c1, a.c1) && close(back.c2, a.c2));
    }
}
