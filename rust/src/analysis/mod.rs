//! Analytical results from the paper: Theorem 1/3/4 stability regions,
//! the Theorem-2 mean-response-time calculator (Lemmas 1–8), and a native
//! CTMC solver used as a near-exact oracle for tests and the autotuner.

pub mod busy;
pub mod ctmc;
pub mod mmk;
pub mod msfq_calc;
pub mod taylor;

pub use ctmc::{CtmcSolution, MsfqCtmc};
pub use msfq_calc::{analyze, best_threshold, MsfqAnalysis, MsfqParams};
