//! PJRT artifact runtime: load the AOT-compiled JAX/Pallas solver
//! (`artifacts/*.hlo.txt`) and execute it from Rust — Python is never on
//! the request path.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `execute`.
//!
//! The PJRT path needs the external `xla` crate, which the offline build
//! registry does not always carry, so it is gated behind the `pjrt`
//! cargo feature (see rust/Cargo.toml). Without the feature a stub with
//! the same API compiles in: `Runtime::new` reports "unavailable" and
//! every caller (coordinator autotuner, CLI `--artifact` paths) falls
//! back to the native Theorem-2 calculator / sparse CTMC solver.

pub mod solver;

pub use solver::{SolverArtifact, SolverMetrics};

use std::path::PathBuf;

/// Resolve the artifacts directory: $QS_ARTIFACTS or ./artifacts
/// (searching upward so tests work from any cwd).
fn resolve_default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("QS_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("meta.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{Context, Result};
    use std::path::{Path, PathBuf};

    /// A compiled HLO artifact ready to execute on the PJRT CPU client.
    pub struct Artifact {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    /// Shared PJRT client; creating one per artifact is wasteful.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
    }

    impl Runtime {
        /// `dir` is the artifacts directory (built by `make artifacts`).
        pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                dir: dir.as_ref().to_path_buf(),
            })
        }

        pub fn default_dir() -> PathBuf {
            super::resolve_default_dir()
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn dir(&self) -> &Path {
            &self.dir
        }

        /// Load and compile `<name>.hlo.txt` from the artifacts directory.
        pub fn load(&self, name: &str) -> Result<Artifact> {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            anyhow::ensure!(
                path.exists(),
                "artifact {path:?} not found — run `make artifacts` first"
            );
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            Ok(Artifact {
                name: name.to_string(),
                exe,
            })
        }
    }

    impl Artifact {
        /// Execute with literal inputs; returns the flattened tuple outputs
        /// (aot.py lowers with `return_tuple=True`).
        pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing artifact {}", self.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            out.to_tuple().context("decomposing result tuple")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::Result;
    use std::path::{Path, PathBuf};

    /// Stub artifact (never constructed without the `pjrt` feature).
    pub struct Artifact {
        pub name: String,
    }

    /// Stub runtime: construction always fails so callers take their
    /// native fallback paths.
    pub struct Runtime {
        #[allow(dead_code)]
        dir: PathBuf,
    }

    impl Runtime {
        pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
            let _ = dir.as_ref();
            anyhow::bail!(
                "PJRT runtime unavailable: quickswap was built without the `pjrt` feature \
                 (the native Theorem-2 calculator / CTMC solver remain available)"
            )
        }

        pub fn default_dir() -> PathBuf {
            super::resolve_default_dir()
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn dir(&self) -> &Path {
            &self.dir
        }

        pub fn load(&self, name: &str) -> Result<Artifact> {
            anyhow::bail!("cannot load artifact {name}: built without the `pjrt` feature")
        }
    }
}

pub use imp::{Artifact, Runtime};

#[cfg(test)]
mod tests {
    // Runtime behaviour is exercised by rust/tests/integration_runtime.rs
    // (requires the `pjrt` feature and built artifacts). Here: path
    // resolution only.
    #[test]
    fn default_dir_resolves() {
        let d = super::Runtime::default_dir();
        assert!(d.ends_with("artifacts"), "{d:?}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = super::Runtime::new("artifacts").err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
