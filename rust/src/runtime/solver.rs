//! Typed wrapper for the MSFQ solver/sweep artifacts.
//!
//! Input layout (python/compile/kernels/ref.py):
//!   params f32[8] = [λ1, λk, μ1, μk, ℓ, k, _, _],  iters i32.
//! Output layout (python/compile/model.py METRICS): f32[16].
//!
//! Like [`super::Runtime`], the executing halves are gated on the `pjrt`
//! feature; without it `load`/`solve`/`autotune` return errors and the
//! coordinator falls back to the native calculator.

use super::Runtime;
use anyhow::Result;

#[cfg(feature = "pjrt")]
use super::Artifact;
#[cfg(feature = "pjrt")]
use anyhow::Context;

/// Decoded metric vector from one solver execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolverMetrics {
    pub en1: f64,
    pub enk: f64,
    pub et1: f64,
    pub etk: f64,
    pub et: f64,
    pub etw: f64,
    pub m1: f64,
    pub m23: f64,
    pub m4: f64,
    pub idle: f64,
    pub blocked1: f64,
    pub blockedk: f64,
    pub residual: f64,
    pub mass: f64,
}

impl SolverMetrics {
    pub fn from_vec(v: &[f32]) -> Result<SolverMetrics> {
        anyhow::ensure!(v.len() >= 14, "metric vector too short: {}", v.len());
        Ok(SolverMetrics {
            en1: v[0] as f64,
            enk: v[1] as f64,
            et1: v[2] as f64,
            etk: v[3] as f64,
            et: v[4] as f64,
            etw: v[5] as f64,
            m1: v[6] as f64,
            m23: v[7] as f64,
            m4: v[8] as f64,
            idle: v[9] as f64,
            blocked1: v[10] as f64,
            blockedk: v[11] as f64,
            residual: v[12] as f64,
            mass: v[13] as f64,
        })
    }

    /// Sanity: did the power iteration converge on a conserved chain?
    /// Thresholds are calibrated for threshold *ranking* (the autotuner's
    /// use), not absolute E[T] accuracy.
    pub fn trustworthy(&self) -> bool {
        (self.mass - 1.0).abs() < 2e-2
            && self.residual < 1e-2
            && self.blocked1 < 0.10
            && self.blockedk < 0.10
    }
}

/// A loaded solver artifact bound to a specific `k` and truncation.
#[cfg(feature = "pjrt")]
pub struct SolverArtifact {
    artifact: Artifact,
    pub k: u32,
}

#[cfg(feature = "pjrt")]
impl SolverArtifact {
    /// Load `msfq_solver_k{k}.hlo.txt` from the runtime's directory.
    pub fn load(rt: &Runtime, k: u32) -> Result<SolverArtifact> {
        let artifact = rt.load(&format!("msfq_solver_k{k}"))?;
        Ok(SolverArtifact { artifact, k })
    }

    fn params_literal(&self, ell: u32, lam1: f64, lamk: f64, mu1: f64, muk: f64) -> xla::Literal {
        let params: Vec<f32> = vec![
            lam1 as f32,
            lamk as f32,
            mu1 as f32,
            muk as f32,
            ell as f32,
            self.k as f32,
            0.0,
            0.0,
        ];
        xla::Literal::vec1(&params)
    }

    /// Solve for stationary metrics with `iters` power steps.
    pub fn solve(
        &self,
        ell: u32,
        lam1: f64,
        lamk: f64,
        mu1: f64,
        muk: f64,
        iters: i32,
    ) -> Result<SolverMetrics> {
        anyhow::ensure!(ell < self.k, "ell must be < k");
        let params = self.params_literal(ell, lam1, lamk, mu1, muk);
        let iters = xla::Literal::from(iters);
        let out = self.artifact.execute(&[params, iters])?;
        let v = out[0]
            .to_vec::<f32>()
            .context("reading solver metric vector")?;
        SolverMetrics::from_vec(&v)
    }

    /// Pick the best Quickswap threshold for the given rates by scanning
    /// a candidate set through the solver artifact (the coordinator's
    /// autotune path — O(|candidates|) artifact executions).
    pub fn autotune(
        &self,
        lam1: f64,
        lamk: f64,
        mu1: f64,
        muk: f64,
        iters: i32,
        weighted: bool,
    ) -> Result<(u32, SolverMetrics)> {
        let mut cands: Vec<u32> = vec![0, self.k / 4, self.k / 2, 3 * self.k / 4, self.k - 1];
        cands.dedup();
        let mut best: Option<(u32, SolverMetrics)> = None;
        for ell in cands {
            let m = self.solve(ell, lam1, lamk, mu1, muk, iters)?;
            if !m.trustworthy() {
                continue;
            }
            let v = if weighted { m.etw } else { m.et };
            if best
                .as_ref()
                .map(|(_, b)| v < if weighted { b.etw } else { b.et })
                .unwrap_or(true)
            {
                best = Some((ell, m));
            }
        }
        best.ok_or_else(|| anyhow::anyhow!("no trustworthy solver result; raise iters"))
    }
}

/// The full-sweep artifact (all thresholds in one execution).
#[cfg(feature = "pjrt")]
pub struct SweepArtifact {
    artifact: Artifact,
    pub k: u32,
}

#[cfg(feature = "pjrt")]
impl SweepArtifact {
    pub fn load(rt: &Runtime, k: u32) -> Result<SweepArtifact> {
        let artifact = rt.load(&format!("msfq_sweep_k{k}"))?;
        Ok(SweepArtifact { artifact, k })
    }

    /// Returns per-threshold metrics plus (best ℓ by E[T], by E[T^w]).
    pub fn sweep(
        &self,
        lam1: f64,
        lamk: f64,
        mu1: f64,
        muk: f64,
        iters: i32,
    ) -> Result<(Vec<SolverMetrics>, u32, u32)> {
        let params: Vec<f32> = vec![
            lam1 as f32,
            lamk as f32,
            mu1 as f32,
            muk as f32,
            0.0,
            self.k as f32,
            0.0,
            0.0,
        ];
        let out = self
            .artifact
            .execute(&[xla::Literal::vec1(&params), xla::Literal::from(iters)])?;
        anyhow::ensure!(out.len() >= 3, "sweep artifact returned {} outputs", out.len());
        let flat = out[0].to_vec::<f32>()?;
        let m = flat.len() / self.k as usize;
        let metrics = flat
            .chunks(m)
            .map(SolverMetrics::from_vec)
            .collect::<Result<Vec<_>>>()?;
        let best_et = out[1].to_vec::<i32>()?[0] as u32;
        let best_etw = out[2].to_vec::<i32>()?[0] as u32;
        Ok((metrics, best_et, best_etw))
    }
}

// ---- stubs without the `pjrt` feature ----

/// Stub: loading always fails; the autotuner falls back to the native
/// Theorem-2 calculator.
#[cfg(not(feature = "pjrt"))]
pub struct SolverArtifact {
    pub k: u32,
}

#[cfg(not(feature = "pjrt"))]
impl SolverArtifact {
    pub fn load(rt: &Runtime, k: u32) -> Result<SolverArtifact> {
        let _ = rt;
        anyhow::bail!("solver artifact k={k} unavailable: built without the `pjrt` feature")
    }

    pub fn solve(
        &self,
        _ell: u32,
        _lam1: f64,
        _lamk: f64,
        _mu1: f64,
        _muk: f64,
        _iters: i32,
    ) -> Result<SolverMetrics> {
        anyhow::bail!("built without the `pjrt` feature")
    }

    pub fn autotune(
        &self,
        _lam1: f64,
        _lamk: f64,
        _mu1: f64,
        _muk: f64,
        _iters: i32,
        _weighted: bool,
    ) -> Result<(u32, SolverMetrics)> {
        anyhow::bail!("built without the `pjrt` feature")
    }
}

#[cfg(not(feature = "pjrt"))]
pub struct SweepArtifact {
    pub k: u32,
}

#[cfg(not(feature = "pjrt"))]
impl SweepArtifact {
    pub fn load(rt: &Runtime, k: u32) -> Result<SweepArtifact> {
        let _ = rt;
        anyhow::bail!("sweep artifact k={k} unavailable: built without the `pjrt` feature")
    }

    pub fn sweep(
        &self,
        _lam1: f64,
        _lamk: f64,
        _mu1: f64,
        _muk: f64,
        _iters: i32,
    ) -> Result<(Vec<SolverMetrics>, u32, u32)> {
        anyhow::bail!("built without the `pjrt` feature")
    }
}
