//! The cluster-scheduler coordinator: a deployable daemon that admits
//! multiserver jobs under any [`crate::policy::Policy`], executes them in
//! scaled real time, exposes a TCP JSONL control API, and autotunes the
//! Quickswap threshold online by invoking the AOT-compiled CTMC solver
//! through PJRT (or the native Theorem-2 calculator as fallback).
//!
//! Threading model (std threads; the offline registry has no tokio —
//! see DESIGN.md §4):
//!   * scheduler thread — owns all mutable state, consumes a command
//!     channel (submissions, completions, control ops);
//!   * timer thread — fires job completions at their deadlines;
//!   * TCP acceptor + per-connection threads — parse JSONL into commands.

pub mod core;
pub mod rates;
pub mod tcp;

pub use self::core::{Coordinator, CoordinatorConfig, CoordinatorHandle, StatsSnapshot};
pub use rates::RateEstimator;
pub use tcp::serve_tcp;
