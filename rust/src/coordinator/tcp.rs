//! TCP JSONL control API for the coordinator.
//!
//! One JSON object per line. Requests:
//!   {"op":"submit","class":0,"size":1.5}      → {"ok":true,"id":N}
//!   {"op":"stats"}                            → {"ok":true, ...snapshot}
//!   {"op":"autotune"}                         → {"ok":true,"ell":L|null}
//!   {"op":"ping"}                             → {"ok":true,"pong":true}
//! Malformed input → {"ok":false,"error":"..."} (connection stays open).

use crate::coordinator::core::CoordinatorHandle;
use crate::util::json::Value;
use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Read one newline-terminated line from an untrusted peer, bounded in
/// both time and space: `budget` is an *absolute* deadline covering the
/// whole line (re-armed per `read` call, so a peer trickling one byte
/// per second cannot extend it — `Some(10s)` means the full line within
/// ten seconds, period; `None` blocks indefinitely), and `max_line`
/// caps the accumulated bytes so a newline-less flood cannot grow the
/// buffer without limit. Returns the line without its terminator, or
/// `None` on timeout, overflow, EOF before any newline, or a socket
/// error. Unlike `BufRead::read_line`, a line is consumed byte-by-byte
/// from the `BufReader` so no bytes beyond the newline are stolen from
/// subsequent reads.
pub fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    budget: Option<Duration>,
    max_line: usize,
) -> Option<String> {
    let start = Instant::now();
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        if let Some(budget) = budget {
            let left = budget.checked_sub(start.elapsed())?;
            if reader.get_ref().set_read_timeout(Some(left)).is_err() {
                return None;
            }
        }
        match reader.read(&mut byte) {
            Ok(0) | Err(_) => return None,
            Ok(_) => {}
        }
        if byte[0] == b'\n' {
            break;
        }
        if buf.len() >= max_line {
            return None;
        }
        buf.push(byte[0]);
    }
    if budget.is_some() && reader.get_ref().set_read_timeout(None).is_err() {
        return None;
    }
    let mut line = String::from_utf8(buf).ok()?;
    if line.ends_with('\r') {
        line.pop();
    }
    Some(line)
}

/// Serve the coordinator API on `addr` (e.g. "127.0.0.1:0"). Returns the
/// bound address; the acceptor runs on a background thread until the
/// process exits or the listener errors out.
pub fn serve_tcp(addr: &str, handle: CoordinatorHandle) -> anyhow::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new()
        .name("qs-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                match conn {
                    Ok(stream) => {
                        let h = handle.clone();
                        let _ = std::thread::Builder::new()
                            .name("qs-conn".into())
                            .spawn(move || handle_conn(stream, h));
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok(bound)
}

fn handle_conn(stream: TcpStream, handle: CoordinatorHandle) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // Cap each request line at 1 MiB: no client request is anywhere
    // near that, and an unbounded `lines()` would let a newline-less
    // peer grow the buffer without limit.
    while let Some(line) = read_line_bounded(&mut reader, None, 1 << 20) {
        if line.trim().is_empty() {
            continue;
        }
        let resp = respond(&line, &handle);
        if writeln!(writer, "{resp}").is_err() {
            return;
        }
    }
}

fn err(msg: &str) -> Value {
    Value::obj().set("ok", false).set("error", msg)
}

fn respond(line: &str, handle: &CoordinatorHandle) -> Value {
    let req = match Value::parse(line) {
        Ok(v) => v,
        Err(e) => return err(&format!("bad json: {e}")),
    };
    match req.get("op").and_then(|o| o.as_str()) {
        Some("ping") => Value::obj().set("ok", true).set("pong", true),
        Some("submit") => {
            let class = match req.get("class").and_then(|c| c.as_u64()) {
                Some(c) => c as usize,
                None => return err("submit needs integer 'class'"),
            };
            let size = req.get("size").and_then(|s| s.as_f64()).unwrap_or(1.0);
            if size <= 0.0 || !size.is_finite() {
                return err("'size' must be positive");
            }
            match handle.submit_wait(class, size) {
                Some(id) => Value::obj().set("ok", true).set("id", id),
                None => err("coordinator unavailable"),
            }
        }
        Some("stats") => match handle.stats() {
            Some(s) => {
                let per_class: Vec<Value> = s
                    .per_class
                    .iter()
                    .map(|&(n, t, sz)| {
                        Value::obj()
                            .set("count", n)
                            .set("mean_t", if t.is_nan() { 0.0 } else { t })
                            .set("mean_size", if sz.is_nan() { 0.0 } else { sz })
                    })
                    .collect();
                let mut v = Value::obj()
                    .set("ok", true)
                    .set("policy", s.policy.as_str())
                    .set("submitted", s.submitted)
                    .set("completed", s.completed)
                    .set("in_system", s.in_system)
                    .set("used", s.used_servers)
                    .set("k", s.k)
                    .set("mean_t", if s.mean_t.is_nan() { 0.0 } else { s.mean_t })
                    .set(
                        "weighted_t",
                        if s.weighted_t.is_nan() { 0.0 } else { s.weighted_t },
                    )
                    .set("retunes", s.retunes)
                    .set("per_class", per_class);
                if let Some(ell) = s.current_ell {
                    v = v.set("ell", ell as u64);
                }
                v
            }
            None => err("coordinator unavailable"),
        },
        Some("autotune") => match handle.autotune() {
            Some(ell) => Value::obj().set("ok", true).set("ell", ell as u64),
            None => Value::obj().set("ok", true).set("ell", Value::Null),
        },
        Some(other) => err(&format!("unknown op '{other}'")),
        None => err("missing 'op'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_paths_are_json() {
        // Exercise respond() without a live coordinator where possible.
        let (tx, _rx) = std::sync::mpsc::channel();
        let h = CoordinatorHandle::test_only(tx);
        assert_eq!(respond("{", &h).get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            respond(r#"{"op":"nope"}"#, &h).get("ok").unwrap().as_bool(),
            Some(false)
        );
        assert_eq!(
            respond(r#"{"op":"submit"}"#, &h)
                .get("ok")
                .unwrap()
                .as_bool(),
            Some(false)
        );
    }
}
