//! Online estimation of per-class arrival rates and mean sizes, feeding
//! the Quickswap-threshold autotuner.

use crate::util::stats::Welford;

/// Windowless estimator: exact totals since the last `reset`, which the
/// autotuner calls after each retune so estimates track the recent regime.
#[derive(Clone, Debug)]
pub struct RateEstimator {
    start: f64,
    now: f64,
    arrivals: Vec<u64>,
    sizes: Vec<Welford>,
}

impl RateEstimator {
    pub fn new(num_classes: usize) -> RateEstimator {
        RateEstimator {
            start: 0.0,
            now: 0.0,
            arrivals: vec![0; num_classes],
            sizes: vec![Welford::new(); num_classes],
        }
    }

    pub fn observe_arrival(&mut self, t: f64, class: usize, size: f64) {
        self.now = self.now.max(t);
        self.arrivals[class] += 1;
        self.sizes[class].push(size);
    }

    /// Observed arrival rate of `class` (jobs per unit virtual time).
    pub fn rate(&self, class: usize) -> f64 {
        let span = self.now - self.start;
        if span <= 0.0 {
            return 0.0;
        }
        self.arrivals[class] as f64 / span
    }

    /// Observed mean size (NaN until a sample arrives).
    pub fn mean_size(&self, class: usize) -> f64 {
        self.sizes[class].mean()
    }

    pub fn total_arrivals(&self) -> u64 {
        self.arrivals.iter().sum()
    }

    /// Enough signal to retune? Require samples in every class.
    pub fn ready(&self, min_per_class: u64) -> bool {
        self.arrivals.iter().all(|&a| a >= min_per_class)
    }

    pub fn reset(&mut self, t: f64) {
        let n = self.arrivals.len();
        self.start = t;
        self.now = t;
        self.arrivals = vec![0; n];
        self.sizes = vec![Welford::new(); n];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_rates_and_sizes() {
        let mut e = RateEstimator::new(2);
        for i in 0..100 {
            e.observe_arrival(i as f64 * 0.1, 0, 2.0);
        }
        e.observe_arrival(10.0, 1, 5.0);
        assert!((e.rate(0) - 10.0).abs() < 0.5, "{}", e.rate(0));
        assert!((e.mean_size(0) - 2.0).abs() < 1e-12);
        assert!((e.mean_size(1) - 5.0).abs() < 1e-12);
        assert!(e.ready(1));
        assert!(!e.ready(2));
        e.reset(20.0);
        assert_eq!(e.total_arrivals(), 0);
    }
}
