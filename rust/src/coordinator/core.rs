//! Coordinator core: scheduler thread + completion-timer thread.

use crate::analysis;
use crate::coordinator::rates::RateEstimator;
use crate::policy::test_support::Harness;
use crate::policy::{JobId, Msfq, Policy};
use crate::runtime::{Runtime, SolverArtifact};
use crate::util::stats::Welford;
use crate::workload::Workload;
use std::collections::BinaryHeap;
use std::sync::mpsc::{self, Receiver, Sender};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Wall seconds per unit of virtual job size (e.g. 1e-3 ⇒ a job of
    /// size 1.0 runs 1 ms).
    pub time_scale: f64,
    /// Autotune every N arrivals (0 = never).
    pub autotune_every: u64,
    /// Use the PJRT solver artifact when available for this k.
    pub use_artifact: bool,
    /// Power-iteration budget per artifact execution.
    pub solver_iters: i32,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            time_scale: 1e-3,
            autotune_every: 0,
            use_artifact: true,
            solver_iters: 20_000,
        }
    }
}

/// Point-in-time statistics exposed over the API.
#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    pub policy: String,
    pub submitted: u64,
    pub completed: u64,
    pub in_system: u64,
    pub used_servers: u32,
    pub k: u32,
    /// Per-class (count, mean response, mean size) in virtual time units.
    pub per_class: Vec<(u64, f64, f64)>,
    pub mean_t: f64,
    pub weighted_t: f64,
    pub current_ell: Option<u32>,
    pub retunes: u64,
}

enum Cmd {
    Submit {
        class: usize,
        size: f64,
        reply: Option<Sender<JobId>>,
    },
    Complete {
        job: JobId,
        starts: u32,
    },
    Stats {
        reply: Sender<StatsSnapshot>,
    },
    Autotune {
        reply: Sender<Option<u32>>,
    },
    /// Result of an asynchronous tune solve (worker thread → scheduler).
    ApplyTuned {
        ell: Option<u32>,
        reply: Option<Sender<Option<u32>>>,
    },
    Shutdown,
}

/// Cloneable handle to a running coordinator.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: Sender<Cmd>,
}

impl CoordinatorHandle {
    /// A handle wired to a dead channel — for exercising API error paths.
    #[doc(hidden)]
    pub fn test_only(tx: Sender<()>) -> CoordinatorHandle {
        drop(tx);
        let (tx, rx) = mpsc::channel();
        drop(rx);
        CoordinatorHandle { tx }
    }

    pub fn submit(&self, class: usize, size: f64) {
        let _ = self.tx.send(Cmd::Submit {
            class,
            size,
            reply: None,
        });
    }

    pub fn submit_wait(&self, class: usize, size: f64) -> Option<JobId> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Submit {
                class,
                size,
                reply: Some(tx),
            })
            .ok()?;
        rx.recv().ok()
    }

    pub fn stats(&self) -> Option<StatsSnapshot> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Cmd::Stats { reply: tx }).ok()?;
        rx.recv().ok()
    }

    /// Trigger a retune now; returns the chosen ℓ if any.
    pub fn autotune(&self) -> Option<u32> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Cmd::Autotune { reply: tx }).ok()?;
        rx.recv().ok().flatten()
    }

    /// Block until all submitted jobs have completed (polling).
    pub fn drain(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        loop {
            match self.stats() {
                Some(s) if s.in_system == 0 => return true,
                None => return false,
                _ => {}
            }
            if start.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Cmd::Shutdown);
    }
}

/// Completion-timer entry (min-heap by deadline).
struct TimerEntry {
    at: Instant,
    job: JobId,
    /// Job's service-start count when the timer was armed; a later
    /// preemption/restart bumps it, invalidating this timer.
    starts: u32,
}

impl PartialEq for TimerEntry {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        o.at.cmp(&self.at) // reverse: min-heap
    }
}

pub struct Coordinator {
    handle: CoordinatorHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the scheduler + timer threads for `wl` under `policy`.
    pub fn spawn(
        wl: &Workload,
        policy: Box<dyn Policy + Send>,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (timer_tx, timer_rx) = mpsc::channel::<TimerEntry>();
        // Timer thread: fires completions back into the command channel.
        {
            let sched_tx = tx.clone();
            std::thread::Builder::new()
                .name("qs-timer".into())
                .spawn(move || timer_loop(timer_rx, sched_tx))
                .expect("spawn timer thread");
        }
        let wl2 = wl.clone();
        let tx2 = tx.clone();
        let thread = std::thread::Builder::new()
            .name("qs-sched".into())
            .spawn(move || scheduler_loop(wl2, policy, cfg, rx, tx2, timer_tx))
            .expect("spawn scheduler thread");
        Coordinator {
            handle: CoordinatorHandle { tx },
            thread: Some(thread),
        }
    }

    pub fn handle(&self) -> CoordinatorHandle {
        self.handle.clone()
    }

    /// Shut down and join.
    pub fn join(mut self) {
        self.handle.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn timer_loop(rx: Receiver<TimerEntry>, sched: Sender<Cmd>) {
    let mut heap: BinaryHeap<TimerEntry> = BinaryHeap::new();
    loop {
        let now = Instant::now();
        // Fire everything due.
        while heap.peek().map(|e| e.at <= now).unwrap_or(false) {
            let e = heap.pop().unwrap();
            if sched
                .send(Cmd::Complete {
                    job: e.job,
                    starts: e.starts,
                })
                .is_err()
            {
                return; // scheduler gone
            }
        }
        let wait = heap
            .peek()
            .map(|e| e.at.saturating_duration_since(now))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(e) => heap.push(e),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Drain remaining deadlines, then exit.
                while let Some(e) = heap.pop() {
                    let now = Instant::now();
                    if e.at > now {
                        std::thread::sleep(e.at - now);
                    }
                    if sched
                        .send(Cmd::Complete {
                            job: e.job,
                            starts: e.starts,
                        })
                        .is_err()
                    {
                        return;
                    }
                }
                return;
            }
        }
    }
}

fn scheduler_loop(
    wl: Workload,
    mut policy: Box<dyn Policy + Send>,
    cfg: CoordinatorConfig,
    rx: Receiver<Cmd>,
    self_tx: Sender<Cmd>,
    timer: Sender<TimerEntry>,
) {
    let needs = wl.needs();
    let mut state = Harness::new(wl.k, &needs);
    let mut resp: Vec<Welford> = vec![Welford::new(); needs.len()];
    let mut arrive_wall: std::collections::HashMap<JobId, Instant> = Default::default();
    let mut start_virtual: std::collections::HashMap<JobId, f64> = Default::default();
    // Two estimators: `rates` is windowed (reset after each retune, so
    // the tuner tracks the recent regime); `rates_all` is all-time and
    // feeds the stats snapshot (load weights must never vanish).
    let mut rates = RateEstimator::new(needs.len());
    let mut rates_all = RateEstimator::new(needs.len());
    let (mut submitted, mut completed, mut retunes) = (0u64, 0u64, 0u64);
    let mut current_ell: Option<u32> = None;
    let mut tune_in_flight = false;
    let epoch0 = Instant::now();

    let vnow = |epoch0: Instant, scale: f64| epoch0.elapsed().as_secs_f64() / scale;

    loop {
        let cmd = match rx.recv() {
            Ok(c) => c,
            Err(_) => return,
        };
        match cmd {
            Cmd::Submit { class, size, reply } => {
                let t = vnow(epoch0, cfg.time_scale);
                let id = state.arrive_sized(class, t, size);
                arrive_wall.insert(id, Instant::now());
                start_virtual.insert(id, t);
                rates.observe_arrival(t, class, size);
                rates_all.observe_arrival(t, class, size);
                submitted += 1;
                if let Some(r) = reply {
                    let _ = r.send(id);
                }
                dispatch(&mut state, policy.as_mut(), &timer, cfg.time_scale);
                if cfg.autotune_every > 0
                    && submitted % cfg.autotune_every == 0
                    && rates.ready(5)
                    && !tune_in_flight
                {
                    tune_in_flight =
                        spawn_tune(&wl, &rates, &cfg, self_tx.clone(), None);
                }
            }
            Cmd::Complete { job, starts } => {
                // Stale timers can exist if a job was restarted; guard.
                if !state.jobs.is_running(job) || state.jobs.starts(job) != starts {
                    continue;
                }
                let t = vnow(epoch0, cfg.time_scale);
                let class = state.jobs.class(job);
                state.complete(job, t);
                completed += 1;
                if let (Some(w0), Some(_)) =
                    (arrive_wall.remove(&job), start_virtual.remove(&job))
                {
                    let vresp = w0.elapsed().as_secs_f64() / cfg.time_scale;
                    resp[class].push(vresp);
                }
                dispatch(&mut state, policy.as_mut(), &timer, cfg.time_scale);
            }
            Cmd::Stats { reply } => {
                let per_class: Vec<(u64, f64, f64)> = (0..needs.len())
                    .map(|c| (resp[c].count(), resp[c].mean(), rates_all.mean_size(c)))
                    .collect();
                let rho: Vec<f64> = (0..needs.len())
                    .map(|c| {
                        needs[c] as f64 * rates_all.rate(c) * rates_all.mean_size(c).max(0.0)
                    })
                    .collect();
                let rho_tot: f64 = rho.iter().filter(|x| x.is_finite()).sum();
                let weighted_t = if rho_tot > 0.0 {
                    (0..needs.len())
                        .filter(|&c| resp[c].count() > 0 && rho[c].is_finite())
                        .map(|c| rho[c] / rho_tot * resp[c].mean())
                        .sum()
                } else {
                    f64::NAN
                };
                let all: Welford = {
                    let mut w = Welford::new();
                    for r in &resp {
                        w.merge(r);
                    }
                    w
                };
                let _ = reply.send(StatsSnapshot {
                    policy: policy.name(),
                    submitted,
                    completed,
                    in_system: state.jobs.len() as u64,
                    used_servers: state.used(),
                    k: wl.k,
                    per_class,
                    mean_t: all.mean(),
                    weighted_t,
                    current_ell,
                    retunes,
                });
            }
            Cmd::Autotune { reply } => {
                if tune_in_flight
                    || !spawn_tune(&wl, &rates, &cfg, self_tx.clone(), Some(reply.clone()))
                {
                    let _ = reply.send(None);
                } else {
                    tune_in_flight = true;
                }
            }
            Cmd::ApplyTuned { ell, reply } => {
                tune_in_flight = false;
                let applied = ell.and_then(|e| match Msfq::new(&wl, e) {
                    Ok(p) => {
                        policy = Box::new(p);
                        current_ell = Some(e);
                        retunes += 1;
                        rates.reset(vnow(epoch0, cfg.time_scale));
                        Some(e)
                    }
                    Err(_) => None,
                });
                // The swapped-in policy may want to act immediately.
                dispatch(&mut state, policy.as_mut(), &timer, cfg.time_scale);
                if let Some(r) = reply {
                    let _ = r.send(applied);
                }
            }
            Cmd::Shutdown => return,
        }
    }
}

/// Consult the policy and start any admitted jobs, arming their timers.
fn dispatch(
    state: &mut Harness,
    policy: &mut dyn Policy,
    timer: &Sender<TimerEntry>,
    scale: f64,
) {
    let admitted = state.consult(policy);
    let now = Instant::now();
    for id in admitted {
        let j = state.jobs.get(id);
        let dur = Duration::from_secs_f64((j.remaining * scale).max(0.0));
        let _ = timer.send(TimerEntry {
            at: now + dur,
            job: id,
            starts: j.starts,
        });
    }
}

/// Snapshot the observed rates and solve for the best Quickswap
/// threshold on a WORKER thread (the PJRT solve takes seconds — it must
/// never block the scheduler's event loop). The result comes back as
/// `Cmd::ApplyTuned`. Returns false if no tune could be started
/// (multiclass workload, not enough signal).
fn spawn_tune(
    wl: &Workload,
    rates: &RateEstimator,
    cfg: &CoordinatorConfig,
    back: Sender<Cmd>,
    reply: Option<Sender<Option<u32>>>,
) -> bool {
    let snapshot = (|| {
        if !wl.is_one_or_all() {
            return None;
        }
        let (mut light, mut heavy) = (None, None);
        for (c, cl) in wl.classes.iter().enumerate() {
            if cl.need() == 1 {
                light = Some(c);
            } else {
                heavy = Some(c);
            }
        }
        let (lc, hc) = (light?, heavy?);
        let (mut lam1, mut lamk) = (rates.rate(lc), rates.rate(hc));
        let (mu1, muk) = (
            1.0 / rates.mean_size(lc).max(1e-12),
            1.0 / rates.mean_size(hc).max(1e-12),
        );
        if lam1 <= 0.0 || lamk <= 0.0 {
            return None;
        }
        // Estimated rates can exceed the stability region (bursty
        // submission or genuine overload). Tune for the clamped
        // operating point ρ = 0.95 instead of refusing: the optimal ℓ
        // is insensitive to the exact ρ near saturation (Fig 2).
        let rho = lam1 / (wl.k as f64 * mu1) + lamk / muk;
        if rho >= 0.95 {
            let scale = 0.95 / rho;
            lam1 *= scale;
            lamk *= scale;
        }
        Some((lam1, lamk, mu1, muk))
    })();
    let Some((lam1, lamk, mu1, muk)) = snapshot else {
        return false;
    };
    let (k, use_artifact, iters) = (wl.k, cfg.use_artifact, cfg.solver_iters);
    std::thread::Builder::new()
        .name("qs-tune".into())
        .spawn(move || {
            let ell = solve_threshold(k, lam1, lamk, mu1, muk, use_artifact, iters);
            let _ = back.send(Cmd::ApplyTuned { ell, reply });
        })
        .is_ok()
}

/// The tune computation itself: PJRT solver artifact when available,
/// native Theorem-2 calculator otherwise.
fn solve_threshold(
    k: u32,
    lam1: f64,
    lamk: f64,
    mu1: f64,
    muk: f64,
    use_artifact: bool,
    iters: i32,
) -> Option<u32> {
    if use_artifact {
        let tuned = Runtime::new(Runtime::default_dir())
            .ok()
            .and_then(|rt| SolverArtifact::load(&rt, k).ok())
            .and_then(|solver| {
                solver
                    .autotune(lam1, lamk, mu1, muk, iters, false)
                    .ok()
                    .map(|(ell, _)| ell)
            });
        if tuned.is_some() {
            return tuned;
        }
    }
    analysis::best_threshold(k, lam1, lamk, mu1, muk, false).map(|(e, _)| e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::workload::ClassSpec;

    fn wl() -> Workload {
        Workload::new(
            4,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(4, 0.2, Dist::exp_mean(1.0)),
            ],
        )
    }

    #[test]
    fn submits_complete_and_report() {
        let w = wl();
        let policy = crate::policy::build(&"msfq:3".parse().unwrap(), &w).unwrap();
        let coord = Coordinator::spawn(
            &w,
            policy,
            CoordinatorConfig {
                time_scale: 5e-4, // 1.0 job size = 0.5 ms
                ..Default::default()
            },
        );
        let h = coord.handle();
        for i in 0..50 {
            h.submit(if i % 5 == 0 { 1 } else { 0 }, 1.0);
        }
        assert!(h.drain(Duration::from_secs(20)), "did not drain");
        let s = h.stats().unwrap();
        assert_eq!(s.completed, 50);
        assert_eq!(s.in_system, 0);
        assert!(s.mean_t > 0.0);
        coord.join();
    }
}
