//! The elastic sweep driver: serves a [`SpecQueue`]'s pooled unit grid
//! to TCP workers, checkpoints completed units to an append-only
//! [`Journal`], and pools the results per spec.
//!
//! Build with [`DriverBuilder`] (spec queue, bind address, auth token,
//! unit timeout, journal path), then [`Driver::serve`]. The driver is
//! "just another [`UnitSource`]": once every unit is resolved, the
//! recorded runs are replayed per spec through the same
//! [`sweep_units`] / [`sweep_paired_units`] pooling paths the local
//! thread runner uses, so sharded, resumed, and multi-spec results are
//! merged by exactly the same code, in the same (replication-order)
//! sequence, as in-process results.
//!
//! Fault model: a worker that disconnects with claimed-but-unreported
//! units has them requeued; duplicate results for a unit id are ignored
//! (first wins). The driver returns once every unit has been delivered
//! or conclusively failed on a worker. A hung-but-connected worker
//! stalls its unit indefinitely by default; setting
//! `QS_UNIT_TIMEOUT_SECS` (or [`DriverBuilder::unit_timeout`]) arms an
//! assignment deadline — a unit held past it is requeued to the next
//! `next` request (heterogeneous worker pacing), with the usual
//! dedupe-by-unit-id if the slow worker eventually reports anyway.
//! Workers may join and leave at any point in the sweep's life.
//!
//! Durability: with a journal configured, every result is appended and
//! flushed *before* the worker's ack, so a driver SIGKILLed mid-sweep
//! and restarted on the same journal re-delivers finished units from
//! disk (never rerunning them) and emits byte-identical CSVs to an
//! uninterrupted run — see [`crate::sweep::journal`].
//!
//! Auth: with `QS_SWEEP_TOKEN` set (or [`DriverBuilder::auth_token`]),
//! the driver requires every peer's opening `hello` to carry the
//! matching shared secret before the spec queue is revealed; mismatches
//! get an `err` line and a closed connection. Unset = open driver (the
//! loopback/test default). The read-only `status` op is available to
//! any authenticated peer.

use crate::experiments::{
    sweep_paired_units, sweep_units, PairedGrid, PairedRun, PairedSweep, PairedUnitSource, Point,
    SweepGrid, UnitRun, UnitSource,
};
use crate::sim::{ReplicationPool, SimResult};
use crate::sweep::journal::Journal;
use crate::sweep::{proto, AnyRun, SpecQueue, SpecTask, SweepSpec};
use crate::util::json::Value;
use crate::workload::Workload;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Optional assignment deadline from the environment: fractional seconds
/// in `QS_UNIT_TIMEOUT_SECS` (unset, empty, or non-positive = off).
fn unit_timeout_from_env() -> Option<Duration> {
    std::env::var("QS_UNIT_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|&s| s > 0.0 && s.is_finite())
        .map(Duration::from_secs_f64)
}

/// Optional shared-secret token from the environment (`QS_SWEEP_TOKEN`;
/// unset or empty = open driver, the loopback/test default).
pub(crate) fn auth_token_from_env() -> Option<String> {
    std::env::var("QS_SWEEP_TOKEN")
        .ok()
        .filter(|t| !t.is_empty())
}

/// Configures and binds a sweep [`Driver`]: the spec queue, bind
/// address, shared-secret auth, assignment deadline, and checkpoint
/// journal all live here, replacing the accreted
/// `with_auth_token`/`with_unit_timeout` chain. `new` seeds the
/// environment defaults (`QS_UNIT_TIMEOUT_SECS`, `QS_SWEEP_TOKEN`);
/// explicit setters override them — tests pin values here so parallel
/// tests never race on process-global env state.
pub struct DriverBuilder {
    specs: Vec<SweepSpec>,
    addr: String,
    unit_timeout: Option<Duration>,
    auth_token: Option<String>,
    journal: Option<PathBuf>,
}

impl DriverBuilder {
    pub fn new() -> DriverBuilder {
        DriverBuilder {
            specs: Vec::new(),
            addr: "127.0.0.1:0".to_string(),
            unit_timeout: unit_timeout_from_env(),
            auth_token: auth_token_from_env(),
            journal: None,
        }
    }

    /// Queue one spec (may be called repeatedly; queue order defines
    /// global unit ids).
    pub fn spec(mut self, spec: &SweepSpec) -> DriverBuilder {
        self.specs.push(spec.clone());
        self
    }

    /// Queue several specs at once.
    pub fn specs<I: IntoIterator<Item = SweepSpec>>(mut self, specs: I) -> DriverBuilder {
        self.specs.extend(specs);
        self
    }

    /// The address to bind (default `127.0.0.1:0`; port 0 lets the OS
    /// pick — read it back with [`Driver::local_addr`]).
    pub fn bind_addr(mut self, addr: &str) -> DriverBuilder {
        self.addr = addr.to_string();
        self
    }

    /// Override the assignment deadline (`None` = never time out).
    pub fn unit_timeout(mut self, timeout: Option<Duration>) -> DriverBuilder {
        self.unit_timeout = timeout;
        self
    }

    /// Override the shared-secret auth token (`None` or empty = accept
    /// any peer).
    pub fn auth_token(mut self, token: Option<String>) -> DriverBuilder {
        self.auth_token = token.filter(|t| !t.is_empty());
        self
    }

    /// Checkpoint completed units to the append-only journal at `path`
    /// (created if missing). A driver restarted on the same journal
    /// resumes instead of rerunning finished units.
    pub fn journal<P: Into<PathBuf>>(mut self, path: P) -> DriverBuilder {
        self.journal = Some(path.into());
        self
    }

    /// Validate the queue and bind the listener. The bind/serve split
    /// lets callers learn the OS-assigned port before workers are
    /// pointed at it.
    pub fn bind(self) -> anyhow::Result<Driver> {
        if self.specs.is_empty() {
            anyhow::bail!("no sweep specs queued");
        }
        let queue = SpecQueue::new(self.specs)?;
        let listener = TcpListener::bind(&self.addr)?;
        let addr = listener.local_addr()?;
        Ok(Driver {
            listener,
            addr,
            queue,
            unit_timeout: self.unit_timeout,
            auth_token: self.auth_token,
            journal_path: self.journal,
        })
    }
}

impl Default for DriverBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// One spec's pooled result.
pub enum SpecOutcome {
    Marginal(Vec<Point>),
    Paired(PairedSweep),
}

impl SpecOutcome {
    /// The marginal points (both variants carry them).
    pub fn points(&self) -> &[Point] {
        match self {
            SpecOutcome::Marginal(pts) => pts,
            SpecOutcome::Paired(sweep) => &sweep.points,
        }
    }

    pub fn as_paired(&self) -> Option<&PairedSweep> {
        match self {
            SpecOutcome::Marginal(_) => None,
            SpecOutcome::Paired(sweep) => Some(sweep),
        }
    }
}

/// What a [`Driver::serve`] call did: per-spec outcomes in queue order,
/// plus unit accounting (`units_from_journal` + `units_executed` =
/// `units_total` on a clean exit — the resume tests assert finished
/// units were served from disk, not rerun).
pub struct ServeReport {
    pub outcomes: Vec<SpecOutcome>,
    pub units_total: usize,
    pub units_from_journal: usize,
    pub units_executed: usize,
}

/// A bound (but not yet serving) sweep driver — build one with
/// [`DriverBuilder`].
pub struct Driver {
    listener: TcpListener,
    addr: SocketAddr,
    queue: SpecQueue,
    unit_timeout: Option<Duration>,
    auth_token: Option<String>,
    journal_path: Option<PathBuf>,
}

impl Driver {
    /// The bound address workers should connect to.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until every unit in the queue has a result (from the
    /// journal or a worker), then pool per spec. Blocks; each outcome
    /// matches the corresponding
    /// [`run_spec_local`](crate::sweep::run_spec_local) /
    /// [`run_spec_paired_local`](crate::sweep::run_spec_paired_local)
    /// output bit for bit, regardless of worker count, assignment,
    /// arrival order, or intervening driver kills.
    pub fn serve(self) -> anyhow::Result<ServeReport> {
        let total = self.queue.total_units();
        let mut journal = None;
        let mut entries = Vec::new();
        if let Some(path) = &self.journal_path {
            let (j, e) = Journal::open(path, &self.queue)?;
            journal = Some(j);
            entries = e;
        }
        let mut runs: Vec<Option<AnyRun>> = (0..total).map(|_| None).collect();
        let mut delivered = vec![false; total];
        let from_journal = entries.len();
        for e in entries {
            let g = self
                .queue
                .global_id(e.spec, e.id)
                .expect("journal entries are validated against the queue");
            delivered[g] = true;
            runs[g] = e.run;
        }
        let pending: VecDeque<usize> = (0..total).filter(|&g| !delivered[g]).collect();
        let remaining = pending.len();
        let specs_line = proto::msg_specs(self.queue.tasks().iter().map(|t| &t.spec)).to_string();
        let svc = Service {
            queue: &self.queue,
            unit_timeout: self.unit_timeout,
            auth_token: self.auth_token.as_deref(),
            specs_line,
            state: Mutex::new(State {
                pending,
                delivered,
                assigned: vec![None; total],
                remaining,
                conns: Vec::new(),
                runs,
                journal,
                executed: 0,
                from_journal,
            }),
            cv: Condvar::new(),
            done: AtomicBool::new(false),
        };
        // A fully-journaled queue needs no workers at all: skip the
        // accept loop and go straight to pooling.
        if remaining > 0 {
            svc.serve_loop(&self.listener, self.addr);
        }
        let st = svc.state.into_inner().unwrap();
        let executed = st.executed;
        let mut all = st.runs;
        let mut outcomes = Vec::with_capacity(self.queue.tasks().len());
        for task in self.queue.tasks() {
            let tail = all.split_off(task.n_units());
            let mut source = Replay {
                runs: std::mem::replace(&mut all, tail),
            };
            let wl_at = |l: f64| task.spec.workload.build(l);
            let outcome = match &task.paired {
                Some(pg) => SpecOutcome::Paired(sweep_paired_units(pg, &wl_at, &mut source)?),
                None => SpecOutcome::Marginal(sweep_units(&task.grid, &wl_at, &mut source)?),
            };
            outcomes.push(outcome);
        }
        Ok(ServeReport {
            outcomes,
            units_total: total,
            units_from_journal: from_journal,
            units_executed: executed,
        })
    }

}

/// Re-delivers recorded runs (journaled or freshly served) through the
/// standard pooling paths, so resumed and multi-spec drives produce
/// byte-identical output to single-shot runs by construction.
struct Replay {
    runs: Vec<Option<AnyRun>>,
}

impl UnitSource for Replay {
    fn run_units(
        &mut self,
        _grid: &SweepGrid,
        _wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, UnitRun) + Sync),
    ) -> anyhow::Result<()> {
        for (u, run) in std::mem::take(&mut self.runs).into_iter().enumerate() {
            if let Some(AnyRun::Marginal(r)) = run {
                deliver(u, r);
            }
        }
        Ok(())
    }
}

impl PairedUnitSource for Replay {
    fn run_paired_units(
        &mut self,
        _grid: &PairedGrid,
        _wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, PairedRun) + Sync),
    ) -> anyhow::Result<()> {
        for (u, run) in std::mem::take(&mut self.runs).into_iter().enumerate() {
            if let Some(AnyRun::Paired(r)) = run {
                deliver(u, r);
            }
        }
        Ok(())
    }
}

/// Shared serving state, guarded by one mutex.
struct State {
    /// Global unit ids not currently assigned to any live connection.
    pending: VecDeque<usize>,
    /// Per-unit "a result (success or failure) has been recorded".
    delivered: Vec<bool>,
    /// Per-unit current assignment: (connection id, claim instant).
    /// `None` while pending, delivered, or reissued elsewhere.
    assigned: Vec<Option<(u64, Instant)>>,
    /// Units still without a recorded result.
    remaining: usize,
    /// Clones of every accepted connection, for shutdown at completion.
    conns: Vec<TcpStream>,
    /// Recorded runs, slotted by global unit id (None = pending or
    /// conclusively failed).
    runs: Vec<Option<AnyRun>>,
    /// The checkpoint journal; written under this lock, *before* the
    /// worker's ack, so record order is total-ordered with delivery.
    journal: Option<Journal>,
    /// Units executed by workers during this serve (excludes journal).
    executed: usize,
    /// Units pre-delivered from the journal at startup.
    from_journal: usize,
}

impl State {
    /// Requeue every unit whose assignment deadline has passed. Runs at
    /// `next`-request cadence, so a stalled worker's unit becomes
    /// available exactly when some live worker asks for more work.
    fn requeue_expired(&mut self, timeout: Duration, now: Instant) {
        for u in 0..self.assigned.len() {
            if let Some((_, t0)) = self.assigned[u] {
                if !self.delivered[u] && now.duration_since(t0) > timeout {
                    self.assigned[u] = None;
                    self.pending.push_back(u);
                    eprintln!(
                        "qs-sweep driver: unit {u} held past the \
                         {}s assignment deadline; requeued",
                        timeout.as_secs_f64()
                    );
                }
            }
        }
    }
}

/// The serving core: connection handling, unit scheduling, journaling,
/// and the status endpoint, shared by every connection thread.
struct Service<'a> {
    queue: &'a SpecQueue,
    unit_timeout: Option<Duration>,
    auth_token: Option<&'a str>,
    specs_line: String,
    state: Mutex<State>,
    cv: Condvar,
    done: AtomicBool,
}

/// Decode a `result` line via the owning spec's mode (the global unit
/// id picks the spec, the spec picks marginal vs paired payload). An
/// out-of-queue id or mismatched payload is an error — the connection
/// is dropped and its claimed units reissue.
fn parse_any(queue: &SpecQueue, v: &Value) -> anyhow::Result<(usize, Result<AnyRun, String>)> {
    let id = proto::id_of(v)?;
    let (si, _) = queue
        .locate(id)
        .ok_or_else(|| anyhow::anyhow!("result unit id {id} is outside the queue"))?;
    if queue.tasks()[si].paired.is_some() {
        let (id, r) = proto::parse_paired_result(v)?;
        Ok((id, r.map(AnyRun::Paired)))
    } else {
        let (id, r) = proto::parse_result(v)?;
        Ok((id, r.map(AnyRun::Marginal)))
    }
}

impl Service<'_> {
    /// Accept connections and serve until every pending unit is
    /// resolved, then shut every connection down.
    fn serve_loop(&self, listener: &TcpListener, addr: SocketAddr) {
        let conn_ids = AtomicU64::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                for conn in listener.incoming() {
                    if self.done.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { break };
                    if let Ok(clone) = stream.try_clone() {
                        self.state.lock().unwrap().conns.push(clone);
                    }
                    let conn_id = conn_ids.fetch_add(1, Ordering::Relaxed);
                    s.spawn(move || self.handle_conn(stream, conn_id));
                }
            });
            let guard = self.state.lock().unwrap();
            let guard = self.cv.wait_while(guard, |st| st.remaining > 0).unwrap();
            drop(guard);
            self.done.store(true, Ordering::SeqCst);
            // Wake the acceptor, then unblock every connection thread
            // still parked in a read (workers see EOF and exit). Connect
            // via loopback: the bound address may be the wildcard
            // 0.0.0.0, which is not connectable on every platform.
            let wake = SocketAddr::from(([127, 0, 0, 1], addr.port()));
            if TcpStream::connect_timeout(&wake, Duration::from_millis(200)).is_err() {
                let _ = TcpStream::connect(addr);
            }
            for c in &self.state.lock().unwrap().conns {
                let _ = c.shutdown(Shutdown::Both);
            }
        });
    }

    fn handle_conn(&self, stream: TcpStream, conn_id: u64) {
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut reader = BufReader::new(stream);
        // Handshake: the peer speaks first. The spec queue (workloads,
        // seeds, grid shapes) is only revealed after the hello validates
        // — with a token configured, that includes the shared secret.
        // The peer is untrusted until then, so the read is bounded by an
        // *absolute* deadline (re-armed per recv so trickled bytes
        // cannot extend it) and a byte cap: a silent, dribbling, or
        // newline-less connection cannot hold the handler thread or grow
        // the buffer.
        let Some(line) = read_handshake_line(&mut reader, Duration::from_secs(10)) else {
            let _ = writeln!(
                writer,
                "{}",
                proto::msg_err("handshake timed out or too large")
            );
            return;
        };
        let hello = proto::parse_line(&line).and_then(|m| proto::parse_hello(&m));
        let token = match hello {
            Ok(token) => token,
            Err(e) => {
                let _ = writeln!(writer, "{}", proto::msg_err(&format!("bad hello: {e}")));
                return;
            }
        };
        if let Some(expected) = self.auth_token {
            if !proto::token_matches(expected, token.as_deref()) {
                eprintln!("qs-sweep driver: rejected worker (QS_SWEEP_TOKEN mismatch)");
                let _ = writeln!(writer, "{}", proto::msg_err("auth failed"));
                return;
            }
        }
        // Authenticated: back to blocking reads for the lockstep loop (a
        // slow-but-live worker is legitimate; the unit timeout handles
        // stalled assignments).
        let _ = reader.get_ref().set_read_timeout(None);
        if writeln!(writer, "{}", self.specs_line).is_err() {
            return;
        }
        // Units this connection has claimed but not yet reported. The
        // lockstep protocol implies at most one, but a pipelining (or
        // buggy) client may claim several — every one of them must be
        // reissued on disconnect or the sweep hangs with units leaked.
        let mut claimed: Vec<usize> = Vec::new();
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            if line.trim().is_empty() {
                continue;
            }
            let Ok(msg) = proto::parse_line(&line) else {
                break;
            };
            match proto::op_of(&msg) {
                Some("next") => {
                    let reply = {
                        let mut st = self.state.lock().unwrap();
                        if let Some(timeout) = self.unit_timeout {
                            st.requeue_expired(timeout, Instant::now());
                        }
                        if let Some(u) = st.pending.pop_front() {
                            st.assigned[u] = Some((conn_id, Instant::now()));
                            claimed.push(u);
                            proto::msg_unit(u)
                        } else if st.remaining == 0 {
                            proto::msg_done()
                        } else {
                            // Everything is assigned elsewhere; poll
                            // again — a disconnect (or an assignment
                            // timeout) may requeue a unit.
                            proto::msg_wait(25)
                        }
                    };
                    let closing = proto::op_of(&reply) == Some("done");
                    if writeln!(writer, "{reply}").is_err() || closing {
                        break;
                    }
                }
                Some("status") => {
                    // Read-only: answer and keep the connection open so
                    // a monitor can poll over one socket.
                    let reply = self.status_line();
                    if writeln!(writer, "{reply}").is_err() {
                        break;
                    }
                }
                Some("result") => {
                    let Ok((id, outcome)) = parse_any(self.queue, &msg) else {
                        break; // malformed: drop the conn, claimed unit reissues
                    };
                    // One lock covers dedupe, journal append, slotting,
                    // and the `remaining` decrement: the main thread
                    // pools the instant it observes remaining == 0 and
                    // must never see it before the run is slotted, and
                    // the journal append must precede the ack below so
                    // an acked unit is guaranteed on disk.
                    let finished = {
                        let mut st = self.state.lock().unwrap();
                        if id >= st.delivered.len() || st.delivered[id] {
                            false // duplicate (first result won)
                        } else {
                            st.delivered[id] = true;
                            // Release the assignment slot only if this
                            // connection still owns it — after a timeout
                            // reissue it may belong to another worker.
                            if st.assigned[id].is_some_and(|(c, _)| c == conn_id) {
                                st.assigned[id] = None;
                            }
                            let (si, lu) =
                                self.queue.locate(id).expect("parse_any validated the id");
                            match &outcome {
                                Ok(run) => {
                                    if let Some(j) = st.journal.as_mut() {
                                        if let Err(e) = j.append_ok(si, lu, run) {
                                            eprintln!(
                                                "qs-sweep driver: journal write failed: {e}"
                                            );
                                        }
                                    }
                                }
                                Err(e) => {
                                    eprintln!("sweep unit {id} failed on worker: {e}");
                                    if let Some(j) = st.journal.as_mut() {
                                        if let Err(we) = j.append_err(si, lu, e) {
                                            eprintln!(
                                                "qs-sweep driver: journal write failed: {we}"
                                            );
                                        }
                                    }
                                }
                            }
                            if let Ok(run) = outcome {
                                st.runs[id] = Some(run);
                            }
                            st.executed += 1;
                            st.remaining -= 1;
                            st.remaining == 0
                        }
                    };
                    claimed.retain(|&u| u != id);
                    // Ack BEFORE announcing completion: the worker must
                    // see its last ack before the driver starts tearing
                    // down connections.
                    let acked = writeln!(writer, "{}", proto::msg_ok()).is_ok();
                    if finished {
                        self.cv.notify_all();
                    }
                    if !acked {
                        break;
                    }
                }
                _ => break,
            }
        }
        // Disconnect cleanup: requeue every claimed-but-unreported unit
        // so other workers pick them up — unless an assignment timeout
        // already reissued it (the unit is then pending or owned by
        // another connection, and requeueing again would double-enqueue
        // it).
        if !claimed.is_empty() {
            let mut st = self.state.lock().unwrap();
            for u in claimed {
                let owned = st.assigned[u].is_some_and(|(c, _)| c == conn_id);
                if owned {
                    st.assigned[u] = None;
                    if !st.delivered[u] {
                        st.pending.push_back(u);
                    }
                }
            }
        }
    }

    /// One JSON line of progress: top-level unit accounting plus a
    /// per-spec `{index, paired, total, done, rows}` array, where
    /// `rows` holds the pooled results of every point whose
    /// replications are all delivered — the same replication-order
    /// pooling the final CSVs use, computed on demand. Informational:
    /// the determinism contract applies to the final CSVs, not to
    /// mid-sweep snapshots.
    fn status_line(&self) -> Value {
        let st = self.state.lock().unwrap();
        let mut specs = Vec::with_capacity(self.queue.tasks().len());
        for (si, task) in self.queue.tasks().iter().enumerate() {
            let done = (task.offset..task.offset + task.n_units())
                .filter(|&g| st.delivered[g])
                .count();
            specs.push(
                Value::obj()
                    .set("index", si)
                    .set("paired", task.paired.is_some())
                    .set("total", task.n_units())
                    .set("done", done)
                    .set("rows", Value::Arr(spec_rows(task, &st))),
            );
        }
        let units_done = st.delivered.iter().filter(|&&d| d).count();
        Value::obj()
            .set("op", "status")
            .set("proto", proto::PROTO_VERSION)
            .set("specs", Value::Arr(specs))
            .set("units_total", st.delivered.len())
            .set("units_done", units_done)
            .set("units_executed", st.executed)
            .set("units_from_journal", st.from_journal)
    }
}

/// JSON-safe float for status rows: NaN/∞ (possible in degenerate
/// pools' CIs) become null rather than invalid JSON.
fn num_or_null(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

fn point_row(lambda: f64, policy: &str, res: &SimResult, reps: u32) -> Value {
    Value::obj()
        .set("lambda", num_or_null(lambda))
        .set("policy", policy)
        .set("et", num_or_null(res.mean_t_all))
        .set("etw", num_or_null(res.weighted_t))
        .set("ci95", num_or_null(res.ci95))
        .set("jain", num_or_null(res.jain))
        .set("util", num_or_null(res.utilization))
        .set("reps", reps)
}

/// Pooled rows for every point of `task` whose replications are all
/// delivered (marginal: per (λ, policy) point; paired: per (λ, policy)
/// from the shared-stream units).
fn spec_rows(task: &SpecTask, st: &State) -> Vec<Value> {
    let mut rows = Vec::new();
    match &task.paired {
        None => {
            let grid = &task.grid;
            for (p, pt) in grid.pts.iter().enumerate() {
                let (lambda, policy) = (pt.0, pt.1.to_string());
                let base = task.offset + p * grid.reps;
                if !(0..grid.reps).all(|r| st.delivered[base + r]) {
                    continue;
                }
                let wl = task.spec.workload.build(lambda);
                let mut pool = ReplicationPool::new(wl.num_classes());
                let mut display: Option<String> = None;
                for r in 0..grid.reps {
                    if let Some(AnyRun::Marginal(run)) = &st.runs[base + r] {
                        pool.absorb_stats(&run.stats);
                        display.get_or_insert_with(|| run.display.clone());
                    }
                }
                if pool.replications() == 0 {
                    continue; // every replication failed on workers
                }
                let res = pool.result(display.as_deref().unwrap_or(&policy), &wl);
                rows.push(point_row(lambda, &policy, &res, pool.replications()));
            }
        }
        Some(pg) => {
            for (li, &lambda) in pg.lambdas.iter().enumerate() {
                let base = task.offset + li * pg.reps;
                if !(0..pg.reps).all(|r| st.delivered[base + r]) {
                    continue;
                }
                let wl = task.spec.workload.build(lambda);
                for (pi, policy) in pg.policies.iter().enumerate() {
                    let policy = policy.to_string();
                    let mut pool = ReplicationPool::new(wl.num_classes());
                    let mut display: Option<String> = None;
                    for r in 0..pg.reps {
                        if let Some(AnyRun::Paired(rep)) = &st.runs[base + r] {
                            if let Some(run) = rep.runs.get(pi).and_then(|x| x.as_ref()) {
                                pool.absorb_stats(&run.stats);
                                display.get_or_insert_with(|| run.display.clone());
                            }
                        }
                    }
                    if pool.replications() == 0 {
                        continue;
                    }
                    let res = pool.result(display.as_deref().unwrap_or(&policy), &wl);
                    rows.push(point_row(lambda, &policy, &res, pool.replications()));
                }
            }
        }
    }
    rows
}

/// Read one `\n`-terminated line from an **unauthenticated** peer under
/// an absolute wall-clock deadline and a 4 KiB size cap. Returns None
/// on timeout, disconnect, or an oversized line. The per-recv socket
/// timeout is re-armed with the *remaining* time before every read, so
/// a peer trickling one byte per poll cannot stretch the handshake
/// beyond the deadline.
fn read_handshake_line(reader: &mut BufReader<TcpStream>, budget: Duration) -> Option<String> {
    const MAX_LINE: usize = 4096;
    let deadline = Instant::now() + budget;
    let mut line: Vec<u8> = Vec::new();
    loop {
        let now = Instant::now();
        if now >= deadline || line.len() >= MAX_LINE {
            return None;
        }
        if reader
            .get_ref()
            .set_read_timeout(Some(deadline - now))
            .is_err()
        {
            return None;
        }
        let buf = match reader.fill_buf() {
            Ok([]) | Err(_) => return None, // EOF, timeout, or error
            Ok(b) => b,
        };
        if let Some(pos) = buf.iter().position(|&c| c == b'\n') {
            if line.len() + pos + 1 > MAX_LINE {
                return None;
            }
            line.extend_from_slice(&buf[..=pos]);
            reader.consume(pos + 1);
            return String::from_utf8(line).ok();
        }
        let take = buf.len().min(MAX_LINE - line.len());
        line.extend_from_slice(&buf[..take]);
        reader.consume(take);
    }
}
