//! The sweep driver: partitions a [`SweepSpec`]'s unit grid, serves
//! units to TCP workers, and pools their results.
//!
//! The driver is "just another [`UnitSource`]": [`Driver::run`] hands a
//! serving source to the same [`sweep_units`] pooling path the local
//! thread runner uses, so sharded results are merged by exactly the
//! same code, in the same (replication-order) sequence, as in-process
//! results.
//!
//! Fault model: a worker that disconnects with a claimed-but-unreported
//! unit has that unit requeued; duplicate results for a unit id are
//! ignored (first wins). The driver returns once every unit has been
//! delivered or conclusively failed on a worker. There is no timeout on
//! an assigned unit while its connection stays open — a hung-but-alive
//! worker stalls the sweep (kill it to trigger reissue); multi-machine
//! auth and pacing are follow-ups tracked in ROADMAP.md.

use crate::experiments::{sweep_units, Point, SweepGrid, UnitRun, UnitSource};
use crate::sweep::{proto, SweepSpec};
use crate::workload::Workload;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// A bound (but not yet serving) sweep driver. `bind` then `run`; the
/// split lets callers learn the OS-assigned port (`addr = "host:0"`)
/// before workers are pointed at it.
pub struct Driver {
    listener: TcpListener,
    addr: SocketAddr,
    spec: SweepSpec,
}

impl Driver {
    pub fn bind(spec: &SweepSpec, addr: &str) -> anyhow::Result<Driver> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Driver {
            listener,
            addr,
            spec: spec.clone(),
        })
    }

    /// The bound address workers should connect to.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until every unit has a result, then pool. Blocks; returns
    /// the same `Vec<Point>` (bit for bit) as
    /// [`run_spec_local`](crate::sweep::run_spec_local) on this spec.
    pub fn run(self) -> anyhow::Result<Vec<Point>> {
        let grid = self.spec.grid();
        let wl_at = |l: f64| self.spec.workload.build(l);
        let mut source = Serve {
            listener: &self.listener,
            addr: self.addr,
            spec: &self.spec,
        };
        sweep_units(&grid, &wl_at, &mut source)
    }
}

/// Shared serving state, guarded by one mutex.
struct State {
    /// Unit ids not currently assigned to any live connection.
    pending: VecDeque<usize>,
    /// Per-unit "a result (success or failure) has been recorded".
    delivered: Vec<bool>,
    /// Units still without a recorded result.
    remaining: usize,
    /// Clones of every accepted connection, for shutdown at completion.
    conns: Vec<TcpStream>,
}

struct Serve<'a> {
    listener: &'a TcpListener,
    addr: SocketAddr,
    spec: &'a SweepSpec,
}

impl UnitSource for Serve<'_> {
    fn run_units(
        &mut self,
        grid: &SweepGrid,
        _wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, UnitRun) + Sync),
    ) -> anyhow::Result<()> {
        let n = grid.n_units();
        if n == 0 {
            return Ok(());
        }
        let state = Mutex::new(State {
            pending: (0..n).collect(),
            delivered: vec![false; n],
            remaining: n,
            conns: Vec::new(),
        });
        let cv = Condvar::new();
        let done = AtomicBool::new(false);
        let spec_line = proto::msg_spec(self.spec).to_string();
        let listener = self.listener;
        let addr = self.addr;
        std::thread::scope(|s| {
            s.spawn(|| {
                for conn in listener.incoming() {
                    if done.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { break };
                    if let Ok(clone) = stream.try_clone() {
                        state.lock().unwrap().conns.push(clone);
                    }
                    s.spawn(|| handle_conn(stream, &spec_line, &state, &cv, deliver));
                }
            });
            let guard = state.lock().unwrap();
            let guard = cv.wait_while(guard, |st| st.remaining > 0).unwrap();
            drop(guard);
            done.store(true, Ordering::SeqCst);
            // Wake the acceptor, then unblock every connection thread
            // still parked in a read (workers see EOF and exit). Connect
            // via loopback: the bound address may be the wildcard
            // 0.0.0.0, which is not connectable on every platform.
            let wake = SocketAddr::from(([127, 0, 0, 1], addr.port()));
            if TcpStream::connect_timeout(&wake, Duration::from_millis(200)).is_err() {
                let _ = TcpStream::connect(addr);
            }
            for c in &state.lock().unwrap().conns {
                let _ = c.shutdown(Shutdown::Both);
            }
        });
        Ok(())
    }
}

fn handle_conn(
    stream: TcpStream,
    spec_line: &str,
    state: &Mutex<State>,
    cv: &Condvar,
    deliver: &(dyn Fn(usize, UnitRun) + Sync),
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    if writeln!(writer, "{spec_line}").is_err() {
        return;
    }
    let mut reader = BufReader::new(stream);
    // Units this connection has claimed but not yet reported. The
    // lockstep protocol implies at most one, but a pipelining (or buggy)
    // client may claim several — every one of them must be reissued on
    // disconnect or the sweep hangs with units leaked.
    let mut claimed: Vec<usize> = Vec::new();
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        let Ok(msg) = proto::parse_line(&line) else {
            break;
        };
        match proto::op_of(&msg) {
            Some("next") => {
                let reply = {
                    let mut st = state.lock().unwrap();
                    if let Some(u) = st.pending.pop_front() {
                        claimed.push(u);
                        proto::msg_unit(u)
                    } else if st.remaining == 0 {
                        proto::msg_done()
                    } else {
                        // Everything is assigned elsewhere; poll again —
                        // a disconnect may requeue a unit.
                        proto::msg_wait(25)
                    }
                };
                let closing = proto::op_of(&reply) == Some("done");
                if writeln!(writer, "{reply}").is_err() || closing {
                    break;
                }
            }
            Some("result") => {
                let Ok((id, outcome)) = proto::parse_result(&msg) else {
                    break; // malformed: drop the conn, claimed unit reissues
                };
                // Claim the id first (dedupes a reissued-unit race), but
                // only decrement `remaining` AFTER delivering: the main
                // thread pools the instant it observes remaining == 0,
                // and must never see it before the last run is slotted.
                let fresh = {
                    let mut st = state.lock().unwrap();
                    if id >= st.delivered.len() || st.delivered[id] {
                        false // duplicate or garbage id
                    } else {
                        st.delivered[id] = true;
                        true
                    }
                };
                claimed.retain(|&u| u != id);
                let mut finished = false;
                if fresh {
                    match outcome {
                        Ok(run) => deliver(id, run),
                        Err(e) => eprintln!("sweep unit {id} failed on worker: {e}"),
                    }
                    let mut st = state.lock().unwrap();
                    st.remaining -= 1;
                    finished = st.remaining == 0;
                }
                // Ack BEFORE announcing completion: the worker must see
                // its last ack before the driver starts tearing down
                // connections.
                let acked = writeln!(writer, "{}", proto::msg_ok()).is_ok();
                if finished {
                    cv.notify_all();
                }
                if !acked {
                    break;
                }
            }
            _ => break,
        }
    }
    // Disconnect cleanup: requeue every claimed-but-unreported unit so
    // other workers pick them up.
    if !claimed.is_empty() {
        let mut st = state.lock().unwrap();
        for u in claimed {
            if !st.delivered[u] {
                st.pending.push_back(u);
            }
        }
    }
}
