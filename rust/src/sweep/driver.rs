//! The sweep driver: partitions a [`SweepSpec`]'s unit grid, serves
//! units to TCP workers, and pools their results.
//!
//! The driver is "just another [`UnitSource`]": [`Driver::run`] hands a
//! serving source to the same [`sweep_units`] pooling path the local
//! thread runner uses, so sharded results are merged by exactly the
//! same code, in the same (replication-order) sequence, as in-process
//! results.
//!
//! Fault model: a worker that disconnects with a claimed-but-unreported
//! unit has that unit requeued; duplicate results for a unit id are
//! ignored (first wins). The driver returns once every unit has been
//! delivered or conclusively failed on a worker. A hung-but-connected
//! worker stalls its unit indefinitely by default; setting
//! `QS_UNIT_TIMEOUT_SECS` (or [`Driver::with_unit_timeout`]) arms an
//! assignment deadline — a unit held past it is requeued to the next
//! `next` request (heterogeneous worker pacing), with the usual
//! dedupe-by-unit-id if the slow worker eventually reports anyway.
//!
//! Auth: with `QS_SWEEP_TOKEN` set (or [`Driver::with_auth_token`]),
//! the driver requires every worker's opening `hello` to carry the
//! matching shared secret before the spec is revealed; mismatches get
//! an `err` line and a closed connection. Unset = open driver (the
//! loopback/test default).

use crate::experiments::{
    sweep_paired_units, sweep_units, PairedGrid, PairedRun, PairedSweep, PairedUnitSource, Point,
    SweepGrid, UnitRun, UnitSource,
};
use crate::sweep::{proto, SweepSpec};
use crate::util::json::Value;
use crate::workload::Workload;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Optional assignment deadline from the environment: fractional seconds
/// in `QS_UNIT_TIMEOUT_SECS` (unset, empty, or non-positive = off).
fn unit_timeout_from_env() -> Option<Duration> {
    std::env::var("QS_UNIT_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|&s| s > 0.0 && s.is_finite())
        .map(Duration::from_secs_f64)
}

/// Optional shared-secret token from the environment (`QS_SWEEP_TOKEN`;
/// unset or empty = open driver, the loopback/test default).
pub(crate) fn auth_token_from_env() -> Option<String> {
    std::env::var("QS_SWEEP_TOKEN")
        .ok()
        .filter(|t| !t.is_empty())
}

/// A bound (but not yet serving) sweep driver. `bind` then `run`; the
/// split lets callers learn the OS-assigned port (`addr = "host:0"`)
/// before workers are pointed at it.
pub struct Driver {
    listener: TcpListener,
    addr: SocketAddr,
    spec: SweepSpec,
    unit_timeout: Option<Duration>,
    auth_token: Option<String>,
}

impl Driver {
    pub fn bind(spec: &SweepSpec, addr: &str) -> anyhow::Result<Driver> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Driver {
            listener,
            addr,
            spec: spec.clone(),
            unit_timeout: unit_timeout_from_env(),
            auth_token: auth_token_from_env(),
        })
    }

    /// Override the assignment deadline (`None` = never time out).
    /// `bind` seeds it from `QS_UNIT_TIMEOUT_SECS`.
    pub fn with_unit_timeout(mut self, timeout: Option<Duration>) -> Driver {
        self.unit_timeout = timeout;
        self
    }

    /// Override the shared-secret auth token (`None` = accept any
    /// peer). `bind` seeds it from `QS_SWEEP_TOKEN`; tests pin it here
    /// so parallel tests never race on process-global env state.
    pub fn with_auth_token(mut self, token: Option<String>) -> Driver {
        self.auth_token = token.filter(|t| !t.is_empty());
        self
    }

    /// The bound address workers should connect to.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until every unit has a result, then pool. Blocks; returns
    /// the same `Vec<Point>` (bit for bit) as
    /// [`run_spec_local`](crate::sweep::run_spec_local) on this spec.
    pub fn run(self) -> anyhow::Result<Vec<Point>> {
        let grid = self.spec.grid();
        let wl_at = |l: f64| self.spec.workload.build(l);
        let mut source = Serve {
            listener: &self.listener,
            addr: self.addr,
            spec: &self.spec,
            unit_timeout: self.unit_timeout,
            auth_token: self.auth_token.as_deref(),
        };
        sweep_units(&grid, &wl_at, &mut source)
    }

    /// Serve a paired (CRN) spec until every (λ, replication) unit has
    /// a result, then pool. Blocks; returns the same [`PairedSweep`]
    /// (bit for bit) as
    /// [`run_spec_paired_local`](crate::sweep::run_spec_paired_local).
    pub fn run_paired(self) -> anyhow::Result<PairedSweep> {
        let grid = self
            .spec
            .paired_grid()?
            .ok_or_else(|| anyhow::anyhow!("spec is not in paired mode"))?;
        let wl_at = |l: f64| self.spec.workload.build(l);
        let mut source = Serve {
            listener: &self.listener,
            addr: self.addr,
            spec: &self.spec,
            unit_timeout: self.unit_timeout,
            auth_token: self.auth_token.as_deref(),
        };
        sweep_paired_units(&grid, &wl_at, &mut source)
    }
}

/// Shared serving state, guarded by one mutex.
struct State {
    /// Unit ids not currently assigned to any live connection.
    pending: VecDeque<usize>,
    /// Per-unit "a result (success or failure) has been recorded".
    delivered: Vec<bool>,
    /// Per-unit current assignment: (connection id, claim instant).
    /// `None` while pending, delivered, or reissued elsewhere.
    assigned: Vec<Option<(u64, Instant)>>,
    /// Units still without a recorded result.
    remaining: usize,
    /// Clones of every accepted connection, for shutdown at completion.
    conns: Vec<TcpStream>,
}

impl State {
    /// Requeue every unit whose assignment deadline has passed. Runs at
    /// `next`-request cadence, so a stalled worker's unit becomes
    /// available exactly when some live worker asks for more work.
    fn requeue_expired(&mut self, timeout: Duration, now: Instant) {
        for u in 0..self.assigned.len() {
            if let Some((_, t0)) = self.assigned[u] {
                if !self.delivered[u] && now.duration_since(t0) > timeout {
                    self.assigned[u] = None;
                    self.pending.push_back(u);
                    eprintln!(
                        "qs-sweep driver: unit {u} held past the \
                         {}s assignment deadline; requeued",
                        timeout.as_secs_f64()
                    );
                }
            }
        }
    }
}

struct Serve<'a> {
    listener: &'a TcpListener,
    addr: SocketAddr,
    spec: &'a SweepSpec,
    unit_timeout: Option<Duration>,
    auth_token: Option<&'a str>,
}

/// How one connection's `result` lines decode, per payload type: the
/// marginal protocol parses `{display, stats}` ([`proto::parse_result`]),
/// the paired protocol a `runs` array ([`proto::parse_paired_result`]).
/// Both carry (unit id, run-or-worker-error); a line that fails to parse
/// breaks the connection so the claimed unit reissues.
type ParseResult<'p, P> =
    &'p (dyn Fn(&Value) -> anyhow::Result<(usize, Result<P, String>)> + Sync);

impl UnitSource for Serve<'_> {
    fn run_units(
        &mut self,
        grid: &SweepGrid,
        _wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, UnitRun) + Sync),
    ) -> anyhow::Result<()> {
        self.serve(grid.n_units(), &proto::parse_result, deliver)
    }
}

impl PairedUnitSource for Serve<'_> {
    fn run_paired_units(
        &mut self,
        grid: &PairedGrid,
        _wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, PairedRun) + Sync),
    ) -> anyhow::Result<()> {
        self.serve(grid.n_units(), &proto::parse_paired_result, deliver)
    }
}

impl Serve<'_> {
    /// The serving core, generic over the unit payload `P`: accept
    /// connections, hand out unit ids in lockstep, slot parsed results
    /// through `deliver`, and return once all `n` units are resolved.
    fn serve<P>(
        &mut self,
        n: usize,
        parse: ParseResult<'_, P>,
        deliver: &(dyn Fn(usize, P) + Sync),
    ) -> anyhow::Result<()> {
        if n == 0 {
            return Ok(());
        }
        let state = Mutex::new(State {
            pending: (0..n).collect(),
            delivered: vec![false; n],
            assigned: vec![None; n],
            remaining: n,
            conns: Vec::new(),
        });
        let cv = Condvar::new();
        let done = AtomicBool::new(false);
        let conn_ids = AtomicU64::new(0);
        let timeout = self.unit_timeout;
        let auth_token = self.auth_token;
        let spec_line = proto::msg_spec(self.spec).to_string();
        let listener = self.listener;
        let addr = self.addr;
        std::thread::scope(|s| {
            s.spawn(|| {
                let (state, cv, spec_line) = (&state, &cv, spec_line.as_str());
                for conn in listener.incoming() {
                    if done.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { break };
                    if let Ok(clone) = stream.try_clone() {
                        state.lock().unwrap().conns.push(clone);
                    }
                    let conn_id = conn_ids.fetch_add(1, Ordering::Relaxed);
                    s.spawn(move || {
                        handle_conn(
                            stream, conn_id, timeout, auth_token, spec_line, state, cv, parse,
                            deliver,
                        )
                    });
                }
            });
            let guard = state.lock().unwrap();
            let guard = cv.wait_while(guard, |st| st.remaining > 0).unwrap();
            drop(guard);
            done.store(true, Ordering::SeqCst);
            // Wake the acceptor, then unblock every connection thread
            // still parked in a read (workers see EOF and exit). Connect
            // via loopback: the bound address may be the wildcard
            // 0.0.0.0, which is not connectable on every platform.
            let wake = SocketAddr::from(([127, 0, 0, 1], addr.port()));
            if TcpStream::connect_timeout(&wake, Duration::from_millis(200)).is_err() {
                let _ = TcpStream::connect(addr);
            }
            for c in &state.lock().unwrap().conns {
                let _ = c.shutdown(Shutdown::Both);
            }
        });
        Ok(())
    }
}

/// Read one `\n`-terminated line from an **unauthenticated** peer under
/// an absolute wall-clock deadline and a 4 KiB size cap. Returns None
/// on timeout, disconnect, or an oversized line. The per-recv socket
/// timeout is re-armed with the *remaining* time before every read, so
/// a peer trickling one byte per poll cannot stretch the handshake
/// beyond the deadline.
fn read_handshake_line(reader: &mut BufReader<TcpStream>, budget: Duration) -> Option<String> {
    const MAX_LINE: usize = 4096;
    let deadline = Instant::now() + budget;
    let mut line: Vec<u8> = Vec::new();
    loop {
        let now = Instant::now();
        if now >= deadline || line.len() >= MAX_LINE {
            return None;
        }
        if reader.get_ref().set_read_timeout(Some(deadline - now)).is_err() {
            return None;
        }
        let buf = match reader.fill_buf() {
            Ok([]) | Err(_) => return None, // EOF, timeout, or error
            Ok(b) => b,
        };
        if let Some(pos) = buf.iter().position(|&c| c == b'\n') {
            if line.len() + pos + 1 > MAX_LINE {
                return None;
            }
            line.extend_from_slice(&buf[..=pos]);
            reader.consume(pos + 1);
            return String::from_utf8(line).ok();
        }
        let take = buf.len().min(MAX_LINE - line.len());
        line.extend_from_slice(&buf[..take]);
        reader.consume(take);
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_conn<P>(
    stream: TcpStream,
    conn_id: u64,
    unit_timeout: Option<Duration>,
    auth_token: Option<&str>,
    spec_line: &str,
    state: &Mutex<State>,
    cv: &Condvar,
    parse: ParseResult<'_, P>,
    deliver: &(dyn Fn(usize, P) + Sync),
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // Handshake: the worker speaks first. The spec (workloads, seeds,
    // grid shape) is only revealed after the hello validates — with a
    // token configured, that includes the shared secret. The peer is
    // untrusted until then, so the read is bounded by an *absolute*
    // deadline (re-armed per recv so trickled bytes cannot extend it)
    // and a byte cap: a silent, dribbling, or newline-less connection
    // cannot hold the handler thread or grow the buffer.
    let Some(line) = read_handshake_line(&mut reader, Duration::from_secs(10)) else {
        let _ = writeln!(writer, "{}", proto::msg_err("handshake timed out or too large"));
        return;
    };
    let hello = proto::parse_line(&line).and_then(|m| proto::parse_hello(&m));
    let token = match hello {
        Ok(token) => token,
        Err(e) => {
            let _ = writeln!(writer, "{}", proto::msg_err(&format!("bad hello: {e}")));
            return;
        }
    };
    if let Some(expected) = auth_token {
        if !proto::token_matches(expected, token.as_deref()) {
            eprintln!("qs-sweep driver: rejected worker (QS_SWEEP_TOKEN mismatch)");
            let _ = writeln!(writer, "{}", proto::msg_err("auth failed"));
            return;
        }
    }
    // Authenticated: back to blocking reads for the lockstep loop (a
    // slow-but-live worker is legitimate; the unit timeout handles
    // stalled assignments).
    let _ = reader.get_ref().set_read_timeout(None);
    if writeln!(writer, "{spec_line}").is_err() {
        return;
    }
    // Units this connection has claimed but not yet reported. The
    // lockstep protocol implies at most one, but a pipelining (or buggy)
    // client may claim several — every one of them must be reissued on
    // disconnect or the sweep hangs with units leaked.
    let mut claimed: Vec<usize> = Vec::new();
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        let Ok(msg) = proto::parse_line(&line) else {
            break;
        };
        match proto::op_of(&msg) {
            Some("next") => {
                let reply = {
                    let mut st = state.lock().unwrap();
                    if let Some(timeout) = unit_timeout {
                        st.requeue_expired(timeout, Instant::now());
                    }
                    if let Some(u) = st.pending.pop_front() {
                        st.assigned[u] = Some((conn_id, Instant::now()));
                        claimed.push(u);
                        proto::msg_unit(u)
                    } else if st.remaining == 0 {
                        proto::msg_done()
                    } else {
                        // Everything is assigned elsewhere; poll again —
                        // a disconnect (or an assignment timeout) may
                        // requeue a unit.
                        proto::msg_wait(25)
                    }
                };
                let closing = proto::op_of(&reply) == Some("done");
                if writeln!(writer, "{reply}").is_err() || closing {
                    break;
                }
            }
            Some("result") => {
                let Ok((id, outcome)) = parse(&msg) else {
                    break; // malformed: drop the conn, claimed unit reissues
                };
                // Claim the id first (dedupes a reissued-unit race), but
                // only decrement `remaining` AFTER delivering: the main
                // thread pools the instant it observes remaining == 0,
                // and must never see it before the last run is slotted.
                let fresh = {
                    let mut st = state.lock().unwrap();
                    if id >= st.delivered.len() || st.delivered[id] {
                        false // duplicate or garbage id
                    } else {
                        st.delivered[id] = true;
                        // Release the assignment slot only if this
                        // connection still owns it — after a timeout
                        // reissue it may belong to another worker.
                        if st.assigned[id].is_some_and(|(c, _)| c == conn_id) {
                            st.assigned[id] = None;
                        }
                        true
                    }
                };
                claimed.retain(|&u| u != id);
                let mut finished = false;
                if fresh {
                    match outcome {
                        Ok(run) => deliver(id, run),
                        Err(e) => eprintln!("sweep unit {id} failed on worker: {e}"),
                    }
                    let mut st = state.lock().unwrap();
                    st.remaining -= 1;
                    finished = st.remaining == 0;
                }
                // Ack BEFORE announcing completion: the worker must see
                // its last ack before the driver starts tearing down
                // connections.
                let acked = writeln!(writer, "{}", proto::msg_ok()).is_ok();
                if finished {
                    cv.notify_all();
                }
                if !acked {
                    break;
                }
            }
            _ => break,
        }
    }
    // Disconnect cleanup: requeue every claimed-but-unreported unit so
    // other workers pick them up — unless an assignment timeout already
    // reissued it (the unit is then pending or owned by another
    // connection, and requeueing again would double-enqueue it).
    if !claimed.is_empty() {
        let mut st = state.lock().unwrap();
        for u in claimed {
            let owned = st.assigned[u].is_some_and(|(c, _)| c == conn_id);
            if owned {
                st.assigned[u] = None;
                if !st.delivered[u] {
                    st.pending.push_back(u);
                }
            }
        }
    }
}
