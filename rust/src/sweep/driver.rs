//! The elastic sweep driver: serves a [`SpecQueue`]'s pooled unit grid
//! to TCP workers, checkpoints completed units to an append-only
//! [`Journal`], and pools the results per spec.
//!
//! Build with [`DriverBuilder`] (spec queue, bind address, auth token,
//! unit timeout, journal path, durability and overload knobs), then
//! [`Driver::serve`]. The driver is "just another [`UnitSource`]": once
//! every unit is resolved, the recorded runs are replayed per spec
//! through the same [`sweep_units`] / [`sweep_paired_units`] pooling
//! paths the local thread runner uses, so sharded, resumed, and
//! multi-spec results are merged by exactly the same code, in the same
//! (replication-order) sequence, as in-process results.
//!
//! Fault model: a worker that disconnects with claimed-but-unreported
//! units has them requeued; duplicate results for a unit id are ignored
//! (first wins — reconnecting workers *resend* unacked results, so
//! duplicates are a normal part of self-healing, not just a rogue-client
//! concern). Three independent detectors reclaim stuck units:
//!
//! * **disconnect** — the connection drops; its claimed units requeue
//!   immediately;
//! * **heartbeat staleness** — v4 workers ping between lockstep
//!   exchanges; a connection silent past the heartbeat deadline
//!   ([`DriverBuilder::heartbeat_timeout`], default 30 s) has its units
//!   requeued even though the socket still looks open, and a connection
//!   silent past 2× the deadline is dropped outright (which also bounds
//!   slow-loris handshakers);
//! * **unit timeout** — `QS_UNIT_TIMEOUT_SECS` /
//!   [`DriverBuilder::unit_timeout`] arms an assignment deadline as
//!   before (heterogeneous worker pacing), off by default.
//!
//! Overload: at the connection cap ([`DriverBuilder::max_conns`],
//! default 256) new peers get a typed `busy` line and a clean close
//! instead of a hung accept queue; workers back off and retry. All
//! counters land in [`Liveness`] (on the [`ServeReport`] and the
//! `status` endpoint).
//!
//! Durability: with a journal configured, every result is appended —
//! and with [`DriverBuilder::fsync`], `sync_all`ed — *before* the
//! worker's ack, so a driver SIGKILLed mid-sweep and restarted on the
//! same journal re-delivers finished units from disk (never rerunning
//! them) and emits byte-identical CSVs to an uninterrupted run — see
//! [`crate::sweep::journal`]. A journal append *failure* is fatal: the
//! unit is not acked, [`Driver::serve`] returns the error, and no state
//! advances past what is durably recorded.
//!
//! Auth: with `QS_SWEEP_TOKEN` set (or [`DriverBuilder::auth_token`]),
//! the driver requires every peer's opening `hello` to carry the
//! matching shared secret before the spec queue is revealed; mismatches
//! get an `err` line and a closed connection. Unset = open driver (the
//! loopback/test default). The read-only `status` op is available to
//! any authenticated peer.

use crate::coordinator::tcp::read_line_bounded;
use crate::experiments::{
    sweep_paired_units, sweep_units, PairedGrid, PairedRun, PairedSweep, PairedUnitSource, Point,
    SweepGrid, UnitRun, UnitSource,
};
use crate::sim::{ReplicationPool, SimResult};
use crate::sweep::faultline::{FaultPlan, PlanState};
use crate::sweep::journal::{Journal, JournalOptions};
use crate::sweep::{proto, AnyRun, SpecQueue, SpecTask, SweepSpec};
use crate::util::json::Value;
use crate::workload::Workload;
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, ErrorKind, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Optional assignment deadline from the environment: fractional seconds
/// in `QS_UNIT_TIMEOUT_SECS` (unset, empty, or non-positive = off).
fn unit_timeout_from_env() -> Option<Duration> {
    env_secs("QS_UNIT_TIMEOUT_SECS").unwrap_or(None)
}

/// Heartbeat deadline from the environment (`QS_HEARTBEAT_TIMEOUT_SECS`,
/// fractional seconds; ≤ 0 disables, unset = 30 s).
fn heartbeat_timeout_from_env() -> Option<Duration> {
    env_secs("QS_HEARTBEAT_TIMEOUT_SECS").unwrap_or(Some(Duration::from_secs(30)))
}

/// `Some(parsed)` when the variable is set and parseable, else `None`
/// (caller supplies the default). Inner `None` = explicitly disabled.
fn env_secs(key: &str) -> Option<Option<Duration>> {
    let v = std::env::var(key).ok()?;
    let s = v.trim().parse::<f64>().ok()?;
    Some((s > 0.0 && s.is_finite()).then(|| Duration::from_secs_f64(s)))
}

/// Connection cap from the environment (`QS_MAX_CONNS`, default 256).
fn max_conns_from_env() -> usize {
    std::env::var("QS_MAX_CONNS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(256)
}

/// Optional shared-secret token from the environment (`QS_SWEEP_TOKEN`;
/// unset or empty = open driver, the loopback/test default).
pub(crate) fn auth_token_from_env() -> Option<String> {
    std::env::var("QS_SWEEP_TOKEN")
        .ok()
        .filter(|t| !t.is_empty())
}

/// Configures and binds a sweep [`Driver`]: the spec queue, bind
/// address, shared-secret auth, assignment/heartbeat deadlines,
/// checkpoint journal, durability, overload cap, and fault plan all
/// live here. `new` seeds the environment defaults
/// (`QS_UNIT_TIMEOUT_SECS`, `QS_SWEEP_TOKEN`, `QS_JOURNAL_FSYNC`,
/// `QS_HEARTBEAT_TIMEOUT_SECS`, `QS_MAX_CONNS`, `QS_FAULT_PLAN`);
/// explicit setters override them — tests pin values here so parallel
/// tests never race on process-global env state.
pub struct DriverBuilder {
    specs: Vec<SweepSpec>,
    addr: String,
    unit_timeout: Option<Duration>,
    auth_token: Option<String>,
    journal: Option<PathBuf>,
    fsync: bool,
    heartbeat_timeout: Option<Duration>,
    max_conns: usize,
    fault_plan: Option<FaultPlan>,
}

impl DriverBuilder {
    pub fn new() -> DriverBuilder {
        let fault_plan = match FaultPlan::from_env() {
            Ok(p) => p,
            Err(e) => {
                eprintln!("qs-sweep driver: ignoring unparseable QS_FAULT_PLAN: {e}");
                None
            }
        };
        DriverBuilder {
            specs: Vec::new(),
            addr: "127.0.0.1:0".to_string(),
            unit_timeout: unit_timeout_from_env(),
            auth_token: auth_token_from_env(),
            journal: None,
            fsync: std::env::var("QS_JOURNAL_FSYNC")
                .map(|v| {
                    let v = v.trim();
                    !(v.is_empty() || v == "0" || v.eq_ignore_ascii_case("false"))
                })
                .unwrap_or(false),
            heartbeat_timeout: heartbeat_timeout_from_env(),
            max_conns: max_conns_from_env(),
            fault_plan,
        }
    }

    /// Queue one spec (may be called repeatedly; queue order defines
    /// global unit ids).
    pub fn spec(mut self, spec: &SweepSpec) -> DriverBuilder {
        self.specs.push(spec.clone());
        self
    }

    /// Queue several specs at once.
    pub fn specs<I: IntoIterator<Item = SweepSpec>>(mut self, specs: I) -> DriverBuilder {
        self.specs.extend(specs);
        self
    }

    /// The address to bind (default `127.0.0.1:0`; port 0 lets the OS
    /// pick — read it back with [`Driver::local_addr`]).
    pub fn bind_addr(mut self, addr: &str) -> DriverBuilder {
        self.addr = addr.to_string();
        self
    }

    /// Override the assignment deadline (`None` = never time out).
    pub fn unit_timeout(mut self, timeout: Option<Duration>) -> DriverBuilder {
        self.unit_timeout = timeout;
        self
    }

    /// Override the heartbeat deadline: a connection silent this long
    /// has its claimed units requeued; silent 2× this long, it is
    /// dropped (`None` disables both).
    pub fn heartbeat_timeout(mut self, timeout: Option<Duration>) -> DriverBuilder {
        self.heartbeat_timeout = timeout;
        self
    }

    /// Override the shared-secret auth token (`None` or empty = accept
    /// any peer).
    pub fn auth_token(mut self, token: Option<String>) -> DriverBuilder {
        self.auth_token = token.filter(|t| !t.is_empty());
        self
    }

    /// Checkpoint completed units to the append-only journal at `path`
    /// (created if missing). A driver restarted on the same journal
    /// resumes instead of rerunning finished units.
    pub fn journal<P: Into<PathBuf>>(mut self, path: P) -> DriverBuilder {
        self.journal = Some(path.into());
        self
    }

    /// `sync_all` every journal record to the device before the
    /// worker's ack (power-cut-safe; default is flush-to-OS only).
    pub fn fsync(mut self, on: bool) -> DriverBuilder {
        self.fsync = on;
        self
    }

    /// Cap on concurrently served connections; peers past it get a
    /// typed `busy` reply and a clean close.
    pub fn max_conns(mut self, cap: usize) -> DriverBuilder {
        self.max_conns = cap.max(1);
        self
    }

    /// Inject storage faults (torn appends, fsync-dropped tails) from a
    /// seeded plan — chaos tests only.
    pub fn fault_plan(mut self, plan: Option<FaultPlan>) -> DriverBuilder {
        self.fault_plan = plan;
        self
    }

    /// Validate the queue and bind the listener. The bind/serve split
    /// lets callers learn the OS-assigned port before workers are
    /// pointed at it.
    pub fn bind(self) -> anyhow::Result<Driver> {
        if self.specs.is_empty() {
            anyhow::bail!("no sweep specs queued");
        }
        let queue = SpecQueue::new(self.specs)?;
        let listener = TcpListener::bind(&self.addr)?;
        let addr = listener.local_addr()?;
        Ok(Driver {
            listener,
            addr,
            queue,
            unit_timeout: self.unit_timeout,
            auth_token: self.auth_token,
            journal_path: self.journal,
            fsync: self.fsync,
            heartbeat_timeout: self.heartbeat_timeout,
            max_conns: self.max_conns,
            faults: self
                .fault_plan
                .map(|p| Arc::new(Mutex::new(PlanState::new(p)))),
        })
    }
}

impl Default for DriverBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// One spec's pooled result.
pub enum SpecOutcome {
    Marginal(Vec<Point>),
    Paired(PairedSweep),
}

impl SpecOutcome {
    /// The marginal points (both variants carry them).
    pub fn points(&self) -> &[Point] {
        match self {
            SpecOutcome::Marginal(pts) => pts,
            SpecOutcome::Paired(sweep) => &sweep.points,
        }
    }

    pub fn as_paired(&self) -> Option<&PairedSweep> {
        match self {
            SpecOutcome::Marginal(_) => None,
            SpecOutcome::Paired(sweep) => Some(sweep),
        }
    }
}

/// Liveness and fault-handling counters for one serve: how many
/// connections were accepted and shed, pings seen, and units requeued
/// by each detector. Purely observational — none of it can affect
/// result bits.
#[derive(Clone, Copy, Debug, Default)]
pub struct Liveness {
    pub conns_accepted: u64,
    pub conns_shed: u64,
    pub pings: u64,
    pub heartbeat_requeues: u64,
    pub timeout_requeues: u64,
    pub disconnect_requeues: u64,
    pub idle_drops: u64,
    pub duplicates: u64,
}

impl Liveness {
    fn to_json(self) -> Value {
        Value::obj()
            .set("conns_accepted", self.conns_accepted)
            .set("conns_shed", self.conns_shed)
            .set("pings", self.pings)
            .set("heartbeat_requeues", self.heartbeat_requeues)
            .set("timeout_requeues", self.timeout_requeues)
            .set("disconnect_requeues", self.disconnect_requeues)
            .set("idle_drops", self.idle_drops)
            .set("duplicates", self.duplicates)
    }
}

/// What a [`Driver::serve`] call did: per-spec outcomes in queue order,
/// unit accounting (`units_from_journal` + `units_executed` =
/// `units_total` on a clean exit — the resume tests assert finished
/// units were served from disk, not rerun), and the [`Liveness`]
/// counters.
pub struct ServeReport {
    pub outcomes: Vec<SpecOutcome>,
    pub units_total: usize,
    pub units_from_journal: usize,
    pub units_executed: usize,
    pub liveness: Liveness,
}

/// A bound (but not yet serving) sweep driver — build one with
/// [`DriverBuilder`].
pub struct Driver {
    listener: TcpListener,
    addr: SocketAddr,
    queue: SpecQueue,
    unit_timeout: Option<Duration>,
    auth_token: Option<String>,
    journal_path: Option<PathBuf>,
    fsync: bool,
    heartbeat_timeout: Option<Duration>,
    max_conns: usize,
    faults: Option<Arc<Mutex<PlanState>>>,
}

impl Driver {
    /// The bound address workers should connect to.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until every unit in the queue has a result (from the
    /// journal or a worker), then pool per spec. Blocks; each outcome
    /// matches the corresponding
    /// [`run_spec_local`](crate::sweep::run_spec_local) /
    /// [`run_spec_paired_local`](crate::sweep::run_spec_paired_local)
    /// output bit for bit, regardless of worker count, assignment,
    /// arrival order, or intervening driver kills. Errors if a journal
    /// append ever fails: nothing past the durable record is acked, so
    /// a rerun on the same journal converges to the same bits.
    pub fn serve(self) -> anyhow::Result<ServeReport> {
        let total = self.queue.total_units();
        let mut journal = None;
        let mut entries = Vec::new();
        if let Some(path) = &self.journal_path {
            let opts = JournalOptions {
                fsync: self.fsync,
                faults: self.faults.clone(),
            };
            let (j, e) = Journal::open_with(path, &self.queue, opts)?;
            journal = Some(j);
            entries = e;
        }
        let mut runs: Vec<Option<AnyRun>> = (0..total).map(|_| None).collect();
        let mut delivered = vec![false; total];
        let from_journal = entries.len();
        for e in entries {
            let g = self
                .queue
                .global_id(e.spec, e.id)
                .expect("journal entries are validated against the queue");
            delivered[g] = true;
            runs[g] = e.run;
        }
        let pending: VecDeque<usize> = (0..total).filter(|&g| !delivered[g]).collect();
        let remaining = pending.len();
        let specs_line = proto::msg_specs(self.queue.tasks().iter().map(|t| &t.spec)).to_string();
        let svc = Service {
            queue: &self.queue,
            unit_timeout: self.unit_timeout,
            heartbeat_timeout: self.heartbeat_timeout,
            max_conns: self.max_conns,
            auth_token: self.auth_token.as_deref(),
            specs_line,
            state: Mutex::new(State {
                pending,
                delivered,
                assigned: vec![None; total],
                remaining,
                conns: Vec::new(),
                conn_seen: HashMap::new(),
                active_conns: 0,
                runs,
                journal,
                executed: 0,
                from_journal,
                fatal: None,
                live: Liveness::default(),
            }),
            cv: Condvar::new(),
            done: AtomicBool::new(false),
        };
        // A fully-journaled queue needs no workers at all: skip the
        // accept loop and go straight to pooling.
        if remaining > 0 {
            svc.serve_loop(&self.listener, self.addr);
        }
        let st = svc.state.into_inner().unwrap();
        if let Some(msg) = st.fatal {
            anyhow::bail!("sweep serve aborted: {msg}");
        }
        let executed = st.executed;
        let liveness = st.live;
        let mut all = st.runs;
        let mut outcomes = Vec::with_capacity(self.queue.tasks().len());
        for task in self.queue.tasks() {
            let tail = all.split_off(task.n_units());
            let mut source = Replay {
                runs: std::mem::replace(&mut all, tail),
            };
            let wl_at = |l: f64| task.spec.workload.build(l);
            let outcome = match &task.paired {
                Some(pg) => SpecOutcome::Paired(sweep_paired_units(pg, &wl_at, &mut source)?),
                None => SpecOutcome::Marginal(sweep_units(&task.grid, &wl_at, &mut source)?),
            };
            outcomes.push(outcome);
        }
        Ok(ServeReport {
            outcomes,
            units_total: total,
            units_from_journal: from_journal,
            units_executed: executed,
            liveness,
        })
    }

}

/// Re-delivers recorded runs (journaled or freshly served) through the
/// standard pooling paths, so resumed and multi-spec drives produce
/// byte-identical output to single-shot runs by construction.
struct Replay {
    runs: Vec<Option<AnyRun>>,
}

impl UnitSource for Replay {
    fn run_units(
        &mut self,
        _grid: &SweepGrid,
        _wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, UnitRun) + Sync),
    ) -> anyhow::Result<()> {
        for (u, run) in std::mem::take(&mut self.runs).into_iter().enumerate() {
            if let Some(AnyRun::Marginal(r)) = run {
                deliver(u, r);
            }
        }
        Ok(())
    }
}

impl PairedUnitSource for Replay {
    fn run_paired_units(
        &mut self,
        _grid: &PairedGrid,
        _wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, PairedRun) + Sync),
    ) -> anyhow::Result<()> {
        for (u, run) in std::mem::take(&mut self.runs).into_iter().enumerate() {
            if let Some(AnyRun::Paired(r)) = run {
                deliver(u, r);
            }
        }
        Ok(())
    }
}

/// Shared serving state, guarded by one mutex.
struct State {
    /// Global unit ids not currently assigned to any live connection
    /// (may contain stale entries for units delivered after a requeue;
    /// the pop path skips them).
    pending: VecDeque<usize>,
    /// Per-unit "a result (success or failure) has been recorded".
    delivered: Vec<bool>,
    /// Per-unit current assignment: (connection id, claim instant).
    /// `None` while pending, delivered, or reissued elsewhere.
    assigned: Vec<Option<(u64, Instant)>>,
    /// Units still without a recorded result.
    remaining: usize,
    /// Clones of every accepted connection, for the teardown broadcast
    /// and shutdown at completion.
    conns: Vec<TcpStream>,
    /// Last instant each live connection was heard from (any op,
    /// including heartbeat pings) — the staleness clock.
    conn_seen: HashMap<u64, Instant>,
    /// Connections currently being served (the overload cap compares
    /// against this).
    active_conns: usize,
    /// Recorded runs, slotted by global unit id (None = pending or
    /// conclusively failed).
    runs: Vec<Option<AnyRun>>,
    /// The checkpoint journal; written under this lock, *before* the
    /// worker's ack, so record order is total-ordered with delivery.
    journal: Option<Journal>,
    /// Units executed by workers during this serve (excludes journal).
    executed: usize,
    /// Units pre-delivered from the journal at startup.
    from_journal: usize,
    /// A condition no ack may advance past (journal append failure):
    /// set once, wakes the main thread, aborts the serve.
    fatal: Option<String>,
    /// Liveness counters (see [`Liveness`]).
    live: Liveness,
}

impl State {
    /// Requeue every unit whose worker is conclusively stuck: held past
    /// the assignment deadline, or owned by a connection that has gone
    /// silent past the heartbeat deadline. Runs at `next`-request
    /// cadence, so a stalled worker's unit becomes available exactly
    /// when some live worker asks for more work.
    fn requeue_dead(
        &mut self,
        unit_timeout: Option<Duration>,
        hb_timeout: Option<Duration>,
        now: Instant,
    ) {
        for u in 0..self.assigned.len() {
            let Some((conn, t0)) = self.assigned[u] else {
                continue;
            };
            if self.delivered[u] {
                continue;
            }
            if let Some(timeout) = unit_timeout {
                if now.duration_since(t0) > timeout {
                    self.assigned[u] = None;
                    self.pending.push_back(u);
                    self.live.timeout_requeues += 1;
                    eprintln!(
                        "qs-sweep driver: unit {u} held past the \
                         {}s assignment deadline; requeued",
                        timeout.as_secs_f64()
                    );
                    continue;
                }
            }
            if let Some(hb) = hb_timeout {
                // Silence is measured from the later of the claim and
                // the last message — a unit claimed a while ago by a
                // worker that pinged a second ago is healthy.
                let last = self.conn_seen.get(&conn).copied().unwrap_or(t0);
                let fresh = if last > t0 { last } else { t0 };
                if now.duration_since(fresh) > hb {
                    self.assigned[u] = None;
                    self.pending.push_back(u);
                    self.live.heartbeat_requeues += 1;
                    eprintln!(
                        "qs-sweep driver: unit {u}'s worker silent past the \
                         {}s heartbeat deadline; requeued",
                        hb.as_secs_f64()
                    );
                }
            }
        }
    }
}

/// The serving core: connection handling, unit scheduling, journaling,
/// and the status endpoint, shared by every connection thread.
struct Service<'a> {
    queue: &'a SpecQueue,
    unit_timeout: Option<Duration>,
    heartbeat_timeout: Option<Duration>,
    max_conns: usize,
    auth_token: Option<&'a str>,
    specs_line: String,
    state: Mutex<State>,
    cv: Condvar,
    done: AtomicBool,
}

/// Decode a `result` line via the owning spec's mode (the global unit
/// id picks the spec, the spec picks marginal vs paired payload). An
/// out-of-queue id or mismatched payload is an error — the connection
/// is dropped and its claimed units reissue.
fn parse_any(queue: &SpecQueue, v: &Value) -> anyhow::Result<(usize, Result<AnyRun, String>)> {
    let id = proto::id_of(v)?;
    let (si, _) = queue
        .locate(id)
        .ok_or_else(|| anyhow::anyhow!("result unit id {id} is outside the queue"))?;
    if queue.tasks()[si].paired.is_some() {
        let (id, r) = proto::parse_paired_result(v)?;
        Ok((id, r.map(AnyRun::Paired)))
    } else {
        let (id, r) = proto::parse_result(v)?;
        Ok((id, r.map(AnyRun::Marginal)))
    }
}

/// One pre-formatted line, one `write_all`: concurrent writers to the
/// same socket (a handler thread and the teardown broadcast) interleave
/// at whole-line granularity instead of tearing mid-line the way
/// `writeln!`'s many small `write_fmt` calls can.
fn write_line<W: Write>(w: &mut W, v: &Value) -> bool {
    let mut line = v.to_string();
    line.push('\n');
    w.write_all(line.as_bytes()).is_ok()
}

impl Service<'_> {
    /// Accept connections and serve until every pending unit is
    /// resolved, then broadcast `done` and shut every connection down
    /// (workers exit cleanly instead of entering their reconnect
    /// dance).
    fn serve_loop(&self, listener: &TcpListener, addr: SocketAddr) {
        let conn_ids = AtomicU64::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                for conn in listener.incoming() {
                    if self.done.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { break };
                    {
                        let mut st = self.state.lock().unwrap();
                        if st.active_conns >= self.max_conns {
                            // Overload: shed with a typed reply instead
                            // of serving (or silently dropping) the peer.
                            st.live.conns_shed += 1;
                            drop(st);
                            let mut w = &stream;
                            write_line(&mut w, &proto::msg_busy(250));
                            let _ = stream.shutdown(Shutdown::Both);
                            eprintln!(
                                "qs-sweep driver: shed connection \
                                 (at the {}-connection cap)",
                                self.max_conns
                            );
                            continue;
                        }
                        st.active_conns += 1;
                        st.live.conns_accepted += 1;
                        if let Ok(clone) = stream.try_clone() {
                            st.conns.push(clone);
                        }
                    }
                    let conn_id = conn_ids.fetch_add(1, Ordering::Relaxed);
                    s.spawn(move || self.handle_conn(stream, conn_id));
                }
            });
            let guard = self.state.lock().unwrap();
            let guard = self
                .cv
                .wait_while(guard, |st| st.remaining > 0 && st.fatal.is_none())
                .unwrap();
            drop(guard);
            self.done.store(true, Ordering::SeqCst);
            // Wake the acceptor, then tell every connection the sweep is
            // over before unblocking its read: workers parked in the
            // lockstep loop see `done` (or EOF) and exit cleanly. Connect
            // via loopback: the bound address may be the wildcard
            // 0.0.0.0, which is not connectable on every platform.
            let wake = SocketAddr::from(([127, 0, 0, 1], addr.port()));
            if TcpStream::connect_timeout(&wake, Duration::from_millis(200)).is_err() {
                let _ = TcpStream::connect(addr);
            }
            for c in &self.state.lock().unwrap().conns {
                let mut w = c;
                write_line(&mut w, &proto::msg_done());
                let _ = c.shutdown(Shutdown::Both);
            }
        });
    }

    fn handle_conn(&self, stream: TcpStream, conn_id: u64) {
        let claimed = self.conn_loop(stream, conn_id);
        // Connection accounting + disconnect cleanup: requeue every
        // claimed-but-unreported unit so other workers pick them up —
        // unless a timeout/heartbeat detector already reissued it (the
        // unit is then pending or owned by another connection, and
        // requeueing again would double-enqueue it).
        let mut st = self.state.lock().unwrap();
        st.active_conns = st.active_conns.saturating_sub(1);
        st.conn_seen.remove(&conn_id);
        for u in claimed {
            let owned = st.assigned[u].is_some_and(|(c, _)| c == conn_id);
            if owned {
                st.assigned[u] = None;
                if !st.delivered[u] {
                    st.pending.push_back(u);
                    st.live.disconnect_requeues += 1;
                    eprintln!(
                        "qs-sweep driver: connection lost holding unit {u}; requeued"
                    );
                }
            }
        }
    }

    /// The per-connection protocol loop. Returns the units this
    /// connection claimed but never reported (for requeueing).
    fn conn_loop(&self, stream: TcpStream, conn_id: u64) -> Vec<usize> {
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return Vec::new(),
        };
        let mut reader = BufReader::new(stream);
        // Handshake: the peer speaks first. The spec queue (workloads,
        // seeds, grid shapes) is only revealed after the hello validates
        // — with a token configured, that includes the shared secret.
        // The peer is untrusted until then, so the read is bounded by an
        // *absolute* deadline (re-armed per recv so trickled bytes
        // cannot extend it) and a byte cap: a silent, dribbling, or
        // newline-less connection cannot hold the handler thread or grow
        // the buffer.
        let Some(line) = read_line_bounded(&mut reader, Some(Duration::from_secs(10)), 4096)
        else {
            write_line(&mut writer, &proto::msg_err("handshake timed out or too large"));
            return Vec::new();
        };
        let hello = proto::parse_line(&line).and_then(|m| proto::parse_hello(&m));
        let token = match hello {
            Ok(token) => token,
            Err(e) => {
                write_line(&mut writer, &proto::msg_err(&format!("bad hello: {e}")));
                return Vec::new();
            }
        };
        if let Some(expected) = self.auth_token {
            if !proto::token_matches(expected, token.as_deref()) {
                eprintln!("qs-sweep driver: rejected worker (QS_SWEEP_TOKEN mismatch)");
                write_line(&mut writer, &proto::msg_err("auth failed"));
                return Vec::new();
            }
        }
        // Authenticated: the lockstep loop's reads are bounded by 2× the
        // heartbeat deadline (a live v4 worker pings well inside it; a
        // connection silent that long is dead weight even if the unit
        // detectors already requeued its work). With heartbeats disabled
        // the read blocks indefinitely, as before.
        let idle_deadline = self.heartbeat_timeout.map(|t| t * 2);
        let _ = reader.get_ref().set_read_timeout(idle_deadline);
        {
            let mut st = self.state.lock().unwrap();
            st.conn_seen.insert(conn_id, Instant::now());
        }
        let mut specs = self.specs_line.clone();
        specs.push('\n');
        if writer.write_all(specs.as_bytes()).is_err() {
            return Vec::new();
        }
        // Units this connection has claimed but not yet reported. The
        // lockstep protocol implies at most one, but a pipelining (or
        // buggy) client may claim several — every one of them must be
        // reissued on disconnect or the sweep hangs with units leaked.
        let mut claimed: Vec<usize> = Vec::new();
        let mut line = String::new();
        loop {
            line.clear();
            use std::io::BufRead;
            match reader.read_line(&mut line) {
                Ok(0) => break,
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    let mut st = self.state.lock().unwrap();
                    st.live.idle_drops += 1;
                    eprintln!(
                        "qs-sweep driver: dropping idle connection \
                         (silent past 2x the heartbeat deadline)"
                    );
                    break;
                }
                Err(_) => break,
                Ok(_) => {}
            }
            if line.trim().is_empty() {
                continue;
            }
            let Ok(msg) = proto::parse_line(&line) else {
                break;
            };
            match proto::op_of(&msg) {
                Some("ping") => {
                    // Heartbeat: refresh the staleness clock. Only echo
                    // pings get a pong — worker heartbeats are one-way,
                    // so the lockstep stream stays timing-independent.
                    let echo = msg.get("echo").and_then(|e| e.as_bool()).unwrap_or(false);
                    {
                        let mut st = self.state.lock().unwrap();
                        st.live.pings += 1;
                        st.conn_seen.insert(conn_id, Instant::now());
                    }
                    if echo && !write_line(&mut writer, &proto::msg_pong()) {
                        break;
                    }
                }
                Some("next") => {
                    let reply = {
                        let mut st = self.state.lock().unwrap();
                        st.conn_seen.insert(conn_id, Instant::now());
                        st.requeue_dead(
                            self.unit_timeout,
                            self.heartbeat_timeout,
                            Instant::now(),
                        );
                        // Skip stale pending entries: a requeued unit
                        // delivered afterwards (resend, duplicate) stays
                        // in the deque until popped here.
                        let mut next = None;
                        while let Some(u) = st.pending.pop_front() {
                            if !st.delivered[u] {
                                next = Some(u);
                                break;
                            }
                        }
                        if let Some(u) = next {
                            st.assigned[u] = Some((conn_id, Instant::now()));
                            claimed.push(u);
                            proto::msg_unit(u)
                        } else if st.remaining == 0 {
                            proto::msg_done()
                        } else {
                            // Everything is assigned elsewhere; poll
                            // again — a disconnect (or a detector)
                            // may requeue a unit.
                            proto::msg_wait(25)
                        }
                    };
                    let closing = proto::op_of(&reply) == Some("done");
                    if !write_line(&mut writer, &reply) || closing {
                        break;
                    }
                }
                Some("status") => {
                    // Read-only: answer and keep the connection open so
                    // a monitor can poll over one socket.
                    let reply = self.status_line();
                    self.state
                        .lock()
                        .unwrap()
                        .conn_seen
                        .insert(conn_id, Instant::now());
                    if !write_line(&mut writer, &reply) {
                        break;
                    }
                }
                Some("result") => {
                    let Ok((id, outcome)) = parse_any(self.queue, &msg) else {
                        break; // malformed: drop the conn, claimed unit reissues
                    };
                    // One lock covers dedupe, journal append, slotting,
                    // and the `remaining` decrement: the main thread
                    // pools the instant it observes remaining == 0 and
                    // must never see it before the run is slotted. The
                    // journal append comes FIRST — before any state
                    // mutation and before the ack — so an acked unit is
                    // guaranteed durable and a failed append leaves no
                    // trace of the unit having "happened".
                    let acked_state = {
                        let mut st = self.state.lock().unwrap();
                        st.conn_seen.insert(conn_id, Instant::now());
                        if id >= st.delivered.len() || st.delivered[id] {
                            st.live.duplicates += 1;
                            Some(false) // duplicate (first result won); ack anyway
                        } else {
                            let (si, lu) =
                                self.queue.locate(id).expect("parse_any validated the id");
                            if let Err(e) = &outcome {
                                eprintln!("sweep unit {id} failed on worker: {e}");
                            }
                            let jres = match (st.journal.as_mut(), &outcome) {
                                (Some(j), Ok(run)) => j.append_ok(si, lu, run),
                                (Some(j), Err(e)) => j.append_err(si, lu, e),
                                (None, _) => Ok(()),
                            };
                            match jres {
                                Err(e) => {
                                    let msg = format!("journal write failed: {e}");
                                    eprintln!("qs-sweep driver: {msg}");
                                    st.fatal = Some(msg);
                                    None // fatal: no ack
                                }
                                Ok(()) => {
                                    st.delivered[id] = true;
                                    // Release the assignment slot only if
                                    // this connection still owns it —
                                    // after a reissue it may belong to
                                    // another worker.
                                    if st.assigned[id].is_some_and(|(c, _)| c == conn_id) {
                                        st.assigned[id] = None;
                                    }
                                    if let Ok(run) = outcome {
                                        st.runs[id] = Some(run);
                                    }
                                    st.executed += 1;
                                    st.remaining -= 1;
                                    Some(st.remaining == 0)
                                }
                            }
                        }
                    };
                    claimed.retain(|&u| u != id);
                    match acked_state {
                        None => {
                            // Journal failure: wake the main thread to
                            // abort the serve; the worker never sees an
                            // ack for this unit, so nothing non-durable
                            // is trusted anywhere.
                            self.cv.notify_all();
                            break;
                        }
                        Some(finished) => {
                            // Ack BEFORE announcing completion: the
                            // worker must see its last ack before the
                            // driver starts tearing down connections.
                            let acked = write_line(&mut writer, &proto::msg_ok());
                            if finished {
                                self.cv.notify_all();
                            }
                            if !acked {
                                break;
                            }
                        }
                    }
                }
                _ => break,
            }
        }
        claimed
    }

    /// One JSON line of progress: top-level unit accounting and
    /// liveness counters plus a per-spec `{index, paired, total, done,
    /// rows}` array, where `rows` holds the pooled results of every
    /// point whose replications are all delivered — the same
    /// replication-order pooling the final CSVs use, computed on
    /// demand. Informational: the determinism contract applies to the
    /// final CSVs, not to mid-sweep snapshots.
    fn status_line(&self) -> Value {
        let st = self.state.lock().unwrap();
        let mut specs = Vec::with_capacity(self.queue.tasks().len());
        for (si, task) in self.queue.tasks().iter().enumerate() {
            let done = (task.offset..task.offset + task.n_units())
                .filter(|&g| st.delivered[g])
                .count();
            specs.push(
                Value::obj()
                    .set("index", si)
                    .set("paired", task.paired.is_some())
                    .set("total", task.n_units())
                    .set("done", done)
                    .set("rows", Value::Arr(spec_rows(task, &st))),
            );
        }
        let units_done = st.delivered.iter().filter(|&&d| d).count();
        Value::obj()
            .set("op", "status")
            .set("proto", proto::PROTO_VERSION)
            .set("specs", Value::Arr(specs))
            .set("units_total", st.delivered.len())
            .set("units_done", units_done)
            .set("units_executed", st.executed)
            .set("units_from_journal", st.from_journal)
            .set("live", st.live.to_json())
    }
}

/// JSON-safe float for status rows: NaN/∞ (possible in degenerate
/// pools' CIs) become null rather than invalid JSON.
fn num_or_null(x: f64) -> Value {
    if x.is_finite() {
        Value::Num(x)
    } else {
        Value::Null
    }
}

fn point_row(lambda: f64, policy: &str, res: &SimResult, reps: u32) -> Value {
    Value::obj()
        .set("lambda", num_or_null(lambda))
        .set("policy", policy)
        .set("et", num_or_null(res.mean_t_all))
        .set("etw", num_or_null(res.weighted_t))
        .set("ci95", num_or_null(res.ci95))
        .set("jain", num_or_null(res.jain))
        .set("util", num_or_null(res.utilization))
        .set("reps", reps)
}

/// Pooled rows for every point of `task` whose replications are all
/// delivered (marginal: per (λ, policy) point; paired: per (λ, policy)
/// from the shared-stream units).
fn spec_rows(task: &SpecTask, st: &State) -> Vec<Value> {
    let mut rows = Vec::new();
    match &task.paired {
        None => {
            let grid = &task.grid;
            for (p, pt) in grid.pts.iter().enumerate() {
                let (lambda, policy) = (pt.0, pt.1.to_string());
                let base = task.offset + p * grid.reps;
                if !(0..grid.reps).all(|r| st.delivered[base + r]) {
                    continue;
                }
                let wl = task.spec.workload.build(lambda);
                let mut pool = ReplicationPool::new(wl.num_classes());
                let mut display: Option<String> = None;
                for r in 0..grid.reps {
                    if let Some(AnyRun::Marginal(run)) = &st.runs[base + r] {
                        pool.absorb_stats(&run.stats);
                        display.get_or_insert_with(|| run.display.clone());
                    }
                }
                if pool.replications() == 0 {
                    continue; // every replication failed on workers
                }
                let res = pool.result(display.as_deref().unwrap_or(&policy), &wl);
                rows.push(point_row(lambda, &policy, &res, pool.replications()));
            }
        }
        Some(pg) => {
            for (li, &lambda) in pg.lambdas.iter().enumerate() {
                let base = task.offset + li * pg.reps;
                if !(0..pg.reps).all(|r| st.delivered[base + r]) {
                    continue;
                }
                let wl = task.spec.workload.build(lambda);
                for (pi, policy) in pg.policies.iter().enumerate() {
                    let policy = policy.to_string();
                    let mut pool = ReplicationPool::new(wl.num_classes());
                    let mut display: Option<String> = None;
                    for r in 0..pg.reps {
                        if let Some(AnyRun::Paired(rep)) = &st.runs[base + r] {
                            if let Some(run) = rep.runs.get(pi).and_then(|x| x.as_ref()) {
                                pool.absorb_stats(&run.stats);
                                display.get_or_insert_with(|| run.display.clone());
                            }
                        }
                    }
                    if pool.replications() == 0 {
                        continue;
                    }
                    let res = pool.result(display.as_deref().unwrap_or(&policy), &wl);
                    rows.push(point_row(lambda, &policy, &res, pool.replications()));
                }
            }
        }
    }
    rows
}
