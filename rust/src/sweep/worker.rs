//! The sweep worker: connects to a driver, rebuilds the served spec
//! *queue* ([`SpecQueue`]), and runs assigned units with the same
//! [`run_unit`] path (same per-unit seeds, same engine reuse) as the
//! in-process runner — the worker adds nothing but transport. Global
//! unit ids resolve through the queue exactly as on the driver, so a
//! worker can join an elastic sweep at any point in its life and pick
//! up whichever spec's units are pending.
//!
//! Self-healing: a worker that loses its driver mid-sweep does not die.
//! It reconnects with capped exponential backoff and deterministic
//! jitter ([`backoff_delay`]), re-authenticates, verifies the spec
//! queue is unchanged, resends any result the old connection never
//! acked (the driver dedupes by unit id — identical bits anyway), and
//! resumes claiming units. A `busy` handshake reply (overload shed)
//! goes through the same backoff. A heartbeat thread sends one-way
//! `ping` lines between lockstep exchanges so the driver can tell a
//! slow unit from a hung worker; pings bypass the fault-injection
//! transport and are never answered, so they cannot perturb the
//! deterministic message ordinals a [`FaultPlan`] fires on. The
//! [`WorkerReport`] distinguishes a clean `done` from a lost driver —
//! silent exits were how real faults used to hide.

use crate::experiments::{run_paired_unit, run_unit};
use crate::sim::Engine;
use crate::sweep::faultline::{
    backoff_delay, FaultPlan, FaultTransport, PlanState, TcpTransport, Transport,
};
use crate::sweep::{proto, SpecQueue};
use crate::util::rng::Rng;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Everything tunable about a worker's session behaviour. Execution
/// knobs only — none of it can affect result bits.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Shared secret for the driver handshake (`QS_SWEEP_TOKEN`).
    pub token: Option<String>,
    /// Consecutive failed reconnect attempts (after a successful first
    /// handshake, or while the driver sheds with `busy`) before giving
    /// up with [`WorkerOutcome::DriverLost`].
    pub max_retries: u32,
    /// First backoff delay; doubles per attempt up to `backoff_cap`.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Seed for the jitter stream — same seed, same schedule, bit for
    /// bit (give each worker of a fleet its own).
    pub backoff_seed: u64,
    /// Heartbeat ping interval (None disables the heartbeat thread).
    pub heartbeat: Option<Duration>,
    /// Fault-injection plan for chaos runs (`QS_FAULT_PLAN`).
    pub plan: Option<FaultPlan>,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        WorkerConfig {
            token: None,
            max_retries: 3,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(1),
            backoff_seed: 0xB0FF,
            heartbeat: Some(Duration::from_secs(2)),
            plan: None,
        }
    }
}

impl WorkerConfig {
    /// Config from the environment: `QS_SWEEP_TOKEN`,
    /// `QS_WORKER_RETRIES`, `QS_WORKER_BACKOFF_MS`,
    /// `QS_WORKER_BACKOFF_CAP_MS`, `QS_HEARTBEAT_SECS` (≤ 0 disables),
    /// `QS_FAULT_PLAN`. An unparseable fault plan is a hard error — a
    /// chaos run that silently tests nothing is worse than one that
    /// refuses to start.
    pub fn from_env() -> anyhow::Result<WorkerConfig> {
        let d = WorkerConfig::default();
        let ms = |v: String| v.trim().parse::<u64>().ok().map(Duration::from_millis);
        let heartbeat = match std::env::var("QS_HEARTBEAT_SECS") {
            Ok(v) => match v.trim().parse::<f64>() {
                Ok(s) if s > 0.0 => Some(Duration::from_secs_f64(s)),
                Ok(_) => None,
                Err(_) => d.heartbeat,
            },
            Err(_) => d.heartbeat,
        };
        Ok(WorkerConfig {
            token: crate::sweep::driver::auth_token_from_env(),
            max_retries: std::env::var("QS_WORKER_RETRIES")
                .ok()
                .and_then(|v| v.trim().parse().ok())
                .unwrap_or(d.max_retries),
            backoff_base: std::env::var("QS_WORKER_BACKOFF_MS")
                .ok()
                .and_then(ms)
                .unwrap_or(d.backoff_base),
            backoff_cap: std::env::var("QS_WORKER_BACKOFF_CAP_MS")
                .ok()
                .and_then(ms)
                .unwrap_or(d.backoff_cap),
            backoff_seed: d.backoff_seed,
            heartbeat,
            plan: FaultPlan::from_env()?,
        })
    }
}

/// How a worker's life ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerOutcome {
    /// The driver said `done`: the sweep is complete.
    Done,
    /// The driver disappeared and `max_retries` reconnect attempts
    /// failed.
    DriverLost,
    /// An injected `crash@U` fired (chaos runs only).
    Crashed,
}

/// What a worker did with its life: units completed *and acked*, how
/// many times it had to reconnect, how often it was shed with `busy`,
/// and how it ended.
#[derive(Clone, Copy, Debug)]
pub struct WorkerReport {
    pub completed: usize,
    pub reconnects: u32,
    pub busy_retries: u32,
    pub outcome: WorkerOutcome,
}

/// Serve one driver until it reports `done` (or is conclusively lost),
/// configured from the environment (see [`WorkerConfig::from_env`]).
pub fn run_worker(addr: &str) -> anyhow::Result<WorkerReport> {
    run_worker_with(addr, &WorkerConfig::from_env()?)
}

/// [`run_worker`] with default config and the auth token pinned
/// explicitly (tests use this so parallel tests never race on
/// process-global env state).
pub fn run_worker_with_token(addr: &str, token: Option<&str>) -> anyhow::Result<WorkerReport> {
    let cfg = WorkerConfig {
        token: token.map(|t| t.to_string()),
        ..WorkerConfig::default()
    };
    run_worker_with(addr, &cfg)
}

/// Serve one driver with an explicit [`WorkerConfig`].
///
/// Errors are reserved for conditions retrying cannot fix: the very
/// first connection failing (nothing is listening), an authentication
/// rejection, a protocol mismatch, or the spec queue changing across a
/// reconnect. Everything transient — disconnects, `busy` sheds —
/// resolves internally into the returned [`WorkerReport`].
pub fn run_worker_with(addr: &str, cfg: &WorkerConfig) -> anyhow::Result<WorkerReport> {
    let plan = cfg
        .plan
        .clone()
        .map(|p| Arc::new(Mutex::new(PlanState::new(p))));
    let short_read = cfg.plan.as_ref().and_then(|p| p.short_read());
    let mut rng = Rng::new(cfg.backoff_seed);
    let mut report = WorkerReport {
        completed: 0,
        reconnects: 0,
        busy_retries: 0,
        outcome: WorkerOutcome::DriverLost,
    };
    // Session-spanning state: the queue and engine caches are built on
    // the first handshake and reused (the specs line is checked for
    // byte-equality on every reconnect, so they cannot go stale); an
    // unacked result line survives a lost connection and is resent.
    let mut specs_line: Option<String> = None;
    let mut queue: Option<SpecQueue> = None;
    let mut caches: Vec<Option<(usize, Engine)>> = Vec::new();
    let mut unacked: Option<String> = None;
    let mut ever_connected = false;
    let mut failures = 0u32;
    loop {
        match open_session(addr, cfg, plan.clone(), short_read, &mut specs_line) {
            Ok((mut tr, writer, fresh_specs)) => {
                if ever_connected {
                    report.reconnects += 1;
                    eprintln!(
                        "qs-sweep worker: reconnected to {addr} (reconnect #{}) ",
                        report.reconnects
                    );
                }
                ever_connected = true;
                failures = 0;
                if let Some(specs) = fresh_specs {
                    let q = SpecQueue::new(specs)?;
                    caches = (0..q.tasks().len()).map(|_| None).collect();
                    queue = Some(q);
                }
                let q = queue.as_ref().expect("queue set on first handshake");
                let hb = cfg.heartbeat.map(|iv| Heartbeat::start(writer, iv));
                let hung = hb.as_ref().map(|h| h.hung.clone());
                let end = run_session(
                    tr.as_mut(),
                    q,
                    &mut caches,
                    &mut unacked,
                    &mut report.completed,
                    plan.as_ref(),
                    hung.as_ref(),
                );
                if let Some(hb) = hb {
                    hb.stop();
                }
                match end? {
                    SessionEnd::Done => {
                        report.outcome = WorkerOutcome::Done;
                        return Ok(report);
                    }
                    SessionEnd::Crashed => {
                        report.outcome = WorkerOutcome::Crashed;
                        return Ok(report);
                    }
                    SessionEnd::Lost => {} // fall through to the backoff
                }
            }
            Err(OpenErr::Fatal(e)) => return Err(e),
            Err(OpenErr::Busy(_hint_ms)) => {
                // The driver is alive but shedding. Our own deterministic
                // backoff schedule, not the advisory hint, paces retries.
                report.busy_retries += 1;
                ever_connected = true; // something is listening
            }
            Err(OpenErr::Lost(e)) => {
                if !ever_connected {
                    // Nothing has ever answered at this address: fail
                    // fast (the driver may simply not be running).
                    return Err(e);
                }
            }
        }
        failures += 1;
        if failures > cfg.max_retries {
            report.outcome = WorkerOutcome::DriverLost;
            eprintln!(
                "qs-sweep worker: driver lost ({} reconnect attempts failed)",
                cfg.max_retries
            );
            return Ok(report);
        }
        std::thread::sleep(backoff_delay(
            failures,
            cfg.backoff_base,
            cfg.backoff_cap,
            &mut rng,
        ));
    }
}

enum OpenErr {
    /// Retrying cannot fix this (auth rejection, protocol mismatch,
    /// spec queue changed).
    Fatal(anyhow::Error),
    /// Overload shed: the driver answered `busy` with a retry hint.
    Busy(u64),
    /// Transient: connect/handshake failed at the transport level.
    Lost(anyhow::Error),
}

/// Connect, authenticate, and receive the spec queue. Returns the
/// transport, the raw shared writer (for the heartbeat thread — pings
/// must bypass the fault layer), and the parsed specs when this is the
/// first successful handshake (`None` on reconnects, after the
/// byte-equality check against the first session's specs line).
fn open_session(
    addr: &str,
    cfg: &WorkerConfig,
    plan: Option<Arc<Mutex<PlanState>>>,
    short_read: Option<usize>,
    specs_line: &mut Option<String>,
) -> Result<(Box<dyn Transport>, Arc<Mutex<TcpStream>>, Option<Vec<crate::sweep::SweepSpec>>), OpenErr>
{
    let tcp = TcpTransport::connect(addr, short_read)
        .map_err(|e| OpenErr::Lost(anyhow::anyhow!("connect {addr}: {e}")))?;
    let writer = tcp.shared_writer();
    let mut tr: Box<dyn Transport> = match plan {
        Some(state) => Box::new(FaultTransport::new(tcp, state)),
        None => Box::new(tcp),
    };
    // The handshake is deadline-bounded; the lockstep loop is not (a
    // unit can legitimately take minutes, and the driver closing the
    // socket gives us EOF either way).
    tr.set_read_deadline(Some(Duration::from_secs(10)));
    tr.send_line(&proto::msg_hello(cfg.token.as_deref()).to_string())
        .map_err(|e| OpenErr::Lost(anyhow::anyhow!("handshake send: {e}")))?;
    let line = match tr.recv_line() {
        Ok(Some(l)) => l,
        Ok(None) => return Err(OpenErr::Lost(anyhow::anyhow!("driver closed mid-handshake"))),
        Err(e) => return Err(OpenErr::Lost(anyhow::anyhow!("handshake recv: {e}"))),
    };
    let first = proto::parse_line(&line)
        .map_err(|e| OpenErr::Lost(anyhow::anyhow!("handshake reply: {e}")))?;
    if let Some(msg) = proto::err_of(&first) {
        return Err(OpenErr::Fatal(anyhow::anyhow!(
            "driver rejected this worker: {msg}"
        )));
    }
    if proto::op_of(&first) == Some("busy") {
        let hint = first.get("retry_ms").and_then(|m| m.as_u64()).unwrap_or(0);
        return Err(OpenErr::Busy(hint));
    }
    let fresh = match specs_line {
        Some(prev) => {
            // Reconnect: the queue must be the *same sweep*, or pooled
            // results would silently mix experiments.
            if *prev != line {
                return Err(OpenErr::Fatal(anyhow::anyhow!(
                    "driver spec queue changed across reconnect — refusing to mix sweeps"
                )));
            }
            None
        }
        None => {
            let specs = proto::parse_specs(&first).map_err(OpenErr::Fatal)?;
            *specs_line = Some(line);
            Some(specs)
        }
    };
    tr.set_read_deadline(None);
    Ok((tr, writer, fresh))
}

enum SessionEnd {
    Done,
    Lost,
    Crashed,
}

/// Receive the next lockstep message, skipping any stray `pong`s (the
/// driver only pongs echo pings, so none are expected — this is
/// defense, not protocol). `None` = the connection is gone (EOF, error,
/// or a line torn mid-teardown).
fn recv_msg(tr: &mut dyn Transport) -> Option<crate::util::json::Value> {
    loop {
        let line = match tr.recv_line() {
            Ok(Some(l)) => l,
            Ok(None) | Err(_) => return None,
        };
        let Ok(v) = proto::parse_line(&line) else {
            return None;
        };
        if proto::op_of(&v) == Some("pong") {
            continue;
        }
        return Some(v);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_session(
    tr: &mut dyn Transport,
    queue: &SpecQueue,
    caches: &mut [Option<(usize, Engine)>],
    unacked: &mut Option<String>,
    completed: &mut usize,
    plan: Option<&Arc<Mutex<PlanState>>>,
    hung: Option<&Arc<AtomicBool>>,
) -> anyhow::Result<SessionEnd> {
    // A result the previous session sent (or tried to) without seeing
    // the ack goes out again first: the driver either never got it
    // (journals it now) or already did (dedupes) — identical bits, and
    // `ok` either way.
    if let Some(line) = unacked.clone() {
        if tr.send_line(&line).is_err() {
            return Ok(SessionEnd::Lost);
        }
        let Some(ack) = recv_msg(tr) else {
            return Ok(SessionEnd::Lost);
        };
        match proto::op_of(&ack) {
            Some("ok") => {
                *completed += 1;
                *unacked = None;
            }
            Some("done") => return Ok(SessionEnd::Done),
            other => anyhow::bail!("unexpected ack {other:?} for a resent result"),
        }
    }
    loop {
        if tr.send_line(&proto::msg_next().to_string()).is_err() {
            return Ok(SessionEnd::Lost);
        }
        let Some(msg) = recv_msg(tr) else {
            return Ok(SessionEnd::Lost);
        };
        match proto::op_of(&msg) {
            Some("unit") => {
                let g = proto::id_of(&msg)?;
                let Some((si, u)) = queue.locate(g) else {
                    anyhow::bail!("driver assigned out-of-range unit {g}");
                };
                // Chaos hooks keyed on the claim ordinal: hang (go
                // silent, heartbeats suppressed, then proceed) and
                // crash (die holding the unit).
                let (hang_ms, crash) = match plan {
                    Some(p) => p.lock().unwrap().on_claim(),
                    None => (None, false),
                };
                if let Some(ms) = hang_ms {
                    if let Some(h) = hung {
                        h.store(true, Ordering::SeqCst);
                    }
                    std::thread::sleep(Duration::from_millis(ms));
                    if let Some(h) = hung {
                        h.store(false, Ordering::SeqCst);
                    }
                }
                if crash {
                    eprintln!("qs-sweep worker: injected crash holding unit {g}");
                    tr.shutdown();
                    return Ok(SessionEnd::Crashed);
                }
                let task = &queue.tasks()[si];
                let cache = &mut caches[si];
                // Paired (CRN) specs use the (λ, replication) grid: one
                // unit runs every policy over one shared stream and
                // ships a runs array. Results carry the *global* id.
                let reply = match &task.paired {
                    Some(pg) => {
                        let (li, _) = pg.point_rep(u);
                        let wl = task.spec.workload.build(pg.lambdas[li]);
                        let run = run_paired_unit(pg, &wl, u, cache);
                        if run.runs.iter().all(|r| r.is_none()) {
                            proto::msg_result_err(g, "policy construction failed")
                        } else {
                            proto::msg_paired_result(g, &run)
                        }
                    }
                    None => {
                        let (p, _) = task.grid.point_rep(u);
                        let wl = task.spec.workload.build(task.grid.pts[p].0);
                        match run_unit(&task.grid, &wl, u, cache) {
                            Some(run) => proto::msg_result(g, &run),
                            None => proto::msg_result_err(g, "policy construction failed"),
                        }
                    }
                };
                let line = reply.to_string();
                // Armed *before* the send: a failure anywhere between
                // here and the ack leaves the result queued for resend.
                *unacked = Some(line.clone());
                if tr.send_line(&line).is_err() {
                    return Ok(SessionEnd::Lost);
                }
                let Some(ack) = recv_msg(tr) else {
                    return Ok(SessionEnd::Lost);
                };
                match proto::op_of(&ack) {
                    Some("ok") => {
                        *completed += 1;
                        *unacked = None;
                    }
                    Some("done") => return Ok(SessionEnd::Done),
                    other => anyhow::bail!("unexpected ack {other:?} for a result"),
                }
            }
            Some("wait") => {
                let ms = msg.get("ms").and_then(|m| m.as_u64()).unwrap_or(25);
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some("done") => return Ok(SessionEnd::Done),
            other => anyhow::bail!("unexpected driver message {other:?}"),
        }
    }
}

/// The heartbeat thread: one-way `ping` lines through the *raw* shared
/// writer (single `write_all` per line, serialized with the lockstep
/// sends by the writer mutex; bypassing the fault transport keeps the
/// plan's message ordinals ping-free). Suppressed while an injected
/// hang is simulating a stuck worker — that is the very condition
/// heartbeats exist to expose.
struct Heartbeat {
    stop: Arc<AtomicBool>,
    hung: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    fn start(writer: Arc<Mutex<TcpStream>>, interval: Duration) -> Heartbeat {
        let stop = Arc::new(AtomicBool::new(false));
        let hung = Arc::new(AtomicBool::new(false));
        let (stop2, hung2) = (stop.clone(), hung.clone());
        let mut line = proto::msg_ping(false).to_string();
        line.push('\n');
        let handle = std::thread::spawn(move || {
            use std::io::Write;
            let mut last = Instant::now();
            loop {
                std::thread::sleep(Duration::from_millis(25));
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if hung2.load(Ordering::SeqCst) {
                    last = Instant::now(); // a hung worker sends nothing
                    continue;
                }
                if last.elapsed() >= interval {
                    let sent = writer.lock().unwrap().write_all(line.as_bytes());
                    if sent.is_err() {
                        break; // connection gone; the session will notice
                    }
                    last = Instant::now();
                }
            }
        });
        Heartbeat {
            stop,
            hung,
            handle: Some(handle),
        }
    }

    fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
