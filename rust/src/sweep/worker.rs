//! The sweep worker: connects to a driver, rebuilds the served spec
//! *queue* ([`SpecQueue`]), and runs assigned units with the same
//! [`run_unit`] path (same per-unit seeds, same engine reuse) as the
//! in-process runner — the worker adds nothing but transport. Global
//! unit ids resolve through the queue exactly as on the driver, so a
//! worker can join an elastic sweep at any point in its life and pick
//! up whichever spec's units are pending.

use crate::experiments::{run_paired_unit, run_unit};
use crate::sim::Engine;
use crate::sweep::{proto, SpecQueue};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Serve one driver until it reports `done` (or disappears — once the
/// handshake succeeded, a lost connection means the driver finished,
/// died and will be resumed from its journal, or will reissue our unit
/// elsewhere, so the worker exits cleanly either way), authenticating
/// with the `QS_SWEEP_TOKEN` shared secret when set. Returns the number
/// of units completed and acknowledged.
pub fn run_worker(addr: &str) -> anyhow::Result<usize> {
    let token = crate::sweep::driver::auth_token_from_env();
    run_worker_with_token(addr, token.as_deref())
}

/// [`run_worker`] with the auth token pinned explicitly (tests use this
/// so parallel tests never race on process-global env state).
pub fn run_worker_with_token(addr: &str, token: Option<&str>) -> anyhow::Result<usize> {
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Handshake: hello (version + optional shared secret) before the
    // driver reveals the spec queue; an `err` reply means rejection.
    writeln!(writer, "{}", proto::msg_hello(token))?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let first = proto::parse_line(&line)?;
    if let Some(msg) = proto::err_of(&first) {
        anyhow::bail!("driver rejected this worker: {msg}");
    }
    let queue = SpecQueue::new(proto::parse_specs(&first)?)?;
    // Engine caches, one per spec: consecutive units of the same point
    // reuse one engine's allocations (reset is bit-identical to fresh).
    // Specs differ in workload/config, so caches never cross specs.
    let mut caches: Vec<Option<(usize, Engine)>> = (0..queue.tasks().len()).map(|_| None).collect();
    let mut completed = 0usize;
    loop {
        if writeln!(writer, "{}", proto::msg_next()).is_err() {
            break;
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let Ok(msg) = proto::parse_line(&line) else {
            break; // torn line mid-teardown: treat as driver gone
        };
        match proto::op_of(&msg) {
            Some("unit") => {
                let g = proto::id_of(&msg)?;
                let Some((si, u)) = queue.locate(g) else {
                    anyhow::bail!("driver assigned out-of-range unit {g}");
                };
                let task = &queue.tasks()[si];
                let cache = &mut caches[si];
                // Paired (CRN) specs use the (λ, replication) grid: one
                // unit runs every policy over one shared stream and
                // ships a runs array. Results carry the *global* id.
                let reply = match &task.paired {
                    Some(pg) => {
                        let (li, _) = pg.point_rep(u);
                        let wl = task.spec.workload.build(pg.lambdas[li]);
                        let run = run_paired_unit(pg, &wl, u, cache);
                        if run.runs.iter().all(|r| r.is_none()) {
                            proto::msg_result_err(g, "policy construction failed")
                        } else {
                            proto::msg_paired_result(g, &run)
                        }
                    }
                    None => {
                        let (p, _) = task.grid.point_rep(u);
                        let wl = task.spec.workload.build(task.grid.pts[p].0);
                        match run_unit(&task.grid, &wl, u, cache) {
                            Some(run) => proto::msg_result(g, &run),
                            None => proto::msg_result_err(g, "policy construction failed"),
                        }
                    }
                };
                if writeln!(writer, "{reply}").is_err() {
                    break;
                }
                line.clear();
                match reader.read_line(&mut line) {
                    Ok(0) | Err(_) => break, // ack lost: driver gone
                    Ok(_) => completed += 1,
                }
            }
            Some("wait") => {
                let ms = msg.get("ms").and_then(|m| m.as_u64()).unwrap_or(25);
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some("done") => break,
            other => anyhow::bail!("unexpected driver message {other:?}"),
        }
    }
    Ok(completed)
}
