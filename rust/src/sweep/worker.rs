//! The sweep worker: connects to a driver, rebuilds the sweep from the
//! served [`SweepSpec`](crate::sweep::SweepSpec), and runs assigned
//! units with the same [`run_unit`] path (same per-unit seeds, same
//! engine reuse) as the in-process runner — the worker adds nothing but
//! transport.

use crate::experiments::{run_paired_unit, run_unit};
use crate::sim::Engine;
use crate::sweep::proto;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Serve one driver until it reports `done` (or disappears — once the
/// handshake succeeded, a lost connection means the driver finished or
/// will reissue our unit elsewhere, so the worker exits cleanly either
/// way), authenticating with the `QS_SWEEP_TOKEN` shared secret when
/// set. Returns the number of units completed and acknowledged.
pub fn run_worker(addr: &str) -> anyhow::Result<usize> {
    let token = crate::sweep::driver::auth_token_from_env();
    run_worker_with_token(addr, token.as_deref())
}

/// [`run_worker`] with the auth token pinned explicitly (tests use this
/// so parallel tests never race on process-global env state).
pub fn run_worker_with_token(addr: &str, token: Option<&str>) -> anyhow::Result<usize> {
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Handshake: hello (version + optional shared secret) before the
    // driver reveals the spec; an `err` reply means we were rejected.
    writeln!(writer, "{}", proto::msg_hello(token))?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let first = proto::parse_line(&line)?;
    if let Some(msg) = proto::err_of(&first) {
        anyhow::bail!("driver rejected this worker: {msg}");
    }
    let spec = proto::parse_spec(&first)?;
    let grid = spec.grid();
    // Paired (CRN) sweeps flip to the (λ, replication) grid: one unit
    // runs every policy over one shared stream and ships a runs array.
    let paired = spec.paired_grid()?;
    let n_units = match &paired {
        Some(pg) => pg.n_units(),
        None => grid.n_units(),
    };
    // Engine cache: consecutive units of the same point reuse one
    // engine's allocations (reset is bit-identical to fresh).
    let mut cache: Option<(usize, Engine)> = None;
    let mut completed = 0usize;
    loop {
        if writeln!(writer, "{}", proto::msg_next()).is_err() {
            break;
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let Ok(msg) = proto::parse_line(&line) else {
            break; // torn line mid-teardown: treat as driver gone
        };
        match proto::op_of(&msg) {
            Some("unit") => {
                let u = proto::id_of(&msg)?;
                if u >= n_units {
                    anyhow::bail!("driver assigned out-of-range unit {u}");
                }
                let reply = match &paired {
                    Some(pg) => {
                        let (li, _) = pg.point_rep(u);
                        let wl = spec.workload.build(pg.lambdas[li]);
                        let run = run_paired_unit(pg, &wl, u, &mut cache);
                        if run.runs.iter().all(|r| r.is_none()) {
                            proto::msg_result_err(u, "policy construction failed")
                        } else {
                            proto::msg_paired_result(u, &run)
                        }
                    }
                    None => {
                        let (p, _) = grid.point_rep(u);
                        let wl = spec.workload.build(grid.pts[p].0);
                        match run_unit(&grid, &wl, u, &mut cache) {
                            Some(run) => proto::msg_result(u, &run),
                            None => proto::msg_result_err(u, "policy construction failed"),
                        }
                    }
                };
                if writeln!(writer, "{reply}").is_err() {
                    break;
                }
                line.clear();
                match reader.read_line(&mut line) {
                    Ok(0) | Err(_) => break, // ack lost: driver gone
                    Ok(_) => completed += 1,
                }
            }
            Some("wait") => {
                let ms = msg.get("ms").and_then(|m| m.as_u64()).unwrap_or(25);
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some("done") => break,
            other => anyhow::bail!("unexpected driver message {other:?}"),
        }
    }
    Ok(completed)
}
