//! Sweep wire protocol: one JSON object per line over TCP (the same
//! JSONL idiom as the coordinator's control API).
//!
//! Handshake (proto v4): on connect the **worker speaks first** with a
//! `hello` line carrying the protocol version and, when configured, the
//! shared secret (`QS_SWEEP_TOKEN`). The driver validates both before
//! revealing anything: a mismatched token or version gets an `err` line
//! and a closed connection — the spec queue (which names workloads,
//! seeds and grid shapes) is never sent to an unauthenticated peer.
//! With the token unset on both sides the handshake is a bare `hello`
//! (loopback tests and single-machine runs need no configuration).
//!
//! The driver's reply is the full **spec queue** (v3: a `specs` array —
//! an elastic driver serves several sweeps, mixed paired/unpaired, from
//! one pooled unit scheduler, and every connection sees the same
//! queue). Unit ids are *global* across the queue: spec offsets are
//! the cumulative unit counts in queue order, a pure function of the
//! queue that driver and workers compute identically
//! ([`SpecQueue`](crate::sweep::SpecQueue)). From then on the peer
//! drives a lockstep request/response loop:
//!
//! ```text
//! worker → driver   {"op":"hello","proto":4[,"token":"..."]}
//! driver → worker   {"op":"specs","proto":4,"specs":[...]}
//!                   | {"op":"err","msg":"..."} | {"op":"busy","retry_ms":M}
//! worker → driver   {"op":"next"}
//! driver → worker   {"op":"unit","id":N} | {"op":"wait","ms":M} | {"op":"done"}
//! worker → driver   {"op":"result","id":N,"display":...,"stats":{...}}
//!                   | {"op":"result","id":N,"runs":[...]}        (paired spec)
//!                   | {"op":"result","id":N,"err":"..."}
//! driver → worker   {"op":"ok"}
//! ```
//!
//! Any authenticated peer may instead send `{"op":"status"}` at any
//! point in the loop and gets one JSON line of per-spec progress and
//! completed pooled rows back — the read-only endpoint `quickswap sweep
//! status` uses this without ever claiming a unit.
//!
//! v4 adds three additive liveness/overload messages:
//!
//! * `{"op":"ping"}` — a worker's heartbeat. Its *heartbeat thread*
//!   sends these between lockstep exchanges so the driver can tell a
//!   hung-but-connected worker from a slow unit. Plain pings get **no
//!   reply** (a pong would interleave with the lockstep stream and make
//!   the worker's receive sequence timing-dependent); the driver just
//!   refreshes the connection's liveness stamp. `{"op":"ping",
//!   "echo":true}` — used by probes *outside* the lockstep loop —
//!   gets `{"op":"pong"}` back.
//! * `{"op":"busy","retry_ms":M}` — overload shedding: a driver at its
//!   connection cap answers the handshake with `busy` and closes.
//!   Workers back off (their own deterministic schedule; `retry_ms` is
//!   an advisory hint) and reconnect instead of dying.
//!
//! Every statistic inside `stats` uses bit-exact f64 encoding
//! ([`crate::util::json::f64_bits`]) — the determinism contract depends
//! on nothing being lost in transit. The driver's checkpoint journal
//! ([`crate::sweep::journal`]) reuses the same result encodings, so a
//! resumed sweep replays exactly the bits a live worker shipped.

use crate::experiments::{PairedRun, UnitRun};
use crate::sim::UnitStats;
use crate::sweep::SweepSpec;
use crate::util::json::Value;

/// Bumped on incompatible wire changes; driver and worker must agree.
/// v2: worker-first `hello` handshake with the optional shared secret.
/// v3: multi-spec queue (`specs` array reply, global unit ids) and the
/// read-only `status` op.
/// v4: `ping`/`pong` heartbeats and the `busy` overload-shed reply.
pub const PROTO_VERSION: u64 = 4;

/// The driver's handshake reply: the entire spec queue, in the order
/// that defines global unit offsets.
pub fn msg_specs<'a, I: IntoIterator<Item = &'a SweepSpec>>(specs: I) -> Value {
    let arr: Vec<Value> = specs.into_iter().map(|s| s.to_json()).collect();
    Value::obj()
        .set("op", "specs")
        .set("proto", PROTO_VERSION)
        .set("specs", Value::Arr(arr))
}

/// The worker's opening line: protocol version plus the optional
/// shared-secret token.
pub fn msg_hello(token: Option<&str>) -> Value {
    let v = Value::obj().set("op", "hello").set("proto", PROTO_VERSION);
    match token {
        Some(t) => v.set("token", t),
        None => v,
    }
}

/// Driver-side rejection (auth failure, version mismatch).
pub fn msg_err(msg: &str) -> Value {
    Value::obj().set("op", "err").set("msg", msg)
}

/// The `err` message's payload, if this is one.
pub fn err_of(v: &Value) -> Option<&str> {
    if op_of(v) == Some("err") {
        v.get("msg").and_then(|m| m.as_str()).or(Some("unspecified"))
    } else {
        None
    }
}

/// Decode a `hello`: checks op and protocol version, returns the token.
pub fn parse_hello(v: &Value) -> anyhow::Result<Option<String>> {
    if op_of(v) != Some("hello") {
        anyhow::bail!("expected a 'hello' message, got {:?}", op_of(v));
    }
    let proto = v.get("proto").and_then(|p| p.as_u64()).unwrap_or(0);
    if proto != PROTO_VERSION {
        anyhow::bail!("protocol mismatch: worker speaks v{proto}, driver v{PROTO_VERSION}");
    }
    Ok(v.get("token")
        .and_then(|t| t.as_str())
        .map(|t| t.to_string()))
}

/// Constant-time-ish token comparison (no early exit on the first
/// differing byte; the length term must not be truncated, or lengths
/// differing by a multiple of 256 would compare prefixes only).
pub fn token_matches(expected: &str, got: Option<&str>) -> bool {
    let got = got.unwrap_or("");
    let mut diff = u8::from(expected.len() != got.len());
    for (a, b) in expected.bytes().zip(got.bytes()) {
        diff |= a ^ b;
    }
    diff == 0
}

pub fn msg_next() -> Value {
    Value::obj().set("op", "next")
}

/// Heartbeat. `echo = false` is the worker heartbeat thread's one-way
/// keepalive (never answered — see the module docs for why); `echo =
/// true` requests a `pong` and is for probes outside the lockstep loop.
pub fn msg_ping(echo: bool) -> Value {
    let v = Value::obj().set("op", "ping");
    if echo {
        v.set("echo", true)
    } else {
        v
    }
}

/// Reply to an echo ping.
pub fn msg_pong() -> Value {
    Value::obj().set("op", "pong")
}

/// Overload shed: the driver is at its connection cap; retry later.
pub fn msg_busy(retry_ms: u64) -> Value {
    Value::obj().set("op", "busy").set("retry_ms", retry_ms)
}

/// Read-only progress query (any authenticated peer, any time).
pub fn msg_status_req() -> Value {
    Value::obj().set("op", "status")
}

pub fn msg_unit(id: usize) -> Value {
    Value::obj().set("op", "unit").set("id", id)
}

pub fn msg_wait(ms: u64) -> Value {
    Value::obj().set("op", "wait").set("ms", ms)
}

pub fn msg_done() -> Value {
    Value::obj().set("op", "done")
}

pub fn msg_ok() -> Value {
    Value::obj().set("op", "ok")
}

pub fn msg_result(id: usize, run: &UnitRun) -> Value {
    Value::obj()
        .set("op", "result")
        .set("id", id)
        .set("display", run.display.as_str())
        .set("stats", run.stats.to_json())
}

pub fn msg_result_err(id: usize, err: &str) -> Value {
    Value::obj().set("op", "result").set("id", id).set("err", err)
}

/// Result line for one *paired* unit: all policies' runs over the
/// unit's shared stream, as a `runs` array (null = failed policy).
/// Which shape a unit uses is determined by its owning spec's
/// `paired` flag — both sides resolve the global unit id through the
/// same [`SpecQueue`](crate::sweep::SpecQueue) before encoding.
pub fn msg_paired_result(id: usize, run: &PairedRun) -> Value {
    Value::obj()
        .set("op", "result")
        .set("id", id)
        .set("runs", run.to_json())
}

/// Parse one wire line into a JSON value.
pub fn parse_line(line: &str) -> anyhow::Result<Value> {
    Value::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad wire json: {e}"))
}

/// The message's `op` field.
pub fn op_of(v: &Value) -> Option<&str> {
    v.get("op").and_then(|o| o.as_str())
}

/// The message's `id` field as a unit index.
pub fn id_of(v: &Value) -> anyhow::Result<usize> {
    v.get("id")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| anyhow::anyhow!("message missing 'id'"))
}

/// Decode a `specs` message into the spec queue (order defines the
/// global unit offsets).
pub fn parse_specs(v: &Value) -> anyhow::Result<Vec<SweepSpec>> {
    if op_of(v) != Some("specs") {
        anyhow::bail!("expected a 'specs' message, got {:?}", op_of(v));
    }
    let proto = v.get("proto").and_then(|p| p.as_u64()).unwrap_or(0);
    if proto != PROTO_VERSION {
        anyhow::bail!("protocol mismatch: driver speaks v{proto}, worker v{PROTO_VERSION}");
    }
    let arr = v
        .get("specs")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow::anyhow!("specs message missing 'specs'"))?;
    if arr.is_empty() {
        anyhow::bail!("specs message carries an empty queue");
    }
    arr.iter().map(SweepSpec::from_json).collect()
}

/// Decode a `result` message into (unit id, run-or-error).
pub fn parse_result(v: &Value) -> anyhow::Result<(usize, Result<UnitRun, String>)> {
    let id = id_of(v)?;
    if let Some(err) = v.get("err").and_then(|e| e.as_str()) {
        return Ok((id, Err(err.to_string())));
    }
    let display = v
        .get("display")
        .and_then(|d| d.as_str())
        .ok_or_else(|| anyhow::anyhow!("result missing 'display'"))?
        .to_string();
    let stats = v
        .get("stats")
        .ok_or_else(|| anyhow::anyhow!("result missing 'stats'"))
        .and_then(UnitStats::from_json)?;
    Ok((id, Ok(UnitRun { stats, display })))
}

/// Decode a paired `result` message into (unit id, runs-or-error).
pub fn parse_paired_result(v: &Value) -> anyhow::Result<(usize, Result<PairedRun, String>)> {
    let id = id_of(v)?;
    if let Some(err) = v.get("err").and_then(|e| e.as_str()) {
        return Ok((id, Err(err.to_string())));
    }
    let runs = v
        .get("runs")
        .ok_or_else(|| anyhow::anyhow!("paired result missing 'runs'"))
        .and_then(PairedRun::from_json)?;
    Ok((id, Ok(runs)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::WorkloadSpec;

    fn spec(seed: u64) -> SweepSpec {
        SweepSpec {
            workload: WorkloadSpec::FourClass,
            lambdas: vec![2.0],
            policies: vec![crate::policy::PolicyId::Msf],
            target_completions: 1000,
            warmup_completions: 200,
            batch: 100,
            seed,
            replications: 2,
            paired: false,
            baseline: None,
            trace: None,
        }
    }

    #[test]
    fn specs_message_roundtrip() {
        let a = spec(9);
        let mut b = spec(10);
        b.paired = true;
        let wire = msg_specs([&a, &b]).to_string();
        let back = parse_specs(&parse_line(&wire).unwrap()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].seed, 9);
        assert_eq!(back[1].seed, 10);
        assert!(!back[0].paired && back[1].paired);
        // Version mismatch and empty queue are rejected.
        let stale = msg_specs([&a]).set("proto", 999u64);
        assert!(parse_specs(&stale).is_err());
        let empty = msg_specs(std::iter::empty::<&SweepSpec>());
        assert!(parse_specs(&empty).is_err());
        // A v2-style single-spec message does not decode.
        let v2ish = Value::obj()
            .set("op", "spec")
            .set("proto", PROTO_VERSION)
            .set("spec", a.to_json());
        assert!(parse_specs(&v2ish).is_err());
    }

    #[test]
    fn status_request_shape() {
        let v = parse_line(&msg_status_req().to_string()).unwrap();
        assert_eq!(op_of(&v), Some("status"));
    }

    #[test]
    fn result_error_roundtrip() {
        let wire = msg_result_err(7, "no such policy").to_string();
        let (id, run) = parse_result(&parse_line(&wire).unwrap()).unwrap();
        assert_eq!(id, 7);
        assert_eq!(run.unwrap_err(), "no such policy");
        // The same error line decodes on the paired path too.
        let (id, run) = parse_paired_result(&parse_line(&wire).unwrap()).unwrap();
        assert_eq!(id, 7);
        assert_eq!(run.unwrap_err(), "no such policy");
    }

    #[test]
    fn paired_result_roundtrip() {
        use crate::sim::Metrics;
        let mut m = Metrics::new(1, 5);
        for i in 0..12 {
            m.record_response(0, 1.0 + i as f64 * 0.125);
        }
        m.flush_responses();
        let run = PairedRun {
            runs: vec![
                None,
                Some(UnitRun {
                    stats: crate::sim::UnitStats::from_metrics(&m, 4.0, 30, 0.002),
                    display: "FCFS".into(),
                }),
            ],
        };
        let wire = msg_paired_result(3, &run).to_string();
        let (id, back) = parse_paired_result(&parse_line(&wire).unwrap()).unwrap();
        assert_eq!(id, 3);
        let back = back.unwrap();
        assert!(back.runs[0].is_none());
        let (a, b) = (run.runs[1].as_ref().unwrap(), back.runs[1].as_ref().unwrap());
        assert_eq!(a.display, b.display);
        assert_eq!(a.stats.to_json().to_string(), b.stats.to_json().to_string());
        // A paired line is not a valid marginal result (missing stats).
        assert!(parse_result(&parse_line(&wire).unwrap()).is_err());
    }

    #[test]
    fn hello_roundtrip_and_version_check() {
        let bare = parse_hello(&parse_line(&msg_hello(None).to_string()).unwrap()).unwrap();
        assert_eq!(bare, None);
        let tok =
            parse_hello(&parse_line(&msg_hello(Some("sesame")).to_string()).unwrap()).unwrap();
        assert_eq!(tok.as_deref(), Some("sesame"));
        let stale = msg_hello(None).set("proto", 2u64);
        assert!(parse_hello(&stale).is_err());
        assert!(parse_hello(&msg_next()).is_err());
    }

    #[test]
    fn token_comparison() {
        assert!(token_matches("abc", Some("abc")));
        assert!(!token_matches("abc", Some("abd")));
        assert!(!token_matches("abc", Some("ab")));
        assert!(!token_matches("abc", None));
        assert!(token_matches("", None), "unset on both sides matches");
    }

    #[test]
    fn liveness_messages() {
        let plain = parse_line(&msg_ping(false).to_string()).unwrap();
        assert_eq!(op_of(&plain), Some("ping"));
        assert!(plain.get("echo").is_none(), "plain pings carry no echo flag");
        let echo = parse_line(&msg_ping(true).to_string()).unwrap();
        assert_eq!(echo.get("echo").and_then(|e| e.as_bool()), Some(true));
        assert_eq!(op_of(&msg_pong()), Some("pong"));
        let busy = parse_line(&msg_busy(250).to_string()).unwrap();
        assert_eq!(op_of(&busy), Some("busy"));
        assert_eq!(busy.get("retry_ms").and_then(|m| m.as_u64()), Some(250));
    }

    #[test]
    fn err_message_payload() {
        let e = parse_line(&msg_err("auth failed").to_string()).unwrap();
        assert_eq!(err_of(&e), Some("auth failed"));
        assert_eq!(err_of(&msg_next()), None);
    }
}
