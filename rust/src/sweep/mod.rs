//! Elastic sweep service: distribute a *queue* of sweeps' (λ, policy,
//! replication) unit grids across worker processes, with durable
//! checkpoint/resume.
//!
//! A [`SweepSpec`] is a self-contained, JSON-serializable description of
//! a sweep (workload family, λ grid, policies, run lengths, seed,
//! replication count) — the shardable form of an experiment harness. A
//! [`SpecQueue`] lines up several specs (multi-figure, mixed
//! paired/unpaired) behind *global* unit ids: spec offsets are the
//! cumulative unit counts in queue order, a pure function of the queue
//! that driver and workers compute identically. A [`Driver`] — built
//! with [`DriverBuilder`] and run with [`Driver::serve`] — serves units
//! from one pooled scheduler to [`run_worker`] processes over the
//! coordinator's TCP JSONL idiom (`util::json`, one object per line;
//! see [`proto`]), and pools returned
//! [`UnitStats`](crate::sim::UnitStats) into the same
//! [`ReplicationPool`](crate::sim::ReplicationPool) CIs the in-process
//! runner produces.
//!
//! **Determinism contract:** at equal (spec), a sharded run is
//! bit-identical to [`run_spec_local`] — regardless of worker count,
//! unit-to-worker assignment, result arrival order, or how many times
//! the driver was killed and resumed along the way. The pieces that
//! make this hold:
//!
//! * per-unit seeds are a pure function of (seed, point, rep);
//! * workers ship accumulators with bit-exact f64 encoding
//!   ([`crate::util::json::f64_bits`]), so nothing is lost in transit;
//! * the driver pools each point's replications in replication order
//!   (results are slotted by unit id, not arrival order);
//! * engine reuse across units is bit-identical to fresh construction;
//! * the checkpoint [`journal`] stores the same bit-exact encodings the
//!   wire ships, so resumed units replay the exact bits a worker sent.
//!
//! Elasticity and fault handling: authenticated workers join and leave
//! at any time (a disconnect requeues its outstanding units; stragglers
//! are requeued on a timeout); duplicate results for a unit are deduped
//! by unit id (first wins — identical bits anyway); with a journal
//! configured, a SIGKILLed driver restarted on the same journal serves
//! finished units from disk instead of rerunning them. A read-only
//! `status` op streams per-spec progress and completed pooled rows as
//! JSON while the sweep runs. `scripts/sweep_smoke.sh` runs 1 driver +
//! 2 workers on localhost, diffs against the in-process CSV, and
//! SIGKILLs/resumes the driver mid-sweep; CI runs it as the
//! `sweep-smoke` job.
//!
//! The self-healing pieces — worker reconnect with seeded backoff,
//! protocol heartbeats, crash-consistent journal/CSV storage, and
//! overload shedding — are exercised by the deterministic fault
//! injection layer in [`faultline`] (`QS_FAULT_PLAN`), with the chaos
//! matrix in `tests/integration_chaos.rs` asserting byte-identical CSVs
//! under every plan.

pub mod driver;
pub mod faultline;
pub mod journal;
pub mod proto;
pub mod worker;

pub use driver::{Driver, DriverBuilder, Liveness, ServeReport, SpecOutcome};
pub use worker::{
    run_worker, run_worker_with, run_worker_with_token, WorkerConfig, WorkerOutcome, WorkerReport,
};

use crate::experiments::{
    sweep_paired_units, sweep_units, LocalThreads, PairedGrid, PairedRun, PairedSweep, Point,
    SweepGrid, TraceShards, UnitRun,
};
use crate::policy::PolicyId;
use crate::sim::SimConfig;
use crate::util::json::Value;
use crate::workload::{borg::borg_workload, Workload};

/// A named workload family a worker can rebuild from parameters alone.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadSpec {
    OneOrAll { k: u32, p1: f64, mu1: f64, muk: f64 },
    FourClass,
    Borg,
    /// 2-resource (servers × memory) family; see [`Workload::multires`].
    Multires { k: u32, mem: u32 },
}

impl WorkloadSpec {
    /// Instantiate the workload at total arrival rate `lambda`.
    pub fn build(&self, lambda: f64) -> Workload {
        match *self {
            WorkloadSpec::OneOrAll { k, p1, mu1, muk } => {
                Workload::one_or_all(k, lambda, p1, mu1, muk)
            }
            WorkloadSpec::FourClass => Workload::four_class(lambda),
            WorkloadSpec::Borg => borg_workload(lambda),
            WorkloadSpec::Multires { k, mem } => Workload::multires(k, mem, lambda),
        }
    }

    pub fn to_json(&self) -> Value {
        match *self {
            WorkloadSpec::OneOrAll { k, p1, mu1, muk } => {
                Value::obj()
                    .set("kind", "one_or_all")
                    .set("k", k)
                    .set("p1", p1)
                    .set("mu1", mu1)
                    .set("muk", muk)
            }
            WorkloadSpec::FourClass => Value::obj().set("kind", "four_class"),
            WorkloadSpec::Borg => Value::obj().set("kind", "borg"),
            WorkloadSpec::Multires { k, mem } => Value::obj()
                .set("kind", "multires")
                .set("k", k)
                .set("mem", mem),
        }
    }

    pub fn from_json(v: &Value) -> anyhow::Result<WorkloadSpec> {
        let f64_of = |key: &str| {
            v.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| anyhow::anyhow!("workload spec missing '{key}'"))
        };
        let u32_of = |key: &str| {
            v.get(key)
                .and_then(|x| x.as_u64())
                .map(|x| x as u32)
                .ok_or_else(|| anyhow::anyhow!("workload spec missing '{key}'"))
        };
        match v.get("kind").and_then(|k| k.as_str()) {
            Some("one_or_all") => Ok(WorkloadSpec::OneOrAll {
                k: u32_of("k")?,
                p1: f64_of("p1")?,
                mu1: f64_of("mu1")?,
                muk: f64_of("muk")?,
            }),
            Some("four_class") => Ok(WorkloadSpec::FourClass),
            Some("borg") => Ok(WorkloadSpec::Borg),
            Some("multires") => Ok(WorkloadSpec::Multires {
                k: u32_of("k")?,
                mem: u32_of("mem")?,
            }),
            other => anyhow::bail!("unknown workload kind {other:?}"),
        }
    }
}

/// A complete, serializable sweep description: everything a worker needs
/// to run any unit of the grid, and everything the driver needs to pool
/// and emit results. Execution knobs (thread/worker counts) are
/// deliberately *not* part of the spec — they cannot affect results.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub workload: WorkloadSpec,
    pub lambdas: Vec<f64>,
    pub policies: Vec<PolicyId>,
    pub target_completions: u64,
    pub warmup_completions: u64,
    /// Batch size for the batch-means CI.
    pub batch: u64,
    pub seed: u64,
    pub replications: u32,
    /// Common-random-number mode: run all policies over one shared
    /// arrival stream per (λ, replication) and report paired Δ CIs
    /// against `baseline` alongside the marginal points.
    pub paired: bool,
    /// Baseline policy for paired Δs (must be one of `policies`; None
    /// defaults to the first policy). Ignored unless `paired`.
    pub baseline: Option<PolicyId>,
    /// Trace-replay mode: every unit replays one block-aligned shard of
    /// this `.qst` trace instead of sampling a synthetic source. The
    /// shard count takes over the replication axis (`replications` is
    /// ignored), each shard runs to stream exhaustion with the spec's
    /// warm-up discarded per shard, and the trace file must be readable
    /// at this path on every worker.
    pub trace: Option<TraceShards>,
}

impl SweepSpec {
    /// Build a spec from a workload family, grid, and sim config (only
    /// the config fields that affect sweep statistics are carried).
    pub fn from_config(
        workload: WorkloadSpec,
        lambdas: &[f64],
        policies: &[PolicyId],
        cfg: &SimConfig,
        seed: u64,
        replications: u32,
    ) -> SweepSpec {
        SweepSpec {
            workload,
            lambdas: lambdas.to_vec(),
            policies: policies.to_vec(),
            target_completions: cfg.target_completions,
            warmup_completions: cfg.warmup_completions,
            batch: cfg.batch,
            seed,
            replications: replications.max(1),
            paired: false,
            baseline: None,
            trace: None,
        }
    }

    /// The sim config this spec describes (defaults elsewhere).
    pub fn config(&self) -> SimConfig {
        SimConfig {
            target_completions: self.target_completions,
            warmup_completions: self.warmup_completions,
            batch: self.batch,
            ..SimConfig::default()
        }
    }

    /// The spec's (point, replication) unit grid. In trace mode the
    /// replication axis becomes the shard axis: `reps = shards`, every
    /// shard runs to stream exhaustion (the completion target is
    /// effectively unbounded — the engine stops when the finite source
    /// drains), and the spec's warm-up is discarded per shard.
    pub fn grid(&self) -> SweepGrid {
        let mut grid = SweepGrid::new(
            &self.lambdas,
            &self.policies,
            &self.config(),
            self.seed,
            match &self.trace {
                Some(tr) => tr.shards.max(1),
                None => self.replications,
            },
        );
        if let Some(tr) = &self.trace {
            grid.rep_cfg.target_completions = u64::MAX / 2;
            grid.rep_cfg.warmup_completions = self.warmup_completions;
            grid.trace = Some(tr.clone());
        }
        grid
    }

    /// The spec's paired (λ, replication) unit grid, or None when the
    /// spec is not in paired mode. Errors when `baseline` names a policy
    /// that is not in the policy list.
    pub fn paired_grid(&self) -> anyhow::Result<Option<PairedGrid>> {
        if !self.paired {
            return Ok(None);
        }
        if self.trace.is_some() {
            // CRN pairing shares one *sampled* stream across policies; a
            // trace is already a fixed stream, so every policy replays
            // it anyway and the paired machinery has nothing to pair.
            anyhow::bail!(
                "--paired and --trace are mutually exclusive (a trace is already a common stream)"
            );
        }
        let baseline = match self.baseline {
            None => 0,
            Some(id) => self
                .policies
                .iter()
                .position(|&p| p == id)
                .ok_or_else(|| {
                    anyhow::anyhow!("baseline policy '{id}' is not in the policy list")
                })?,
        };
        Ok(Some(PairedGrid::new(
            &self.lambdas,
            &self.policies,
            baseline,
            &self.config(),
            self.seed,
            self.replications,
        )))
    }

    /// Per-class display names (CSV headers), from the λ=1 instance.
    pub fn class_names(&self) -> Vec<String> {
        let wl = self.workload.build(1.0);
        wl.classes.iter().map(|c| c.name.clone()).collect()
    }

    pub fn to_json(&self) -> Value {
        let lambdas: Vec<Value> = self.lambdas.iter().map(|&l| Value::Num(l)).collect();
        // Policies travel as their canonical names (PolicyId::Display),
        // byte-identical to the former stringly wire form.
        let policies: Vec<Value> = self.policies.iter().map(|p| p.to_string().into()).collect();
        // The seed is arbitrary user-provided bits: it travels as a
        // decimal string because Value::Num is f64-backed and would
        // silently round seeds above 2^53, breaking the sharded ==
        // in-process bit-identity contract.
        let mut v = Value::obj()
            .set("workload", self.workload.to_json())
            .set("lambdas", Value::Arr(lambdas))
            .set("policies", Value::Arr(policies))
            .set("target_completions", self.target_completions)
            .set("warmup_completions", self.warmup_completions)
            .set("batch", self.batch)
            .set("seed", format!("{}", self.seed))
            .set("replications", self.replications);
        // Paired fields travel only when set: an unpaired spec's wire
        // form is byte-identical to what pre-paired builds emitted.
        if self.paired {
            v = v.set("paired", true);
            if let Some(b) = self.baseline {
                v = v.set("baseline", b.to_string());
            }
        }
        // Likewise additive: only trace sweeps carry the trace object,
        // so synthetic specs stay byte-identical on the wire.
        if let Some(tr) = &self.trace {
            v = v.set(
                "trace",
                Value::obj()
                    .set("path", tr.path.as_str())
                    .set("shards", tr.shards),
            );
        }
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<SweepSpec> {
        let u64_of = |key: &str| {
            v.get(key)
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow::anyhow!("sweep spec missing '{key}'"))
        };
        let lambdas = v
            .get("lambdas")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'lambdas'"))?
            .iter()
            .map(|l| {
                l.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("non-numeric lambda"))
            })
            .collect::<anyhow::Result<Vec<f64>>>()?;
        let policies = v
            .get("policies")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'policies'"))?
            .iter()
            .map(|p| {
                p.as_str()
                    .ok_or_else(|| anyhow::anyhow!("non-string policy"))
                    .and_then(PolicyId::parse)
            })
            .collect::<anyhow::Result<Vec<PolicyId>>>()?;
        let workload = v
            .get("workload")
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'workload'"))
            .and_then(WorkloadSpec::from_json)?;
        let seed = v
            .get("seed")
            .and_then(|x| x.as_str())
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing 'seed'"))?;
        Ok(SweepSpec {
            workload,
            lambdas,
            policies,
            target_completions: u64_of("target_completions")?,
            warmup_completions: u64_of("warmup_completions")?,
            batch: u64_of("batch")?,
            seed,
            replications: u64_of("replications")? as u32,
            paired: v.get("paired").and_then(|p| p.as_bool()).unwrap_or(false),
            baseline: v
                .get("baseline")
                .and_then(|b| b.as_str())
                .map(PolicyId::parse)
                .transpose()?,
            trace: v
                .get("trace")
                .map(|t| -> anyhow::Result<TraceShards> {
                    Ok(TraceShards {
                        path: t
                            .get("path")
                            .and_then(|p| p.as_str())
                            .ok_or_else(|| anyhow::anyhow!("trace spec missing 'path'"))?
                            .to_string(),
                        shards: t
                            .get("shards")
                            .and_then(|s| s.as_u64())
                            .ok_or_else(|| anyhow::anyhow!("trace spec missing 'shards'"))?
                            as u32,
                    })
                })
                .transpose()?,
        })
    }
}

/// Run a spec with in-process threads — the single-process reference
/// path the sharded run must match bit for bit.
pub fn run_spec_local(spec: &SweepSpec, threads: usize) -> Vec<Point> {
    let grid = spec.grid();
    let wl_at = |l: f64| spec.workload.build(l);
    let mut source = LocalThreads { threads };
    sweep_units(&grid, &wl_at, &mut source).expect("local unit execution is infallible")
}

/// Run a paired spec with in-process threads — the reference path a
/// sharded paired run must match bit for bit. Errors when the spec is
/// not in paired mode or names a bad baseline.
pub fn run_spec_paired_local(spec: &SweepSpec, threads: usize) -> anyhow::Result<PairedSweep> {
    let grid = spec
        .paired_grid()?
        .ok_or_else(|| anyhow::anyhow!("spec is not in paired mode"))?;
    let wl_at = |l: f64| spec.workload.build(l);
    let mut source = LocalThreads { threads };
    sweep_paired_units(&grid, &wl_at, &mut source)
}

/// A completed unit's payload, type-erased across the spec queue: the
/// driver and journal slot marginal and paired results into one global
/// vector and split them back per spec when pooling.
#[derive(Clone, Debug)]
pub enum AnyRun {
    Marginal(UnitRun),
    Paired(PairedRun),
}

/// One queued spec with its precomputed grids and global unit offset.
pub struct SpecTask {
    pub spec: SweepSpec,
    pub grid: SweepGrid,
    /// Present iff the spec is in paired (CRN) mode; its unit grid then
    /// replaces `grid`'s for scheduling purposes.
    pub paired: Option<PairedGrid>,
    /// Global unit id of this spec's local unit 0.
    pub offset: usize,
}

impl SpecTask {
    pub fn n_units(&self) -> usize {
        match &self.paired {
            Some(pg) => pg.n_units(),
            None => self.grid.n_units(),
        }
    }
}

/// An ordered queue of sweep specs served from one pooled unit
/// scheduler. Global unit ids are assigned by cumulative unit counts in
/// queue order — a pure function of the queue, so driver and workers
/// resolve them identically without any extra coordination.
pub struct SpecQueue {
    tasks: Vec<SpecTask>,
    total: usize,
}

impl SpecQueue {
    /// Build the queue, validating every spec's grids up front (a bad
    /// paired baseline fails here, before anything binds or connects).
    pub fn new(specs: Vec<SweepSpec>) -> anyhow::Result<SpecQueue> {
        let mut tasks = Vec::with_capacity(specs.len());
        let mut offset = 0usize;
        for spec in specs {
            let grid = spec.grid();
            let paired = spec.paired_grid()?;
            let task = SpecTask {
                spec,
                grid,
                paired,
                offset,
            };
            offset += task.n_units();
            tasks.push(task);
        }
        Ok(SpecQueue {
            tasks,
            total: offset,
        })
    }

    pub fn tasks(&self) -> &[SpecTask] {
        &self.tasks
    }

    /// Total unit count across the queue (the global id space).
    pub fn total_units(&self) -> usize {
        self.total
    }

    /// Resolve a global unit id to (spec index, local unit id).
    pub fn locate(&self, global: usize) -> Option<(usize, usize)> {
        if global >= self.total {
            return None;
        }
        let si = self.tasks.partition_point(|t| t.offset <= global) - 1;
        Some((si, global - self.tasks[si].offset))
    }

    /// Resolve (spec index, local unit id) to a global unit id.
    pub fn global_id(&self, spec: usize, local: usize) -> Option<usize> {
        let t = self.tasks.get(spec)?;
        (local < t.n_units()).then(|| t.offset + local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        let spec = SweepSpec {
            workload: WorkloadSpec::OneOrAll {
                k: 8,
                p1: 0.9,
                mu1: 1.0,
                muk: 1.0,
            },
            lambdas: vec![2.0, 3.25, 0.1],
            policies: vec![PolicyId::Msf, PolicyId::Msfq(Some(7))],
            target_completions: 6_000,
            warmup_completions: 1_200,
            batch: 1000,
            // Above 2^53: must survive the wire without f64 rounding.
            seed: 0xDEAD_BEEF_DEAD_BEEF,
            replications: 3,
            paired: false,
            baseline: None,
            trace: None,
        };
        let wire = spec.to_json().to_string();
        let back = SweepSpec::from_json(&Value::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.workload, spec.workload);
        assert_eq!(back.policies, spec.policies);
        assert_eq!(back.target_completions, spec.target_completions);
        assert_eq!(back.warmup_completions, spec.warmup_completions);
        assert_eq!(back.batch, spec.batch);
        assert_eq!(back.seed, spec.seed);
        assert_eq!(back.replications, spec.replications);
        assert!(!back.paired);
        assert!(back.baseline.is_none());
        // An unpaired spec's wire form carries no paired fields at all
        // (wire compatibility with pre-paired builds), and a traceless
        // spec carries no trace object (pre-trace builds).
        assert!(!wire.contains("paired") && !wire.contains("baseline"));
        assert!(!wire.contains("trace"));
        // λ values round-trip bit-exactly (shortest-round-trip Display).
        for (a, b) in spec.lambdas.iter().zip(&back.lambdas) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Grids built on both sides agree.
        assert_eq!(spec.grid().n_units(), back.grid().n_units());
        assert_eq!(spec.grid().pts, back.grid().pts);
    }

    #[test]
    fn paired_spec_roundtrip_and_grid() {
        let mut spec = SweepSpec {
            workload: WorkloadSpec::OneOrAll {
                k: 8,
                p1: 0.9,
                mu1: 1.0,
                muk: 1.0,
            },
            lambdas: vec![2.0, 3.0],
            policies: vec![PolicyId::Msf, PolicyId::Msfq(Some(7)), PolicyId::Fcfs],
            target_completions: 6_000,
            warmup_completions: 1_200,
            batch: 1000,
            seed: 42,
            replications: 3,
            paired: true,
            baseline: Some(PolicyId::Msfq(Some(7))),
            trace: None,
        };
        let wire = spec.to_json().to_string();
        let back = SweepSpec::from_json(&Value::parse(&wire).unwrap()).unwrap();
        assert!(back.paired);
        assert_eq!(back.baseline, Some(PolicyId::Msfq(Some(7))));
        let grid = back.paired_grid().unwrap().unwrap();
        assert_eq!(grid.baseline, 1);
        assert_eq!(grid.n_units(), 6);
        assert_eq!(grid.rep_cfg.target_completions, 2_000);
        // Default baseline: first policy.
        spec.baseline = None;
        assert_eq!(spec.paired_grid().unwrap().unwrap().baseline, 0);
        // A baseline absent from the policy list is an error, not a
        // silent default.
        spec.baseline = Some(PolicyId::ServerFilling);
        assert!(spec.paired_grid().is_err());
        // Not paired: no grid.
        spec.paired = false;
        assert!(spec.paired_grid().unwrap().is_none());
    }

    #[test]
    fn spec_queue_offsets_and_locate() {
        let mk = |lambdas: &[f64], paired: bool| SweepSpec {
            workload: WorkloadSpec::OneOrAll {
                k: 8,
                p1: 0.9,
                mu1: 1.0,
                muk: 1.0,
            },
            lambdas: lambdas.to_vec(),
            policies: vec![PolicyId::Msf, PolicyId::Fcfs],
            target_completions: 6_000,
            warmup_completions: 1_200,
            batch: 1000,
            seed: 1,
            replications: 3,
            paired,
            baseline: None,
            trace: None,
        };
        // Spec 0 (marginal): 2λ × 2 policies × 3 reps = 12 units.
        // Spec 1 (paired): 1λ × 3 reps = 3 units (all policies per unit).
        let q = SpecQueue::new(vec![mk(&[2.0, 3.0], false), mk(&[2.0], true)]).unwrap();
        assert_eq!(q.total_units(), 15);
        assert_eq!(q.tasks().len(), 2);
        assert_eq!(q.tasks()[0].offset, 0);
        assert_eq!(q.tasks()[1].offset, 12);
        assert!(q.tasks()[0].paired.is_none() && q.tasks()[1].paired.is_some());
        assert_eq!(q.locate(0), Some((0, 0)));
        assert_eq!(q.locate(11), Some((0, 11)));
        assert_eq!(q.locate(12), Some((1, 0)));
        assert_eq!(q.locate(14), Some((1, 2)));
        assert_eq!(q.locate(15), None);
        assert_eq!(q.global_id(0, 11), Some(11));
        assert_eq!(q.global_id(1, 2), Some(14));
        assert_eq!(q.global_id(1, 3), None);
        assert_eq!(q.global_id(2, 0), None);
        // Every global id round-trips through locate/global_id.
        for g in 0..q.total_units() {
            let (s, l) = q.locate(g).unwrap();
            assert_eq!(q.global_id(s, l), Some(g));
        }
        // Queue validation surfaces bad paired baselines up front.
        let mut bad = mk(&[2.0], true);
        bad.baseline = Some(PolicyId::ServerFilling);
        assert!(SpecQueue::new(vec![bad]).is_err());
        // An empty queue is structurally valid (the builder rejects it).
        let empty = SpecQueue::new(Vec::new()).unwrap();
        assert_eq!(empty.total_units(), 0);
        assert_eq!(empty.locate(0), None);
    }

    #[test]
    fn workload_spec_builds_expected_families() {
        let one = WorkloadSpec::OneOrAll {
            k: 16,
            p1: 0.9,
            mu1: 1.0,
            muk: 1.0,
        };
        assert_eq!(one.build(3.0).k, 16);
        assert_eq!(WorkloadSpec::FourClass.build(2.0).k, 15);
        assert_eq!(WorkloadSpec::Borg.build(2.0).num_classes(), 26);
        let multi = WorkloadSpec::Multires { k: 16, mem: 64 };
        assert_eq!(multi.build(3.0).dims(), 2);
        let back = WorkloadSpec::from_json(&multi.to_json()).unwrap();
        assert_eq!(back, multi);
        assert!(WorkloadSpec::from_json(&Value::obj().set("kind", "nope")).is_err());
    }
}
