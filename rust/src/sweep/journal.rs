//! Append-only JSONL checkpoint journal for the elastic sweep driver.
//!
//! Layout: line 1 is a header binding the file to the exact spec queue
//! it checkpoints —
//!
//! ```text
//! {"journal":"qs-sweep","version":2,"specs":[...]}
//! ```
//!
//! — compared against the current queue by canonical serialization
//! (byte-equal spec JSON, in order, or the resume refuses). Every
//! subsequent line is one completed unit, `{"crc":"XXXXXXXX","n":SEQ,
//! "spec":S,"id":U,...payload}`, where the payload reuses the wire
//! result encoding ([`proto::msg_result`] / [`proto::msg_paired_result`]
//! / [`proto::msg_result_err`]): `display`+`stats` for marginal units,
//! `runs` for paired units, `err` for units that conclusively failed on
//! a worker (journaled as delivered, exactly as a live sweep treats
//! them). The statistics keep the bit-exact `f64_bits` encoding, so a
//! driver resumed from the journal pools exactly the bits a worker
//! shipped and its CSVs are byte-identical to an uninterrupted run.
//!
//! v2 adds a per-record CRC-32 (`crc`, hex, over the record's canonical
//! serialization minus the `crc` field itself — sound because
//! `Value::Obj` serializes with sorted keys). v1 journals (no CRCs) are
//! still read.
//!
//! WAL semantics: records are written with one `write_all` per line as
//! results arrive, *before* the worker's ack — once a worker has seen
//! `ok`, the unit is recorded (and with fsync enabled, durable on the
//! device). A crash can therefore tear at most the *final* record: a
//! partial line with no trailing newline, or — if the crash landed
//! mid-`write` inside the kernel — a final line whose tail is garbage.
//! Both are detected structurally (unparseable JSON, a missing CRC on a
//! v2 file, or a CRC mismatch), warned about, truncated away, and the
//! unit reruns — same bits either way. The forgiveness is strictly
//! tail-only: a structurally broken line *followed by* a structurally
//! valid one cannot be a crash artifact of this append discipline, so
//! it is a hard error, as is any semantic violation on an intact record
//! (out-of-sequence, duplicate, a unit outside the queue, a shape
//! mismatch, a header mismatch) — silently rerunning "finished" units
//! over a corrupted journal would mask data loss.

use crate::sweep::faultline::{Durable, FaultDurable, FileDurable, PlanState};
use crate::sweep::{proto, AnyRun, SpecQueue};
use crate::util::crc::crc32;
use crate::util::json::Value;
use std::fs::OpenOptions;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

const MAGIC: &str = "qs-sweep";
const VERSION: u64 = 2;

fn jerr(path: &Path, msg: String) -> anyhow::Error {
    anyhow::anyhow!("journal {}: {msg}", path.display())
}

/// Durability and fault-injection knobs for an open journal.
#[derive(Default)]
pub struct JournalOptions {
    /// `sync_all` every record to the device before the worker's ack
    /// (power-cut-safe WAL; default is flush-to-OS only).
    pub fsync: bool,
    /// Chaos-test hook: route appends through a
    /// [`FaultDurable`] driven by this plan state.
    pub faults: Option<Arc<Mutex<PlanState>>>,
}

/// One recorded unit result: spec index, local unit id, and the run
/// (`None` = the unit conclusively failed on a worker; it is delivered,
/// not rerun).
pub struct JournalEntry {
    pub spec: usize,
    pub id: usize,
    pub run: Option<AnyRun>,
}

/// An open journal, positioned for appending.
pub struct Journal {
    sink: Box<dyn Durable>,
    fsync: bool,
    seq: u64,
}

/// Structural validity: does this line decode to an intact record at
/// all? (Semantic checks — sequence, ranges, duplicates, shape — only
/// apply to structurally intact lines.)
fn check_structural(line: &str, file_version: u64) -> Result<Value, String> {
    let v = Value::parse(line).map_err(|e| format!("unparseable ({e})"))?;
    if file_version >= 2 {
        let recorded = v
            .get("crc")
            .and_then(|c| c.as_str())
            .ok_or_else(|| "missing crc".to_string())?
            .to_string();
        let computed = format!("{:08x}", crc32(v.without("crc").to_string().as_bytes()));
        if recorded != computed {
            return Err(format!("crc mismatch (recorded {recorded}, computed {computed})"));
        }
    }
    Ok(v)
}

impl Journal {
    /// [`Journal::open_with`] with default options (no fsync, no fault
    /// injection).
    pub fn open(path: &Path, queue: &SpecQueue) -> anyhow::Result<(Journal, Vec<JournalEntry>)> {
        Self::open_with(path, queue, JournalOptions::default())
    }

    /// Open (or create) the journal at `path` for `queue`, returning
    /// the journal plus every previously recorded entry in sequence
    /// order. A fresh (or empty) file gets the header written; an
    /// existing file must carry a byte-identical spec queue.
    pub fn open_with(
        path: &Path,
        queue: &SpecQueue,
        opts: JournalOptions,
    ) -> anyhow::Result<(Journal, Vec<JournalEntry>)> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .map_err(|e| jerr(path, e.to_string()))?;
        let mut text = String::new();
        file.read_to_string(&mut text)
            .map_err(|e| jerr(path, e.to_string()))?;

        // The header is written directly (not through the fault sink):
        // record ordinals seen by a fault plan's `torn-append@R` start
        // at the first *record*.
        if text.is_empty() {
            let specs: Vec<Value> = queue.tasks().iter().map(|t| t.spec.to_json()).collect();
            let header = Value::obj()
                .set("journal", MAGIC)
                .set("version", VERSION)
                .set("specs", Value::Arr(specs));
            let mut line = header.to_string();
            line.push('\n');
            file.write_all(line.as_bytes())
                .map_err(|e| jerr(path, e.to_string()))?;
            if opts.fsync {
                file.sync_all().map_err(|e| jerr(path, e.to_string()))?;
            }
            let sink = Self::wrap_sink(file, &opts).map_err(|e| jerr(path, e.to_string()))?;
            return Ok((Journal { sink, fsync: opts.fsync, seq: 0 }, Vec::new()));
        }

        // Split into complete lines (with their byte offsets, for
        // truncation) plus a possibly-torn final segment. A final
        // segment without a newline is structurally torn even if it
        // happens to parse — uniform rule, and the unit reruns to the
        // same bits anyway.
        let mut lines: Vec<(usize, &str)> = Vec::new();
        let mut torn_tail: Option<(usize, &str)> = None;
        let mut offset = 0usize;
        let mut iter = text.split('\n').peekable();
        while let Some(seg) = iter.next() {
            if iter.peek().is_none() {
                // Last segment: empty iff the text ends with '\n'.
                if !seg.is_empty() {
                    torn_tail = Some((offset, seg));
                }
            } else {
                lines.push((offset, seg));
            }
            offset += seg.len() + 1;
        }

        let header = Value::parse(lines.first().map(|(_, l)| *l).unwrap_or(""))
            .map_err(|e| jerr(path, format!("corrupt header line ({e})")))?;
        if header.get("journal").and_then(|m| m.as_str()) != Some(MAGIC) {
            return Err(jerr(path, "not a qs-sweep journal (bad header magic)".into()));
        }
        let file_version = header.get("version").and_then(|v| v.as_u64()).unwrap_or(0);
        if !(1..=VERSION).contains(&file_version) {
            return Err(jerr(path, "unsupported journal version".into()));
        }
        let header_specs = header
            .get("specs")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| jerr(path, "header missing 'specs'".into()))?;
        if header_specs.len() != queue.tasks().len() {
            return Err(jerr(
                path,
                format!(
                    "spec queue mismatch: journal has {} specs, current queue {} — \
                     this journal belongs to a different sweep",
                    header_specs.len(),
                    queue.tasks().len()
                ),
            ));
        }
        for (i, (js, task)) in header_specs.iter().zip(queue.tasks()).enumerate() {
            if js.to_string() != task.spec.to_json().to_string() {
                return Err(jerr(
                    path,
                    format!(
                        "spec {i} does not match the current queue — \
                         this journal belongs to a different sweep"
                    ),
                ));
            }
        }

        // Structural pass over the record lines: find where (if
        // anywhere) the file stops being intact.
        let mut records: Vec<(usize, usize, Value)> = Vec::new(); // (lineno, offset, value)
        let mut first_bad: Option<(usize, usize, String)> = None; // (lineno, offset, reason)
        for (li, (off, line)) in lines.iter().enumerate().skip(1) {
            let lineno = li + 1;
            match check_structural(line, file_version) {
                Ok(v) => {
                    if let Some((bad_line, _, reason)) = &first_bad {
                        // Intact records after a broken one: not a tail
                        // tear, the file is corrupt in the middle.
                        return Err(jerr(
                            path,
                            format!(
                                "mid-file corruption: record on line {bad_line} is broken \
                                 ({reason}) but line {lineno} after it is intact — \
                                 refusing to resume over lost records"
                            ),
                        ));
                    }
                    records.push((lineno, *off, v));
                }
                Err(reason) => {
                    if first_bad.is_none() {
                        first_bad = Some((lineno, *off, reason));
                    }
                }
            }
        }
        if let Some((off, tail)) = torn_tail {
            if first_bad.is_none() {
                first_bad = Some((lines.len() + 1, off, format!("torn ({} bytes, no newline)", tail.len())));
            }
        }

        // Semantic pass over the intact prefix.
        let mut entries: Vec<JournalEntry> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for (lineno, _, v) in &records {
            let lineno = *lineno;
            let n = v
                .get("n")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| jerr(path, format!("record on line {lineno} missing 'n'")))?;
            if n != entries.len() as u64 {
                return Err(jerr(
                    path,
                    format!(
                        "record out of sequence on line {lineno} (expected n={}, found n={n})",
                        entries.len()
                    ),
                ));
            }
            let spec = v
                .get("spec")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| jerr(path, format!("record on line {lineno} missing 'spec'")))?;
            let task = queue.tasks().get(spec).ok_or_else(|| {
                jerr(
                    path,
                    format!("record on line {lineno} names spec {spec}, outside the queue"),
                )
            })?;
            let id = proto::id_of(v)
                .map_err(|e| jerr(path, format!("record on line {lineno}: {e}")))?;
            if id >= task.n_units() {
                return Err(jerr(
                    path,
                    format!("record on line {lineno} names unit {id}, outside spec {spec}'s grid"),
                ));
            }
            if !seen.insert((spec, id)) {
                return Err(jerr(
                    path,
                    format!("duplicate record for spec {spec} unit {id} on line {lineno}"),
                ));
            }
            // Decode via the owning spec's mode; a shape mismatch (a
            // paired payload on a marginal spec, or vice versa) surfaces
            // here as corruption.
            let run = if task.paired.is_some() {
                let (_, r) = proto::parse_paired_result(v).map_err(|e| {
                    jerr(path, format!("corrupt paired record on line {lineno} ({e})"))
                })?;
                r.ok().map(AnyRun::Paired)
            } else {
                let (_, r) = proto::parse_result(v)
                    .map_err(|e| jerr(path, format!("corrupt record on line {lineno} ({e})")))?;
                r.ok().map(AnyRun::Marginal)
            };
            entries.push(JournalEntry { spec, id, run });
        }

        if let Some((lineno, off, reason)) = first_bad {
            eprintln!(
                "qs-sweep journal {}: dropping broken final record on line {lineno} \
                 ({reason}; crash artifact); the unit will rerun",
                path.display()
            );
            // Truncate so appended records start on a clean boundary.
            file.set_len(off as u64)
                .map_err(|e| jerr(path, e.to_string()))?;
        }
        file.seek(SeekFrom::End(0))
            .map_err(|e| jerr(path, e.to_string()))?;
        let seq = entries.len() as u64;
        let sink = Self::wrap_sink(file, &opts).map_err(|e| jerr(path, e.to_string()))?;
        Ok((Journal { sink, fsync: opts.fsync, seq }, entries))
    }

    fn wrap_sink(file: std::fs::File, opts: &JournalOptions) -> std::io::Result<Box<dyn Durable>> {
        Ok(match &opts.faults {
            Some(state) => Box::new(FaultDurable::new(file, state.clone())?),
            None => Box::new(FileDurable::new(file)),
        })
    }

    fn append(&mut self, payload: Value) -> std::io::Result<()> {
        // CRC over the canonical (sorted-key) serialization without the
        // crc field — exactly what the reader recomputes.
        let crc = crc32(payload.to_string().as_bytes());
        let mut line = payload.set("crc", format!("{crc:08x}")).to_string();
        line.push('\n');
        // One write per record: a crash tears at most the final line.
        self.sink.append(line.as_bytes())?;
        if self.fsync {
            self.sink.sync()?;
        } else {
            self.sink.flush()?;
        }
        self.seq += 1;
        Ok(())
    }

    /// Record a completed unit (durable before the caller acks it).
    pub fn append_ok(&mut self, spec: usize, id: usize, run: &AnyRun) -> std::io::Result<()> {
        let payload = match run {
            AnyRun::Marginal(r) => proto::msg_result(id, r),
            AnyRun::Paired(r) => proto::msg_paired_result(id, r),
        };
        let n = self.seq;
        self.append(payload.set("n", n).set("spec", spec))
    }

    /// Record a unit that conclusively failed on a worker.
    pub fn append_err(&mut self, spec: usize, id: usize, err: &str) -> std::io::Result<()> {
        let n = self.seq;
        self.append(proto::msg_result_err(id, err).set("n", n).set("spec", spec))
    }
}
