//! Append-only JSONL checkpoint journal for the elastic sweep driver.
//!
//! Layout: line 1 is a header binding the file to the exact spec queue
//! it checkpoints —
//!
//! ```text
//! {"journal":"qs-sweep","version":1,"specs":[...]}
//! ```
//!
//! — compared against the current queue by canonical serialization
//! (byte-equal spec JSON, in order, or the resume refuses). Every
//! subsequent line is one completed unit, `{"n":SEQ,"spec":S,"id":U,
//! ...payload}`, where the payload reuses the wire result encoding
//! ([`proto::msg_result`] / [`proto::msg_paired_result`] /
//! [`proto::msg_result_err`]): `display`+`stats` for marginal units,
//! `runs` for paired units, `err` for units that conclusively failed on
//! a worker (journaled as delivered, exactly as a live sweep treats
//! them). The statistics keep the bit-exact `f64_bits` encoding, so a
//! driver resumed from the journal pools exactly the bits a worker
//! shipped and its CSVs are byte-identical to an uninterrupted run.
//!
//! WAL semantics: records are flushed line-by-line as results arrive,
//! *before* the worker's ack — once a worker has seen `ok`, the unit is
//! on disk. A SIGKILL can therefore tear at most the final line (a
//! partial write with no trailing newline). A torn tail is a crash
//! artifact: it is warned about, truncated away, and its unit reruns —
//! same bits either way. Anything else — mid-file garbage, an
//! out-of-sequence or duplicate record, a unit outside the queue, a
//! header mismatch — is a hard error: silently rerunning "finished"
//! units over a corrupted journal would mask data loss.

use crate::sweep::{proto, AnyRun, SpecQueue};
use crate::util::json::Value;
use std::fs::OpenOptions;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

const MAGIC: &str = "qs-sweep";
const VERSION: u64 = 1;

fn jerr(path: &Path, msg: String) -> anyhow::Error {
    anyhow::anyhow!("journal {}: {msg}", path.display())
}

/// One recorded unit result: spec index, local unit id, and the run
/// (`None` = the unit conclusively failed on a worker; it is delivered,
/// not rerun).
pub struct JournalEntry {
    pub spec: usize,
    pub id: usize,
    pub run: Option<AnyRun>,
}

/// An open journal, positioned for appending.
pub struct Journal {
    file: std::fs::File,
    seq: u64,
}

impl Journal {
    /// Open (or create) the journal at `path` for `queue`, returning
    /// the journal plus every previously recorded entry in sequence
    /// order. A fresh (or empty) file gets the header written; an
    /// existing file must carry a byte-identical spec queue.
    pub fn open(path: &Path, queue: &SpecQueue) -> anyhow::Result<(Journal, Vec<JournalEntry>)> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .map_err(|e| jerr(path, e.to_string()))?;
        let mut text = String::new();
        file.read_to_string(&mut text)
            .map_err(|e| jerr(path, e.to_string()))?;

        if text.is_empty() {
            let specs: Vec<Value> = queue.tasks().iter().map(|t| t.spec.to_json()).collect();
            let header = Value::obj()
                .set("journal", MAGIC)
                .set("version", VERSION)
                .set("specs", Value::Arr(specs));
            let mut line = header.to_string();
            line.push('\n');
            file.write_all(line.as_bytes())
                .map_err(|e| jerr(path, e.to_string()))?;
            return Ok((Journal { file, seq: 0 }, Vec::new()));
        }

        // Split complete lines from a possibly-torn tail. A final
        // segment without a newline is treated as torn even if it
        // happens to parse — uniform rule, and the unit reruns to the
        // same bits anyway.
        let mut lines: Vec<&str> = text.split('\n').collect();
        let torn = if text.ends_with('\n') {
            lines.pop(); // the empty segment after the final newline
            None
        } else {
            lines.pop()
        };

        let header = Value::parse(lines.first().copied().unwrap_or(""))
            .map_err(|e| jerr(path, format!("corrupt header line ({e})")))?;
        if header.get("journal").and_then(|m| m.as_str()) != Some(MAGIC) {
            return Err(jerr(path, "not a qs-sweep journal (bad header magic)".into()));
        }
        if header.get("version").and_then(|v| v.as_u64()) != Some(VERSION) {
            return Err(jerr(path, "unsupported journal version".into()));
        }
        let header_specs = header
            .get("specs")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| jerr(path, "header missing 'specs'".into()))?;
        if header_specs.len() != queue.tasks().len() {
            return Err(jerr(
                path,
                format!(
                    "spec queue mismatch: journal has {} specs, current queue {} — \
                     this journal belongs to a different sweep",
                    header_specs.len(),
                    queue.tasks().len()
                ),
            ));
        }
        for (i, (js, task)) in header_specs.iter().zip(queue.tasks()).enumerate() {
            if js.to_string() != task.spec.to_json().to_string() {
                return Err(jerr(
                    path,
                    format!(
                        "spec {i} does not match the current queue — \
                         this journal belongs to a different sweep"
                    ),
                ));
            }
        }

        let mut entries: Vec<JournalEntry> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for (li, line) in lines.iter().enumerate().skip(1) {
            let lineno = li + 1;
            let v = Value::parse(line)
                .map_err(|e| jerr(path, format!("corrupt record on line {lineno} ({e})")))?;
            let n = v
                .get("n")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| jerr(path, format!("record on line {lineno} missing 'n'")))?;
            if n != entries.len() as u64 {
                return Err(jerr(
                    path,
                    format!(
                        "record out of sequence on line {lineno} (expected n={}, found n={n})",
                        entries.len()
                    ),
                ));
            }
            let spec = v
                .get("spec")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| jerr(path, format!("record on line {lineno} missing 'spec'")))?;
            let task = queue.tasks().get(spec).ok_or_else(|| {
                jerr(
                    path,
                    format!("record on line {lineno} names spec {spec}, outside the queue"),
                )
            })?;
            let id = proto::id_of(&v)
                .map_err(|e| jerr(path, format!("record on line {lineno}: {e}")))?;
            if id >= task.n_units() {
                return Err(jerr(
                    path,
                    format!("record on line {lineno} names unit {id}, outside spec {spec}'s grid"),
                ));
            }
            if !seen.insert((spec, id)) {
                return Err(jerr(
                    path,
                    format!("duplicate record for spec {spec} unit {id} on line {lineno}"),
                ));
            }
            // Decode via the owning spec's mode; a shape mismatch (a
            // paired payload on a marginal spec, or vice versa) surfaces
            // here as corruption.
            let run = if task.paired.is_some() {
                let (_, r) = proto::parse_paired_result(&v).map_err(|e| {
                    jerr(path, format!("corrupt paired record on line {lineno} ({e})"))
                })?;
                r.ok().map(AnyRun::Paired)
            } else {
                let (_, r) = proto::parse_result(&v)
                    .map_err(|e| jerr(path, format!("corrupt record on line {lineno} ({e})")))?;
                r.ok().map(AnyRun::Marginal)
            };
            entries.push(JournalEntry { spec, id, run });
        }

        if let Some(t) = torn {
            eprintln!(
                "qs-sweep journal {}: dropping torn final record ({} bytes, crash artifact); \
                 the unit will rerun",
                path.display(),
                t.len()
            );
            // Truncate the tail away so appended records start on a
            // clean line boundary.
            file.set_len((text.len() - t.len()) as u64)
                .map_err(|e| jerr(path, e.to_string()))?;
        }
        file.seek(SeekFrom::End(0))
            .map_err(|e| jerr(path, e.to_string()))?;
        let seq = entries.len() as u64;
        Ok((Journal { file, seq }, entries))
    }

    fn append(&mut self, payload: Value) -> std::io::Result<()> {
        let mut line = payload.to_string();
        line.push('\n');
        // One write_all per record (then a flush for symmetry with
        // buffered writers): a crash tears at most the final line.
        self.file.write_all(line.as_bytes())?;
        self.file.flush()?;
        self.seq += 1;
        Ok(())
    }

    /// Record a completed unit (flushed before the caller acks it).
    pub fn append_ok(&mut self, spec: usize, id: usize, run: &AnyRun) -> std::io::Result<()> {
        let payload = match run {
            AnyRun::Marginal(r) => proto::msg_result(id, r),
            AnyRun::Paired(r) => proto::msg_paired_result(id, r),
        };
        let n = self.seq;
        self.append(payload.set("n", n).set("spec", spec))
    }

    /// Record a unit that conclusively failed on a worker.
    pub fn append_err(&mut self, spec: usize, id: usize, err: &str) -> std::io::Result<()> {
        let n = self.seq;
        self.append(proto::msg_result_err(id, err).set("n", n).set("spec", spec))
    }
}
