//! `sweep::faultline` — deterministic, seeded fault injection at the
//! sweep fabric's transport and storage boundaries.
//!
//! The elastic sweep service promises the PR-7 determinism contract
//! *under fire*: workers may crash, connections may drop, journal
//! appends may tear mid-line, and the final CSVs must still come out
//! byte-identical to an undisturbed run at equal (seed, R). This module
//! makes those failures injectable and replayable:
//!
//! - [`Transport`] is the narrow line-oriented interface the worker
//!   speaks to the driver ([`TcpTransport`] is the real thing,
//!   [`FaultTransport`] the fault-injecting wrapper).
//! - [`Durable`] is the narrow append/sync interface the journal and
//!   the atomic CSV sink write through ([`FileDurable`] real,
//!   [`FaultDurable`] injecting torn appends and fsync-dropped tails).
//! - [`AtomicFile`] is the crash-consistent CSV sink: writes land in a
//!   sibling `*.tmp`, `commit()` fsyncs and renames — a crash at any
//!   point leaves either the complete old file or the complete new one,
//!   never a torn CSV.
//! - [`FaultPlan`] is the plan itself: parsed from the `QS_FAULT_PLAN`
//!   environment variable or built programmatically, carrying its own
//!   RNG seed so every derived quantity (torn-write garbage, jitter) is
//!   a pure function of the plan.
//!
//! ## Plan grammar
//!
//! `;`-separated directives, each firing **once**, with an optional
//! leading `seed=S`:
//!
//! ```text
//! seed=S                 RNG stream for derived randomness (default 0)
//! disconnect@M           drop the connection at the Mth transport message
//! delay@M:MS             stall the Mth transport message by MS milliseconds
//! crash@U                die while holding the Uth claimed unit (worker)
//! hang@U:MS              go silent for MS ms on claiming the Uth unit,
//!                        heartbeats suppressed (worker)
//! short-read@B           cap every transport read at B bytes (persistent)
//! torn-append@R:F        Rth durable append writes only fraction F plus
//!                        trailing garbage, then fails (storage)
//! drop-sync@R            Rth durable append vanishes back to the last
//!                        synced length, then fails — a power cut between
//!                        write and fsync (storage)
//! ```
//!
//! Message counts are a pure function of the protocol exchange: each
//! `send_line`/`recv_line` through a [`FaultTransport`] increments one
//! shared counter (heartbeat pings bypass the transport and pongs are
//! never sent for them, so wall-clock timing cannot shift the count).
//! Unit counts are the worker's claim ordinals; append counts are the
//! journal's (or CSV sink's) record ordinals. Each process consumes the
//! directives relevant to its own boundaries: workers act on
//! disconnect/delay/crash/hang/short-read, the driver on
//! torn-append/drop-sync — one plan string can therefore be exported
//! once and handed to a whole fleet.

use crate::util::rng::Rng;
use std::fmt;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Environment variable holding the fault-plan string.
pub const ENV_PLAN: &str = "QS_FAULT_PLAN";

/// One fault directive (see the module-level grammar).
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    Disconnect { msg: u64 },
    Delay { msg: u64, ms: u64 },
    Crash { unit: u64 },
    Hang { unit: u64, ms: u64 },
    ShortRead { bytes: usize },
    TornAppend { rec: u64, frac: f64 },
    DropSync { rec: u64 },
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Fault::Disconnect { msg } => write!(f, "disconnect@{msg}"),
            Fault::Delay { msg, ms } => write!(f, "delay@{msg}:{ms}"),
            Fault::Crash { unit } => write!(f, "crash@{unit}"),
            Fault::Hang { unit, ms } => write!(f, "hang@{unit}:{ms}"),
            Fault::ShortRead { bytes } => write!(f, "short-read@{bytes}"),
            Fault::TornAppend { rec, frac } => write!(f, "torn-append@{rec}:{frac}"),
            Fault::DropSync { rec } => write!(f, "drop-sync@{rec}"),
        }
    }
}

/// A seeded, replayable fault plan: an ordered set of one-shot
/// directives plus the RNG seed every derived quantity flows from.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub faults: Vec<Fault>,
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for fault in &self.faults {
            write!(f, ";{fault}")?;
        }
        Ok(())
    }
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, faults: Vec::new() }
    }

    pub fn disconnect_at(mut self, msg: u64) -> FaultPlan {
        self.faults.push(Fault::Disconnect { msg });
        self
    }

    pub fn delay_at(mut self, msg: u64, ms: u64) -> FaultPlan {
        self.faults.push(Fault::Delay { msg, ms });
        self
    }

    pub fn crash_on_unit(mut self, unit: u64) -> FaultPlan {
        self.faults.push(Fault::Crash { unit });
        self
    }

    pub fn hang_on_unit(mut self, unit: u64, ms: u64) -> FaultPlan {
        self.faults.push(Fault::Hang { unit, ms });
        self
    }

    pub fn short_read_cap(mut self, bytes: usize) -> FaultPlan {
        self.faults.push(Fault::ShortRead { bytes });
        self
    }

    pub fn torn_append(mut self, rec: u64, frac: f64) -> FaultPlan {
        self.faults.push(Fault::TornAppend { rec, frac });
        self
    }

    pub fn drop_sync(mut self, rec: u64) -> FaultPlan {
        self.faults.push(Fault::DropSync { rec });
        self
    }

    /// The persistent read cap, if any `short-read` directive is set.
    pub fn short_read(&self) -> Option<usize> {
        self.faults.iter().find_map(|f| match f {
            Fault::ShortRead { bytes } => Some((*bytes).max(1)),
            _ => None,
        })
    }

    /// Parse the `;`-grammar (see module docs). Unknown directives and
    /// malformed arities are hard errors — a half-understood fault plan
    /// would silently test less than the caller asked for.
    pub fn parse(s: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::new(0);
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(v) = part.strip_prefix("seed=") {
                plan.seed = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault plan: bad seed '{v}'"))?;
                continue;
            }
            let (name, rest) = part
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault plan: '{part}' is not NAME@ARGS"))?;
            let args: Vec<&str> = rest.split(':').collect();
            let argn = |i: usize| -> anyhow::Result<u64> {
                args.get(i)
                    .and_then(|a| a.trim().parse().ok())
                    .ok_or_else(|| anyhow::anyhow!("fault plan: '{part}' needs integer arg {i}"))
            };
            let fault = match (name.trim(), args.len()) {
                ("disconnect", 1) => Fault::Disconnect { msg: argn(0)? },
                ("delay", 2) => Fault::Delay { msg: argn(0)?, ms: argn(1)? },
                ("crash", 1) => Fault::Crash { unit: argn(0)? },
                ("hang", 2) => Fault::Hang { unit: argn(0)?, ms: argn(1)? },
                ("short-read", 1) => Fault::ShortRead { bytes: argn(0)? as usize },
                ("torn-append", 2) => {
                    let frac: f64 = args[1]
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("fault plan: '{part}' needs a fraction"))?;
                    if !(0.0..=1.0).contains(&frac) {
                        anyhow::bail!("fault plan: '{part}' fraction must be in [0,1]");
                    }
                    Fault::TornAppend { rec: argn(0)?, frac }
                }
                ("drop-sync", 1) => Fault::DropSync { rec: argn(0)? },
                _ => anyhow::bail!("fault plan: unknown directive '{part}'"),
            };
            plan.faults.push(fault);
        }
        Ok(plan)
    }

    /// The plan from `QS_FAULT_PLAN`, if set and non-empty. A present
    /// but unparseable plan is a hard error, not a silent no-op.
    pub fn from_env() -> anyhow::Result<Option<FaultPlan>> {
        match std::env::var(ENV_PLAN) {
            Ok(s) if !s.trim().is_empty() => Ok(Some(FaultPlan::parse(&s)?)),
            _ => Ok(None),
        }
    }
}

/// Marker payload inside injected `io::Error`s, so callers (and tests)
/// can tell an injected fault from a genuine I/O failure.
#[derive(Debug)]
pub struct InjectedFault(pub &'static str);

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "faultline: injected {}", self.0)
    }
}

impl std::error::Error for InjectedFault {}

fn injected(what: &'static str) -> io::Error {
    io::Error::other(InjectedFault(what))
}

/// Whether `e` was manufactured by this module.
pub fn is_injected(e: &io::Error) -> bool {
    e.get_ref().is_some_and(|r| r.is::<InjectedFault>())
}

/// What the fault plan wants done to one transport message.
enum MsgAction {
    Pass,
    Delay(u64),
    Disconnect,
}

/// Live state of one process's plan: fire-once bookkeeping plus the
/// message/unit/append counters and the seeded RNG stream.
pub struct PlanState {
    plan: FaultPlan,
    rng: Rng,
    fired: Vec<bool>,
    msgs: u64,
    claims: u64,
    appends: u64,
}

impl PlanState {
    pub fn new(plan: FaultPlan) -> PlanState {
        let rng = Rng::new(plan.seed);
        let fired = vec![false; plan.faults.len()];
        PlanState { plan, rng, fired, msgs: 0, claims: 0, appends: 0 }
    }

    fn next_msg(&mut self) -> MsgAction {
        self.msgs += 1;
        let m = self.msgs;
        let mut action = MsgAction::Pass;
        for (i, f) in self.plan.faults.iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            match f {
                Fault::Disconnect { msg } if *msg == m => {
                    self.fired[i] = true;
                    return MsgAction::Disconnect;
                }
                Fault::Delay { msg, ms } if *msg == m => {
                    self.fired[i] = true;
                    action = MsgAction::Delay(*ms);
                }
                _ => {}
            }
        }
        action
    }

    /// Called by the worker on each unit claim. Returns
    /// `(hang_ms, crash)` for this claim ordinal.
    pub fn on_claim(&mut self) -> (Option<u64>, bool) {
        self.claims += 1;
        let u = self.claims;
        let mut hang = None;
        let mut crash = false;
        for (i, f) in self.plan.faults.iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            match f {
                Fault::Hang { unit, ms } if *unit == u => {
                    self.fired[i] = true;
                    hang = Some(*ms);
                }
                Fault::Crash { unit } if *unit == u => {
                    self.fired[i] = true;
                    crash = true;
                }
                _ => {}
            }
        }
        (hang, crash)
    }

    /// Called by [`FaultDurable`] per append: the fault to apply, if
    /// any. Torn appends carry the keep-fraction; the garbage suffix is
    /// drawn from the plan's RNG stream.
    fn next_append(&mut self) -> Option<Fault> {
        self.appends += 1;
        let r = self.appends;
        for (i, f) in self.plan.faults.iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            match f {
                Fault::TornAppend { rec, .. } | Fault::DropSync { rec } if *rec == r => {
                    self.fired[i] = true;
                    return Some(f.clone());
                }
                _ => {}
            }
        }
        None
    }

    /// Deterministic garbage for a torn write: stale-disk bytes that are
    /// printable (the journal reads itself as UTF-8) but never valid
    /// JSON.
    fn torn_garbage(&mut self) -> Vec<u8> {
        let len = 4 + (self.rng.next_u64() % 21) as usize;
        (0..len)
            .map(|_| b'A' + (self.rng.next_u64() % 26) as u8)
            .collect()
    }
}

/// The worker's line transport to the driver. `recv_line` strips the
/// newline; `Ok(None)` is a clean EOF.
pub trait Transport: Send {
    fn send_line(&mut self, line: &str) -> io::Result<()>;
    fn recv_line(&mut self) -> io::Result<Option<String>>;
    /// Abruptly close both directions (used when simulating crashes).
    fn shutdown(&mut self);
    /// Bound (or unbound) blocking reads — armed around the handshake.
    fn set_read_deadline(&self, deadline: Option<Duration>);
}

/// A `Read` adapter that caps every read at `max` bytes — the kernel is
/// always allowed to return short reads; this makes them mandatory so
/// line-reassembly paths are exercised deterministically hard.
pub struct ShortRead<R: Read> {
    inner: R,
    max: usize,
}

impl<R: Read> ShortRead<R> {
    pub fn new(inner: R, max: usize) -> ShortRead<R> {
        ShortRead { inner, max: max.max(1) }
    }
}

impl<R: Read> Read for ShortRead<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = buf.len().min(self.max);
        self.inner.read(&mut buf[..n])
    }
}

/// The real TCP transport: one stream, a shared writer handle (the
/// heartbeat thread writes pings through it, serialized by the mutex),
/// and a buffered reader, optionally short-read-capped.
pub struct TcpTransport {
    stream: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    reader: io::BufReader<Box<dyn Read + Send>>,
}

impl TcpTransport {
    pub fn connect(addr: &str, short_read: Option<usize>) -> io::Result<TcpTransport> {
        let stream = TcpStream::connect(addr)?;
        let writer = Arc::new(Mutex::new(stream.try_clone()?));
        let rd: Box<dyn Read + Send> = match short_read {
            Some(n) => Box::new(ShortRead::new(stream.try_clone()?, n)),
            None => Box::new(stream.try_clone()?),
        };
        Ok(TcpTransport { stream, writer, reader: io::BufReader::new(rd) })
    }

    /// The writer handle the heartbeat thread shares with `send_line`.
    pub fn shared_writer(&self) -> Arc<Mutex<TcpStream>> {
        self.writer.clone()
    }
}

impl Transport for TcpTransport {
    fn send_line(&mut self, line: &str) -> io::Result<()> {
        let mut buf = Vec::with_capacity(line.len() + 1);
        buf.extend_from_slice(line.as_bytes());
        buf.push(b'\n');
        // One write_all per line: whole-line granularity on the wire.
        let mut w = self.writer.lock().unwrap();
        w.write_all(&buf)
    }

    fn recv_line(&mut self) -> io::Result<Option<String>> {
        use io::BufRead;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        while line.ends_with('\n') || line.ends_with('\r') {
            line.pop();
        }
        Ok(Some(line))
    }

    fn shutdown(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    fn set_read_deadline(&self, deadline: Option<Duration>) {
        let _ = self.stream.set_read_timeout(deadline);
    }
}

/// Fault-injecting transport wrapper. The message counter lives in the
/// shared [`PlanState`], so it spans reconnections: `disconnect@9`
/// means the 9th message of the worker's *life*, not of one socket.
pub struct FaultTransport<T: Transport> {
    inner: T,
    state: Arc<Mutex<PlanState>>,
}

impl<T: Transport> FaultTransport<T> {
    pub fn new(inner: T, state: Arc<Mutex<PlanState>>) -> FaultTransport<T> {
        FaultTransport { inner, state }
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn send_line(&mut self, line: &str) -> io::Result<()> {
        match self.state.lock().unwrap().next_msg() {
            MsgAction::Pass => {}
            MsgAction::Delay(ms) => std::thread::sleep(Duration::from_millis(ms)),
            MsgAction::Disconnect => {
                self.inner.shutdown();
                return Err(injected("disconnect (on send)"));
            }
        }
        self.inner.send_line(line)
    }

    fn recv_line(&mut self) -> io::Result<Option<String>> {
        match self.state.lock().unwrap().next_msg() {
            MsgAction::Pass => {}
            MsgAction::Delay(ms) => std::thread::sleep(Duration::from_millis(ms)),
            MsgAction::Disconnect => {
                self.inner.shutdown();
                return Err(injected("disconnect (on recv)"));
            }
        }
        self.inner.recv_line()
    }

    fn shutdown(&mut self) {
        self.inner.shutdown();
    }

    fn set_read_deadline(&self, deadline: Option<Duration>) {
        self.inner.set_read_deadline(deadline);
    }
}

/// Narrow durable-storage interface: append bytes, make them crash-safe.
/// `flush` pushes to the OS (survives a process crash); `sync` pushes to
/// the device (survives a power cut).
pub trait Durable: Send {
    fn append(&mut self, buf: &[u8]) -> io::Result<()>;
    fn flush(&mut self) -> io::Result<()>;
    fn sync(&mut self) -> io::Result<()>;
}

/// The real thing: a plain `File`.
pub struct FileDurable {
    file: std::fs::File,
}

impl FileDurable {
    pub fn new(file: std::fs::File) -> FileDurable {
        FileDurable { file }
    }
}

impl Durable for FileDurable {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        self.file.write_all(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.file.flush()
    }

    fn sync(&mut self) -> io::Result<()> {
        self.file.sync_all()
    }
}

/// Fault-injecting durable sink: `torn-append@R:F` writes only the
/// first `F` of record `R` plus deterministic garbage and fails (a
/// crash mid-write); `drop-sync@R` rolls the file back to the last
/// *synced* length and fails (a power cut before fsync — everything
/// since the last `sync()` never happened).
pub struct FaultDurable {
    file: std::fs::File,
    state: Arc<Mutex<PlanState>>,
    len: u64,
    synced_len: u64,
}

impl FaultDurable {
    pub fn new(file: std::fs::File, state: Arc<Mutex<PlanState>>) -> io::Result<FaultDurable> {
        let len = file.metadata()?.len();
        // Pre-existing content (header, resumed records) counts as
        // synced: drop-sync models losing the *unsynced* tail only.
        Ok(FaultDurable { file, state, len, synced_len: len })
    }
}

impl Durable for FaultDurable {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        let fault = self.state.lock().unwrap().next_append();
        match fault {
            None => {
                self.file.write_all(buf)?;
                self.len += buf.len() as u64;
                Ok(())
            }
            Some(Fault::TornAppend { frac, .. }) => {
                let keep = ((buf.len() as f64 * frac) as usize).min(buf.len().saturating_sub(1));
                let garbage = self.state.lock().unwrap().torn_garbage();
                self.file.write_all(&buf[..keep])?;
                self.file.write_all(&garbage)?;
                self.file.write_all(b"\n")?;
                let _ = self.file.flush();
                Err(injected("torn append"))
            }
            Some(Fault::DropSync { .. }) => {
                self.file.set_len(self.synced_len)?;
                Err(injected("fsync-dropped tail (power cut)"))
            }
            Some(_) => unreachable!("next_append only yields storage faults"),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.file.flush()
    }

    fn sync(&mut self) -> io::Result<()> {
        self.file.sync_all()?;
        self.synced_len = self.len;
        Ok(())
    }
}

/// Crash-consistent file writer: all writes go to a sibling
/// `<name>.<pid>.tmp`; `commit()` fsyncs and renames over the
/// destination. Dropping without committing removes the temp file and
/// leaves any previous destination untouched — a torn write can never
/// surface as a half-written CSV.
pub struct AtomicFile {
    sink: Box<dyn Durable>,
    tmp: PathBuf,
    dest: PathBuf,
    committed: bool,
}

impl AtomicFile {
    pub fn create(dest: impl AsRef<Path>) -> io::Result<AtomicFile> {
        Self::create_with(dest, |f| Box::new(FileDurable::new(f)))
    }

    /// `create` with the sink wrapped by `wrap` — chaos tests inject a
    /// [`FaultDurable`] here.
    pub fn create_with<F>(dest: impl AsRef<Path>, wrap: F) -> io::Result<AtomicFile>
    where
        F: FnOnce(std::fs::File) -> Box<dyn Durable>,
    {
        let dest = dest.as_ref().to_path_buf();
        if let Some(dir) = dest.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut name = dest.file_name().map(|n| n.to_os_string()).unwrap_or_default();
        name.push(format!(".{}.tmp", std::process::id()));
        let tmp = dest.with_file_name(name);
        let file = std::fs::File::create(&tmp)?;
        Ok(AtomicFile { sink: wrap(file), tmp, dest, committed: false })
    }

    /// Make the contents durable and atomically publish them at the
    /// destination path.
    pub fn commit(mut self) -> io::Result<()> {
        self.sink.sync()?;
        std::fs::rename(&self.tmp, &self.dest)?;
        self.committed = true;
        Ok(())
    }
}

impl Write for AtomicFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.sink.append(buf)?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.sink.flush()
    }
}

impl Drop for AtomicFile {
    fn drop(&mut self) {
        if !self.committed {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Reconnect backoff: capped exponential with deterministic jitter.
/// `attempt` is 1-based; the delay is `min(cap, base·2^(attempt−1))`
/// scaled into `[0.5, 1.0)` of itself by the RNG stream — two workers
/// seeded differently never thundering-herd the driver, while the same
/// seed replays the same schedule bit for bit.
pub fn backoff_delay(attempt: u32, base: Duration, cap: Duration, rng: &mut Rng) -> Duration {
    let exp = base.as_secs_f64() * 2f64.powi(attempt.saturating_sub(1).min(24) as i32);
    let capped = exp.min(cap.as_secs_f64());
    let jitter = 0.5 + 0.5 * rng.f64();
    Duration::from_secs_f64(capped * jitter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_round_trips() {
        let plan = FaultPlan::new(42)
            .disconnect_at(9)
            .delay_at(3, 150)
            .crash_on_unit(4)
            .hang_on_unit(2, 800)
            .short_read_cap(7)
            .torn_append(5, 0.5)
            .drop_sync(6);
        let text = plan.to_string();
        assert_eq!(
            text,
            "seed=42;disconnect@9;delay@3:150;crash@4;hang@2:800;\
             short-read@7;torn-append@5:0.5;drop-sync@6"
        );
        let back = FaultPlan::parse(&text).unwrap();
        assert_eq!(back, plan);
        // Whitespace and empty segments are tolerated; garbage is not.
        assert_eq!(FaultPlan::parse(" seed=7 ; crash@1 ; ").unwrap().seed, 7);
        assert!(FaultPlan::parse("explode@3").is_err());
        assert!(FaultPlan::parse("crash").is_err());
        assert!(FaultPlan::parse("torn-append@1:1.5").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
    }

    #[test]
    fn directives_fire_once_at_their_ordinal() {
        let mut st = PlanState::new(FaultPlan::new(1).disconnect_at(3).delay_at(2, 10));
        assert!(matches!(st.next_msg(), MsgAction::Pass));
        assert!(matches!(st.next_msg(), MsgAction::Delay(10)));
        assert!(matches!(st.next_msg(), MsgAction::Disconnect));
        for _ in 0..10 {
            assert!(matches!(st.next_msg(), MsgAction::Pass), "one-shot directives");
        }
        let mut st = PlanState::new(FaultPlan::new(1).crash_on_unit(2).hang_on_unit(1, 50));
        assert_eq!(st.on_claim(), (Some(50), false));
        assert_eq!(st.on_claim(), (None, true));
        assert_eq!(st.on_claim(), (None, false));
    }

    #[test]
    fn backoff_schedule_is_deterministic_capped_and_jittered() {
        let base = Duration::from_millis(100);
        let cap = Duration::from_secs(2);
        let schedule = |seed: u64| -> Vec<Duration> {
            let mut rng = Rng::new(seed);
            (1..=8).map(|a| backoff_delay(a, base, cap, &mut rng)).collect()
        };
        let a = schedule(7);
        let b = schedule(7);
        assert_eq!(a, b, "same seed, same schedule, bit for bit");
        let c = schedule(8);
        assert_ne!(a, c, "different seed, different jitter");
        for (i, d) in a.iter().enumerate() {
            let envelope = (base.as_secs_f64() * 2f64.powi(i as i32)).min(cap.as_secs_f64());
            let lo = 0.5 * envelope;
            assert!(d.as_secs_f64() >= lo - 1e-12 && d.as_secs_f64() < envelope + 1e-12,
                "attempt {} delay {:?} outside [{lo}, {envelope}]", i + 1, d);
        }
        // The cap binds: late attempts never exceed it.
        assert!(a[7].as_secs_f64() <= cap.as_secs_f64());
    }

    #[test]
    fn torn_garbage_is_seed_deterministic() {
        let mut a = PlanState::new(FaultPlan::new(99));
        let mut b = PlanState::new(FaultPlan::new(99));
        assert_eq!(a.torn_garbage(), b.torn_garbage());
        let mut c = PlanState::new(FaultPlan::new(100));
        assert_ne!(a.torn_garbage(), c.torn_garbage());
    }

    #[test]
    fn atomic_file_commit_and_abandon() {
        let dir = std::env::temp_dir().join(format!("qs_faultline_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let dest = dir.join("out.csv");
        // Commit publishes atomically.
        let mut f = AtomicFile::create(&dest).unwrap();
        f.write_all(b"a,b\n1,2\n").unwrap();
        f.commit().unwrap();
        assert_eq!(std::fs::read(&dest).unwrap(), b"a,b\n1,2\n");
        // An abandoned write leaves the old contents and no temp litter.
        {
            let mut f = AtomicFile::create(&dest).unwrap();
            f.write_all(b"torn").unwrap();
        }
        assert_eq!(std::fs::read(&dest).unwrap(), b"a,b\n1,2\n");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files must not survive an abandon");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_durable_torn_append_and_drop_sync() {
        let dir = std::env::temp_dir().join(format!("qs_faultdur_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        let state = Arc::new(Mutex::new(PlanState::new(
            FaultPlan::new(5).torn_append(2, 0.5),
        )));
        let file = std::fs::File::create(&path).unwrap();
        let mut d = FaultDurable::new(file, state).unwrap();
        d.append(b"record-one\n").unwrap();
        d.sync().unwrap();
        let err = d.append(b"record-two\n").unwrap_err();
        assert!(is_injected(&err), "torn append is marked injected: {err}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("record-one\nrecor"), "half of record two: {text:?}");
        assert!(text.ends_with('\n') && text.lines().count() == 2);

        // drop-sync rolls back to the synced length.
        let state = Arc::new(Mutex::new(PlanState::new(FaultPlan::new(5).drop_sync(2))));
        let file = std::fs::File::create(&path).unwrap();
        let mut d = FaultDurable::new(file, state).unwrap();
        d.append(b"kept\n").unwrap();
        d.sync().unwrap();
        let err = d.append(b"lost\n").unwrap_err();
        assert!(is_injected(&err));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "kept\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
