//! Experiment harnesses: one entry point per table/figure in the paper's
//! evaluation (§6, Appendices C–D). Each harness runs the simulations
//! (in parallel across fine-grained replication units), prints the
//! paper-style rows, and writes CSV series under `results/`.
//!
//! Scale: `Scale::full()` reproduces the paper-quality curves (minutes);
//! `Scale::bench()` is the reduced-but-faithful version the `cargo
//! bench` targets run; `Scale::smoke()` is for tests.
//!
//! Parallelism model: every (λ, policy) point fans out into R
//! independent, seed-streamed replications, scheduled as fine-grained
//! *(point, replication)* units. Short points no longer serialize behind
//! long ones (the old sweep scheduled whole points), workers reuse one
//! resettable [`Engine`] per point (no per-replication allocation), and
//! the per-point replications pool their batch means into a single CI
//! ([`ReplicationPool`]).
//!
//! Where units *execute* is abstracted behind [`UnitSource`]:
//! [`LocalThreads`] pulls units off a shared counter with in-process
//! worker threads, and [`crate::sweep::Driver`] serves the same units to
//! remote worker processes over TCP JSONL. Both deliver bit-identical
//! [`UnitRun`]s for a given (grid, seed), so sharded and in-process
//! sweeps produce byte-identical CSVs.

pub mod figures;

use crate::policy::PolicyId;
use crate::sim::{Engine, ReplicationPool, SimConfig, SimResult, UnitStats};
use crate::util::json::Value;
use crate::util::rng::{Rng, SplitMix64};
use crate::util::stats::PairedDiff;
use crate::workload::{MaterializedStream, SyntheticSource, Workload};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Typed paper-figure identifier — replaces the stringly integer
/// figures that used to thread through spec dispatch, the CLI, and the
/// `QS_REPS_FIG<N>` lookup. Parses both bare numbers ("6") and
/// "fig6"-style names, case-insensitively.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FigureId {
    Fig1,
    Fig2,
    Fig3,
    Fig4,
    Fig5,
    Fig6,
    Fig7,
    Fig8,
}

impl FigureId {
    pub const ALL: [FigureId; 8] = [
        FigureId::Fig1,
        FigureId::Fig2,
        FigureId::Fig3,
        FigureId::Fig4,
        FigureId::Fig5,
        FigureId::Fig6,
        FigureId::Fig7,
        FigureId::Fig8,
    ];

    pub fn parse(s: &str) -> anyhow::Result<FigureId> {
        let t = s.trim().to_ascii_lowercase();
        let digit = t.strip_prefix("fig").unwrap_or(&t);
        match digit {
            "1" => Ok(FigureId::Fig1),
            "2" => Ok(FigureId::Fig2),
            "3" => Ok(FigureId::Fig3),
            "4" => Ok(FigureId::Fig4),
            "5" => Ok(FigureId::Fig5),
            "6" => Ok(FigureId::Fig6),
            "7" => Ok(FigureId::Fig7),
            "8" => Ok(FigureId::Fig8),
            _ => anyhow::bail!("unknown figure '{s}' (expected 1..8 or fig1..fig8)"),
        }
    }

    pub fn number(self) -> u32 {
        match self {
            FigureId::Fig1 => 1,
            FigureId::Fig2 => 2,
            FigureId::Fig3 => 3,
            FigureId::Fig4 => 4,
            FigureId::Fig5 => 5,
            FigureId::Fig6 => 6,
            FigureId::Fig7 => 7,
            FigureId::Fig8 => 8,
        }
    }

    /// The `QS_REPS_<suffix>` env-var suffix, e.g. `FIG6`.
    pub fn env_suffix(self) -> String {
        format!("FIG{}", self.number())
    }

    /// Figures whose harness is a shardable λ × policy sweep grid (the
    /// ones `sweep --fig` / `sweep drive --figs` accept).
    pub fn is_sweep_shaped(self) -> bool {
        matches!(
            self,
            FigureId::Fig2 | FigureId::Fig3 | FigureId::Fig5 | FigureId::Fig6 | FigureId::Fig8
        )
    }
}

impl std::fmt::Display for FigureId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fig{}", self.number())
    }
}

/// Run-length control shared by all harnesses.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub completions: u64,
    pub seed: u64,
    pub threads: usize,
}

impl Scale {
    pub fn full() -> Scale {
        Scale {
            completions: 2_000_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    pub fn bench() -> Scale {
        Scale {
            completions: 200_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    pub fn smoke() -> Scale {
        Scale {
            completions: 30_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    /// The scale name QS_SCALE resolves to (unknown values fall back to
    /// "bench", mirroring [`Scale::from_env`]).
    pub fn env_name() -> &'static str {
        match std::env::var("QS_SCALE").as_deref() {
            Ok("full") => "full",
            Ok("smoke") => "smoke",
            _ => "bench",
        }
    }

    /// From the environment: QS_SCALE=full|bench|smoke (default bench).
    pub fn from_env() -> Scale {
        match Self::env_name() {
            "full" => Scale::full(),
            "smoke" => Scale::smoke(),
            _ => Scale::bench(),
        }
    }

    pub fn config(&self) -> SimConfig {
        SimConfig::default().with_completions(self.completions)
    }

    /// Sweep options bound to this scale's thread budget.
    pub fn sweep_opts(&self) -> SweepOpts {
        SweepOpts {
            threads: self.threads,
            ..SweepOpts::from_env()
        }
    }

    /// Like [`Scale::sweep_opts`], honoring a per-figure replication
    /// override (`QS_REPS_FIG6=8` beats `QS_REPS` for [`FigureId::Fig6`]).
    pub fn sweep_opts_for(&self, figure: FigureId) -> SweepOpts {
        SweepOpts {
            threads: self.threads,
            ..SweepOpts::from_env_for(Some(figure))
        }
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Replication/threading knobs for [`sweep_with`].
#[derive(Clone, Copy, Debug)]
pub struct SweepOpts {
    /// Independent replications per (λ, policy) point; the configured
    /// completion budget is split evenly across them.
    pub replications: u32,
    pub threads: usize,
}

impl SweepOpts {
    /// QS_REPS overrides the replication count (default 4).
    pub fn from_env() -> SweepOpts {
        Self::from_env_for(None)
    }

    /// Replication count with an optional per-figure override: for
    /// `figure = Some(FigureId::Fig6)`, `QS_REPS_FIG6` beats `QS_REPS`
    /// (the warmup-dominated figures need a different R than the
    /// default).
    pub fn from_env_for(figure: Option<FigureId>) -> SweepOpts {
        SweepOpts {
            replications: reps_from(figure, |key| std::env::var(key).ok()),
            threads: default_threads(),
        }
    }
}

/// Resolve the replication count from an env-like lookup (factored out
/// of [`SweepOpts::from_env_for`] so the precedence is testable without
/// mutating process environment).
fn reps_from(figure: Option<FigureId>, get: impl Fn(&str) -> Option<String>) -> u32 {
    let parse = |v: Option<String>| v.and_then(|s| s.trim().parse::<u32>().ok());
    let per_fig = figure.and_then(|f| parse(get(&format!("QS_REPS_{}", f.env_suffix()))));
    per_fig.or_else(|| parse(get("QS_REPS"))).unwrap_or(4).max(1)
}

impl Default for SweepOpts {
    fn default() -> SweepOpts {
        SweepOpts::from_env()
    }
}

/// One simulation point in a sweep.
#[derive(Clone, Debug)]
pub struct Point {
    pub lambda: f64,
    /// The requested policy, as passed in (its `Display` form — e.g.
    /// "msfq:31" — is what CSVs and printed rows show).
    pub policy: PolicyId,
    pub result: SimResult,
}

/// Everything a finished replication contributes to its point's pool:
/// the serializable stats plus the policy display name (e.g.
/// "MSFQ(ell=31)") captured from the run.
#[derive(Clone, Debug)]
pub struct UnitRun {
    pub stats: UnitStats,
    pub display: String,
}

/// Deterministic per-(point, replication) seed stream: neither thread
/// scheduling nor unit-to-worker assignment can change which random
/// numbers a replication consumes.
fn rep_seed(seed: u64, point: u64, rep: u64) -> u64 {
    let mixed = seed
        ^ point.wrapping_mul(0x9E3779B97F4A7C15)
        ^ rep.wrapping_mul(0xD1B54A32D192ED03);
    SplitMix64::new(mixed).next_u64()
}

/// A `.qst` trace split into block-aligned shards: shard `r` of a
/// `shards`-way split replays blocks `[r·nb/shards, (r+1)·nb/shards)`
/// of the trace (planned from the footer index alone). In a trace
/// sweep the replication axis *is* the shard axis — unit `(point, r)`
/// replays shard `r` — so the elastic driver/worker fabric distributes
/// a multi-million-job trace exactly like a figure grid, and the pooled
/// batch-means statistics aggregate shards the way they aggregate
/// independent replications.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceShards {
    pub path: String,
    pub shards: u32,
}

/// The complete (point, replication) unit grid of one sweep. Unit `u`
/// maps to point `u / reps`, replication `u % reps` (point-major), and
/// points enumerate λ-major then policy — the partition is a pure
/// function of the inputs, identical on every process that builds it.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    /// (λ, policy) per point, λ-major.
    pub pts: Vec<(f64, PolicyId)>,
    /// Replications per point (≥ 1).
    pub reps: usize,
    /// Per-replication config (measured budget split across reps;
    /// warmup NOT split — see [`sweep_with`]).
    pub rep_cfg: SimConfig,
    /// Base seed feeding the per-unit seed stream.
    pub seed: u64,
    /// Trace replay: each unit replays shard `rep` of this trace
    /// through a [`StreamingTraceSource`](crate::workload::trace::StreamingTraceSource)
    /// instead of sampling a
    /// [`SyntheticSource`] (`reps` must equal `shards`; see
    /// [`crate::sweep::SweepSpec::grid`]).
    pub trace: Option<TraceShards>,
}

impl SweepGrid {
    pub fn new(
        lambdas: &[f64],
        policies: &[PolicyId],
        cfg: &SimConfig,
        seed: u64,
        replications: u32,
    ) -> SweepGrid {
        let mut pts: Vec<(f64, PolicyId)> = Vec::new();
        for &l in lambdas {
            for &p in policies {
                pts.push((l, p));
            }
        }
        let reps = replications.max(1) as usize;
        // Split the measured-completion budget so total measured work
        // matches the single-replication configuration. Warmup is NOT
        // split: the transient length is a property of the system, not of
        // the run length, and every replication starts from an empty
        // system — each stream discards the full configured warmup.
        let rep_cfg = SimConfig {
            target_completions: cfg.target_completions.div_ceil(reps as u64),
            warmup_completions: cfg.warmup_completions,
            ..cfg.clone()
        };
        SweepGrid {
            pts,
            reps,
            rep_cfg,
            seed,
            trace: None,
        }
    }

    pub fn n_units(&self) -> usize {
        self.pts.len() * self.reps
    }

    /// (point index, replication index) of unit `u`.
    pub fn point_rep(&self, u: usize) -> (usize, usize) {
        (u / self.reps, u % self.reps)
    }
}

/// Execute one (point, replication) unit. `wl` must be the workload for
/// the unit's point; `cache` carries a reusable engine across units of
/// the same point (reset is bit-identical to fresh construction).
/// Returns `None` when the policy cannot be constructed.
pub fn run_unit(
    grid: &SweepGrid,
    wl: &Workload,
    u: usize,
    cache: &mut Option<(usize, Engine)>,
) -> Option<UnitRun> {
    let (p, r) = grid.point_rep(u);
    let (lambda, policy) = &grid.pts[p];
    let reuse = matches!(cache, Some((idx, _)) if *idx == p);
    if !reuse {
        *cache = Some((p, Engine::new(wl, grid.rep_cfg.clone())));
    }
    let engine = &mut cache.as_mut().expect("cached engine").1;
    if reuse {
        engine.reset();
    }
    match crate::policy::build(policy, wl) {
        Ok(mut pol) => {
            // Trace sweeps replay shard `r` of the `.qst` file (the
            // replication axis is the shard axis); synthetic sweeps
            // sample a live source. Either way the engine sees one
            // `ArrivalSource` and the unit stays a pure function of
            // (grid, u).
            let mut src: Box<dyn crate::workload::ArrivalSource> = match &grid.trace {
                Some(tr) => {
                    match crate::workload::trace::StreamingTraceSource::open_shard(
                        &tr.path,
                        wl.clone(),
                        r as u32,
                        grid.reps as u32,
                    ) {
                        Ok(s) => Box::new(s),
                        Err(e) => {
                            eprintln!("point ({lambda}, {policy}) shard {r}: {e}");
                            return None;
                        }
                    }
                }
                None => Box::new(SyntheticSource::new(wl.clone())),
            };
            let mut rng = Rng::new(rep_seed(grid.seed, p as u64, r as u64));
            let result = engine.run(src.as_mut(), pol.as_mut(), &mut rng);
            Some(UnitRun {
                stats: UnitStats::from_metrics(
                    engine.metrics(),
                    engine.now(),
                    result.events,
                    result.wall_s,
                ),
                display: result.policy,
            })
        }
        Err(e) => {
            eprintln!("point ({lambda}, {policy}) failed: {e}");
            None
        }
    }
}

/// Where (point, replication) units execute. Implementations must call
/// `deliver(u, run)` exactly once per *successfully finished* unit (any
/// order; duplicate deliveries for a unit are ignored, first wins) and
/// return once every unit has either delivered or conclusively failed.
pub trait UnitSource {
    fn run_units(
        &mut self,
        grid: &SweepGrid,
        wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, UnitRun) + Sync),
    ) -> anyhow::Result<()>;
}

/// In-process execution: `threads` workers pull units off a shared
/// counter (the original fine-grained replication runner).
pub struct LocalThreads {
    pub threads: usize,
}

impl UnitSource for LocalThreads {
    fn run_units(
        &mut self,
        grid: &SweepGrid,
        wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, UnitRun) + Sync),
    ) -> anyhow::Result<()> {
        let n_units = grid.n_units();
        let next = AtomicUsize::new(0);
        let threads = self.threads.max(1).min(n_units.max(1));
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    // Engine cache: consecutive units of the same point
                    // reuse one engine's allocations via reset().
                    let mut cache: Option<(usize, Engine)> = None;
                    loop {
                        let u = next.fetch_add(1, Ordering::Relaxed);
                        if u >= n_units {
                            break;
                        }
                        let (p, _) = grid.point_rep(u);
                        let wl = wl_at(grid.pts[p].0);
                        if let Some(run) = run_unit(grid, &wl, u, &mut cache) {
                            deliver(u, run);
                        }
                    }
                });
            }
        });
        Ok(())
    }
}

/// Drive `source` over the grid and pool the delivered units into
/// [`Point`]s. Pooling is per point in replication order (deterministic
/// floating-point merge order), and the output is sorted by (policy, λ)
/// — the result is a pure function of (grid, wl_at), independent of the
/// source's scheduling, worker count, or result arrival order.
pub fn sweep_units(
    grid: &SweepGrid,
    wl_at: &(dyn Fn(f64) -> Workload + Sync),
    source: &mut dyn UnitSource,
) -> anyhow::Result<Vec<Point>> {
    let slots: Vec<Mutex<Vec<Option<UnitRun>>>> = grid
        .pts
        .iter()
        .map(|_| Mutex::new((0..grid.reps).map(|_| None).collect()))
        .collect();
    let deliver = |u: usize, run: UnitRun| {
        let (p, r) = grid.point_rep(u);
        let mut slot = slots[p].lock().unwrap();
        // First result wins: a reissued-then-raced unit is dropped here
        // (identical bits anyway under the determinism contract).
        if slot[r].is_none() {
            slot[r] = Some(run);
        }
    };
    source.run_units(grid, wl_at, &deliver)?;
    let mut out = Vec::with_capacity(grid.pts.len());
    for (slot, (lambda, policy)) in slots.into_iter().zip(grid.pts.iter()) {
        let wl = wl_at(*lambda);
        let mut pool = ReplicationPool::new(wl.num_classes());
        let runs = slot.into_inner().unwrap();
        let mut display = None;
        for run in runs.iter().flatten() {
            pool.absorb_stats(&run.stats);
            if display.is_none() {
                display = Some(run.display.clone());
            }
        }
        if pool.replications() == 0 {
            continue; // every replication failed (policy build error)
        }
        let display = display.unwrap_or_else(|| policy.to_string());
        out.push(Point {
            lambda: *lambda,
            policy: *policy,
            result: pool.result(&display, &wl),
        });
    }
    // Sort on the canonical Display spelling: the same order the
    // stringly grid produced for canonical policy names.
    out.sort_by(|a, b| {
        a.policy
            .to_string()
            .cmp(&b.policy.to_string())
            .then(a.lambda.partial_cmp(&b.lambda).unwrap())
    });
    Ok(out)
}

// ---- common-random-number (CRN) paired replications ----

/// All requested policies' runs for one (λ, replication), every engine
/// replaying the *same* materialized arrival stream — the paired (CRN)
/// analogue of [`UnitRun`]. `runs[i]` corresponds to policy `i` of the
/// [`PairedGrid`]'s policy list; `None` marks a policy that failed to
/// construct.
#[derive(Clone, Debug)]
pub struct PairedRun {
    pub runs: Vec<Option<UnitRun>>,
}

impl PairedRun {
    /// Bit-exact JSON form (the paired-sweep wire format): one entry per
    /// grid policy — `null` or `{display, stats}`.
    pub fn to_json(&self) -> Value {
        let runs: Vec<Value> = self
            .runs
            .iter()
            .map(|r| match r {
                Some(run) => Value::obj()
                    .set("display", run.display.clone())
                    .set("stats", run.stats.to_json()),
                None => Value::Null,
            })
            .collect();
        Value::Arr(runs)
    }

    /// Inverse of [`PairedRun::to_json`].
    pub fn from_json(v: &Value) -> anyhow::Result<PairedRun> {
        let arr = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("paired run is not an array"))?;
        let runs = arr
            .iter()
            .map(|r| match r {
                Value::Null => Ok(None),
                _ => {
                    let display = r
                        .get("display")
                        .and_then(|d| d.as_str())
                        .ok_or_else(|| anyhow::anyhow!("paired run missing 'display'"))?
                        .to_string();
                    let stats = r
                        .get("stats")
                        .ok_or_else(|| anyhow::anyhow!("paired run missing 'stats'"))
                        .and_then(UnitStats::from_json)?;
                    Ok(Some(UnitRun { stats, display }))
                }
            })
            .collect::<anyhow::Result<Vec<Option<UnitRun>>>>()?;
        Ok(PairedRun { runs })
    }
}

/// The (λ, replication) unit grid of a paired sweep: unit `u` maps to
/// λ index `u / reps`, replication `u % reps`, and one unit runs *all*
/// policies over one shared stream seeded `rep_seed(seed, λ index, rep)`.
/// Each policy's replay of that stream is bit-identical to a solo run
/// with a live [`SyntheticSource`] at the same stream seed (the CRN
/// determinism contract), so pairing changes which comparisons are
/// cheap, never what any single policy's statistics are.
#[derive(Clone, Debug)]
pub struct PairedGrid {
    pub lambdas: Vec<f64>,
    pub policies: Vec<PolicyId>,
    /// Index into `policies` of the baseline every Δ subtracts.
    pub baseline: usize,
    /// Replications per λ (≥ 1).
    pub reps: usize,
    /// Per-replication config (measured budget split across reps;
    /// warmup NOT split — same rule as [`SweepGrid::new`]).
    pub rep_cfg: SimConfig,
    pub seed: u64,
}

impl PairedGrid {
    pub fn new(
        lambdas: &[f64],
        policies: &[PolicyId],
        baseline: usize,
        cfg: &SimConfig,
        seed: u64,
        replications: u32,
    ) -> PairedGrid {
        assert!(baseline < policies.len(), "baseline index out of range");
        let reps = replications.max(1) as usize;
        let rep_cfg = SimConfig {
            target_completions: cfg.target_completions.div_ceil(reps as u64),
            warmup_completions: cfg.warmup_completions,
            ..cfg.clone()
        };
        PairedGrid {
            lambdas: lambdas.to_vec(),
            policies: policies.to_vec(),
            baseline,
            reps,
            rep_cfg,
            seed,
        }
    }

    pub fn n_units(&self) -> usize {
        self.lambdas.len() * self.reps
    }

    /// (λ index, replication index) of unit `u`.
    pub fn point_rep(&self, u: usize) -> (usize, usize) {
        (u / self.reps, u % self.reps)
    }
}

/// Execute one paired (λ, replication) unit: materialize the shared
/// arrival stream once (lazily, during the first policy's run) and
/// replay it through every policy sequentially on one reusable engine.
/// `wl` must be the workload at the unit's λ; `cache` carries an engine
/// across units of the same λ, exactly like [`run_unit`].
pub fn run_paired_unit(
    grid: &PairedGrid,
    wl: &Workload,
    u: usize,
    cache: &mut Option<(usize, Engine)>,
) -> PairedRun {
    let (li, r) = grid.point_rep(u);
    let reuse = matches!(cache, Some((idx, _)) if *idx == li);
    if !reuse {
        *cache = Some((li, Engine::new(wl, grid.rep_cfg.clone())));
    }
    let engine = &mut cache.as_mut().expect("cached engine").1;
    let mut stream =
        MaterializedStream::new(wl.clone(), rep_seed(grid.seed, li as u64, r as u64));
    let mut used = reuse;
    let mut runs = Vec::with_capacity(grid.policies.len());
    for policy in &grid.policies {
        if used {
            engine.reset();
        }
        used = true;
        match crate::policy::build(policy, wl) {
            Ok(mut pol) => {
                // Replay never consumes the engine-side RNG; a fixed
                // dummy keeps the run signature uniform.
                let mut rng = Rng::new(0);
                let mut cursor = stream.cursor();
                let result = engine.run(&mut cursor, pol.as_mut(), &mut rng);
                runs.push(Some(UnitRun {
                    stats: UnitStats::from_metrics(
                        engine.metrics(),
                        engine.now(),
                        result.events,
                        result.wall_s,
                    ),
                    display: result.policy,
                }));
            }
            Err(e) => {
                eprintln!("paired point ({}, {policy}) failed: {e}", grid.lambdas[li]);
                runs.push(None);
            }
        }
    }
    PairedRun { runs }
}

/// Where paired units execute — the CRN counterpart of [`UnitSource`],
/// with the same delivery contract (exactly once per finished unit, any
/// order, duplicates deduped first-wins by the pooling layer).
pub trait PairedUnitSource {
    fn run_paired_units(
        &mut self,
        grid: &PairedGrid,
        wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, PairedRun) + Sync),
    ) -> anyhow::Result<()>;
}

impl PairedUnitSource for LocalThreads {
    fn run_paired_units(
        &mut self,
        grid: &PairedGrid,
        wl_at: &(dyn Fn(f64) -> Workload + Sync),
        deliver: &(dyn Fn(usize, PairedRun) + Sync),
    ) -> anyhow::Result<()> {
        let n_units = grid.n_units();
        let next = AtomicUsize::new(0);
        let threads = self.threads.max(1).min(n_units.max(1));
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    let mut cache: Option<(usize, Engine)> = None;
                    loop {
                        let u = next.fetch_add(1, Ordering::Relaxed);
                        if u >= n_units {
                            break;
                        }
                        let (li, _) = grid.point_rep(u);
                        let wl = wl_at(grid.lambdas[li]);
                        deliver(u, run_paired_unit(grid, &wl, u, &mut cache));
                    }
                });
            }
        });
        Ok(())
    }
}

/// One paired-comparison row: Δ = policy − baseline statistics at one λ
/// (negative Δ ⇒ the policy responds faster).
#[derive(Clone, Debug)]
pub struct DiffPoint {
    pub lambda: f64,
    pub policy: PolicyId,
    pub baseline: PolicyId,
    pub diff: PairedDiff,
    /// What the unpaired estimator would report from the same runs'
    /// marginal CIs: the quadrature √(ci_p² + ci_b²). The ratio
    /// `unpaired_ci95 / diff.ci95_half_width()` is the CRN
    /// variance-reduction factor the bench smoke prints.
    pub unpaired_ci95: f64,
}

/// A paired sweep's complete output: pooled marginal points — one per
/// (λ, policy), the same shape an unpaired sweep emits — plus the
/// paired Δ rows against the baseline policy.
#[derive(Clone, Debug)]
pub struct PairedSweep {
    pub points: Vec<Point>,
    pub diffs: Vec<DiffPoint>,
}

/// Drive `source` over a paired grid and pool results. Marginal pooling
/// per (λ, policy) follows [`sweep_units`] exactly (replication order,
/// sorted output); paired deltas pair each replication's policy run
/// with the baseline run *of the same shared stream*. Deterministic for
/// a given (grid, wl_at) regardless of scheduling or arrival order.
pub fn sweep_paired_units(
    grid: &PairedGrid,
    wl_at: &(dyn Fn(f64) -> Workload + Sync),
    source: &mut dyn PairedUnitSource,
) -> anyhow::Result<PairedSweep> {
    let slots: Vec<Mutex<Vec<Option<PairedRun>>>> = grid
        .lambdas
        .iter()
        .map(|_| Mutex::new((0..grid.reps).map(|_| None).collect()))
        .collect();
    let deliver = |u: usize, run: PairedRun| {
        let (li, r) = grid.point_rep(u);
        let mut slot = slots[li].lock().unwrap();
        if slot[r].is_none() {
            slot[r] = Some(run);
        }
    };
    source.run_paired_units(grid, wl_at, &deliver)?;
    let np = grid.policies.len();
    let mut points = Vec::new();
    let mut diffs = Vec::new();
    for (slot, &lambda) in slots.into_iter().zip(grid.lambdas.iter()) {
        let wl = wl_at(lambda);
        let nc = wl.num_classes();
        let runs = slot.into_inner().unwrap();
        let mut pools: Vec<ReplicationPool> =
            (0..np).map(|_| ReplicationPool::new(nc)).collect();
        let mut displays: Vec<Option<String>> = vec![None; np];
        let mut pds: Vec<PairedDiff> = (0..np).map(|_| PairedDiff::new(nc)).collect();
        for rep in runs.iter().flatten() {
            for (pi, run) in rep.runs.iter().enumerate() {
                if let Some(run) = run {
                    pools[pi].absorb_stats(&run.stats);
                    if displays[pi].is_none() {
                        displays[pi] = Some(run.display.clone());
                    }
                }
            }
            // Paired deltas need both sides of the same shared stream.
            if let Some(base) = rep.runs[grid.baseline].as_ref() {
                let b_means: Vec<f64> = base.stats.resp.iter().map(|w| w.mean()).collect();
                for (pi, run) in rep.runs.iter().enumerate() {
                    if pi == grid.baseline {
                        continue;
                    }
                    if let Some(run) = run {
                        let p_means: Vec<f64> =
                            run.stats.resp.iter().map(|w| w.mean()).collect();
                        pds[pi].push_rep(
                            &p_means,
                            &b_means,
                            run.stats.resp_all.batch_means(),
                            base.stats.resp_all.batch_means(),
                        );
                    }
                }
            }
        }
        let results: Vec<Option<SimResult>> = pools
            .iter()
            .enumerate()
            .map(|(pi, pool)| {
                if pool.replications() == 0 {
                    return None; // every replication failed (bad policy)
                }
                let display = displays[pi]
                    .clone()
                    .unwrap_or_else(|| grid.policies[pi].to_string());
                Some(pool.result(&display, &wl))
            })
            .collect();
        let base_ci = results[grid.baseline].as_ref().map(|r| r.ci95);
        for (pi, policy) in grid.policies.iter().enumerate() {
            let Some(result) = &results[pi] else {
                continue;
            };
            if pi != grid.baseline {
                let unpaired_ci95 = match base_ci {
                    Some(b) => (result.ci95 * result.ci95 + b * b).sqrt(),
                    None => f64::NAN,
                };
                diffs.push(DiffPoint {
                    lambda,
                    policy: *policy,
                    baseline: grid.policies[grid.baseline],
                    diff: pds[pi].clone(),
                    unpaired_ci95,
                });
            }
            points.push(Point {
                lambda,
                policy: *policy,
                result: result.clone(),
            });
        }
    }
    points.sort_by(|a, b| {
        a.policy
            .to_string()
            .cmp(&b.policy.to_string())
            .then(a.lambda.partial_cmp(&b.lambda).unwrap())
    });
    diffs.sort_by(|a, b| {
        a.policy
            .to_string()
            .cmp(&b.policy.to_string())
            .then(a.lambda.partial_cmp(&b.lambda).unwrap())
    });
    Ok(PairedSweep { points, diffs })
}

/// Write paired Δ rows as CSV: lambda, policy, baseline, pooled Δ of
/// batch means with the paired CI, the unpaired quadrature CI for
/// comparison, the replication count, and per-class replication-level
/// Δs of the class means.
pub fn write_diff_csv(
    path: &str,
    diffs: &[DiffPoint],
    class_names: &[String],
) -> std::io::Result<()> {
    let mut header: Vec<String> = vec![
        "lambda".into(),
        "policy".into(),
        "baseline".into(),
        "d_et".into(),
        "ci95_paired".into(),
        "ci95_unpaired".into(),
        "reps".into(),
    ];
    header.extend(class_names.iter().map(|n| format!("d_et_{n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    // Atomic publish: rows accumulate in a same-directory temp file
    // that only replaces `path` on a clean, synced finish — a crash (or
    // injected fault) mid-write never leaves a torn CSV at the final
    // name.
    let tmp = crate::sweep::faultline::AtomicFile::create(std::path::Path::new(path))?;
    let mut w = crate::util::csv::CsvWriter::new(tmp, &header_refs)?;
    for d in diffs {
        let mut row = vec![
            crate::util::csv::format_g(d.lambda),
            d.policy.to_string(),
            d.baseline.to_string(),
            crate::util::csv::format_g(d.diff.delta_mean()),
            crate::util::csv::format_g(d.diff.ci95_half_width()),
            crate::util::csv::format_g(d.unpaired_ci95),
            format!("{}", d.diff.replications()),
        ];
        for c in 0..class_names.len() {
            row.push(crate::util::csv::format_g(d.diff.class_delta_mean(c)));
        }
        w.row(&row)?;
    }
    w.flush()?;
    w.into_inner().commit()
}

/// Pretty-print paired Δ rows grouped by λ.
pub fn print_paired(title: &str, diffs: &[DiffPoint]) {
    println!("\n=== {title} ===");
    let mut lambdas: Vec<f64> = diffs.iter().map(|d| d.lambda).collect();
    lambdas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lambdas.dedup();
    for l in lambdas {
        println!("λ = {l}:");
        for d in diffs.iter().filter(|d| d.lambda == l) {
            let ratio = d.unpaired_ci95 / d.diff.ci95_half_width();
            println!(
                "  Δ({} − {}) = {:>10.4} ±{:<9.4} (unpaired ±{:.4}, {:.1}× narrower, R={})",
                d.policy,
                d.baseline,
                d.diff.delta_mean(),
                d.diff.ci95_half_width(),
                d.unpaired_ci95,
                ratio,
                d.diff.replications()
            );
        }
    }
}

/// Run `policies × lambdas` with environment-default replication and
/// threading (see [`SweepOpts::from_env`]).
pub fn sweep(
    wl_at: &(dyn Fn(f64) -> Workload + Sync),
    lambdas: &[f64],
    policies: &[PolicyId],
    cfg: &SimConfig,
    seed: u64,
) -> Vec<Point> {
    sweep_with(wl_at, lambdas, policies, cfg, seed, &SweepOpts::from_env())
}

/// Run `policies × lambdas`, each point as `opts.replications`
/// independent replications scheduled as fine-grained parallel units.
/// Output order and every statistic are deterministic for a given
/// (workloads, cfg, seed, replications) regardless of thread count.
pub fn sweep_with(
    wl_at: &(dyn Fn(f64) -> Workload + Sync),
    lambdas: &[f64],
    policies: &[PolicyId],
    cfg: &SimConfig,
    seed: u64,
    opts: &SweepOpts,
) -> Vec<Point> {
    let grid = SweepGrid::new(lambdas, policies, cfg, seed, opts.replications);
    let mut source = LocalThreads {
        threads: opts.threads,
    };
    sweep_units(&grid, wl_at, &mut source).expect("local unit execution is infallible")
}

/// Write a sweep as CSV: lambda, policy, et, etw, ci95, jain, util, and
/// per-class means.
pub fn write_sweep_csv(
    path: &str,
    points: &[Point],
    class_names: &[String],
) -> std::io::Result<()> {
    let mut header: Vec<String> = vec![
        "lambda".into(),
        "policy".into(),
        "et".into(),
        "etw".into(),
        "ci95".into(),
        "jain".into(),
        "util".into(),
    ];
    header.extend(class_names.iter().map(|n| format!("et_{n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    // Atomic publish, as in write_diff_csv: temp file + rename, so the
    // CSV at `path` is always either the old complete file or the new
    // complete file.
    let tmp = crate::sweep::faultline::AtomicFile::create(std::path::Path::new(path))?;
    let mut w = crate::util::csv::CsvWriter::new(tmp, &header_refs)?;
    for p in points {
        let mut row = vec![
            crate::util::csv::format_g(p.lambda),
            p.policy.to_string(),
            crate::util::csv::format_g(p.result.mean_t_all),
            crate::util::csv::format_g(p.result.weighted_t),
            crate::util::csv::format_g(p.result.ci95),
            crate::util::csv::format_g(p.result.jain),
            crate::util::csv::format_g(p.result.utilization),
        ];
        for c in 0..class_names.len() {
            row.push(crate::util::csv::format_g(p.result.mean_t[c]));
        }
        w.row(&row)?;
    }
    w.flush()?;
    w.into_inner().commit()
}

/// Pretty-print a sweep grouped by λ.
pub fn print_sweep(title: &str, points: &[Point], weighted: bool) {
    println!("\n=== {title} ===");
    let mut lambdas: Vec<f64> = points.iter().map(|p| p.lambda).collect();
    lambdas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lambdas.dedup();
    for l in lambdas {
        println!("λ = {l}:");
        for p in points.iter().filter(|p| p.lambda == l) {
            let v = if weighted {
                p.result.weighted_t
            } else {
                p.result.mean_t_all
            };
            println!(
                "  {:<16} {}[T] = {:>12.3}   (±{:.3}, util {:.3}, jain {:.3})",
                p.policy,
                if weighted { "E_w" } else { "E" },
                v,
                p.result.ci95,
                p.result.utilization,
                p.result.jain
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// QS_REPS_FIG<N> beats QS_REPS beats the default of 4; garbage and
    /// zero fall through / clamp.
    #[test]
    fn per_figure_reps_precedence() {
        let env = |pairs: &'static [(&'static str, &'static str)]| {
            move |key: &str| {
                let hit = pairs.iter().find(|(k, _)| *k == key);
                hit.map(|(_, v)| v.to_string())
            }
        };
        let empty = env(&[]);
        let global = env(&[("QS_REPS", "7")]);
        let both = env(&[("QS_REPS", "7"), ("QS_REPS_FIG6", "8")]);
        let garbage = env(&[("QS_REPS", "7"), ("QS_REPS_FIG6", "lots")]);
        let zero = env(&[("QS_REPS", "0")]);
        assert_eq!(reps_from(None, &empty), 4);
        assert_eq!(reps_from(Some(FigureId::Fig6), &empty), 4);
        assert_eq!(reps_from(None, &global), 7);
        assert_eq!(reps_from(Some(FigureId::Fig6), &global), 7);
        assert_eq!(reps_from(Some(FigureId::Fig6), &both), 8);
        // Another figure does not see fig6's override.
        assert_eq!(reps_from(Some(FigureId::Fig3), &both), 7);
        // Unparseable per-figure value falls back to QS_REPS.
        assert_eq!(reps_from(Some(FigureId::Fig6), &garbage), 7);
        // Zero clamps to 1.
        assert_eq!(reps_from(None, &zero), 1);
    }

    #[test]
    fn figure_id_parsing_and_names() {
        assert_eq!(FigureId::parse("6").unwrap(), FigureId::Fig6);
        assert_eq!(FigureId::parse("fig6").unwrap(), FigureId::Fig6);
        assert_eq!(FigureId::parse(" FIG2 ").unwrap(), FigureId::Fig2);
        assert!(FigureId::parse("9").is_err());
        assert!(FigureId::parse("figure6").is_err());
        assert!(FigureId::parse("").is_err());
        assert_eq!(FigureId::Fig6.env_suffix(), "FIG6");
        assert_eq!(FigureId::Fig3.to_string(), "fig3");
        // Round-trip every figure through its display name; only the
        // sweep-shaped subset is accepted by the sweep CLI.
        for f in FigureId::ALL {
            assert_eq!(FigureId::parse(&f.to_string()).unwrap(), f);
            assert_eq!(
                f.is_sweep_shaped(),
                matches!(f.number(), 2 | 3 | 5 | 6 | 8),
            );
        }
    }

    /// The unit grid partition is point-major and deterministic.
    #[test]
    fn grid_partition_is_point_major() {
        let cfg = SimConfig::default().with_completions(9_000);
        let grid = SweepGrid::new(
            &[2.0, 3.0],
            &[PolicyId::Msf, PolicyId::Fcfs],
            &cfg,
            1,
            3,
        );
        assert_eq!(grid.pts.len(), 4);
        assert_eq!(grid.n_units(), 12);
        assert_eq!(grid.point_rep(0), (0, 0));
        assert_eq!(grid.point_rep(2), (0, 2));
        assert_eq!(grid.point_rep(3), (1, 0));
        assert_eq!(grid.point_rep(11), (3, 2));
        // Budget split, warmup untouched.
        assert_eq!(grid.rep_cfg.target_completions, 3_000);
        assert_eq!(grid.rep_cfg.warmup_completions, 9_000 / 5);
        // λ-major point order.
        assert_eq!(grid.pts[0], (2.0, PolicyId::Msf));
        assert_eq!(grid.pts[1], (2.0, PolicyId::Fcfs));
        assert_eq!(grid.pts[2], (3.0, PolicyId::Msf));
    }

    /// The paired grid partitions by (λ, replication) — one unit runs
    /// every policy — and splits the budget like the marginal grid.
    #[test]
    fn paired_grid_partition_is_lambda_major() {
        let cfg = SimConfig::default().with_completions(9_000);
        let grid = PairedGrid::new(
            &[2.0, 3.0],
            &[PolicyId::Msf, PolicyId::Msfq(Some(7)), PolicyId::Fcfs],
            0,
            &cfg,
            1,
            3,
        );
        assert_eq!(grid.n_units(), 6);
        assert_eq!(grid.point_rep(0), (0, 0));
        assert_eq!(grid.point_rep(2), (0, 2));
        assert_eq!(grid.point_rep(3), (1, 0));
        assert_eq!(grid.point_rep(5), (1, 2));
        assert_eq!(grid.rep_cfg.target_completions, 3_000);
        assert_eq!(grid.rep_cfg.warmup_completions, 9_000 / 5);
        assert_eq!(grid.policies.len(), 3);
        assert_eq!(grid.baseline, 0);
    }

    /// PairedRun wire format: None slots survive as null, stats are
    /// bit-exact.
    #[test]
    fn paired_run_json_roundtrip() {
        use crate::sim::Metrics;
        let mut m = Metrics::new(2, 3);
        for i in 0..20 {
            m.record_response(i % 2, 0.5 + i as f64);
        }
        m.flush_responses();
        let run = PairedRun {
            runs: vec![
                Some(UnitRun {
                    stats: UnitStats::from_metrics(&m, 10.0, 40, 0.01),
                    display: "MSF".into(),
                }),
                None,
            ],
        };
        let wire = run.to_json().to_string();
        let back = PairedRun::from_json(&Value::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.runs.len(), 2);
        assert!(back.runs[1].is_none());
        let (a, b) = (run.runs[0].as_ref().unwrap(), back.runs[0].as_ref().unwrap());
        assert_eq!(a.display, b.display);
        assert_eq!(a.stats.to_json().to_string(), b.stats.to_json().to_string());
    }
}
