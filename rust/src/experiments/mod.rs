//! Experiment harnesses: one entry point per table/figure in the paper's
//! evaluation (§6, Appendices C–D). Each harness runs the simulations
//! (in parallel across (λ, policy) points), prints the paper-style rows,
//! and writes CSV series under `results/`.
//!
//! Scale: `Scale::full()` reproduces the paper-quality curves (minutes);
//! `Scale::bench()` is the reduced-but-faithful version the `cargo
//! bench` targets run; `Scale::smoke()` is for tests.

pub mod figures;

use crate::sim::{SimConfig, SimResult};
use crate::workload::Workload;

/// Run-length control shared by all harnesses.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub completions: u64,
    pub seed: u64,
    pub threads: usize,
}

impl Scale {
    pub fn full() -> Scale {
        Scale {
            completions: 2_000_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    pub fn bench() -> Scale {
        Scale {
            completions: 200_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    pub fn smoke() -> Scale {
        Scale {
            completions: 30_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    /// From the environment: QS_SCALE=full|bench|smoke (default bench).
    pub fn from_env() -> Scale {
        match std::env::var("QS_SCALE").as_deref() {
            Ok("full") => Scale::full(),
            Ok("smoke") => Scale::smoke(),
            _ => Scale::bench(),
        }
    }

    pub fn config(&self) -> SimConfig {
        SimConfig::default().with_completions(self.completions)
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// One simulation point in a sweep.
#[derive(Clone, Debug)]
pub struct Point {
    pub lambda: f64,
    pub policy: String,
    pub result: SimResult,
}

/// Run `policies × lambdas` simulations in parallel threads.
pub fn sweep(
    wl_at: &(dyn Fn(f64) -> Workload + Sync),
    lambdas: &[f64],
    policies: &[&str],
    cfg: &SimConfig,
    seed: u64,
) -> Vec<Point> {
    let mut jobs: Vec<(f64, String)> = Vec::new();
    for &l in lambdas {
        for &p in policies {
            jobs.push((l, p.to_string()));
        }
    }
    let results = std::sync::Mutex::new(Vec::<Point>::new());
    let next = std::sync::atomic::AtomicUsize::new(0);
    let threads = default_threads().min(jobs.len().max(1));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (lambda, policy) = &jobs[i];
                let wl = wl_at(*lambda);
                // Derive a per-point seed so replications differ but are
                // reproducible.
                let pseed = seed
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(i as u64);
                match crate::sim::run_named(&wl, policy, cfg, pseed) {
                    Ok(result) => results.lock().unwrap().push(Point {
                        lambda: *lambda,
                        policy: policy.clone(),
                        result,
                    }),
                    Err(e) => eprintln!("point ({lambda}, {policy}) failed: {e}"),
                }
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by(|a, b| {
        a.policy
            .cmp(&b.policy)
            .then(a.lambda.partial_cmp(&b.lambda).unwrap())
    });
    out
}

/// Write a sweep as CSV: lambda, policy, et, etw, ci95, jain, util, and
/// per-class means.
pub fn write_sweep_csv(
    path: &str,
    points: &[Point],
    class_names: &[String],
) -> std::io::Result<()> {
    let mut header: Vec<String> = vec![
        "lambda".into(),
        "policy".into(),
        "et".into(),
        "etw".into(),
        "ci95".into(),
        "jain".into(),
        "util".into(),
    ];
    header.extend(class_names.iter().map(|n| format!("et_{n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut w = crate::util::csv::CsvWriter::create(path, &header_refs)?;
    for p in points {
        let mut row = vec![
            crate::util::csv::format_g(p.lambda),
            p.policy.clone(),
            crate::util::csv::format_g(p.result.mean_t_all),
            crate::util::csv::format_g(p.result.weighted_t),
            crate::util::csv::format_g(p.result.ci95),
            crate::util::csv::format_g(p.result.jain),
            crate::util::csv::format_g(p.result.utilization),
        ];
        for c in 0..class_names.len() {
            row.push(crate::util::csv::format_g(p.result.mean_t[c]));
        }
        w.row(&row)?;
    }
    w.flush()
}

/// Pretty-print a sweep grouped by λ.
pub fn print_sweep(title: &str, points: &[Point], weighted: bool) {
    println!("\n=== {title} ===");
    let mut lambdas: Vec<f64> = points.iter().map(|p| p.lambda).collect();
    lambdas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lambdas.dedup();
    for l in lambdas {
        println!("λ = {l}:");
        for p in points.iter().filter(|p| p.lambda == l) {
            let v = if weighted {
                p.result.weighted_t
            } else {
                p.result.mean_t_all
            };
            println!(
                "  {:<16} {}[T] = {:>12.3}   (±{:.3}, util {:.3}, jain {:.3})",
                p.policy,
                if weighted { "E_w" } else { "E" },
                v,
                p.result.ci95,
                p.result.utilization,
                p.result.jain
            );
        }
    }
}
