//! Experiment harnesses: one entry point per table/figure in the paper's
//! evaluation (§6, Appendices C–D). Each harness runs the simulations
//! (in parallel across fine-grained replication units), prints the
//! paper-style rows, and writes CSV series under `results/`.
//!
//! Scale: `Scale::full()` reproduces the paper-quality curves (minutes);
//! `Scale::bench()` is the reduced-but-faithful version the `cargo
//! bench` targets run; `Scale::smoke()` is for tests.
//!
//! Parallelism model: every (λ, policy) point fans out into R
//! independent, seed-streamed replications, and worker threads pull
//! *(point, replication)* units off a shared counter. Short points no
//! longer serialize behind long ones (the old sweep scheduled whole
//! points), workers reuse one resettable [`Engine`] per point (no
//! per-replication allocation), and the per-point replications pool
//! their batch means into a single CI ([`ReplicationPool`]).

pub mod figures;

use crate::sim::{Engine, Metrics, ReplicationPool, SimConfig, SimResult};
use crate::util::rng::{Rng, SplitMix64};
use crate::workload::{SyntheticSource, Workload};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run-length control shared by all harnesses.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub completions: u64,
    pub seed: u64,
    pub threads: usize,
}

impl Scale {
    pub fn full() -> Scale {
        Scale {
            completions: 2_000_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    pub fn bench() -> Scale {
        Scale {
            completions: 200_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    pub fn smoke() -> Scale {
        Scale {
            completions: 30_000,
            seed: 20250710,
            threads: default_threads(),
        }
    }

    /// The scale name QS_SCALE resolves to (unknown values fall back to
    /// "bench", mirroring [`Scale::from_env`]).
    pub fn env_name() -> &'static str {
        match std::env::var("QS_SCALE").as_deref() {
            Ok("full") => "full",
            Ok("smoke") => "smoke",
            _ => "bench",
        }
    }

    /// From the environment: QS_SCALE=full|bench|smoke (default bench).
    pub fn from_env() -> Scale {
        match Self::env_name() {
            "full" => Scale::full(),
            "smoke" => Scale::smoke(),
            _ => Scale::bench(),
        }
    }

    pub fn config(&self) -> SimConfig {
        SimConfig::default().with_completions(self.completions)
    }

    /// Sweep options bound to this scale's thread budget.
    pub fn sweep_opts(&self) -> SweepOpts {
        SweepOpts {
            threads: self.threads,
            ..SweepOpts::from_env()
        }
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Replication/threading knobs for [`sweep_with`].
#[derive(Clone, Copy, Debug)]
pub struct SweepOpts {
    /// Independent replications per (λ, policy) point; the configured
    /// completion budget is split evenly across them.
    pub replications: u32,
    pub threads: usize,
}

impl SweepOpts {
    /// QS_REPS overrides the replication count (default 4).
    pub fn from_env() -> SweepOpts {
        let replications = std::env::var("QS_REPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(4);
        SweepOpts {
            replications: replications.max(1),
            threads: default_threads(),
        }
    }
}

impl Default for SweepOpts {
    fn default() -> SweepOpts {
        SweepOpts::from_env()
    }
}

/// One simulation point in a sweep.
#[derive(Clone, Debug)]
pub struct Point {
    pub lambda: f64,
    /// The requested policy name (e.g. "msfq:31"), as passed in.
    pub policy: String,
    pub result: SimResult,
}

/// Everything a finished replication contributes to its point's pool.
struct RepRun {
    metrics: Metrics,
    now: f64,
    events: u64,
    wall_s: f64,
    /// Policy display name (e.g. "MSFQ(ell=31)"), captured from the run.
    display: String,
}

/// Deterministic per-(point, replication) seed stream: thread scheduling
/// can never change which random numbers a replication consumes.
fn rep_seed(seed: u64, point: u64, rep: u64) -> u64 {
    let mixed = seed
        ^ point.wrapping_mul(0x9E3779B97F4A7C15)
        ^ rep.wrapping_mul(0xD1B54A32D192ED03);
    SplitMix64::new(mixed).next_u64()
}

/// Run `policies × lambdas` with environment-default replication and
/// threading (see [`SweepOpts::from_env`]).
pub fn sweep(
    wl_at: &(dyn Fn(f64) -> Workload + Sync),
    lambdas: &[f64],
    policies: &[&str],
    cfg: &SimConfig,
    seed: u64,
) -> Vec<Point> {
    sweep_with(wl_at, lambdas, policies, cfg, seed, &SweepOpts::from_env())
}

/// Run `policies × lambdas`, each point as `opts.replications`
/// independent replications scheduled as fine-grained parallel units.
/// Output order and every statistic are deterministic for a given
/// (workloads, cfg, seed, replications) regardless of thread count.
pub fn sweep_with(
    wl_at: &(dyn Fn(f64) -> Workload + Sync),
    lambdas: &[f64],
    policies: &[&str],
    cfg: &SimConfig,
    seed: u64,
    opts: &SweepOpts,
) -> Vec<Point> {
    let mut pts: Vec<(f64, String)> = Vec::new();
    for &l in lambdas {
        for &p in policies {
            pts.push((l, p.to_string()));
        }
    }
    let reps = opts.replications.max(1) as usize;
    // Split the measured-completion budget so total measured work matches
    // the single-replication configuration. Warmup is NOT split: the
    // transient length is a property of the system, not of the run
    // length, and every replication starts from an empty system — each
    // stream discards the full configured warmup.
    let rep_cfg = SimConfig {
        target_completions: cfg.target_completions.div_ceil(reps as u64),
        warmup_completions: cfg.warmup_completions,
        ..cfg.clone()
    };
    let n_units = pts.len() * reps;
    let slots: Vec<Mutex<Vec<Option<RepRun>>>> = pts
        .iter()
        .map(|_| Mutex::new((0..reps).map(|_| None).collect()))
        .collect();
    let next = AtomicUsize::new(0);
    let threads = opts.threads.max(1).min(n_units.max(1));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                // Engine cache: consecutive units of the same point reuse
                // one engine's allocations via reset().
                let mut cached: Option<(usize, Engine)> = None;
                loop {
                    let u = next.fetch_add(1, Ordering::Relaxed);
                    if u >= n_units {
                        break;
                    }
                    let (p, r) = (u / reps, u % reps);
                    let (lambda, policy) = &pts[p];
                    let wl = wl_at(*lambda);
                    let reuse = matches!(&cached, Some((idx, _)) if *idx == p);
                    if !reuse {
                        cached = Some((p, Engine::new(&wl, rep_cfg.clone())));
                    }
                    let engine = &mut cached.as_mut().expect("cached engine").1;
                    if reuse {
                        engine.reset();
                    }
                    match crate::policy::by_name(policy, &wl) {
                        Ok(mut pol) => {
                            let mut src = SyntheticSource::new(wl.clone());
                            let mut rng = Rng::new(rep_seed(seed, p as u64, r as u64));
                            let result = engine.run(&mut src, pol.as_mut(), &mut rng);
                            let run = RepRun {
                                metrics: engine.metrics().clone(),
                                now: engine.now(),
                                events: result.events,
                                wall_s: result.wall_s,
                                display: result.policy,
                            };
                            slots[p].lock().unwrap()[r] = Some(run);
                        }
                        Err(e) => eprintln!("point ({lambda}, {policy}) failed: {e}"),
                    }
                }
            });
        }
    });
    // Pool each point's replications in replication order (deterministic
    // floating-point merge order).
    let mut out = Vec::with_capacity(pts.len());
    for (slot, (lambda, policy)) in slots.into_iter().zip(pts.into_iter()) {
        let wl = wl_at(lambda);
        let mut pool = ReplicationPool::new(wl.num_classes());
        let runs = slot.into_inner().unwrap();
        let mut display = None;
        for run in runs.iter().flatten() {
            pool.absorb(&run.metrics, run.now, run.events, run.wall_s);
            if display.is_none() {
                display = Some(run.display.clone());
            }
        }
        if pool.replications() == 0 {
            continue; // every replication failed (bad policy name)
        }
        let display = display.unwrap_or_else(|| policy.clone());
        out.push(Point {
            lambda,
            policy,
            result: pool.result(&display, &wl),
        });
    }
    out.sort_by(|a, b| {
        a.policy
            .cmp(&b.policy)
            .then(a.lambda.partial_cmp(&b.lambda).unwrap())
    });
    out
}

/// Write a sweep as CSV: lambda, policy, et, etw, ci95, jain, util, and
/// per-class means.
pub fn write_sweep_csv(
    path: &str,
    points: &[Point],
    class_names: &[String],
) -> std::io::Result<()> {
    let mut header: Vec<String> = vec![
        "lambda".into(),
        "policy".into(),
        "et".into(),
        "etw".into(),
        "ci95".into(),
        "jain".into(),
        "util".into(),
    ];
    header.extend(class_names.iter().map(|n| format!("et_{n}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut w = crate::util::csv::CsvWriter::create(path, &header_refs)?;
    for p in points {
        let mut row = vec![
            crate::util::csv::format_g(p.lambda),
            p.policy.clone(),
            crate::util::csv::format_g(p.result.mean_t_all),
            crate::util::csv::format_g(p.result.weighted_t),
            crate::util::csv::format_g(p.result.ci95),
            crate::util::csv::format_g(p.result.jain),
            crate::util::csv::format_g(p.result.utilization),
        ];
        for c in 0..class_names.len() {
            row.push(crate::util::csv::format_g(p.result.mean_t[c]));
        }
        w.row(&row)?;
    }
    w.flush()
}

/// Pretty-print a sweep grouped by λ.
pub fn print_sweep(title: &str, points: &[Point], weighted: bool) {
    println!("\n=== {title} ===");
    let mut lambdas: Vec<f64> = points.iter().map(|p| p.lambda).collect();
    lambdas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lambdas.dedup();
    for l in lambdas {
        println!("λ = {l}:");
        for p in points.iter().filter(|p| p.lambda == l) {
            let v = if weighted {
                p.result.weighted_t
            } else {
                p.result.mean_t_all
            };
            println!(
                "  {:<16} {}[T] = {:>12.3}   (±{:.3}, util {:.3}, jain {:.3})",
                p.policy,
                if weighted { "E_w" } else { "E" },
                v,
                p.result.ci95,
                p.result.utilization,
                p.result.jain
            );
        }
    }
}
