//! One harness per paper figure. See DESIGN.md §3 for the experiment
//! index and EXPERIMENTS.md for recorded paper-vs-measured outcomes.
//!
//! The sweep-shaped figures (2, 3, 5, 6/8) are **shardable
//! descriptions**: each has a `figN_spec` returning the
//! [`SweepSpec`] that fully determines its grid and statistics, and the
//! harness itself is "run the spec locally, then format". The same spec
//! fed to a [`crate::sweep::Driver`] fleet produces bit-identical
//! points. Figures 1 and 4 are single-run trajectory/phase harnesses
//! and stay closures. Per-figure replication overrides: `QS_REPS_FIG6=8`
//! beats `QS_REPS` for fig6 (see [`Scale::sweep_opts_for`]).

use crate::analysis::{analyze, MsfqParams};
use crate::experiments::{print_sweep, write_sweep_csv, FigureId, Point, Scale};
use crate::policy::PolicyId;
use crate::sim::{Engine, SimConfig, TimeseriesSpec};
use crate::sweep::{run_spec_local, SweepSpec, WorkloadSpec};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::workload::{SyntheticSource, Workload};

/// Build a figure's spec: grid + scale config + per-figure replications.
fn spec_for(
    workload: WorkloadSpec,
    lambdas: &[f64],
    policies: &[PolicyId],
    scale: Scale,
    figure: FigureId,
) -> SweepSpec {
    SweepSpec::from_config(
        workload,
        lambdas,
        policies,
        &scale.config(),
        scale.seed,
        scale.sweep_opts_for(figure).replications,
    )
}

/// A sweep-shaped figure's default grid (the λ lists and ℓ set the
/// paper uses) as a spec — what `sweep drive --figs 2,6` queues.
/// Errors for the non-sweep-shaped figures (1, 4, 7 are trajectory /
/// phase / derived harnesses).
pub fn default_spec(fig: FigureId, scale: Scale) -> anyhow::Result<SweepSpec> {
    match fig {
        FigureId::Fig2 => Ok(fig2_spec(scale, 7.5, &[0, 1, 2, 4, 8, 16, 24, 31])),
        FigureId::Fig3 => Ok(fig3_spec(scale, &[4.0, 5.0, 6.0, 6.75, 7.25, 7.5])),
        FigureId::Fig5 => Ok(fig5_spec(scale, &[2.0, 3.0, 4.0, 4.5, 4.75])),
        FigureId::Fig6 => Ok(fig6_spec(scale, &[2.0, 3.0, 4.0, 4.5], false)),
        FigureId::Fig8 => Ok(fig6_spec(scale, &[2.0, 3.0, 4.0, 4.5], true)),
        other => anyhow::bail!("{other} is not a sweep-shaped figure (use 2|3|5|6|8)"),
    }
}

/// The one-or-all family at the paper's Figs 1–4 shape (k=32, p1=0.9).
fn one_or_all_spec() -> WorkloadSpec {
    WorkloadSpec::OneOrAll {
        k: 32,
        p1: 0.9,
        mu1: 1.0,
        muk: 1.0,
    }
}

/// The paper's one-or-all configuration (Figs 1–4): k=32, 90% lights,
/// unit mean sizes.
pub fn one_or_all_at(lambda: f64) -> Workload {
    Workload::one_or_all(32, lambda, 0.9, 1.0, 1.0)
}

fn results_path(name: &str) -> String {
    std::fs::create_dir_all("results").ok();
    format!("results/{name}")
}

// ---------------------------------------------------------------------
// Fig 1: number of jobs in system over time, MSF vs MSFQ(k−1).
// ---------------------------------------------------------------------
pub struct Fig1Out {
    pub policy: String,
    pub mean_n: f64,
    pub peak_n: u32,
    pub samples: usize,
}

pub fn fig1(scale: Scale) -> Vec<Fig1Out> {
    let wl = one_or_all_at(7.5);
    let mut out = Vec::new();
    for policy in [PolicyId::Msf, PolicyId::Msfq(Some(31))] {
        let cfg = SimConfig {
            target_completions: scale.completions.min(400_000),
            warmup_completions: scale.completions.min(400_000) / 5,
            timeseries: Some(TimeseriesSpec {
                dt: 1.0,
                max_samples: 20_000,
            }),
            ..Default::default()
        };
        let mut engine = Engine::new(&wl, cfg.clone());
        let mut src = SyntheticSource::new(wl.clone());
        let mut rng = Rng::new(scale.seed);
        let mut pol = crate::policy::build(&policy, &wl).unwrap();
        let r = engine.run(&mut src, pol.as_mut(), &mut rng);
        let ts = r.timeseries.as_ref().unwrap();
        let total: Vec<u32> = (0..ts.len())
            .map(|i| ts.per_class.iter().map(|c| c[i]).sum())
            .collect();
        let mean_n = total.iter().map(|&x| x as f64).sum::<f64>() / total.len().max(1) as f64;
        let peak_n = total.iter().copied().max().unwrap_or(0);
        let tag = if policy == PolicyId::Msf { "msf" } else { "msfq" };
        ts.write_csv(
            results_path(&format!("fig1_{tag}.csv")),
            &wl.classes.iter().map(|c| c.name.clone()).collect::<Vec<_>>(),
        )
        .ok();
        println!(
            "fig1 {:<10} mean #jobs = {:>9.1}   peak = {:>6}   ({} samples)",
            r.policy, mean_n, peak_n, total.len()
        );
        out.push(Fig1Out {
            policy: r.policy.clone(),
            mean_n,
            peak_n,
            samples: total.len(),
        });
    }
    out
}

// ---------------------------------------------------------------------
// Fig 2: E[T] vs threshold ℓ (simulation + Theorem-2 analysis).
// ---------------------------------------------------------------------
/// Shardable description of fig2's grid (msfq:ℓ for each ℓ at one λ).
pub fn fig2_spec(scale: Scale, lambda: f64, ells: &[u32]) -> SweepSpec {
    let policies: Vec<PolicyId> = ells.iter().map(|&e| PolicyId::Msfq(Some(e))).collect();
    spec_for(one_or_all_spec(), &[lambda], &policies, scale, FigureId::Fig2)
}

pub fn fig2(scale: Scale, lambda: f64, ells: &[u32]) -> Vec<(u32, f64, f64)> {
    let wl = one_or_all_at(lambda);
    let policies: Vec<PolicyId> = ells.iter().map(|&e| PolicyId::Msfq(Some(e))).collect();
    let pts = run_spec_local(&fig2_spec(scale, lambda, ells), scale.threads);
    let mut rows = Vec::new();
    let mut w = CsvWriter::create(
        results_path("fig2_threshold.csv"),
        &["ell", "et_sim", "et_analysis"],
    )
    .unwrap();
    println!("\nfig2: E[T] vs ℓ at λ={lambda} (k=32, p1=0.9)");
    for (i, &ell) in ells.iter().enumerate() {
        let sim_et = pts
            .iter()
            .find(|p| p.policy == policies[i])
            .map(|p| p.result.mean_t_all)
            .unwrap_or(f64::NAN);
        let ana = analyze(&MsfqParams::standard(wl.k, ell, lambda, 0.9))
            .map(|a| a.et)
            .unwrap_or(f64::NAN);
        println!("  ℓ={ell:<3} sim={sim_et:>10.2}  analysis={ana:>10.2}");
        w.row_f64(&[ell as f64, sim_et, ana]).unwrap();
        rows.push((ell, sim_et, ana));
    }
    w.flush().unwrap();
    rows
}

// ---------------------------------------------------------------------
// Fig 3: E[T]/E[T^w]/per-class vs λ for all one-or-all policies, with
// the analysis overlay for MSF and MSFQ.
// ---------------------------------------------------------------------
/// Shardable description of fig3's grid.
pub fn fig3_spec(scale: Scale, lambdas: &[f64]) -> SweepSpec {
    let policies = [
        PolicyId::Msf,
        PolicyId::Msfq(Some(31)),
        PolicyId::Fcfs,
        PolicyId::FirstFit,
        PolicyId::Nmsr(None),
    ];
    spec_for(one_or_all_spec(), lambdas, &policies, scale, FigureId::Fig3)
}

pub fn fig3(scale: Scale, lambdas: &[f64]) -> Vec<Point> {
    let spec = fig3_spec(scale, lambdas);
    let pts = run_spec_local(&spec, scale.threads);
    write_sweep_csv(
        &results_path("fig3_one_or_all.csv"),
        &pts,
        &spec.class_names(),
    )
    .ok();
    // Analysis overlay (Theorem 2): MSFQ(31) and MSF(= ℓ0).
    let mut w = CsvWriter::create(
        results_path("fig3_analysis.csv"),
        &["lambda", "policy", "et", "etw", "et_light", "et_heavy"],
    )
    .unwrap();
    for &l in lambdas {
        for (name, ell) in [("analysis-msfq", 31u32), ("analysis-msf", 0u32)] {
            if let Ok(a) = analyze(&MsfqParams::standard(32, ell, l, 0.9)) {
                w.row(&[
                    format!("{l}"),
                    name.into(),
                    format!("{}", a.et),
                    format!("{}", a.etw),
                    format!("{}", a.et_light),
                    format!("{}", a.et_heavy),
                ])
                .unwrap();
            }
        }
    }
    w.flush().unwrap();
    print_sweep("fig3: one-or-all, k=32, p1=0.9 (unweighted)", &pts, false);
    print_sweep("fig3: one-or-all (weighted)", &pts, true);
    pts
}

// ---------------------------------------------------------------------
// Fig 4: phase durations vs λ, MSF vs MSFQ.
// ---------------------------------------------------------------------
pub struct Fig4Row {
    pub lambda: f64,
    pub policy: String,
    /// Mean duration of phases 1..4 (index 0 unused).
    pub mean: [f64; 5],
}

pub fn fig4(scale: Scale, lambdas: &[f64]) -> Vec<Fig4Row> {
    let mut rows = Vec::new();
    let mut w = CsvWriter::create(
        results_path("fig4_phases.csv"),
        &["lambda", "policy", "h1", "h2", "h3", "h4"],
    )
    .unwrap();
    for &l in lambdas {
        for policy in [PolicyId::Msf, PolicyId::Msfq(Some(31))] {
            let wl = one_or_all_at(l);
            let cfg = SimConfig {
                track_phases: true,
                ..scale.config()
            };
            let r = crate::sim::run_policy(&wl, &policy, &cfg, scale.seed).unwrap();
            let ph = r.phases.as_ref().unwrap();
            let mean = [
                f64::NAN,
                ph.mean(1),
                ph.mean(2),
                ph.mean(3),
                ph.mean(4),
            ];
            println!(
                "fig4 λ={l:<5} {:<12} E[H1]={:>9.2} E[H2]={:>9.2} E[H3]={:>7.3} E[H4]={:>7.3}",
                r.policy, mean[1], mean[2], mean[3], mean[4]
            );
            w.row(&[
                crate::util::csv::format_g(l),
                r.policy.clone(),
                crate::util::csv::format_g(mean[1]),
                crate::util::csv::format_g(mean[2]),
                crate::util::csv::format_g(mean[3]),
                crate::util::csv::format_g(mean[4]),
            ])
            .ok();
            rows.push(Fig4Row {
                lambda: l,
                policy: r.policy.clone(),
                mean,
            });
        }
    }
    w.flush().ok();
    rows
}

// ---------------------------------------------------------------------
// Fig 5: weighted E[T] vs λ in the 4-class system (k=15).
// ---------------------------------------------------------------------
/// Shardable description of fig5's grid.
pub fn fig5_spec(scale: Scale, lambdas: &[f64]) -> SweepSpec {
    let policies = [
        PolicyId::StaticQs(None),
        PolicyId::AdaptiveQs,
        PolicyId::Msf,
        PolicyId::FirstFit,
        PolicyId::Fcfs,
    ];
    spec_for(WorkloadSpec::FourClass, lambdas, &policies, scale, FigureId::Fig5)
}

pub fn fig5(scale: Scale, lambdas: &[f64]) -> Vec<Point> {
    let spec = fig5_spec(scale, lambdas);
    let pts = run_spec_local(&spec, scale.threads);
    write_sweep_csv(
        &results_path("fig5_multiclass.csv"),
        &pts,
        &spec.class_names(),
    )
    .ok();
    print_sweep("fig5: 4 classes, k=15 (weighted)", &pts, true);
    pts
}

// ---------------------------------------------------------------------
// Fig 6 / C.7 / D.8: Borg-derived workload (k=2048, 26 classes).
// ---------------------------------------------------------------------
/// Shardable description of the Borg grid (fig8 adds ServerFilling and
/// reads its own `QS_REPS_FIG8` override).
pub fn fig6_spec(scale: Scale, lambdas: &[f64], include_preemptive: bool) -> SweepSpec {
    let mut policies = vec![
        PolicyId::AdaptiveQs,
        PolicyId::StaticQs(None),
        PolicyId::Msf,
        PolicyId::FirstFit,
    ];
    if include_preemptive {
        policies.push(PolicyId::ServerFilling);
    }
    let figure = if include_preemptive {
        FigureId::Fig8
    } else {
        FigureId::Fig6
    };
    spec_for(WorkloadSpec::Borg, lambdas, &policies, scale, figure)
}

pub fn fig6(scale: Scale, lambdas: &[f64], include_preemptive: bool) -> Vec<Point> {
    let spec = fig6_spec(scale, lambdas, include_preemptive);
    let pts = run_spec_local(&spec, scale.threads);
    let file = if include_preemptive {
        "fig8_preemptive.csv"
    } else {
        "fig6_borg.csv"
    };
    write_sweep_csv(&results_path(file), &pts, &spec.class_names()).ok();
    print_sweep(
        if include_preemptive {
            "fig D.8: Borg workload incl. preemptive ServerFilling"
        } else {
            "fig6: Borg workload (weighted)"
        },
        &pts,
        true,
    );
    pts
}

/// C.7: fairness view of the Borg sweep — per-class extremes + Jain index.
pub struct FairnessRow {
    pub lambda: f64,
    pub policy: String,
    pub et: f64,
    pub et_lightest: f64,
    pub et_heaviest: f64,
    pub jain: f64,
}

pub fn fig7(points: &[Point]) -> Vec<FairnessRow> {
    let mut rows = Vec::new();
    let mut w = CsvWriter::create(
        results_path("fig7_fairness.csv"),
        &["lambda", "policy", "et", "et_lightest", "et_heaviest", "jain"],
    )
    .unwrap();
    println!("\nfig C.7: fairness (Borg workload)");
    for p in points {
        let nc = p.result.mean_t.len();
        let row = FairnessRow {
            lambda: p.lambda,
            policy: p.policy.to_string(),
            et: p.result.mean_t_all,
            et_lightest: p.result.mean_t[0],
            et_heaviest: p.result.mean_t[nc - 1],
            jain: p.result.jain,
        };
        println!(
            "  λ={:<5} {:<16} E[T]={:>9.2} light={:>8.2} heavy={:>11.2} jain={:.3}",
            row.lambda, row.policy, row.et, row.et_lightest, row.et_heaviest, row.jain
        );
        w.row(&[
            format!("{}", row.lambda),
            row.policy.clone(),
            crate::util::csv::format_g(row.et),
            crate::util::csv::format_g(row.et_lightest),
            crate::util::csv::format_g(row.et_heaviest),
            crate::util::csv::format_g(row.jain),
        ])
        .ok();
        rows.push(row);
    }
    w.flush().ok();
    rows
}
