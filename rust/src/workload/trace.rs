//! Workload traces: record/replay the arrival stream.
//!
//! Two on-disk forms share one validation surface ([`TraceError`]):
//!
//! * **CSV** (`t,class,size` — absolute arrival time, class index into
//!   the accompanying workload, service requirement): human-readable
//!   interchange, materialized by [`Trace::read_csv_file`].
//! * **`.qst`** ([`crate::workload::qst`]): the streaming columnar
//!   binary format. [`StreamingTraceSource`] replays it one block at a
//!   time through an mmap — no per-arrival parsing, no materialized
//!   `Vec<Arrival>` — and is bit-identical to replaying the equivalent
//!   CSV through [`TraceSource`] (`tests/prop_trace.rs`).
//!
//! Class ids are validated against the workload *before* replay starts
//! (`TraceSource::new` / `StreamingTraceSource::open`), so a foreign or
//! mislabeled trace fails with a typed error naming the row instead of
//! panicking mid-simulation.

use crate::util::csv::{read_csv, CsvWriter};
use crate::util::rng::Rng;
use crate::workload::qst::{Footer, QstReader, QstWriter, DEFAULT_BLOCK};
use crate::workload::{Arrival, ArrivalSource, SyntheticSource, Workload};
use std::path::Path;

/// Everything that can go wrong loading or replaying a trace. Row
/// numbers are 0-based data-row indices (the CSV header line excluded).
#[derive(Debug)]
pub enum TraceError {
    Io(std::io::Error),
    /// The file does not start with the expected CSV header / qst magic.
    BadHeader,
    /// A row failed to parse (wrong cell count, non-numeric cell).
    Malformed { row: usize, msg: String },
    /// `t` or `size` is NaN or infinite (a NaN time would pass a
    /// `t >= last_t` check and corrupt the event schedule).
    NonFinite { row: usize, field: &'static str },
    NonMonotonic { row: usize, t: f64, last_t: f64 },
    NegativeTime { row: usize },
    NegativeSize { row: usize },
    /// The class id does not exist in the accompanying workload.
    ClassOutOfRange {
        row: usize,
        class: usize,
        num_classes: usize,
    },
    /// The trace was written for a different class count than the
    /// workload replaying it.
    ClassCountMismatch { file: usize, workload: usize },
    /// CRC mismatch or structural damage in a `.qst` block
    /// (`block == usize::MAX`: the footer itself).
    Corrupt { block: usize, msg: &'static str },
    Format(String),
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace io error: {e}"),
            TraceError::BadHeader => write!(f, "unexpected trace header"),
            TraceError::Malformed { row, msg } => write!(f, "trace row {row} malformed: {msg}"),
            TraceError::NonFinite { row, field } => {
                write!(f, "non-finite {field} at trace row {row}")
            }
            TraceError::NonMonotonic { row, t, last_t } => write!(
                f,
                "trace times must be nondecreasing (row {row}: t={t} after {last_t})"
            ),
            TraceError::NegativeTime { row } => write!(f, "negative time at trace row {row}"),
            TraceError::NegativeSize { row } => write!(f, "negative size at trace row {row}"),
            TraceError::ClassOutOfRange {
                row,
                class,
                num_classes,
            } => write!(
                f,
                "class {class} at trace row {row} out of range for a \
                 {num_classes}-class workload"
            ),
            TraceError::ClassCountMismatch { file, workload } => write!(
                f,
                "trace was written for {file} classes but the workload has {workload}"
            ),
            TraceError::Corrupt { block, msg } => {
                if *block == usize::MAX {
                    write!(f, "corrupt qst footer: {msg}")
                } else {
                    write!(f, "corrupt qst block {block}: {msg}")
                }
            }
            TraceError::Format(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for TraceError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TraceError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TraceError {
    fn from(e: std::io::Error) -> TraceError {
        TraceError::Io(e)
    }
}

/// Parse one CSV data row into `(t, class, size)`, rejecting malformed
/// cells and non-finite numbers. Monotonicity/sign checks live with the
/// consumer (they need running state).
pub(crate) fn parse_row(cells: &[String], row: usize) -> Result<(f64, usize, f64), TraceError> {
    if cells.len() != 3 {
        return Err(TraceError::Malformed {
            row,
            msg: format!("expected 3 cells, got {}", cells.len()),
        });
    }
    let t: f64 = cells[0].parse().map_err(|_| TraceError::Malformed {
        row,
        msg: format!("bad t {:?}", cells[0]),
    })?;
    let class: usize = cells[1].parse().map_err(|_| TraceError::Malformed {
        row,
        msg: format!("bad class {:?}", cells[1]),
    })?;
    let size: f64 = cells[2].parse().map_err(|_| TraceError::Malformed {
        row,
        msg: format!("bad size {:?}", cells[2]),
    })?;
    if !t.is_finite() {
        return Err(TraceError::NonFinite { row, field: "t" });
    }
    if !size.is_finite() {
        return Err(TraceError::NonFinite { row, field: "size" });
    }
    Ok((t, class, size))
}

/// A fully materialized arrival trace (small traces, tests, CSV
/// interchange; Borg-scale replay goes through
/// [`StreamingTraceSource`] instead).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub arrivals: Vec<Arrival>,
}

impl Trace {
    /// Sample `n` arrivals from the workload's synthetic source.
    pub fn generate(wl: &Workload, n: usize, seed: u64) -> Trace {
        let mut src = SyntheticSource::new(wl.clone());
        let mut rng = Rng::new(seed);
        let arrivals = (0..n)
            .map_while(|_| src.next_arrival(&mut rng))
            .collect();
        Trace { arrivals }
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut w = CsvWriter::create(path, &["t", "class", "size"])?;
        for a in &self.arrivals {
            w.row_f64(&[a.t, a.class as f64, a.size])?;
        }
        w.flush()
    }

    pub fn read_csv_file(path: impl AsRef<Path>) -> Result<Trace, TraceError> {
        let (header, rows) = read_csv(path)?;
        if header != ["t", "class", "size"] {
            return Err(TraceError::BadHeader);
        }
        let mut arrivals = Vec::with_capacity(rows.len());
        let mut last_t = f64::NEG_INFINITY;
        for (row, cells) in rows.iter().enumerate() {
            let (t, class, size) = parse_row(cells, row)?;
            if t < 0.0 {
                return Err(TraceError::NegativeTime { row });
            }
            if t < last_t {
                return Err(TraceError::NonMonotonic { row, t, last_t });
            }
            if size < 0.0 {
                return Err(TraceError::NegativeSize { row });
            }
            last_t = t;
            arrivals.push(Arrival { t, class, size });
        }
        Ok(Trace { arrivals })
    }

    /// Write the trace in the columnar `.qst` format.
    pub fn write_qst(
        &self,
        path: impl AsRef<Path>,
        num_classes: usize,
        block_size: usize,
    ) -> Result<Footer, TraceError> {
        let mut w = QstWriter::create(path, num_classes, block_size)?;
        for a in &self.arrivals {
            w.push(a.t, a.class, a.size)?;
        }
        w.finish()
    }

    /// Materialize a `.qst` file (tools and tests; replay should stream).
    pub fn read_qst(path: impl AsRef<Path>) -> Result<Trace, TraceError> {
        let r = QstReader::open(path)?;
        let mut arrivals = Vec::with_capacity(r.footer().total as usize);
        let (mut ts, mut cs, mut ss) = (Vec::new(), Vec::new(), Vec::new());
        for i in 0..r.num_blocks() {
            r.decode_block(i, &mut ts, &mut cs, &mut ss)?;
            for j in 0..ts.len() {
                arrivals.push(Arrival {
                    t: ts[j],
                    class: cs[j] as usize,
                    size: ss[j],
                });
            }
        }
        Ok(Trace { arrivals })
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Every class id must exist in a `num_classes`-class workload; the
    /// error names the first offending row.
    pub fn validate_classes(&self, num_classes: usize) -> Result<(), TraceError> {
        for (row, a) in self.arrivals.iter().enumerate() {
            if a.class >= num_classes {
                return Err(TraceError::ClassOutOfRange {
                    row,
                    class: a.class,
                    num_classes,
                });
            }
        }
        Ok(())
    }

    /// Empirical per-class arrival counts (sanity checks / reporting).
    pub fn class_counts(&self, num_classes: usize) -> Result<Vec<usize>, TraceError> {
        self.validate_classes(num_classes)?;
        let mut c = vec![0usize; num_classes];
        for a in &self.arrivals {
            c[a.class] += 1;
        }
        Ok(c)
    }
}

/// Replays a materialized trace as an [`ArrivalSource`]; finite
/// (returns None at end). Construction validates every class id against
/// the workload.
pub struct TraceSource {
    wl: Workload,
    trace: Trace,
    idx: usize,
}

impl TraceSource {
    pub fn new(wl: Workload, trace: Trace) -> Result<TraceSource, TraceError> {
        trace.validate_classes(wl.num_classes())?;
        Ok(TraceSource { wl, trace, idx: 0 })
    }
}

impl ArrivalSource for TraceSource {
    fn next_arrival(&mut self, _rng: &mut Rng) -> Option<Arrival> {
        let a = self.trace.arrivals.get(self.idx).copied();
        self.idx += 1;
        a
    }

    fn workload(&self) -> &Workload {
        &self.wl
    }
}

/// Streams a `.qst` trace (or a block-aligned shard of one) as an
/// [`ArrivalSource`]: one block is decoded at a time from the mmap into
/// reused column buffers, so replay of a multi-million-job trace holds
/// a single block's columns plus the footer in memory — never the
/// trace. The engine-supplied RNG is deliberately unused (the recorded
/// stream is the randomness), mirroring
/// [`ReplayCursor`](crate::workload::ReplayCursor)'s CRN contract.
pub struct StreamingTraceSource {
    wl: Workload,
    reader: QstReader,
    /// Next block to decode and one past the last (the shard's range).
    next_block: usize,
    end_block: usize,
    times: Vec<f64>,
    classes: Vec<u16>,
    sizes: Vec<f64>,
    pos: usize,
}

impl StreamingTraceSource {
    /// Open the whole trace for replay.
    pub fn open(path: impl AsRef<Path>, wl: Workload) -> Result<StreamingTraceSource, TraceError> {
        StreamingTraceSource::open_shard(path, wl, 0, 1)
    }

    /// Open shard `shard` of `shards`: the block-aligned slice
    /// `[shard·nb/shards, (shard+1)·nb/shards)` of the trace's blocks,
    /// planned from the footer alone. The shard union over
    /// `0..shards` is exactly the full trace, in order, with no overlap.
    pub fn open_shard(
        path: impl AsRef<Path>,
        wl: Workload,
        shard: u32,
        shards: u32,
    ) -> Result<StreamingTraceSource, TraceError> {
        assert!(shards >= 1 && shard < shards, "shard {shard} of {shards}");
        let reader = QstReader::open(path)?;
        let file_classes = reader.footer().num_classes as usize;
        if file_classes != wl.num_classes() {
            return Err(TraceError::ClassCountMismatch {
                file: file_classes,
                workload: wl.num_classes(),
            });
        }
        let nb = reader.num_blocks();
        let next_block = (shard as usize * nb) / shards as usize;
        let end_block = ((shard as usize + 1) * nb) / shards as usize;
        Ok(StreamingTraceSource {
            wl,
            reader,
            next_block,
            end_block,
            times: Vec::new(),
            classes: Vec::new(),
            sizes: Vec::new(),
            pos: 0,
        })
    }

    /// The footer index (shard planning, `trace stats`).
    pub fn footer(&self) -> &Footer {
        self.reader.footer()
    }

    /// Arrivals in this shard (from the footer, nothing decoded).
    pub fn shard_len(&self) -> u64 {
        self.reader.footer().blocks[self.next_block..self.end_block]
            .iter()
            .map(|b| b.n as u64)
            .sum()
    }

    /// Decode the next block of the shard into the reused buffers.
    /// Returns false at shard end. Corruption cannot surface here —
    /// every block's CRC was verified at open — so decode failures
    /// indicate the file changed underneath us and panic.
    fn refill(&mut self) -> bool {
        while self.next_block < self.end_block {
            self.reader
                .decode_block(self.next_block, &mut self.times, &mut self.classes, &mut self.sizes)
                .expect("qst block decoded after CRC verification at open");
            self.next_block += 1;
            self.pos = 0;
            if !self.times.is_empty() {
                return true;
            }
        }
        false
    }
}

impl ArrivalSource for StreamingTraceSource {
    #[inline]
    fn next_arrival(&mut self, _rng: &mut Rng) -> Option<Arrival> {
        if self.pos == self.times.len() && !self.refill() {
            return None;
        }
        let i = self.pos;
        self.pos += 1;
        Some(Arrival {
            t: self.times[i],
            class: self.classes[i] as usize,
            size: self.sizes[i],
        })
    }

    fn fill_arrivals(&mut self, _rng: &mut Rng, out: &mut Vec<Arrival>, max: usize) -> usize {
        let mut filled = 0;
        while filled < max {
            if self.pos == self.times.len() && !self.refill() {
                break;
            }
            let take = (self.times.len() - self.pos).min(max - filled);
            for i in self.pos..self.pos + take {
                out.push(Arrival {
                    t: self.times[i],
                    class: self.classes[i] as usize,
                    size: self.sizes[i],
                });
            }
            self.pos += take;
            filled += take;
        }
        filled
    }

    fn workload(&self) -> &Workload {
        &self.wl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("qs_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn generate_write_read_roundtrip() {
        let wl = Workload::one_or_all(8, 2.0, 0.8, 1.0, 1.0);
        let tr = Trace::generate(&wl, 500, 7);
        assert_eq!(tr.len(), 500);
        let dir = tmp_dir();
        let path = dir.join("t.csv");
        tr.write_csv(&path).unwrap();
        let back = Trace::read_csv_file(&path).unwrap();
        assert_eq!(back.len(), 500);
        for (a, b) in tr.arrivals.iter().zip(back.arrivals.iter()) {
            assert!((a.t - b.t).abs() < 1e-9);
            assert_eq!(a.class, b.class);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn qst_roundtrip_is_bitwise() {
        let wl = Workload::four_class(4.0);
        let tr = Trace::generate(&wl, 2_000, 11);
        let dir = tmp_dir();
        let path = dir.join("t.qst");
        let footer = tr.write_qst(&path, wl.num_classes(), 64).unwrap();
        assert_eq!(footer.total, 2_000);
        let back = Trace::read_qst(&path).unwrap();
        assert_eq!(back.len(), tr.len());
        for (a, b) in tr.arrivals.iter().zip(back.arrivals.iter()) {
            assert_eq!(a.t.to_bits(), b.t.to_bits());
            assert_eq!(a.class, b.class);
            assert_eq!(a.size.to_bits(), b.size.to_bits());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_rejects_nan_and_infinite_values() {
        let dir = tmp_dir();
        let path = dir.join("nan.csv");
        std::fs::write(&path, "t,class,size\n1.0,0,2.0\nNaN,0,1.0\n").unwrap();
        let err = Trace::read_csv_file(&path).unwrap_err();
        assert!(
            matches!(err, TraceError::NonFinite { row: 1, field: "t" }),
            "unexpected error: {err}"
        );
        std::fs::write(&path, "t,class,size\n1.0,0,inf\n").unwrap();
        let err = Trace::read_csv_file(&path).unwrap_err();
        assert!(matches!(err, TraceError::NonFinite { row: 0, field: "size" }));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn class_validation_names_the_row() {
        let wl = Workload::one_or_all(8, 2.0, 0.8, 1.0, 1.0);
        let mut tr = Trace::generate(&wl, 10, 3);
        tr.arrivals[7].class = 9;
        let err = TraceSource::new(wl.clone(), tr.clone()).unwrap_err();
        assert!(
            matches!(err, TraceError::ClassOutOfRange { row: 7, class: 9, num_classes: 2 }),
            "unexpected error: {err}"
        );
        assert!(tr.class_counts(2).is_err());
        tr.arrivals[7].class = 1;
        let counts = tr.class_counts(2).unwrap();
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(TraceSource::new(wl, tr).is_ok());
    }

    #[test]
    fn trace_source_replays_and_ends() {
        let wl = Workload::one_or_all(8, 2.0, 0.8, 1.0, 1.0);
        let tr = Trace::generate(&wl, 50, 9);
        let mut src = TraceSource::new(wl, tr.clone()).unwrap();
        let mut rng = Rng::new(0);
        for want in &tr.arrivals {
            let got = src.next_arrival(&mut rng).unwrap();
            assert_eq!(got.t, want.t);
        }
        assert!(src.next_arrival(&mut rng).is_none());
    }

    #[test]
    fn streaming_source_matches_trace_source() {
        let wl = Workload::one_or_all(8, 3.0, 0.9, 1.0, 1.0);
        let tr = Trace::generate(&wl, 1_000, 17);
        let dir = tmp_dir();
        let path = dir.join("stream.qst");
        tr.write_qst(&path, wl.num_classes(), 128).unwrap();
        let mut src = StreamingTraceSource::open(&path, wl).unwrap();
        assert_eq!(src.shard_len(), 1_000);
        let mut rng = Rng::new(0);
        for want in &tr.arrivals {
            let got = src.next_arrival(&mut rng).unwrap();
            assert_eq!(got.t.to_bits(), want.t.to_bits());
            assert_eq!(got.class, want.class);
            assert_eq!(got.size.to_bits(), want.size.to_bits());
        }
        assert!(src.next_arrival(&mut rng).is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_union_is_the_full_trace() {
        let wl = Workload::four_class(4.0);
        let tr = Trace::generate(&wl, 997, 23);
        let dir = tmp_dir();
        let path = dir.join("shards.qst");
        tr.write_qst(&path, wl.num_classes(), 64).unwrap();
        for shards in [1u32, 2, 3, 5] {
            let mut got = Vec::new();
            let mut rng = Rng::new(0);
            for s in 0..shards {
                let mut src =
                    StreamingTraceSource::open_shard(&path, wl.clone(), s, shards).unwrap();
                while let Some(a) = src.next_arrival(&mut rng) {
                    got.push(a);
                }
            }
            assert_eq!(got.len(), tr.len(), "shards={shards}");
            for (a, b) in got.iter().zip(tr.arrivals.iter()) {
                assert_eq!(a.t.to_bits(), b.t.to_bits());
                assert_eq!(a.class, b.class);
                assert_eq!(a.size.to_bits(), b.size.to_bits());
            }
        }
        std::fs::remove_file(&path).ok();
    }

    /// Simulating from a replayed trace matches simulating from the
    /// synthetic source with the same seed (same arrival stream).
    #[test]
    fn trace_sim_equals_synthetic_sim() {
        let wl = Workload::one_or_all(8, 3.0, 0.9, 1.0, 1.0);
        let cfg = crate::sim::SimConfig {
            target_completions: 5_000,
            warmup_completions: 0,
            ..Default::default()
        };
        let id = "msfq:7".parse().unwrap();
        let r1 = crate::sim::run_policy(&wl, &id, &cfg, 123).unwrap();
        let tr = Trace::generate(&wl, 40_000, 123);
        let mut src = TraceSource::new(wl.clone(), tr).unwrap();
        let mut pol = crate::policy::build(&id, &wl).unwrap();
        let mut eng = crate::sim::Engine::new(&wl, cfg);
        let mut rng = Rng::new(123);
        let r2 = eng.run(&mut src, pol.as_mut(), &mut rng);
        assert!((r1.mean_t_all - r2.mean_t_all).abs() < 1e-9);
    }
}
