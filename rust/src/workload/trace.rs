//! Workload traces: record/replay the arrival stream.
//!
//! Format: CSV with header `t,class,size` (absolute arrival time, class
//! index into the accompanying workload, service requirement). Traces let
//! the coordinator and simulator consume identical workloads, and make
//! experiments reproducible across machines.

use crate::util::csv::{read_csv, CsvWriter};
use crate::util::rng::Rng;
use crate::workload::{Arrival, ArrivalSource, SyntheticSource, Workload};
use std::path::Path;

/// A fully materialized arrival trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub arrivals: Vec<Arrival>,
}

impl Trace {
    /// Sample `n` arrivals from the workload's synthetic source.
    pub fn generate(wl: &Workload, n: usize, seed: u64) -> Trace {
        let mut src = SyntheticSource::new(wl.clone());
        let mut rng = Rng::new(seed);
        let arrivals = (0..n)
            .map_while(|_| src.next_arrival(&mut rng))
            .collect();
        Trace { arrivals }
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut w = CsvWriter::create(path, &["t", "class", "size"])?;
        for a in &self.arrivals {
            w.row_f64(&[a.t, a.class as f64, a.size])?;
        }
        w.flush()
    }

    pub fn read_csv_file(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
        let (header, rows) = read_csv(path)?;
        anyhow::ensure!(
            header == ["t", "class", "size"],
            "unexpected trace header {header:?}"
        );
        let mut arrivals = Vec::with_capacity(rows.len());
        let mut last_t = f64::NEG_INFINITY;
        for (i, row) in rows.iter().enumerate() {
            anyhow::ensure!(row.len() == 3, "trace row {i} malformed");
            let t: f64 = row[0].parse()?;
            let class: usize = row[1].parse()?;
            let size: f64 = row[2].parse()?;
            anyhow::ensure!(t >= last_t, "trace times must be nondecreasing (row {i})");
            anyhow::ensure!(size >= 0.0, "negative size at row {i}");
            last_t = t;
            arrivals.push(Arrival { t, class, size });
        }
        Ok(Trace { arrivals })
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Empirical per-class arrival counts (sanity checks / reporting).
    pub fn class_counts(&self, num_classes: usize) -> Vec<usize> {
        let mut c = vec![0usize; num_classes];
        for a in &self.arrivals {
            c[a.class] += 1;
        }
        c
    }
}

/// Replays a trace as an [`ArrivalSource`]; finite (returns None at end).
pub struct TraceSource {
    wl: Workload,
    trace: Trace,
    idx: usize,
}

impl TraceSource {
    pub fn new(wl: Workload, trace: Trace) -> TraceSource {
        TraceSource { wl, trace, idx: 0 }
    }
}

impl ArrivalSource for TraceSource {
    fn next_arrival(&mut self, _rng: &mut Rng) -> Option<Arrival> {
        let a = self.trace.arrivals.get(self.idx).copied();
        self.idx += 1;
        a
    }

    fn workload(&self) -> &Workload {
        &self.wl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_write_read_roundtrip() {
        let wl = Workload::one_or_all(8, 2.0, 0.8, 1.0, 1.0);
        let tr = Trace::generate(&wl, 500, 7);
        assert_eq!(tr.len(), 500);
        let dir = std::env::temp_dir().join(format!("qs_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        tr.write_csv(&path).unwrap();
        let back = Trace::read_csv_file(&path).unwrap();
        assert_eq!(back.len(), 500);
        for (a, b) in tr.arrivals.iter().zip(back.arrivals.iter()) {
            assert!((a.t - b.t).abs() < 1e-9);
            assert_eq!(a.class, b.class);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_source_replays_and_ends() {
        let wl = Workload::one_or_all(8, 2.0, 0.8, 1.0, 1.0);
        let tr = Trace::generate(&wl, 50, 9);
        let mut src = TraceSource::new(wl, tr.clone());
        let mut rng = Rng::new(0);
        for want in &tr.arrivals {
            let got = src.next_arrival(&mut rng).unwrap();
            assert_eq!(got.t, want.t);
        }
        assert!(src.next_arrival(&mut rng).is_none());
    }

    /// Simulating from a replayed trace matches simulating from the
    /// synthetic source with the same seed (same arrival stream).
    #[test]
    fn trace_sim_equals_synthetic_sim() {
        let wl = Workload::one_or_all(8, 3.0, 0.9, 1.0, 1.0);
        let cfg = crate::sim::SimConfig {
            target_completions: 5_000,
            warmup_completions: 0,
            ..Default::default()
        };
        let id = "msfq:7".parse().unwrap();
        let r1 = crate::sim::run_policy(&wl, &id, &cfg, 123).unwrap();
        let tr = Trace::generate(&wl, 40_000, 123);
        let mut src = TraceSource::new(wl.clone(), tr);
        let mut pol = crate::policy::build(&id, &wl).unwrap();
        let mut eng = crate::sim::Engine::new(&wl, cfg);
        let mut rng = Rng::new(123);
        let r2 = eng.run(&mut src, pol.as_mut(), &mut rng);
        assert!((r1.mean_t_all - r2.mean_t_all).abs() < 1e-9);
    }
}
