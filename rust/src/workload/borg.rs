//! Borg-derived workload (§6.4 substitution).
//!
//! The paper extracts a 26-class workload from Cell B of the 2019 Google
//! Borg traces using the methodology of [43] (arrival rates, mean sizes,
//! server needs per class). The raw traces are not redistributable (and
//! this environment is offline), so we *synthesize* a class table
//! calibrated to every statistic the paper reports about its workload:
//!
//! * 26 job classes, k = 2048 set by the heaviest class;
//! * stability region boundary λ* = 4.94 (Remark 1, floored capacity);
//! * extreme skew: ≈0.34% of jobs contribute ≈85.8% of system load;
//! * needs spanning 1..2048, job-count distribution a power law in need,
//!   heavier classes having longer mean durations.
//!
//! Calibration solves two monotone one-dimensional problems (bisection):
//! the job-count exponent α matches the heavy-job fraction, then the size
//! exponent γ matches the heavy-load share; a final scale pins λ*.
//! All §6.4 metrics depend on the workload only through
//! (p_j, need_j, E[S_j]), so matching these statistics preserves the
//! experiments' behaviour (documented in DESIGN.md §4).

use crate::dist::Dist;
use crate::workload::{ClassSpec, Workload};

/// Server needs of the 26 classes (heaviest defines k = 2048).
pub const BORG_NEEDS: [u32; 26] = [
    1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 160, 192, 256, 320, 384, 512, 640, 768,
    1024, 1280, 1536, 2048,
];

/// Classes with need ≥ this form the "heavy group" whose job/load shares
/// are calibrated (the top 7 classes).
pub const HEAVY_NEED: u32 = 512;

/// Paper-reported targets.
pub const TARGET_HEAVY_JOB_FRAC: f64 = 0.0034;
pub const TARGET_HEAVY_LOAD_SHARE: f64 = 0.858;
pub const TARGET_LAMBDA_STAR: f64 = 4.94;

fn job_probs(alpha: f64) -> Vec<f64> {
    let w: Vec<f64> = BORG_NEEDS.iter().map(|&n| (n as f64).powf(-alpha)).collect();
    let tot: f64 = w.iter().sum();
    w.into_iter().map(|x| x / tot).collect()
}

fn heavy_job_frac(alpha: f64) -> f64 {
    job_probs(alpha)
        .iter()
        .zip(BORG_NEEDS.iter())
        .filter(|(_, &n)| n >= HEAVY_NEED)
        .map(|(p, _)| p)
        .sum()
}

fn heavy_load_share(p: &[f64], gamma: f64) -> f64 {
    let rho: Vec<f64> = BORG_NEEDS
        .iter()
        .zip(p.iter())
        .map(|(&n, &pj)| pj * n as f64 * (n as f64).powf(gamma))
        .collect();
    let tot: f64 = rho.iter().sum();
    BORG_NEEDS
        .iter()
        .zip(rho.iter())
        .filter(|(&n, _)| n >= HEAVY_NEED)
        .map(|(_, r)| r)
        .sum::<f64>()
        / tot
}

/// Monotone bisection on `[lo, hi]` for `f(x) = target`.
fn bisect(mut lo: f64, mut hi: f64, target: f64, f: impl Fn(f64) -> f64) -> f64 {
    let increasing = f(hi) > f(lo);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if (f(mid) > target) == increasing {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Build the calibrated Borg-like workload with total arrival rate
/// `lambda` (stability requires `lambda < TARGET_LAMBDA_STAR`).
pub fn borg_workload(lambda: f64) -> Workload {
    let k: u32 = 2048;
    // 1. Job-count skew: the heavy group gets 0.34% of arrivals.
    let alpha = bisect(0.5, 4.0, TARGET_HEAVY_JOB_FRAC, heavy_job_frac);
    let p = job_probs(alpha);
    // 2. Size growth: the heavy group carries 85.8% of the load.
    let p2 = p.clone();
    let gamma = bisect(0.0, 3.0, TARGET_HEAVY_LOAD_SHARE, move |g| {
        heavy_load_share(&p2, g)
    });
    // 3. Scale mean sizes so that λ* (Remark 1) = 4.94.
    let raw_mean: Vec<f64> = BORG_NEEDS.iter().map(|&n| (n as f64).powf(gamma)).collect();
    let denom: f64 = BORG_NEEDS
        .iter()
        .zip(p.iter().zip(raw_mean.iter()))
        .map(|(&n, (&pj, &mj))| pj * mj / (k / n) as f64)
        .sum();
    let scale = 1.0 / (TARGET_LAMBDA_STAR * denom);

    let classes: Vec<ClassSpec> = BORG_NEEDS
        .iter()
        .zip(p.iter().zip(raw_mean.iter()))
        .map(|(&n, (&pj, &mj))| {
            ClassSpec::new(n, lambda * pj, Dist::exp_mean(mj * scale)).named(&format!("borg{n}"))
        })
        .collect();
    Workload::new(k, classes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_26_classes_and_k2048() {
        let wl = borg_workload(1.0);
        assert_eq!(wl.num_classes(), 26);
        assert_eq!(wl.k, 2048);
        assert!(wl.classes.iter().all(|c| c.need() <= wl.k && c.need() >= 1));
    }

    #[test]
    fn stability_boundary_is_494() {
        let wl = borg_workload(1.0);
        let crit = wl.lambda_critical_floored();
        assert!((crit - TARGET_LAMBDA_STAR).abs() < 1e-6, "lambda* = {crit}");
        assert!(borg_workload(4.0).load() < 1.0);
    }

    #[test]
    fn heavy_group_calibration() {
        let wl = borg_workload(1.0);
        let total_rate = wl.total_rate();
        let heavy_jobs: f64 = wl
            .classes
            .iter()
            .filter(|c| c.need() >= HEAVY_NEED)
            .map(|c| c.rate)
            .sum::<f64>()
            / total_rate;
        assert!(
            (heavy_jobs - TARGET_HEAVY_JOB_FRAC).abs() < 2e-4,
            "heavy job fraction = {heavy_jobs}"
        );
        let rho_tot: f64 = (0..26).map(|c| wl.rho_class(c)).sum();
        let rho_heavy: f64 = (0..26)
            .filter(|&c| wl.classes[c].need() >= HEAVY_NEED)
            .map(|c| wl.rho_class(c))
            .sum();
        let share = rho_heavy / rho_tot;
        assert!(
            (share - TARGET_HEAVY_LOAD_SHARE).abs() < 5e-3,
            "heavy load share = {share}"
        );
    }

    #[test]
    fn sizes_grow_with_need() {
        let wl = borg_workload(1.0);
        assert!(wl.classes[25].size.mean() > wl.classes[0].size.mean());
    }
}
