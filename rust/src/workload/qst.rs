//! `.qst` — the streaming columnar trace format.
//!
//! A trace is a sequence of independently decodable **blocks**, each
//! holding up to `block_size` arrivals in columnar layout:
//!
//! ```text
//! header   "QSTRACE1" | u32 version=1 | u32 num_classes
//! block    payload | u32 crc32(payload)
//!   payload: u32 n
//!          | u64 first-arrival time bits (absolute)
//!          | (n-1) × LEB128 varint deltas of successive time bit patterns
//!          | n × u16 class id
//!          | n × u64 size bits
//! footer   u32 n_blocks
//!          | per block: u64 offset, u32 payload_len, u32 n,
//!                       u64 t_min bits, u64 t_max bits
//!          | u32 num_classes | per class: u64 count
//!          | u64 total | u64 t_first bits | u64 t_last bits
//! tail     u64 footer_len | u32 crc32(footer) | "QSTEND01"
//! ```
//!
//! All integers little-endian. Arrival times are nonnegative and
//! nondecreasing, so their IEEE-754 bit patterns are nondecreasing `u64`s
//! and successive deltas are nonnegative — delta-encoding the *bit
//! patterns* (not the float values) keeps the format lossless and the
//! replay bit-identical to the CSV path. Each block stores its first
//! time absolutely, so any block decodes without its predecessors —
//! that independence is what lets the sweep layer hand out block-aligned
//! trace *shards* as units. The footer (reachable from the 20-byte tail
//! without scanning the blocks) carries per-block time bounds and
//! per-class counts, so `trace stats` and shard planning never touch
//! block payloads. Block payloads and the footer are CRC-32 protected
//! ([`crate::util::crc::crc32`]); torn or corrupted files hard-error at
//! open, never silently replay garbage.

use crate::util::crc::crc32;
use crate::workload::trace::TraceError;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"QSTRACE1";
pub const TAIL_MAGIC: &[u8; 8] = b"QSTEND01";
pub const VERSION: u32 = 1;
/// Default arrivals per block: large enough to amortize per-block
/// decode/CRC cost, small enough that a block's decoded columns stay in
/// cache (~4096 × 18 B ≈ 72 KiB).
pub const DEFAULT_BLOCK: usize = 4096;

/// Read-only view of a file's bytes: mmap'd on unix (the kernel pages
/// blocks in on demand — a multi-GiB trace never needs a resident
/// copy), a plain read-to-Vec everywhere else.
pub struct FileBytes {
    #[cfg(unix)]
    map: Option<(*const u8, usize)>,
    buf: Vec<u8>,
}

#[cfg(unix)]
extern "C" {
    fn mmap(
        addr: *mut core::ffi::c_void,
        len: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut core::ffi::c_void;
    fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
}

// The mapping is PROT_READ/MAP_PRIVATE and never mutated.
unsafe impl Send for FileBytes {}
unsafe impl Sync for FileBytes {}

impl FileBytes {
    pub fn open(path: impl AsRef<Path>) -> std::io::Result<FileBytes> {
        let mut f = File::open(path)?;
        let len = f.metadata()?.len() as usize;
        #[cfg(unix)]
        {
            if len > 0 {
                use std::os::unix::io::AsRawFd;
                const PROT_READ: i32 = 1;
                const MAP_PRIVATE: i32 = 2;
                let ptr = unsafe {
                    mmap(
                        std::ptr::null_mut(),
                        len,
                        PROT_READ,
                        MAP_PRIVATE,
                        f.as_raw_fd(),
                        0,
                    )
                };
                if ptr as isize != -1 && !ptr.is_null() {
                    return Ok(FileBytes {
                        map: Some((ptr as *const u8, len)),
                        buf: Vec::new(),
                    });
                }
                // mmap refused (exotic fs, resource limits): fall through
                // to the read path.
            }
        }
        let mut buf = Vec::with_capacity(len);
        f.read_to_end(&mut buf)?;
        Ok(FileBytes {
            #[cfg(unix)]
            map: None,
            buf,
        })
    }

    pub fn bytes(&self) -> &[u8] {
        #[cfg(unix)]
        if let Some((ptr, len)) = self.map {
            return unsafe { std::slice::from_raw_parts(ptr, len) };
        }
        &self.buf
    }
}

impl Drop for FileBytes {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Some((ptr, len)) = self.map.take() {
            unsafe {
                munmap(ptr as *mut core::ffi::c_void, len);
            }
        }
    }
}

/// Footer record for one block.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockMeta {
    /// File offset of the payload's first byte.
    pub offset: u64,
    /// Payload length in bytes (CRC excluded).
    pub len: u32,
    /// Arrivals in the block.
    pub n: u32,
    pub t_min: f64,
    pub t_max: f64,
}

/// The trace-wide index parsed from the footer — everything `trace
/// stats` and shard planning need without touching block payloads.
#[derive(Clone, Debug, PartialEq)]
pub struct Footer {
    pub num_classes: u32,
    pub blocks: Vec<BlockMeta>,
    pub class_counts: Vec<u64>,
    pub total: u64,
    pub t_first: f64,
    pub t_last: f64,
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Bounds-checked little-endian reads over a byte slice.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
    block: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8], block: usize) -> Cursor<'a> {
        Cursor { b, pos: 0, block }
    }

    fn err(&self, msg: &'static str) -> TraceError {
        TraceError::Corrupt {
            block: self.block,
            msg,
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], TraceError> {
        if self.pos + n > self.b.len() {
            return Err(self.err("truncated record"));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, TraceError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u16(&mut self) -> Result<u16, TraceError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, TraceError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn varint(&mut self) -> Result<u64, TraceError> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let &b = self
                .b
                .get(self.pos)
                .ok_or_else(|| self.err("truncated varint"))?;
            self.pos += 1;
            if shift >= 64 || (shift == 63 && b > 1) {
                return Err(self.err("varint overflows u64"));
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }
}

/// One-pass `.qst` writer: arrivals are validated as they are pushed
/// (finiteness, monotone times, class range — the row number in every
/// error is the 0-based arrival index), buffered per block, and flushed
/// columnar with a CRC. `finish` writes the footer and returns it.
pub struct QstWriter<W: Write> {
    out: W,
    num_classes: u32,
    block_size: usize,
    // Pending block columns.
    times: Vec<u64>,
    classes: Vec<u16>,
    sizes: Vec<u64>,
    t_min: f64,
    t_max: f64,
    // Running file state.
    offset: u64,
    blocks: Vec<BlockMeta>,
    class_counts: Vec<u64>,
    total: u64,
    last_t: f64,
    t_first: f64,
    t_last: f64,
    scratch: Vec<u8>,
}

impl QstWriter<BufWriter<File>> {
    pub fn create(
        path: impl AsRef<Path>,
        num_classes: usize,
        block_size: usize,
    ) -> Result<QstWriter<BufWriter<File>>, TraceError> {
        QstWriter::new(BufWriter::new(File::create(path)?), num_classes, block_size)
    }
}

impl<W: Write> QstWriter<W> {
    pub fn new(
        mut out: W,
        num_classes: usize,
        block_size: usize,
    ) -> Result<QstWriter<W>, TraceError> {
        if num_classes == 0 || num_classes > u16::MAX as usize {
            return Err(TraceError::Format(format!(
                "qst supports 1..={} classes, got {num_classes}",
                u16::MAX
            )));
        }
        if block_size == 0 {
            return Err(TraceError::Format("block size must be >= 1".into()));
        }
        out.write_all(MAGIC)?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&(num_classes as u32).to_le_bytes())?;
        Ok(QstWriter {
            out,
            num_classes: num_classes as u32,
            block_size,
            times: Vec::with_capacity(block_size),
            classes: Vec::with_capacity(block_size),
            sizes: Vec::with_capacity(block_size),
            t_min: f64::INFINITY,
            t_max: f64::NEG_INFINITY,
            offset: (MAGIC.len() + 8) as u64,
            blocks: Vec::new(),
            class_counts: vec![0; num_classes],
            total: 0,
            last_t: f64::NEG_INFINITY,
            t_first: 0.0,
            t_last: 0.0,
            scratch: Vec::new(),
        })
    }

    /// Append one arrival. `row` in errors is the 0-based index of the
    /// offending arrival in the stream pushed so far.
    pub fn push(&mut self, t: f64, class: usize, size: f64) -> Result<(), TraceError> {
        let row = self.total as usize;
        if !t.is_finite() {
            return Err(TraceError::NonFinite { row, field: "t" });
        }
        if !size.is_finite() {
            return Err(TraceError::NonFinite { row, field: "size" });
        }
        if t < 0.0 {
            return Err(TraceError::NegativeTime { row });
        }
        if size < 0.0 {
            return Err(TraceError::NegativeSize { row });
        }
        if self.total > 0 && t < self.last_t {
            return Err(TraceError::NonMonotonic {
                row,
                t,
                last_t: self.last_t,
            });
        }
        if class >= self.num_classes as usize {
            return Err(TraceError::ClassOutOfRange {
                row,
                class,
                num_classes: self.num_classes as usize,
            });
        }
        if self.total == 0 {
            self.t_first = t;
        }
        self.last_t = t;
        self.t_last = t;
        self.t_min = self.t_min.min(t);
        self.t_max = self.t_max.max(t);
        self.times.push(t.to_bits());
        self.classes.push(class as u16);
        self.sizes.push(size.to_bits());
        self.class_counts[class] += 1;
        self.total += 1;
        if self.times.len() == self.block_size {
            self.flush_block()?;
        }
        Ok(())
    }

    fn flush_block(&mut self) -> Result<(), TraceError> {
        let n = self.times.len();
        if n == 0 {
            return Ok(());
        }
        let payload = &mut self.scratch;
        payload.clear();
        push_u32(payload, n as u32);
        push_u64(payload, self.times[0]);
        for i in 1..n {
            // Nondecreasing nonnegative times have nondecreasing bit
            // patterns, so the delta is a nonnegative u64.
            push_varint(payload, self.times[i] - self.times[i - 1]);
        }
        for &c in &self.classes {
            payload.extend_from_slice(&c.to_le_bytes());
        }
        for &s in &self.sizes {
            push_u64(payload, s);
        }
        let crc = crc32(payload);
        self.out.write_all(payload)?;
        self.out.write_all(&crc.to_le_bytes())?;
        self.blocks.push(BlockMeta {
            offset: self.offset,
            len: payload.len() as u32,
            n: n as u32,
            t_min: self.t_min,
            t_max: self.t_max,
        });
        self.offset += payload.len() as u64 + 4;
        self.times.clear();
        self.classes.clear();
        self.sizes.clear();
        self.t_min = f64::INFINITY;
        self.t_max = f64::NEG_INFINITY;
        Ok(())
    }

    /// Flush the tail block, write the footer, and return the index.
    pub fn finish(mut self) -> Result<Footer, TraceError> {
        self.flush_block()?;
        let mut footer = Vec::new();
        push_u32(&mut footer, self.blocks.len() as u32);
        for b in &self.blocks {
            push_u64(&mut footer, b.offset);
            push_u32(&mut footer, b.len);
            push_u32(&mut footer, b.n);
            push_u64(&mut footer, b.t_min.to_bits());
            push_u64(&mut footer, b.t_max.to_bits());
        }
        push_u32(&mut footer, self.num_classes);
        for &c in &self.class_counts {
            push_u64(&mut footer, c);
        }
        push_u64(&mut footer, self.total);
        push_u64(&mut footer, self.t_first.to_bits());
        push_u64(&mut footer, self.t_last.to_bits());
        let crc = crc32(&footer);
        self.out.write_all(&footer)?;
        self.out.write_all(&(footer.len() as u64).to_le_bytes())?;
        self.out.write_all(&crc.to_le_bytes())?;
        self.out.write_all(TAIL_MAGIC)?;
        self.out.flush()?;
        Ok(Footer {
            num_classes: self.num_classes,
            blocks: self.blocks,
            class_counts: self.class_counts,
            total: self.total,
            t_first: self.t_first,
            t_last: self.t_last,
        })
    }
}

/// Random-access `.qst` reader over an mmap'd (or read) file. `open`
/// verifies the tail magic, footer CRC, and every block's CRC and
/// structural bounds up front — a torn or bit-flipped file fails here,
/// before any replay starts — but decodes block payloads only on demand
/// via [`decode_block`](QstReader::decode_block).
pub struct QstReader {
    bytes: FileBytes,
    footer: Footer,
}

impl QstReader {
    pub fn open(path: impl AsRef<Path>) -> Result<QstReader, TraceError> {
        let bytes = FileBytes::open(path)?;
        let b = bytes.bytes();
        let head = MAGIC.len() + 8;
        let tail = 20; // u64 footer_len + u32 crc + 8-byte magic
        if b.len() < head + tail || &b[..MAGIC.len()] != MAGIC {
            return Err(TraceError::BadHeader);
        }
        let version = u32::from_le_bytes(b[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(TraceError::Format(format!(
                "unsupported qst version {version} (expected {VERSION})"
            )));
        }
        let head_classes = u32::from_le_bytes(b[12..16].try_into().unwrap());
        if &b[b.len() - 8..] != TAIL_MAGIC {
            return Err(TraceError::Format(
                "missing qst tail magic (truncated file?)".into(),
            ));
        }
        let fl_at = b.len() - tail;
        let footer_len = u64::from_le_bytes(b[fl_at..fl_at + 8].try_into().unwrap()) as usize;
        let footer_crc = u32::from_le_bytes(b[fl_at + 8..fl_at + 12].try_into().unwrap());
        if footer_len > fl_at - head {
            return Err(TraceError::Format("qst footer overruns the file".into()));
        }
        let footer_bytes = &b[fl_at - footer_len..fl_at];
        if crc32(footer_bytes) != footer_crc {
            return Err(TraceError::Corrupt {
                block: usize::MAX,
                msg: "footer CRC mismatch",
            });
        }
        let mut c = Cursor::new(footer_bytes, usize::MAX);
        let n_blocks = c.u32()? as usize;
        let mut blocks = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            blocks.push(BlockMeta {
                offset: c.u64()?,
                len: c.u32()?,
                n: c.u32()?,
                t_min: f64::from_bits(c.u64()?),
                t_max: f64::from_bits(c.u64()?),
            });
        }
        let num_classes = c.u32()?;
        if num_classes != head_classes {
            return Err(TraceError::Format(format!(
                "qst header says {head_classes} classes, footer says {num_classes}"
            )));
        }
        let mut class_counts = Vec::with_capacity(num_classes as usize);
        for _ in 0..num_classes {
            class_counts.push(c.u64()?);
        }
        let footer = Footer {
            num_classes,
            blocks,
            class_counts,
            total: c.u64()?,
            t_first: f64::from_bits(c.u64()?),
            t_last: f64::from_bits(c.u64()?),
        };
        // Structural bounds + per-block CRC: the payloads stream through
        // the CRC without being decoded or copied, so open cost is one
        // sequential pass and corruption can never surface mid-replay.
        for (i, blk) in footer.blocks.iter().enumerate() {
            let start = blk.offset as usize;
            let end = start
                .checked_add(blk.len as usize + 4)
                .filter(|&e| e <= fl_at - footer_len)
                .ok_or(TraceError::Corrupt {
                    block: i,
                    msg: "block overruns the file",
                })?;
            let payload = &b[start..end - 4];
            let crc = u32::from_le_bytes(b[end - 4..end].try_into().unwrap());
            if crc32(payload) != crc {
                return Err(TraceError::Corrupt {
                    block: i,
                    msg: "block CRC mismatch",
                });
            }
        }
        Ok(QstReader { bytes, footer })
    }

    pub fn footer(&self) -> &Footer {
        &self.footer
    }

    pub fn num_blocks(&self) -> usize {
        self.footer.blocks.len()
    }

    /// Decode block `i` into the caller's column buffers (cleared and
    /// refilled — the buffers are reused across blocks, so steady-state
    /// replay does zero allocation).
    pub fn decode_block(
        &self,
        i: usize,
        times: &mut Vec<f64>,
        classes: &mut Vec<u16>,
        sizes: &mut Vec<f64>,
    ) -> Result<(), TraceError> {
        let blk = self.footer.blocks[i];
        let b = self.bytes.bytes();
        let payload = &b[blk.offset as usize..blk.offset as usize + blk.len as usize];
        let mut c = Cursor::new(payload, i);
        let n = c.u32()? as usize;
        if n != blk.n as usize {
            return Err(c.err("block count disagrees with the footer"));
        }
        times.clear();
        classes.clear();
        sizes.clear();
        times.reserve(n);
        classes.reserve(n);
        sizes.reserve(n);
        if n == 0 {
            return Ok(());
        }
        let mut bits = c.u64()?;
        times.push(f64::from_bits(bits));
        for _ in 1..n {
            bits = bits
                .checked_add(c.varint()?)
                .ok_or_else(|| c.err("time delta overflows"))?;
            times.push(f64::from_bits(bits));
        }
        for _ in 0..n {
            classes.push(c.u16()?);
        }
        for _ in 0..n {
            sizes.push(f64::from_bits(c.u64()?));
        }
        if c.pos != payload.len() {
            return Err(c.err("trailing bytes in block payload"));
        }
        Ok(())
    }
}

/// One-pass streaming CSV → `.qst` conversion: rows are validated,
/// delta-encoded, and flushed block by block without ever materializing
/// the trace (the CSV is read line by line, not loaded).
pub fn convert_csv(
    input: impl AsRef<Path>,
    output: impl AsRef<Path>,
    num_classes: usize,
    block_size: usize,
) -> Result<Footer, TraceError> {
    use crate::util::csv::split_line;
    let reader = BufReader::new(File::open(input)?);
    let mut w = QstWriter::create(output, num_classes, block_size)?;
    let mut lines = reader.lines();
    let header = match lines.next() {
        Some(line) => split_line(&line?),
        None => return Err(TraceError::BadHeader),
    };
    if header != ["t", "class", "size"] {
        return Err(TraceError::BadHeader);
    }
    for (row, line) in lines.enumerate() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let cells = split_line(&line);
        let (t, class, size) = crate::workload::trace::parse_row(&cells, row)?;
        w.push(t, class, size)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize) -> Vec<(f64, usize, f64)> {
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                t += 0.25 + (i % 7) as f64 * 0.125;
                (t, i % 3, 1.0 + (i % 5) as f64)
            })
            .collect()
    }

    fn write_qst(path: &Path, rows: &[(f64, usize, f64)], block: usize) -> Footer {
        let mut w = QstWriter::create(path, 3, block).unwrap();
        for &(t, c, s) in rows {
            w.push(t, c, s).unwrap();
        }
        w.finish().unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("qs_qst_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_bitwise_across_block_sizes() {
        let rows = sample(1000);
        for block in [1usize, 7, 64, 4096] {
            let path = tmp(&format!("rt_{block}.qst"));
            let footer = write_qst(&path, &rows, block);
            assert_eq!(footer.total, 1000);
            assert_eq!(footer.blocks.len(), 1000usize.div_ceil(block));
            let r = QstReader::open(&path).unwrap();
            assert_eq!(r.footer(), &footer);
            let (mut ts, mut cs, mut ss) = (Vec::new(), Vec::new(), Vec::new());
            let mut got = Vec::new();
            for i in 0..r.num_blocks() {
                r.decode_block(i, &mut ts, &mut cs, &mut ss).unwrap();
                for j in 0..ts.len() {
                    got.push((ts[j], cs[j] as usize, ss[j]));
                }
            }
            assert_eq!(got.len(), rows.len());
            for (a, b) in got.iter().zip(rows.iter()) {
                assert_eq!(a.0.to_bits(), b.0.to_bits());
                assert_eq!(a.1, b.1);
                assert_eq!(a.2.to_bits(), b.2.to_bits());
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn footer_counts_and_bounds() {
        let rows = sample(100);
        let path = tmp("footer.qst");
        let footer = write_qst(&path, &rows, 16);
        let mut counts = [0u64; 3];
        for &(_, c, _) in &rows {
            counts[c] += 1;
        }
        assert_eq!(footer.class_counts, counts);
        assert_eq!(footer.t_first.to_bits(), rows[0].0.to_bits());
        assert_eq!(footer.t_last.to_bits(), rows[99].0.to_bits());
        for (i, b) in footer.blocks.iter().enumerate() {
            let lo = rows[i * 16].0;
            let hi = rows[(i * 16 + 15).min(99)].0;
            assert_eq!(b.t_min.to_bits(), lo.to_bits());
            assert_eq!(b.t_max.to_bits(), hi.to_bits());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupted_block_fails_open() {
        let rows = sample(200);
        let path = tmp("corrupt.qst");
        let footer = write_qst(&path, &rows, 32);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one byte inside the third block's payload.
        let at = footer.blocks[2].offset as usize + 5;
        bytes[at] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = QstReader::open(&path).unwrap_err();
        assert!(
            matches!(err, TraceError::Corrupt { block: 2, .. }),
            "unexpected error: {err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_fails_open() {
        let rows = sample(200);
        let path = tmp("torn.qst");
        write_qst(&path, &rows, 32);
        let bytes = std::fs::read(&path).unwrap();
        // A torn write: the final 33 bytes (footer tail) never landed.
        std::fs::write(&path, &bytes[..bytes.len() - 33]).unwrap();
        assert!(QstReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn writer_validates_rows() {
        let path = tmp("validate.qst");
        let mut w = QstWriter::create(&path, 3, 64).unwrap();
        w.push(1.0, 0, 1.0).unwrap();
        assert!(matches!(
            w.push(f64::NAN, 0, 1.0),
            Err(TraceError::NonFinite { row: 1, field: "t" })
        ));
        assert!(matches!(
            w.push(2.0, 0, f64::INFINITY),
            Err(TraceError::NonFinite { row: 1, field: "size" })
        ));
        assert!(matches!(
            w.push(0.5, 0, 1.0),
            Err(TraceError::NonMonotonic { row: 1, .. })
        ));
        assert!(matches!(
            w.push(2.0, 3, 1.0),
            Err(TraceError::ClassOutOfRange { row: 1, class: 3, num_classes: 3 })
        ));
        assert!(matches!(
            w.push(2.0, 0, -1.0),
            Err(TraceError::NegativeSize { row: 1 })
        ));
        w.push(2.0, 2, 0.0).unwrap();
        let f = w.finish().unwrap();
        assert_eq!(f.total, 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn varint_roundtrip_extremes() {
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        let mut buf = Vec::new();
        for &v in &vals {
            push_varint(&mut buf, v);
        }
        let mut c = Cursor::new(&buf, 0);
        for &v in &vals {
            assert_eq!(c.varint().unwrap(), v);
        }
        assert_eq!(c.pos, buf.len());
    }
}
