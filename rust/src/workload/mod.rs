//! Workload specification and arrival sources.

pub mod borg;
pub mod qst;
pub mod rate;
pub mod resources;
pub mod trace;

use crate::dist::Dist;
use crate::util::rng::Rng;
pub use rate::{RateCurve, RateWarp};
pub use resources::{ResourceVec, MAX_RESOURCES};

/// One job class: all class members demand the same `demand` resource
/// vector (dimension 0 = servers); sizes are drawn i.i.d. from `size`;
/// arrivals are Poisson with rate `rate`.
#[derive(Clone, Debug)]
pub struct ClassSpec {
    pub demand: ResourceVec,
    pub rate: f64,
    pub size: Dist,
    pub name: String,
}

impl ClassSpec {
    /// A scalar (servers-only) class — the paper's original model.
    pub fn new(need: u32, rate: f64, size: Dist) -> ClassSpec {
        ClassSpec {
            name: format!("c{need}"),
            demand: ResourceVec::scalar(need),
            rate,
            size,
        }
    }

    /// A multiresource class demanding `demand` (dimension 0 = servers).
    pub fn with_demand(demand: ResourceVec, rate: f64, size: Dist) -> ClassSpec {
        ClassSpec {
            name: format!("c{demand}"),
            demand,
            rate,
            size,
        }
    }

    /// Server demand: the dimension-0 projection of `demand` (the
    /// scalar model's `need`).
    #[inline]
    pub fn need(&self) -> u32 {
        self.demand.servers()
    }

    pub fn named(mut self, name: &str) -> ClassSpec {
        self.name = name.to_string();
        self
    }
}

/// A multiserver-job workload: a resource `capacity` (dimension 0 = the
/// `k` servers) and a set of job classes. `k` is kept as the dimension-0
/// mirror of `capacity` so the scalar model reads exactly as before.
#[derive(Clone, Debug)]
pub struct Workload {
    pub k: u32,
    pub capacity: ResourceVec,
    pub classes: Vec<ClassSpec>,
    /// Shared time-varying modulation of every class's arrival rate
    /// ([`RateCurve::Constant`] = the homogeneous model, bit-identical
    /// to the pre-curve source).
    pub rate_curve: RateCurve,
}

impl Workload {
    pub fn new(k: u32, classes: Vec<ClassSpec>) -> Workload {
        Workload::with_capacity(ResourceVec::scalar(k), classes)
    }

    /// A workload over a multiresource capacity vector. Every class
    /// demand must share the capacity's dimension count, demand at
    /// least one server, and fit the capacity per dimension.
    pub fn with_capacity(capacity: ResourceVec, classes: Vec<ClassSpec>) -> Workload {
        let k = capacity.servers();
        assert!(k >= 1);
        for c in &classes {
            assert_eq!(
                c.demand.dims(),
                capacity.dims(),
                "class demand dimensions must match the capacity"
            );
            assert!(c.need() >= 1, "class must demand at least one server");
            assert!(
                c.demand.fits_in(&capacity),
                "class demand must fit the capacity in every dimension"
            );
            assert!(c.rate >= 0.0);
        }
        Workload {
            k,
            capacity,
            classes,
            rate_curve: RateCurve::Constant,
        }
    }

    /// The same workload with its arrival rates modulated by `curve`
    /// (validated; see [`rate::parse_rate_curve`] for the CLI grammar).
    pub fn with_rate_curve(mut self, curve: RateCurve) -> Workload {
        curve
            .validate()
            .unwrap_or_else(|e| panic!("invalid rate curve: {e}"));
        self.rate_curve = curve;
        self
    }

    /// The paper's one-or-all workload: class-1 ("light") and class-k
    /// ("heavy") jobs; `lambda` is the total arrival rate, `p1` the light
    /// fraction. Class 0 = light, class 1 = heavy.
    pub fn one_or_all(k: u32, lambda: f64, p1: f64, mu1: f64, muk: f64) -> Workload {
        Workload::new(
            k,
            vec![
                ClassSpec::new(1, lambda * p1, Dist::Exp { mu: mu1 }).named("light"),
                ClassSpec::new(k, lambda * (1.0 - p1), Dist::Exp { mu: muk }).named("heavy"),
            ],
        )
    }

    /// The Fig-5 4-class workload: k=15, needs {1,3,5,15},
    /// p = {0.5, 0.25, 0.2, 0.05}, unit mean sizes, total rate `lambda`.
    pub fn four_class(lambda: f64) -> Workload {
        let p = [0.5, 0.25, 0.2, 0.05];
        let needs = [1u32, 3, 5, 15];
        Workload::new(
            15,
            needs
                .iter()
                .zip(p.iter())
                .map(|(&n, &pi)| ClassSpec::new(n, lambda * pi, Dist::exp_mean(1.0)))
                .collect(),
        )
    }

    /// A 2-dimensional (servers × memory) demonstration family for the
    /// multiresource model: `k` servers and `mem` memory units shared by
    /// three classes — small jobs (1 server, 1 memory), CPU-bound jobs
    /// (k/2 servers, mem/8 memory) and memory-bound jobs (k/8 servers,
    /// mem/2 memory), with p = {0.7, 0.15, 0.15} and unit-mean
    /// exponential sizes. Total arrival rate `lambda`.
    pub fn multires(k: u32, mem: u32, lambda: f64) -> Workload {
        assert!(k >= 8 && mem >= 8, "multires needs k >= 8 and mem >= 8");
        let cap = ResourceVec::new(&[k, mem]);
        let specs = [
            (ResourceVec::new(&[1, 1]), 0.70, "small"),
            (ResourceVec::new(&[k / 2, mem / 8]), 0.15, "cpu"),
            (ResourceVec::new(&[k / 8, mem / 2]), 0.15, "mem"),
        ];
        Workload::with_capacity(
            cap,
            specs
                .iter()
                .map(|(d, p, name)| {
                    ClassSpec::with_demand(*d, lambda * p, Dist::exp_mean(1.0)).named(name)
                })
                .collect(),
        )
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Resource dimensions (1 for the scalar model).
    pub fn dims(&self) -> usize {
        self.capacity.dims()
    }

    pub fn needs(&self) -> Vec<u32> {
        self.classes.iter().map(|c| c.need()).collect()
    }

    /// Per-class demand vectors.
    pub fn demands(&self) -> Vec<ResourceVec> {
        self.classes.iter().map(|c| c.demand).collect()
    }

    /// Total arrival rate λ.
    pub fn total_rate(&self) -> f64 {
        self.classes.iter().map(|c| c.rate).sum()
    }

    /// Load contributed by class `c`: ρ_c = need·λ_c·E[S_c] / k? —
    /// NOTE: the paper defines ρ_j = j·λ_j/μ_j (server-hours per unit
    /// time, *not* normalized by k); `rho_class` follows the paper.
    pub fn rho_class(&self, c: usize) -> f64 {
        let cl = &self.classes[c];
        cl.need() as f64 * cl.rate * cl.size.mean()
    }

    /// Load offered to resource dimension `j`, normalized by that
    /// dimension's capacity: Σ_c demand_j(c)·λ_c·E[S_c] / capacity_j.
    pub fn load_dim(&self, j: usize) -> f64 {
        let cap = self.capacity.get(j);
        if cap == 0 {
            return 0.0;
        }
        self.classes
            .iter()
            .map(|c| c.demand.get(j) as f64 * c.rate * c.size.mean())
            .sum::<f64>()
            / cap as f64
    }

    /// Normalized total system load ∈ [0, 1) for stability: the maximum
    /// per-dimension load (dimension 0 alone in the scalar model, where
    /// this is the paper's ρ/k).
    pub fn load(&self) -> f64 {
        (0..self.dims())
            .map(|j| self.load_dim(j))
            .fold(0.0, f64::max)
    }

    /// Upper bound on any policy's stability (Theorem 4 / Remark 1):
    /// stable only if Σ_j λ_j/((k/j)·μ_j) < 1, i.e. `load() < 1`.
    /// Returns the critical total arrival rate λ* keeping class mix fixed.
    pub fn lambda_critical(&self) -> f64 {
        let lam = self.total_rate();
        if lam == 0.0 {
            return f64::INFINITY;
        }
        lam / self.load().max(1e-300) * 1.0
    }

    /// Sufficient stability bound for Static Quickswap (Remark 1):
    /// Σ_j λ_j/(⌊k/j⌋·μ_j) < 1. Returns critical λ with mix fixed.
    pub fn lambda_critical_floored(&self) -> f64 {
        let lam = self.total_rate();
        let denom: f64 = self
            .classes
            .iter()
            .map(|c| c.rate * c.size.mean() / c.demand.max_pack(&self.capacity) as f64)
            .sum();
        if denom <= 0.0 {
            f64::INFINITY
        } else {
            lam / denom
        }
    }

    /// Same workload with total arrival rate scaled to `lambda`
    /// (class mix preserved).
    pub fn with_total_rate(&self, lambda: f64) -> Workload {
        let cur = self.total_rate();
        assert!(cur > 0.0);
        let mut wl = self.clone();
        for c in &mut wl.classes {
            c.rate *= lambda / cur;
        }
        wl
    }

    /// True if this is a one-or-all workload (scalar, needs ⊆ {1, k}).
    pub fn is_one_or_all(&self) -> bool {
        self.dims() == 1
            && self
                .classes
                .iter()
                .all(|c| c.need() == 1 || c.need() == self.k)
    }
}

/// One arrival produced by a source.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// Absolute arrival time.
    pub t: f64,
    pub class: usize,
    /// Service requirement (duration on `need` servers).
    pub size: f64,
}

/// Produces the arrival stream consumed by the engine.
pub trait ArrivalSource {
    /// The next arrival at or after the previous one, or None when the
    /// stream is exhausted (finite traces).
    fn next_arrival(&mut self, rng: &mut Rng) -> Option<Arrival>;

    /// Append up to `max` arrivals to `out`, returning how many were
    /// appended (0 = exhausted). The engine refills its heap-external
    /// arrival buffer through this, amortizing the virtual dispatch to
    /// one call per chunk. The default delegates to
    /// [`next_arrival`](ArrivalSource::next_arrival) in order, drawing
    /// from `rng` identically — so any source is bit-identical whether
    /// the engine pulls arrivals one at a time or in chunks. Block
    /// sources ([`trace::StreamingTraceSource`]) override it with a
    /// straight columnar copy.
    fn fill_arrivals(&mut self, rng: &mut Rng, out: &mut Vec<Arrival>, max: usize) -> usize {
        let start = out.len();
        while out.len() - start < max {
            match self.next_arrival(rng) {
                Some(a) => out.push(a),
                None => break,
            }
        }
        out.len() - start
    }

    fn workload(&self) -> &Workload;
}

/// Per-class pregenerated arrivals per refill. Large enough to amortize
/// the RNG/dispatch cost of chunk generation, small enough that even the
/// 26-class Borg workload buffers well under a megabyte.
const ARRIVAL_CHUNK: usize = 64;

/// Poisson arrivals per class with i.i.d. sizes (the paper's model).
///
/// Batched: instead of thinning one merged exponential stream (one
/// `exp` + one weighted class draw per arrival), each class owns an
/// independent Poisson stream — statistically identical by superposition
/// — pre-generated in chunks of [`ARRIVAL_CHUNK`] into **flat per-class
/// buffers**: one [`Rng::fill_exp`] pass fills the chunk's 64
/// interarrival gaps, one [`crate::dist::Dist::fill`] pass fills its 64
/// service sizes (pre-sampling the departure size consumed when the job
/// is admitted). The RNG stream layout is deterministic per
/// (class, chunk) — 64 gap draws then 64 size draws — so replications
/// are reproducible regardless of how the merge interleaves classes.
/// `next_arrival` merges the per-class next-arrival cursors by linear
/// argmin (classes are few; the scan replaces the old per-arrival
/// weight scan) and is consumed lazily by the engine's heap-external
/// arrival cursor, so saturation sweeps pay neither a heap round-trip
/// nor per-arrival RNG dispatch.
pub struct SyntheticSource {
    wl: Workload,
    /// Absolute time of each class's next arrival (∞: zero-rate class).
    next_t: Vec<f64>,
    /// Size of each class's next arrival.
    next_size: Vec<f64>,
    /// Per-class pregenerated interarrival gaps (flat chunk buffer).
    gaps: Vec<Vec<f64>>,
    /// Per-class pregenerated service sizes (flat chunk buffer).
    sizes: Vec<Vec<f64>>,
    /// Per-class read position into the chunk buffers.
    pos: Vec<usize>,
    primed: bool,
    /// Time warp realizing the workload's [`RateCurve`] (None for
    /// `Constant`: the hot path carries no curve code at all). The
    /// per-class cursors stay in homogeneous *virtual* time; only the
    /// emitted timestamp is warped through `G⁻¹`, which is strictly
    /// increasing — so the argmin merge order, the RNG stream layout,
    /// and the constant-curve output are all exactly as before.
    warp: Option<RateWarp>,
}

impl SyntheticSource {
    pub fn new(wl: Workload) -> SyntheticSource {
        assert!(wl.total_rate() > 0.0, "workload has zero arrival rate");
        let nc = wl.num_classes();
        SyntheticSource {
            next_t: vec![f64::INFINITY; nc],
            next_size: vec![0.0; nc],
            gaps: (0..nc).map(|_| Vec::new()).collect(),
            sizes: (0..nc).map(|_| Vec::new()).collect(),
            pos: vec![0; nc],
            primed: false,
            warp: RateWarp::new(&wl.rate_curve),
            wl,
        }
    }

    /// Pop class `c`'s next pregenerated (interarrival, size), refilling
    /// its chunk from `rng` when exhausted — two chunk-fill passes, one
    /// per flat buffer.
    #[inline]
    fn take(&mut self, c: usize, rng: &mut Rng) -> (f64, f64) {
        if self.pos[c] == self.gaps[c].len() {
            let cl = &self.wl.classes[c];
            self.gaps[c].resize(ARRIVAL_CHUNK, 0.0);
            rng.fill_exp(cl.rate, &mut self.gaps[c]);
            self.sizes[c].resize(ARRIVAL_CHUNK, 0.0);
            cl.size.fill(rng, &mut self.sizes[c]);
            self.pos[c] = 0;
        }
        let i = self.pos[c];
        self.pos[c] += 1;
        (self.gaps[c][i], self.sizes[c][i])
    }

    fn prime(&mut self, rng: &mut Rng) {
        for c in 0..self.wl.num_classes() {
            if self.wl.classes[c].rate > 0.0 {
                let (gap, size) = self.take(c, rng);
                self.next_t[c] = gap;
                self.next_size[c] = size;
            }
        }
        self.primed = true;
    }
}

impl ArrivalSource for SyntheticSource {
    #[inline]
    fn next_arrival(&mut self, rng: &mut Rng) -> Option<Arrival> {
        if !self.primed {
            self.prime(rng);
        }
        // Earliest per-class cursor (ties → lowest class id, determinate).
        let mut class = 0usize;
        let mut best = f64::INFINITY;
        for (c, &t) in self.next_t.iter().enumerate() {
            if t < best {
                best = t;
                class = c;
            }
        }
        debug_assert!(best.is_finite(), "no class has a pending arrival");
        let size = self.next_size[class];
        let (gap, next_size) = self.take(class, rng);
        self.next_t[class] = best + gap;
        self.next_size[class] = next_size;
        let t = match self.warp.as_mut() {
            Some(w) => w.warp(best),
            None => best,
        };
        Some(Arrival { t, class, size })
    }

    fn workload(&self) -> &Workload {
        &self.wl
    }
}

/// A common-random-number (CRN) arrival stream: the `SyntheticSource`
/// output for one (workload, seed), materialized **once** and replayed
/// read-only by any number of engines.
///
/// The stream is extended lazily — the first consumer to reach index
/// `i` pays the sampling cost; every later [`ReplayCursor`] reads the
/// recorded `Arrival` verbatim. Because the engine threads its RNG only
/// through `ArrivalSource::next_arrival` (policies never draw from it;
/// NMSR carries its own fixed-seed chain), replaying the recorded
/// arrivals while ignoring the engine-supplied RNG is bit-identical to
/// a solo run with a live `SyntheticSource` at the same seed — the CRN
/// determinism contract, differential-tested in
/// `tests/integration_paired.rs`.
pub struct MaterializedStream {
    wl: Workload,
    src: SyntheticSource,
    rng: Rng,
    arrivals: Vec<Arrival>,
}

impl MaterializedStream {
    pub fn new(wl: Workload, seed: u64) -> MaterializedStream {
        MaterializedStream {
            src: SyntheticSource::new(wl.clone()),
            rng: Rng::new(seed),
            arrivals: Vec::new(),
            wl,
        }
    }

    /// Number of arrivals materialized so far.
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// The arrival at index `i`, sampling forward as needed.
    #[inline]
    fn ensure(&mut self, i: usize) -> Option<Arrival> {
        while self.arrivals.len() <= i {
            let a = self.src.next_arrival(&mut self.rng)?;
            self.arrivals.push(a);
        }
        Some(self.arrivals[i])
    }

    /// A fresh read cursor at the start of the stream. Cursors borrow
    /// the stream mutably (lazy extension), so the engines sharing one
    /// stream run sequentially — the win is sampling the stream once,
    /// not running policies concurrently.
    pub fn cursor(&mut self) -> ReplayCursor<'_> {
        ReplayCursor {
            stream: self,
            pos: 0,
        }
    }
}

/// Read cursor over a [`MaterializedStream`]; implements
/// [`ArrivalSource`] so the engine is agnostic between live sampling
/// and replay. The engine-supplied RNG is deliberately unused: the
/// stream's own RNG already produced (or lazily produces) every
/// arrival, and consuming the caller's RNG would break the
/// bit-identity contract with solo runs.
pub struct ReplayCursor<'a> {
    stream: &'a mut MaterializedStream,
    pos: usize,
}

impl ArrivalSource for ReplayCursor<'_> {
    #[inline]
    fn next_arrival(&mut self, _rng: &mut Rng) -> Option<Arrival> {
        let a = self.stream.ensure(self.pos);
        if a.is_some() {
            self.pos += 1;
        }
        a
    }

    fn workload(&self) -> &Workload {
        &self.stream.wl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_or_all_loads() {
        // k=32, λ=7.5, p1=0.9, μ=1: ρ = (0.9·7.5·1 + 0.1·7.5·32)/32.
        let wl = Workload::one_or_all(32, 7.5, 0.9, 1.0, 1.0);
        let expect = (0.9 * 7.5 + 0.1 * 7.5 * 32.0) / 32.0;
        assert!((wl.load() - expect).abs() < 1e-12);
        assert!(wl.is_one_or_all());
        // Critical λ: load scales linearly in λ.
        let crit = wl.lambda_critical();
        assert!((wl.with_total_rate(crit).load() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn four_class_critical_rate_is_five() {
        // All needs divide k=15 ⇒ ⌊k/j⌋ = k/j and λ* = 5 (paper §6.3).
        let wl = Workload::four_class(1.0);
        assert!((wl.lambda_critical() - 5.0).abs() < 1e-9);
        assert!((wl.lambda_critical_floored() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn multires_family_loads_and_capacity() {
        let wl = Workload::multires(16, 64, 3.0);
        assert_eq!(wl.dims(), 2);
        assert_eq!(wl.k, 16);
        assert_eq!(wl.capacity, ResourceVec::new(&[16, 64]));
        assert_eq!(wl.num_classes(), 3);
        assert!(wl.classes.iter().all(|c| c.demand.fits_in(&wl.capacity)));
        assert!(!wl.is_one_or_all());
        // The vector load is the max over per-dimension loads, and each
        // dimension's load matches the hand-computed sum.
        let dim0 = wl
            .classes
            .iter()
            .map(|c| c.demand.get(0) as f64 * c.rate * c.size.mean())
            .sum::<f64>()
            / 16.0;
        assert!((wl.load_dim(0) - dim0).abs() < 1e-12);
        assert!((wl.load() - wl.load_dim(0).max(wl.load_dim(1))).abs() < 1e-12);
        // Critical λ scales the max dimension to load 1.
        let crit = wl.lambda_critical();
        assert!((wl.with_total_rate(crit).load() - 1.0).abs() < 1e-9);
        // d=1 workloads keep the scalar capacity mirror.
        let scalar = Workload::one_or_all(8, 2.0, 0.9, 1.0, 1.0);
        assert_eq!(scalar.capacity, ResourceVec::scalar(8));
        assert_eq!(scalar.dims(), 1);
    }

    #[test]
    fn synthetic_interarrivals_match_rate() {
        let wl = Workload::one_or_all(8, 4.0, 0.5, 1.0, 1.0);
        let mut src = SyntheticSource::new(wl);
        let mut rng = Rng::new(1);
        let n = 100_000;
        let mut last = 0.0;
        let mut counts = [0u64; 2];
        for _ in 0..n {
            let a = src.next_arrival(&mut rng).unwrap();
            assert!(a.t >= last);
            last = a.t;
            counts[a.class] += 1;
        }
        let rate = n as f64 / last;
        assert!((rate - 4.0).abs() < 0.05, "rate={rate}");
        assert!((counts[0] as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    /// An explicit `Constant` curve must leave the source bit-identical
    /// to one that never heard of rate curves (no warp installed).
    #[test]
    fn constant_rate_curve_is_bit_identical() {
        let wl = Workload::one_or_all(8, 4.0, 0.5, 1.0, 1.0);
        let wl2 = wl.clone().with_rate_curve(RateCurve::Constant);
        let mut a = SyntheticSource::new(wl);
        let mut b = SyntheticSource::new(wl2);
        let (mut ra, mut rb) = (Rng::new(5), Rng::new(5));
        for _ in 0..10_000 {
            let x = a.next_arrival(&mut ra).unwrap();
            let y = b.next_arrival(&mut rb).unwrap();
            assert_eq!(x.t.to_bits(), y.t.to_bits());
            assert_eq!(x.class, y.class);
            assert_eq!(x.size.to_bits(), y.size.to_bits());
        }
    }

    /// A warped source stays monotone, preserves the class mix, and
    /// concentrates arrivals where the curve says the rate is high.
    #[test]
    fn diurnal_rate_curve_modulates_arrivals() {
        let wl = Workload::one_or_all(8, 4.0, 0.5, 1.0, 1.0).with_rate_curve(RateCurve::Diurnal {
            period: 50.0,
            amp: 0.9,
            phase: 0.0,
        });
        let curve = wl.rate_curve.clone();
        let mut src = SyntheticSource::new(wl);
        let mut rng = Rng::new(2);
        let mut last = 0.0;
        let mut arrivals = Vec::new();
        for _ in 0..200_000 {
            let a = src.next_arrival(&mut rng).unwrap();
            assert!(a.t >= last, "warped times must stay nondecreasing");
            last = a.t;
            arrivals.push(a.t);
        }
        // Count arrivals in the first high-rate half-period vs the
        // following low-rate half-period: the ratio estimates
        // ∫f(high)/∫f(low) = (25+45/π)/(25−45/π) ≈ 3.7.
        let hi = arrivals.iter().filter(|&&t| t < 25.0).count() as f64;
        let lo = arrivals
            .iter()
            .filter(|&&t| (25.0..50.0).contains(&t))
            .count() as f64;
        assert!(hi / lo > 3.0, "hi={hi} lo={lo}");
        // The warp inverts the curve's cumulative: G(t_i) must be close
        // to the homogeneous virtual times (rate-4 Poisson ⇒ the n-th
        // virtual arrival sits near n/4).
        let n = arrivals.len() as f64;
        let g_last = curve.cumulative(last);
        assert!((g_last - n / 4.0).abs() / (n / 4.0) < 0.05, "G(last)={g_last}");
    }

    #[test]
    fn materialized_replay_matches_live_source_bitwise() {
        let wl = Workload::one_or_all(8, 4.0, 0.5, 1.0, 2.0);
        let seed = 99;
        let mut live = SyntheticSource::new(wl.clone());
        let mut live_rng = Rng::new(seed);
        let mut stream = MaterializedStream::new(wl, seed);
        // Two interleaved cursors at different depths plus a third full
        // pass: every read must match the live stream bit for bit, and
        // the engine-side RNG handed to the cursor must stay untouched.
        let mut dummy = Rng::new(0);
        let reference: Vec<Arrival> = (0..1000)
            .map(|_| live.next_arrival(&mut live_rng).unwrap())
            .collect();
        {
            let mut c1 = stream.cursor();
            for want in reference.iter().take(700) {
                let got = c1.next_arrival(&mut dummy).unwrap();
                assert_eq!(got.t.to_bits(), want.t.to_bits());
                assert_eq!(got.class, want.class);
                assert_eq!(got.size.to_bits(), want.size.to_bits());
            }
        }
        assert_eq!(stream.len(), 700);
        let mut c2 = stream.cursor();
        for want in &reference {
            let got = c2.next_arrival(&mut dummy).unwrap();
            assert_eq!(got.t.to_bits(), want.t.to_bits());
            assert_eq!(got.class, want.class);
            assert_eq!(got.size.to_bits(), want.size.to_bits());
        }
        // The dummy RNG was never consumed by replay.
        assert_eq!(dummy.next_u64(), Rng::new(0).next_u64());
    }
}
