//! Nonstationary arrival rates: modulated Poisson via time-warping.
//!
//! A [`RateCurve`] multiplies every class's base rate by a shared,
//! time-varying factor `f(t) > 0` — the load *wave* of a real trace
//! (diurnal cycles, stepped regimes) with the class mix fixed. The
//! nonhomogeneous process is realized by **warping time**: with
//! `G(t) = ∫₀ᵗ f(u) du`, a homogeneous arrival at virtual time `s`
//! becomes a real arrival at `t = G⁻¹(s)` — the standard inversion
//! construction for a nonhomogeneous Poisson process. The synthetic
//! source keeps its per-class chunked sampling untouched in virtual
//! time (the RNG stream layout is byte-for-byte the constant-rate one)
//! and applies the warp only to emitted timestamps; since `G⁻¹` is
//! strictly increasing, the per-class argmin merge order is preserved.
//! [`RateCurve::Constant`] installs no warp at all, so the default path
//! is bit-identical to the pre-curve source.

/// A positive rate-modulation factor over time.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum RateCurve {
    /// `f(t) = 1`: the homogeneous model, exactly as before.
    #[default]
    Constant,
    /// Piecewise-constant: `factors[i]` applies on
    /// `[times[i], times[i+1])` (and the last factor forever).
    /// `times[0]` must be 0, times strictly increasing, factors > 0.
    Piecewise { times: Vec<f64>, factors: Vec<f64> },
    /// Sinusoidal diurnal wave: `f(t) = 1 + amp·sin(2πt/period + phase)`
    /// with `0 ≤ amp < 1` (so `f > 0`).
    Diurnal { period: f64, amp: f64, phase: f64 },
}

impl RateCurve {
    /// Validate the curve's parameters.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            RateCurve::Constant => Ok(()),
            RateCurve::Piecewise { times, factors } => {
                if times.is_empty() || times.len() != factors.len() {
                    return Err("piecewise curve needs equal, nonzero times/factors".into());
                }
                if times[0] != 0.0 {
                    return Err("piecewise curve must start at t=0".into());
                }
                for w in times.windows(2) {
                    if !w[1].is_finite() || w[1] <= w[0] {
                        return Err(format!(
                            "piecewise times must be finite and strictly increasing \
                             ({} after {})",
                            w[1], w[0]
                        ));
                    }
                }
                for &f in factors {
                    if !f.is_finite() || f <= 0.0 {
                        return Err(format!("piecewise factors must be positive, got {f}"));
                    }
                }
                Ok(())
            }
            RateCurve::Diurnal { period, amp, phase } => {
                if !period.is_finite() || *period <= 0.0 {
                    return Err(format!("diurnal period must be positive, got {period}"));
                }
                if !(0.0..1.0).contains(amp) {
                    return Err(format!("diurnal amp must be in [0, 1), got {amp}"));
                }
                if !phase.is_finite() {
                    return Err(format!("diurnal phase must be finite, got {phase}"));
                }
                Ok(())
            }
        }
    }

    /// The modulation factor `f(t)`.
    pub fn factor(&self, t: f64) -> f64 {
        match self {
            RateCurve::Constant => 1.0,
            RateCurve::Piecewise { times, factors } => {
                // partition_point: index of the first time > t.
                let i = times.partition_point(|&x| x <= t);
                factors[i.saturating_sub(1).min(factors.len() - 1)]
            }
            RateCurve::Diurnal { period, amp, phase } => {
                1.0 + amp * (std::f64::consts::TAU * t / period + phase).sin()
            }
        }
    }

    /// Cumulative modulation `G(t) = ∫₀ᵗ f(u) du` (strictly increasing).
    pub fn cumulative(&self, t: f64) -> f64 {
        match self {
            RateCurve::Constant => t,
            RateCurve::Piecewise { times, factors } => {
                let mut acc = 0.0;
                for i in 0..times.len() {
                    let seg_end = times.get(i + 1).copied().unwrap_or(f64::INFINITY);
                    if t <= seg_end {
                        return acc + factors[i] * (t - times[i]);
                    }
                    acc += factors[i] * (seg_end - times[i]);
                }
                unreachable!("segments cover [0, inf)")
            }
            RateCurve::Diurnal { period, amp, phase } => {
                let omega = std::f64::consts::TAU / period;
                t + amp / omega * (phase.cos() - (omega * t + phase).cos())
            }
        }
    }

    /// Inverse warp `G⁻¹(s)` for the diurnal curve: Newton from the
    /// identity-warp guess, with a bisection fallback (f is bounded in
    /// `[1−amp, 1+amp]`, so both converge fast).
    fn invert_diurnal(&self, s: f64) -> f64 {
        let RateCurve::Diurnal { amp, .. } = *self else {
            unreachable!()
        };
        if s <= 0.0 {
            return 0.0;
        }
        let mut t = s; // G(t) ≈ t globally (the wave integrates to 0).
        for _ in 0..64 {
            let g = self.cumulative(t) - s;
            if g.abs() <= 1e-12 * s.max(1.0) {
                return t.max(0.0);
            }
            t -= g / self.factor(t).max(1e-12);
            if t < 0.0 {
                t = 0.0;
            }
        }
        // Newton cycled (can only happen deep in the float tail):
        // bisect on the bracket implied by 1−amp ≤ f ≤ 1+amp.
        let (mut lo, mut hi) = (s / (1.0 + amp), s / (1.0 - amp));
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if self.cumulative(mid) < s {
                lo = mid;
            } else {
                hi = mid;
            }
            if hi - lo <= 1e-12 * s.max(1.0) {
                break;
            }
        }
        0.5 * (lo + hi)
    }
}

/// The stateful warp a [`SyntheticSource`](crate::workload::SyntheticSource)
/// applies to emitted timestamps. Emitted virtual times are
/// nondecreasing, so the piecewise inverse keeps a forward segment
/// cursor and is O(1) amortized.
#[derive(Clone, Debug)]
pub struct RateWarp {
    curve: RateCurve,
    /// Piecewise state: current segment index, and G at its left edge.
    seg: usize,
    seg_start_g: f64,
}

impl RateWarp {
    /// `None` for the constant curve: the no-warp path stays
    /// bit-identical to the pre-curve source by not existing.
    pub fn new(curve: &RateCurve) -> Option<RateWarp> {
        match curve {
            RateCurve::Constant => None,
            _ => Some(RateWarp {
                curve: curve.clone(),
                seg: 0,
                seg_start_g: 0.0,
            }),
        }
    }

    /// Map a virtual (homogeneous) arrival time to real time: `G⁻¹(s)`.
    pub fn warp(&mut self, s: f64) -> f64 {
        match &self.curve {
            RateCurve::Constant => s,
            RateCurve::Diurnal { .. } => self.curve.invert_diurnal(s),
            RateCurve::Piecewise { times, factors } => {
                // Advance to the segment containing s (s nondecreasing
                // across calls, so the cursor only moves forward).
                loop {
                    let seg_end = times.get(self.seg + 1).copied().unwrap_or(f64::INFINITY);
                    let g_end = if seg_end.is_finite() {
                        self.seg_start_g + factors[self.seg] * (seg_end - times[self.seg])
                    } else {
                        f64::INFINITY
                    };
                    if s <= g_end || self.seg + 1 >= times.len() {
                        return times[self.seg] + (s - self.seg_start_g) / factors[self.seg];
                    }
                    self.seg_start_g = g_end;
                    self.seg += 1;
                }
            }
        }
    }
}

/// Parse the CLI grammar:
/// `constant` | `diurnal:period=24,amp=0.5[,phase=0]` |
/// `piecewise:0=1,10=2.5,20=0.5` (time=factor breakpoints).
pub fn parse_rate_curve(s: &str) -> Result<RateCurve, String> {
    let s = s.trim();
    if s.is_empty() || s == "constant" {
        return Ok(RateCurve::Constant);
    }
    let (kind, body) = s.split_once(':').unwrap_or((s, ""));
    let curve = match kind {
        "diurnal" => {
            let (mut period, mut amp, mut phase) = (None, None, 0.0);
            for kv in body.split(',').filter(|p| !p.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("expected key=value in rate curve, got {kv:?}"))?;
                let v: f64 = v.parse().map_err(|_| format!("bad number {v:?} in rate curve"))?;
                match k {
                    "period" => period = Some(v),
                    "amp" => amp = Some(v),
                    "phase" => phase = v,
                    _ => return Err(format!("unknown diurnal parameter {k:?}")),
                }
            }
            RateCurve::Diurnal {
                period: period.ok_or("diurnal curve needs period=")?,
                amp: amp.ok_or("diurnal curve needs amp=")?,
                phase,
            }
        }
        "piecewise" => {
            let (mut times, mut factors) = (Vec::new(), Vec::new());
            for kv in body.split(',').filter(|p| !p.is_empty()) {
                let (t, f) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("expected time=factor in rate curve, got {kv:?}"))?;
                times.push(t.parse::<f64>().map_err(|_| format!("bad time {t:?}"))?);
                factors.push(f.parse::<f64>().map_err(|_| format!("bad factor {f:?}"))?);
            }
            RateCurve::Piecewise { times, factors }
        }
        _ => {
            return Err(format!(
                "unknown rate curve {kind:?} (expected constant, diurnal:…, piecewise:…)"
            ))
        }
    };
    curve.validate()?;
    Ok(curve)
}

/// JSON wire form (workload files): `{"kind": "diurnal", ...}`.
pub fn rate_curve_to_json(c: &RateCurve) -> crate::util::json::Value {
    use crate::util::json::Value;
    match c {
        RateCurve::Constant => Value::obj().set("kind", "constant"),
        RateCurve::Piecewise { times, factors } => Value::obj()
            .set("kind", "piecewise")
            .set("times", times.clone())
            .set("factors", factors.clone()),
        RateCurve::Diurnal { period, amp, phase } => Value::obj()
            .set("kind", "diurnal")
            .set("period", *period)
            .set("amp", *amp)
            .set("phase", *phase),
    }
}

pub fn rate_curve_from_json(v: &crate::util::json::Value) -> Result<RateCurve, String> {
    let kind = v
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or("rate_curve needs a \"kind\"")?;
    let curve = match kind {
        "constant" => RateCurve::Constant,
        "piecewise" => {
            let nums = |key: &str| -> Result<Vec<f64>, String> {
                v.get(key)
                    .and_then(|a| a.as_arr())
                    .ok_or_else(|| format!("piecewise rate_curve needs \"{key}\" array"))?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| format!("non-number in \"{key}\"")))
                    .collect()
            };
            RateCurve::Piecewise {
                times: nums("times")?,
                factors: nums("factors")?,
            }
        }
        "diurnal" => RateCurve::Diurnal {
            period: v
                .get("period")
                .and_then(|x| x.as_f64())
                .ok_or("diurnal rate_curve needs \"period\"")?,
            amp: v
                .get("amp")
                .and_then(|x| x.as_f64())
                .ok_or("diurnal rate_curve needs \"amp\"")?,
            phase: v.get("phase").and_then(|x| x.as_f64()).unwrap_or(0.0),
        },
        _ => return Err(format!("unknown rate_curve kind {kind:?}")),
    };
    curve.validate()?;
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_curve_installs_no_warp() {
        assert!(RateWarp::new(&RateCurve::Constant).is_none());
    }

    #[test]
    fn piecewise_warp_inverts_cumulative() {
        let c = RateCurve::Piecewise {
            times: vec![0.0, 10.0, 20.0],
            factors: vec![1.0, 2.5, 0.5],
        };
        c.validate().unwrap();
        let mut w = RateWarp::new(&c).unwrap();
        // G(10)=10, G(20)=35; monotone probes across all segments.
        for &t in &[0.0, 1.0, 5.0, 9.99, 10.0, 12.0, 19.5, 20.0, 30.0, 100.0] {
            let s = c.cumulative(t);
            let back = w.warp(s);
            assert!((back - t).abs() < 1e-9, "t={t} s={s} back={back}");
        }
        assert!((c.cumulative(20.0) - 35.0).abs() < 1e-12);
        assert_eq!(c.factor(15.0), 2.5);
        assert_eq!(c.factor(25.0), 0.5);
    }

    #[test]
    fn diurnal_warp_inverts_cumulative() {
        let c = RateCurve::Diurnal {
            period: 24.0,
            amp: 0.8,
            phase: 0.3,
        };
        c.validate().unwrap();
        let mut w = RateWarp::new(&c).unwrap();
        let mut last = -1.0;
        for i in 0..500 {
            let s = i as f64 * 0.37;
            let t = w.warp(s);
            assert!(t >= last, "warp must be monotone");
            last = t;
            let roundtrip = c.cumulative(t);
            assert!(
                (roundtrip - s).abs() < 1e-8 * s.max(1.0),
                "s={s} t={t} G(t)={roundtrip}"
            );
        }
    }

    #[test]
    fn grammar_parses_and_validates() {
        assert_eq!(parse_rate_curve("constant").unwrap(), RateCurve::Constant);
        assert_eq!(parse_rate_curve("").unwrap(), RateCurve::Constant);
        assert_eq!(
            parse_rate_curve("diurnal:period=24,amp=0.5").unwrap(),
            RateCurve::Diurnal {
                period: 24.0,
                amp: 0.5,
                phase: 0.0
            }
        );
        assert_eq!(
            parse_rate_curve("piecewise:0=1,10=2.5,20=0.5").unwrap(),
            RateCurve::Piecewise {
                times: vec![0.0, 10.0, 20.0],
                factors: vec![1.0, 2.5, 0.5]
            }
        );
        assert!(parse_rate_curve("diurnal:amp=0.5").is_err()); // no period
        assert!(parse_rate_curve("diurnal:period=24,amp=1.5").is_err()); // amp ≥ 1
        assert!(parse_rate_curve("piecewise:5=1").is_err()); // must start at 0
        assert!(parse_rate_curve("piecewise:0=1,0=2").is_err()); // not increasing
        assert!(parse_rate_curve("sawtooth:x=1").is_err());
    }

    #[test]
    fn json_roundtrip() {
        for c in [
            RateCurve::Constant,
            RateCurve::Diurnal {
                period: 24.0,
                amp: 0.5,
                phase: 1.25,
            },
            RateCurve::Piecewise {
                times: vec![0.0, 8.0, 16.0],
                factors: vec![0.5, 2.0, 1.0],
            },
        ] {
            let wire = rate_curve_to_json(&c).to_string();
            let back =
                rate_curve_from_json(&crate::util::json::Value::parse(&wire).unwrap()).unwrap();
            assert_eq!(back, c, "wire: {wire}");
        }
    }
}
