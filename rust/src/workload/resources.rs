//! d-dimensional resource demands.
//!
//! The paper's model is a scalar server demand; the companion MSR work
//! (Chen/Grosof/Berg, arXiv 2412.08915) generalizes multiserver jobs to
//! vectors of resources (servers, memory, GPUs, ...). [`ResourceVec`]
//! is that demand/capacity type: a small fixed-capacity inline vector
//! (`MAX_RESOURCES` dimensions) with **dimension 0 = servers**, so every
//! scalar quantity in the original model is exactly the dimension-0
//! projection of its vector generalization.
//!
//! The compatibility contract the whole crate leans on: a 1-dimensional
//! `ResourceVec` behaves *bit-identically* to the old `need: u32` — all
//! fitting predicates reduce to the single `u32` comparison the scalar
//! code performed, and the vector-only index structures are never
//! consulted at d=1.

use std::fmt;
use std::str::FromStr;

/// Maximum number of resource dimensions (servers, memory, GPUs, ...).
pub const MAX_RESOURCES: usize = 4;

/// A demand or capacity vector over up to [`MAX_RESOURCES`] dimensions.
/// Dimension 0 is always the server count; unused trailing dimensions
/// are stored as zero so equality and hashing are well-defined.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceVec {
    dims: u8,
    v: [u32; MAX_RESOURCES],
}

impl ResourceVec {
    /// A 1-dimensional (servers-only) vector — the scalar model.
    #[inline]
    pub const fn scalar(need: u32) -> ResourceVec {
        ResourceVec {
            dims: 1,
            v: [need, 0, 0, 0],
        }
    }

    /// A vector over `vals.len()` dimensions (1..=[`MAX_RESOURCES`]).
    pub fn new(vals: &[u32]) -> ResourceVec {
        assert!(
            !vals.is_empty() && vals.len() <= MAX_RESOURCES,
            "ResourceVec takes 1..={MAX_RESOURCES} dimensions, got {}",
            vals.len()
        );
        let mut v = [0u32; MAX_RESOURCES];
        v[..vals.len()].copy_from_slice(vals);
        ResourceVec {
            dims: vals.len() as u8,
            v,
        }
    }

    /// The all-zero vector over `dims` dimensions.
    pub fn zero(dims: usize) -> ResourceVec {
        assert!(dims >= 1 && dims <= MAX_RESOURCES);
        ResourceVec {
            dims: dims as u8,
            v: [0; MAX_RESOURCES],
        }
    }

    #[inline]
    pub fn dims(&self) -> usize {
        self.dims as usize
    }

    /// True for the scalar (servers-only) model.
    #[inline]
    pub fn is_scalar(&self) -> bool {
        self.dims == 1
    }

    /// Component `j` (zero beyond `dims`, so padding never binds).
    #[inline]
    pub fn get(&self, j: usize) -> u32 {
        self.v[j]
    }

    /// Dimension 0: the server demand — the scalar model's `need`.
    #[inline]
    pub fn servers(&self) -> u32 {
        self.v[0]
    }

    #[inline]
    pub fn as_slice(&self) -> &[u32] {
        &self.v[..self.dims as usize]
    }

    /// Component-wise `self[j] <= avail[j]` over every dimension: the
    /// fitting predicate. At d=1 this is exactly the scalar
    /// `need <= free` comparison.
    #[inline]
    pub fn fits_in(&self, avail: &ResourceVec) -> bool {
        debug_assert_eq!(self.dims, avail.dims);
        if self.dims == 1 {
            return self.v[0] <= avail.v[0];
        }
        self.as_slice()
            .iter()
            .zip(avail.as_slice())
            .all(|(&d, &a)| d <= a)
    }

    /// Component-wise `self >= other` (dominance).
    #[inline]
    pub fn dominates(&self, other: &ResourceVec) -> bool {
        other.fits_in(self)
    }

    /// Component-wise saturating `self - other` (free = capacity − used).
    #[inline]
    pub fn saturating_sub(&self, other: &ResourceVec) -> ResourceVec {
        let mut out = *self;
        for j in 0..self.dims as usize {
            out.v[j] = out.v[j].saturating_sub(other.v[j]);
        }
        out
    }

    /// Component-wise in-place add (admission bookkeeping).
    #[inline]
    pub fn add_assign(&mut self, other: &ResourceVec) {
        debug_assert_eq!(self.dims, other.dims);
        for j in 0..self.dims as usize {
            self.v[j] += other.v[j];
        }
    }

    /// Component-wise in-place subtract; panics (overflow in debug) if
    /// any component would go negative.
    #[inline]
    pub fn sub_assign(&mut self, other: &ResourceVec) {
        debug_assert_eq!(self.dims, other.dims);
        for j in 0..self.dims as usize {
            debug_assert!(self.v[j] >= other.v[j], "resource usage underflow");
            self.v[j] -= other.v[j];
        }
    }

    /// How many copies of `self` pack into `cap`:
    /// `min_j floor(cap[j] / self[j])` over dimensions with positive
    /// demand. At d=1 this is the scalar `k / need`. Zero-demand
    /// dimensions never bind; a vector with no positive dimension packs
    /// `u32::MAX` copies (degenerate, excluded by workload validation).
    pub fn max_pack(&self, cap: &ResourceVec) -> u32 {
        let mut slots = u32::MAX;
        for j in 0..self.dims as usize {
            if self.v[j] > 0 {
                slots = slots.min(cap.v[j] / self.v[j]);
            }
        }
        slots
    }
}

/// `8` for a scalar, `8x64x1` for a vector (dimensions joined by `x`).
impl fmt::Display for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (j, d) in self.as_slice().iter().enumerate() {
            if j > 0 {
                write!(f, "x")?;
            }
            write!(f, "{d}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ResourceVec({self})")
    }
}

/// Parses the `Display` form: `"8"` or `"8x64x1"`.
impl FromStr for ResourceVec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<ResourceVec> {
        let parts: Vec<&str> = s.split('x').collect();
        if parts.is_empty() || parts.len() > MAX_RESOURCES {
            anyhow::bail!("resource vector needs 1..={MAX_RESOURCES} 'x'-separated dimensions");
        }
        let mut vals = Vec::with_capacity(parts.len());
        for p in parts {
            vals.push(
                p.trim()
                    .parse::<u32>()
                    .map_err(|_| anyhow::anyhow!("bad resource component '{p}' in '{s}'"))?,
            );
        }
        Ok(ResourceVec::new(&vals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_dim0_projection() {
        let r = ResourceVec::scalar(7);
        assert_eq!(r.dims(), 1);
        assert!(r.is_scalar());
        assert_eq!(r.servers(), 7);
        assert_eq!(r.as_slice(), &[7]);
        assert!(ResourceVec::scalar(3).fits_in(&r));
        assert!(!ResourceVec::scalar(8).fits_in(&r));
        assert_eq!(r.max_pack(&ResourceVec::scalar(32)), 4);
    }

    #[test]
    fn vector_fit_is_componentwise() {
        let cap = ResourceVec::new(&[16, 64]);
        assert!(ResourceVec::new(&[16, 64]).fits_in(&cap));
        assert!(!ResourceVec::new(&[17, 1]).fits_in(&cap));
        assert!(!ResourceVec::new(&[1, 65]).fits_in(&cap));
        assert_eq!(ResourceVec::new(&[4, 8]).max_pack(&cap), 4);
        assert_eq!(ResourceVec::new(&[1, 0]).max_pack(&cap), 16);
        let mut used = ResourceVec::zero(2);
        used.add_assign(&ResourceVec::new(&[4, 8]));
        used.add_assign(&ResourceVec::new(&[1, 2]));
        assert_eq!(used, ResourceVec::new(&[5, 10]));
        assert_eq!(cap.saturating_sub(&used), ResourceVec::new(&[11, 54]));
        used.sub_assign(&ResourceVec::new(&[4, 8]));
        assert_eq!(used, ResourceVec::new(&[1, 2]));
    }

    #[test]
    fn display_parse_roundtrip() {
        for s in ["8", "8x64", "1x2x3x4"] {
            let r: ResourceVec = s.parse().unwrap();
            assert_eq!(r.to_string(), s);
        }
        assert!("".parse::<ResourceVec>().is_err());
        assert!("1x2x3x4x5".parse::<ResourceVec>().is_err());
        assert!("8xmem".parse::<ResourceVec>().is_err());
    }
}
