//! `quickswap` — CLI for the multiserver-job scheduling framework.
//!
//! Subcommands:
//!   simulate   run one policy on a workload, print metrics
//!   sweep      λ × policy sweep: run | drive | work | status
//!   analyze    Theorem-2 calculator for MSFQ (one-or-all)
//!   solve      stationary CTMC solve (native sparse or PJRT artifact)
//!   autotune   pick the best quickswap threshold ℓ for given rates
//!   fig        reproduce a paper figure (1..8)
//!   serve      start the coordinator daemon (TCP JSONL API)
//!   trace      workload traces: generate | convert (csv -> qst) | stats

use quickswap::analysis::{self, MsfqCtmc, MsfqParams};
use quickswap::config::parse_workload;
use quickswap::coordinator::{serve_tcp, Coordinator, CoordinatorConfig};
use quickswap::experiments::{figures, FigureId, Scale, SweepOpts, TraceShards};
use quickswap::sim::SimConfig;
use quickswap::sweep::{proto, DriverBuilder, SpecOutcome, SweepSpec, WorkerConfig, WorkerOutcome, WorkloadSpec};
use quickswap::util::cli::{render_help, Args, OptSpec};
use quickswap::util::json::Value;
use quickswap::workload::rate::parse_rate_curve;
use quickswap::workload::trace::{StreamingTraceSource, Trace};
use quickswap::workload::{borg::borg_workload, qst, Workload};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{}", help());
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(argv.into_iter().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "analyze" => cmd_analyze(&args),
        "solve" => cmd_solve(&args),
        "autotune" => cmd_autotune(&args),
        "fig" => cmd_fig(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        other => {
            eprintln!("unknown command '{other}'\n{}", help());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn help() -> String {
    render_help(
        "quickswap",
        "nonpreemptive multiserver-job scheduling with Quickswap",
        &[
            ("simulate", "run one policy on a workload"),
            ("sweep", "lambda × policy sweep: run (in-process) | drive (serve units to workers) | work (pull units) | status (probe a driver)"),
            ("analyze", "Theorem-2 MSFQ calculator"),
            ("solve", "stationary CTMC solve (native or PJRT artifact)"),
            ("autotune", "best quickswap threshold for given rates"),
            ("fig", "reproduce a paper figure: --id 1..8"),
            ("serve", "start the coordinator daemon"),
            ("trace", "workload traces: generate (csv or .qst) | convert (csv -> .qst) | stats (footer-only summary)"),
        ],
        &[
            OptSpec { name: "workload", help: "one_or_all|four_class|borg|multires or JSON file", default: Some("one_or_all".into()) },
            OptSpec { name: "k", help: "servers (one_or_all, multires)", default: Some("32".into()) },
            OptSpec { name: "mem", help: "memory units (multires)", default: Some("128".into()) },
            OptSpec { name: "lambda", help: "total arrival rate", default: Some("7.5".into()) },
            OptSpec { name: "p1", help: "light-job fraction", default: Some("0.9".into()) },
            OptSpec { name: "policy", help: "fcfs|first-fit|msf|msfq[:ell]|static-qs|adaptive-qs|nmsr[:cycle]|msr-seq[:cycle]|msr-rand[:cycle]|server-filling", default: Some("msfq".into()) },
            OptSpec { name: "completions", help: "measured completions", default: Some("1000000".into()) },
            OptSpec { name: "seed", help: "RNG seed", default: Some("1".into()) },
            OptSpec { name: "reps", help: "replications per sweep point", default: Some("QS_REPS or 4".into()) },
            OptSpec { name: "addr", help: "sweep drive|work|status: TCP address (\":0\" picks a port for drive); set QS_SWEEP_TOKEN to require/offer a shared secret", default: Some("127.0.0.1:0 for drive".into()) },
            OptSpec { name: "journal", help: "sweep drive: append-only JSONL checkpoint; a restarted driver pointed at the same journal resumes without rerunning finished units", default: None },
            OptSpec { name: "fsync", help: "sweep drive (flag): sync_all every journal record to the device before acking (power-cut-safe); or set QS_JOURNAL_FSYNC=1", default: None },
            OptSpec { name: "hb-timeout-secs", help: "sweep drive: requeue units whose worker has been silent this long (0 disables; QS_HEARTBEAT_TIMEOUT_SECS)", default: Some("30".into()) },
            OptSpec { name: "max-conns", help: "sweep drive: connection cap — extra peers get a typed 'busy' and a clean close (QS_MAX_CONNS)", default: Some("256".into()) },
            OptSpec { name: "fault-plan", help: "sweep drive|work: seeded deterministic fault plan, e.g. 'seed=7;disconnect@5;crash@3' (QS_FAULT_PLAN) — chaos testing", default: None },
            OptSpec { name: "retries", help: "sweep work: reconnect attempts before declaring the driver lost (QS_WORKER_RETRIES)", default: Some("3".into()) },
            OptSpec { name: "backoff-ms", help: "sweep work: base reconnect backoff, doubled per attempt with deterministic jitter (QS_WORKER_BACKOFF_MS)", default: Some("50".into()) },
            OptSpec { name: "backoff-cap-ms", help: "sweep work: reconnect backoff ceiling (QS_WORKER_BACKOFF_CAP_MS)", default: Some("1000".into()) },
            OptSpec { name: "heartbeat-secs", help: "sweep work: one-way ping interval so the driver can tell hung from busy (0 disables; QS_HEARTBEAT_SECS)", default: Some("2".into()) },
            OptSpec { name: "figs", help: "sweep drive: queue several figures' predefined grids in one sweep, e.g. --figs 2,6,8", default: None },
            OptSpec { name: "fig", help: "sweep: use a figure's predefined grid (2|3|5|6|8)", default: None },
            OptSpec { name: "paired", help: "sweep: common-random-number mode — all policies replay one shared arrival stream per (lambda, replication); prints paired-difference CIs", default: None },
            OptSpec { name: "baseline", help: "sweep --paired: policy the differences are taken against (implies --paired)", default: Some("first policy in the list".into()) },
            OptSpec { name: "rate-curve", help: "nonstationary arrivals: constant | diurnal:period=24,amp=0.5[,phase=0] | piecewise:0=1,10=2.5,...", default: Some("constant".into()) },
            OptSpec { name: "trace", help: "simulate|sweep: replay a .qst trace instead of synthetic arrivals", default: None },
            OptSpec { name: "shards", help: "sweep --trace: split the trace into N block-aligned shards (replaces the replication axis)", default: Some("1".into()) },
            OptSpec { name: "in", help: "trace convert|stats: input file", default: None },
            OptSpec { name: "classes", help: "trace convert: class count stamped into the .qst header", default: Some("from --workload".into()) },
            OptSpec { name: "block", help: "trace generate|convert: arrivals per .qst block", default: Some("4096".into()) },
            OptSpec { name: "buckets", help: "trace stats: buckets for the empirical lambda(t) table", default: Some("10".into()) },
        ],
    )
}

fn workload_from(args: &Args) -> anyhow::Result<Workload> {
    let kind = args.str_or("workload", "one_or_all");
    let lambda = args.f64_or("lambda", 7.5)?;
    let wl = match kind.as_str() {
        "one_or_all" => {
            let k = args.u64_or("k", 32)? as u32;
            Ok(Workload::one_or_all(
                k,
                lambda,
                args.f64_or("p1", 0.9)?,
                args.f64_or("mu1", 1.0)?,
                args.f64_or("muk", 1.0)?,
            ))
        }
        "four_class" => Ok(Workload::four_class(lambda)),
        "borg" => Ok(borg_workload(lambda)),
        "multires" => {
            let k = args.u64_or("k", 32)? as u32;
            let mem = args.u64_or("mem", 128)? as u32;
            Ok(Workload::multires(k, mem, lambda))
        }
        path => {
            let text = std::fs::read_to_string(path)?;
            let v = Value::parse(&text)?;
            let wl = parse_workload(&v)?;
            Ok(wl.with_total_rate(lambda))
        }
    };
    // `--rate-curve` modulates arrivals in time (the CLI override wins
    // over any curve a JSON workload file declares).
    match args.get("rate-curve") {
        Some(s) => {
            let curve = parse_rate_curve(s).map_err(|e| anyhow::anyhow!("--rate-curve: {e}"))?;
            Ok(wl?.with_rate_curve(curve))
        }
        None => wl,
    }
}

fn sim_config_from(args: &Args) -> anyhow::Result<SimConfig> {
    let completions = args.u64_or("completions", 1_000_000)?;
    let mut cfg = SimConfig::default().with_completions(completions);
    cfg.track_phases = args.flag("phases");
    Ok(cfg)
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let wl = workload_from(args)?;
    let cfg = sim_config_from(args)?;
    let seed = args.u64_or("seed", 1)?;
    let policy: quickswap::policy::PolicyId = args.str_or("policy", "msfq").parse()?;
    let r = if let Some(path) = args.get("trace") {
        // Replay a `.qst` trace instead of drawing synthetic arrivals.
        // Without an explicit --completions the whole trace is measured
        // (the shard, not the target, ends the run).
        let mut cfg = cfg;
        if args.get("completions").is_none() {
            cfg.target_completions = u64::MAX / 2;
            cfg.warmup_completions = 0;
        }
        let mut src = StreamingTraceSource::open(path, wl.clone())?;
        let mut pol = quickswap::policy::build(&policy, &wl)?;
        let mut rng = quickswap::util::rng::Rng::new(seed);
        quickswap::sim::Engine::new(&wl, cfg).run(&mut src, pol.as_mut(), &mut rng)
    } else {
        quickswap::sim::run_policy(&wl, &policy, &cfg, seed)?
    };
    println!("{}", r.summary());
    for (c, cl) in wl.classes.iter().enumerate() {
        println!(
            "  class {:<8} (demand {:>7}): E[T] = {:>10.3}  n = {:>9}  E[N] = {:>9.2}",
            cl.name,
            cl.demand.to_string(),
            r.mean_t[c],
            r.count[c],
            r.mean_n[c]
        );
    }
    if let Some(ph) = &r.phases {
        for i in 1..=4 {
            println!(
                "  phase {i}: E[H] = {:>9.3} (visits {:>7}, {:>5.1}% of time)",
                ph.mean(i),
                ph.visits[i],
                100.0 * ph.fraction(i)
            );
        }
    }
    Ok(())
}

/// Build the sweep description from CLI args: either a figure's
/// predefined grid (`--fig 2|3|5|6|8`) or an ad-hoc
/// workload × λ × policy grid. The spec fully determines the results;
/// thread/worker counts never enter it.
fn sweep_spec_from(args: &Args) -> anyhow::Result<SweepSpec> {
    let reps = args.u32_or("reps", SweepOpts::from_env().replications)?;
    let mut spec = sweep_grid_from(args, reps)?;
    // Paired (CRN) mode is orthogonal to where the grid came from:
    // --baseline implies --paired; the baseline must name a grid policy
    // (paired_grid resolves it and rejects strangers up front).
    spec.paired = args.flag("paired") || args.get("baseline").is_some();
    spec.baseline = args
        .get("baseline")
        .map(|b| quickswap::policy::PolicyId::parse(b))
        .transpose()?;
    // `--trace file.qst --shards N`: replay a recorded trace instead of
    // synthetic arrivals; the shard axis replaces the replication axis.
    if let Some(path) = args.get("trace") {
        spec.trace = Some(TraceShards {
            path: path.to_string(),
            shards: args.u32_or("shards", 1)?.max(1),
        });
    }
    if spec.paired {
        spec.paired_grid()?;
    }
    Ok(spec)
}

fn sweep_grid_from(args: &Args, reps: u32) -> anyhow::Result<SweepSpec> {
    if let Some(figstr) = args.get("fig") {
        let fig = FigureId::parse(figstr)?;
        let scale = Scale::from_env();
        let mut spec = match fig {
            FigureId::Fig2 => {
                let lambda = args.f64_or("lambda", 7.5)?;
                figures::fig2_spec(scale, lambda, &[0, 1, 2, 4, 8, 16, 24, 31])
            }
            FigureId::Fig3 => {
                let ls = args.f64_list("lambdas", &[4.0, 5.0, 6.0, 6.75, 7.25, 7.5])?;
                figures::fig3_spec(scale, &ls)
            }
            FigureId::Fig5 => {
                let ls = args.f64_list("lambdas", &[2.0, 3.0, 4.0, 4.5, 4.75])?;
                figures::fig5_spec(scale, &ls)
            }
            FigureId::Fig6 => {
                let ls = args.f64_list("lambdas", &[2.0, 3.0, 4.0, 4.5])?;
                figures::fig6_spec(scale, &ls, false)
            }
            FigureId::Fig8 => {
                let ls = args.f64_list("lambdas", &[2.0, 3.0, 4.0, 4.5])?;
                figures::fig6_spec(scale, &ls, true)
            }
            other => anyhow::bail!("--fig {other} is not a sweep-shaped figure (2|3|5|6|8)"),
        };
        // Explicit --reps/--seed/--completions beat the figure's
        // QS_SCALE/QS_REPS-resolved defaults (other grid args are the
        // figure's own and stay fixed).
        if args.get("reps").is_some() {
            spec.replications = reps;
        }
        if args.get("seed").is_some() {
            spec.seed = args.u64_or("seed", spec.seed)?;
        }
        if args.get("completions").is_some() {
            let c = args.u64_or("completions", spec.target_completions)?;
            spec.target_completions = c;
            spec.warmup_completions = c / 5;
        }
        return Ok(spec);
    }
    let lambdas = args.f64_list("lambdas", &[4.0, 5.0, 6.0, 7.0, 7.5])?;
    let policies_s = args.str_or("policies", "msf,msfq:31,fcfs,first-fit");
    let policies = policies_s
        .split(',')
        .map(|s| quickswap::policy::PolicyId::parse(s))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let cfg = sim_config_from(args)?;
    let seed = args.u64_or("seed", 1)?;
    let workload = match args.str_or("workload", "one_or_all").as_str() {
        "four_class" => WorkloadSpec::FourClass,
        "borg" => WorkloadSpec::Borg,
        "multires" => WorkloadSpec::Multires {
            k: args.u64_or("k", 32)? as u32,
            mem: args.u64_or("mem", 128)? as u32,
        },
        "one_or_all" => WorkloadSpec::OneOrAll {
            k: args.u64_or("k", 32)? as u32,
            p1: args.f64_or("p1", 0.9)?,
            mu1: args.f64_or("mu1", 1.0)?,
            muk: args.f64_or("muk", 1.0)?,
        },
        other => {
            anyhow::bail!("sweep workload must be one_or_all|four_class|borg|multires, got {other}")
        }
    };
    Ok(SweepSpec::from_config(workload, &lambdas, &policies, &cfg, seed, reps))
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    match args.positional().first().map(|s| s.as_str()) {
        Some("run") => cmd_sweep_run(args),
        Some("drive") => cmd_sweep_drive(args),
        Some("work") => cmd_sweep_work(args),
        Some("status") => cmd_sweep_status(args),
        Some(other) => anyhow::bail!("unknown sweep subcommand '{other}' (run|drive|work|status)"),
        None => anyhow::bail!("sweep needs a subcommand: run|drive|work|status"),
    }
}

/// `sweep run`: resolve the spec and execute it in-process.
fn cmd_sweep_run(args: &Args) -> anyhow::Result<()> {
    let spec = sweep_spec_from(args)?;
    let threads = SweepOpts::from_env().threads;
    let outcome = if spec.paired {
        SpecOutcome::Paired(quickswap::sweep::run_spec_paired_local(&spec, threads)?)
    } else {
        SpecOutcome::Marginal(quickswap::sweep::run_spec_local(&spec, threads))
    };
    emit_outcome(&spec, &outcome, args.flag("weighted"), args.get("out"), "sweep")
}

/// `sweep drive`: serve a spec queue to TCP workers, optionally
/// journaled for kill/resume durability.
fn cmd_sweep_drive(args: &Args) -> anyhow::Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:0");
    // Spec queue: `--figs 2,6,8` queues each figure's predefined grid
    // (paired flags apply to every queued spec); otherwise the single
    // ad-hoc/--fig spec, exactly as `sweep run` would build it.
    let (specs, labels): (Vec<SweepSpec>, Vec<String>) = match args.str_list("figs") {
        Some(figs) => {
            let scale = Scale::from_env();
            let mut specs = Vec::new();
            let mut labels = Vec::new();
            for f in &figs {
                let fig = FigureId::parse(f)?;
                let mut spec = figures::default_spec(fig, scale)?;
                spec.paired = args.flag("paired") || args.get("baseline").is_some();
                spec.baseline = args
                    .get("baseline")
                    .map(|b| quickswap::policy::PolicyId::parse(b))
                    .transpose()?;
                if spec.paired {
                    spec.paired_grid()?;
                }
                specs.push(spec);
                labels.push(fig.to_string());
            }
            (specs, labels)
        }
        None => (vec![sweep_spec_from(args)?], vec!["sweep".to_string()]),
    };
    let mut builder = DriverBuilder::new()
        .specs(specs.iter().cloned())
        .bind_addr(&addr);
    if let Some(j) = args.get("journal") {
        builder = builder.journal(j);
    }
    if args.flag("fsync") {
        builder = builder.fsync(true);
    }
    if args.get("hb-timeout-secs").is_some() {
        let secs = args.f64_or("hb-timeout-secs", 30.0)?;
        let hb = (secs > 0.0 && secs.is_finite())
            .then(|| std::time::Duration::from_secs_f64(secs));
        builder = builder.heartbeat_timeout(hb);
    }
    if args.get("max-conns").is_some() {
        builder = builder.max_conns(args.u64_or("max-conns", 256)? as usize);
    }
    if let Some(plan) = args.get("fault-plan") {
        // Explicit CLI plans must parse — unlike the env default, a typo
        // here is an error, not a warning.
        builder = builder.fault_plan(Some(quickswap::sweep::faultline::FaultPlan::parse(plan)?));
    }
    let driver = builder.bind()?;
    // Stderr, machine-parseable: scripts read the bound port from this
    // line (ports chosen with ":0").
    eprintln!("qs-sweep driver listening on {}", driver.local_addr());
    for (spec, label) in specs.iter().zip(&labels) {
        if spec.paired {
            eprintln!(
                "  {label}: paired grid {} lambdas x {} replications = {} units ({} policies each)",
                spec.lambdas.len(),
                spec.replications,
                spec.lambdas.len() * spec.replications.max(1) as usize,
                spec.policies.len()
            );
        } else {
            eprintln!(
                "  {label}: grid {} points x {} replications = {} units",
                spec.lambdas.len() * spec.policies.len(),
                spec.replications,
                spec.grid().n_units()
            );
        }
    }
    let report = driver.serve()?;
    eprintln!(
        "qs-sweep driver: {} units total, {} from journal, {} executed",
        report.units_total, report.units_from_journal, report.units_executed
    );
    let l = report.liveness;
    eprintln!(
        "qs-sweep driver liveness: accepted={} shed={} pings={} hb_requeues={} \
         timeout_requeues={} disconnect_requeues={} idle_drops={} duplicates={}",
        l.conns_accepted,
        l.conns_shed,
        l.pings,
        l.heartbeat_requeues,
        l.timeout_requeues,
        l.disconnect_requeues,
        l.idle_drops,
        l.duplicates
    );
    let weighted = args.flag("weighted");
    for ((spec, label), outcome) in specs.iter().zip(&labels).zip(&report.outcomes) {
        let out = args.get("out").map(|o| {
            if specs.len() > 1 {
                spec_csv_path(o, label)
            } else {
                o.to_string()
            }
        });
        emit_outcome(spec, outcome, weighted, out.as_deref(), label)?;
    }
    Ok(())
}

/// `sweep work`: everything (grids, seeds, run lengths) comes from the
/// driver; local grid args are ignored. Self-healing knobs (reconnect
/// retries, backoff, heartbeat cadence, fault plan) come from the
/// environment with CLI overrides.
fn cmd_sweep_work(args: &Args) -> anyhow::Result<()> {
    let addr = args.required("addr")?;
    let mut cfg = WorkerConfig::from_env()?;
    if args.get("retries").is_some() {
        cfg.max_retries = args.u64_or("retries", cfg.max_retries as u64)? as u32;
    }
    if args.get("backoff-ms").is_some() {
        cfg.backoff_base = std::time::Duration::from_millis(args.u64_or("backoff-ms", 50)?);
    }
    if args.get("backoff-cap-ms").is_some() {
        cfg.backoff_cap = std::time::Duration::from_millis(args.u64_or("backoff-cap-ms", 1000)?);
    }
    if args.get("heartbeat-secs").is_some() {
        let secs = args.f64_or("heartbeat-secs", 2.0)?;
        cfg.heartbeat = (secs > 0.0 && secs.is_finite())
            .then(|| std::time::Duration::from_secs_f64(secs));
    }
    if let Some(plan) = args.get("fault-plan") {
        cfg.plan = Some(quickswap::sweep::faultline::FaultPlan::parse(plan)?);
    }
    let report = quickswap::sweep::run_worker_with(addr, &cfg)?;
    if report.reconnects > 0 {
        eprintln!(
            "qs-sweep worker: {} reconnect(s), {} busy retr{} along the way",
            report.reconnects,
            report.busy_retries,
            if report.busy_retries == 1 { "y" } else { "ies" }
        );
    }
    match report.outcome {
        WorkerOutcome::Done => {
            eprintln!("qs-sweep worker: completed {} units", report.completed)
        }
        WorkerOutcome::DriverLost => eprintln!(
            "qs-sweep worker: driver lost after {} completed units",
            report.completed
        ),
        WorkerOutcome::Crashed => eprintln!(
            "qs-sweep worker: stopped by injected crash after {} completed units",
            report.completed
        ),
    }
    Ok(())
}

/// `sweep status`: handshake with a running driver and print its
/// one-line JSON progress report (per-spec done counts plus pooled rows
/// for every point whose replications have all arrived).
fn cmd_sweep_status(args: &Args) -> anyhow::Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let addr = args.required("addr")?;
    let stream = std::net::TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let token = std::env::var("QS_SWEEP_TOKEN").ok().filter(|t| !t.is_empty());
    writeln!(writer, "{}", proto::msg_hello(token.as_deref()))?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let first = proto::parse_line(&line)?;
    if let Some(msg) = proto::err_of(&first) {
        anyhow::bail!("driver rejected this status probe: {msg}");
    }
    if proto::op_of(&first) == Some("busy") {
        anyhow::bail!("driver is at its connection cap (busy); try again shortly");
    }
    writeln!(writer, "{}", proto::msg_status_req())?;
    line.clear();
    if reader.read_line(&mut line)? == 0 {
        anyhow::bail!("driver closed the connection before replying to status");
    }
    // Raw JSON to stdout: the status line is already one JSON object,
    // ready for jq/python consumers.
    print!("{line}");
    Ok(())
}

/// Print a completed spec's tables and write its CSVs: marginal points
/// always, plus the paired-difference table/CSV when the outcome is
/// paired. `title` labels the printed tables (the figure name under
/// `drive --figs`).
fn emit_outcome(
    spec: &SweepSpec,
    outcome: &SpecOutcome,
    weighted: bool,
    out: Option<&str>,
    title: &str,
) -> anyhow::Result<()> {
    match outcome {
        SpecOutcome::Marginal(pts) => {
            quickswap::experiments::print_sweep(title, pts, weighted);
            if let Some(out) = out {
                quickswap::experiments::write_sweep_csv(out, pts, &spec.class_names())?;
                println!("wrote {out}");
            }
        }
        SpecOutcome::Paired(sweep) => {
            let marginal_title = format!("{title} (marginals)");
            quickswap::experiments::print_sweep(&marginal_title, &sweep.points, weighted);
            quickswap::experiments::print_paired("paired differences", &sweep.diffs);
            if let Some(out) = out {
                quickswap::experiments::write_sweep_csv(out, &sweep.points, &spec.class_names())?;
                let diff_out = diff_csv_path(out);
                quickswap::experiments::write_diff_csv(&diff_out, &sweep.diffs, &spec.class_names())?;
                println!("wrote {out} and {diff_out}");
            }
        }
    }
    Ok(())
}

/// Per-spec CSV path for a multi-spec queue: `x.csv` + label `fig6` →
/// `x.fig6.csv` (no recognizable extension: append `.<label>.csv`).
fn spec_csv_path(out: &str, label: &str) -> String {
    match out.rfind('.') {
        Some(i) if !out[i..].contains('/') => format!("{}.{label}{}", &out[..i], &out[i..]),
        _ => format!("{out}.{label}.csv"),
    }
}

/// Companion path for the paired-difference CSV: `x.csv` → `x.diff.csv`
/// (no recognizable extension: append `.diff.csv`).
fn diff_csv_path(out: &str) -> String {
    match out.rfind('.') {
        Some(i) if !out[i..].contains('/') => format!("{}.diff{}", &out[..i], &out[i..]),
        _ => format!("{out}.diff.csv"),
    }
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let k = args.u64_or("k", 32)? as u32;
    let lambda = args.f64_or("lambda", 7.5)?;
    let p1 = args.f64_or("p1", 0.9)?;
    let ell = args.u64_or("ell", (k - 1) as u64)? as u32;
    let a = analysis::analyze(&MsfqParams::standard(k, ell, lambda, p1))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("MSFQ analysis (Theorem 2): k={k} ell={ell} lambda={lambda} p1={p1}");
    println!("  E[T]       = {:>12.4}", a.et);
    println!("  E[T] light = {:>12.4}", a.et_light);
    println!("  E[T] heavy = {:>12.4}", a.et_heavy);
    println!("  E[T^w]     = {:>12.4}", a.etw);
    for i in 1..=4 {
        println!(
            "  phase {i}: E[H]={:>10.4}  E[H^2]={:>12.4}  m={:.4}",
            a.eh[i], a.eh2[i], a.m[i]
        );
    }
    println!("  E[N1H]={:.3} E[N2L]={:.3}", a.en1h.0, a.en2l.0);
    Ok(())
}

fn cmd_solve(args: &Args) -> anyhow::Result<()> {
    let k = args.u64_or("k", 8)? as u32;
    let lambda = args.f64_or("lambda", 4.4)?;
    let p1 = args.f64_or("p1", 0.9)?;
    let ell = args.u64_or("ell", (k - 1) as u64)? as u32;
    let p = MsfqParams::standard(k, ell, lambda, p1);
    if args.flag("artifact") {
        let rt = quickswap::runtime::Runtime::new(quickswap::runtime::Runtime::default_dir())?;
        let solver = quickswap::runtime::SolverArtifact::load(&rt, k)?;
        let iters = args.u64_or("iters", 30_000)? as i32;
        let m = solver.solve(ell, p.lam1, p.lamk, p.mu1, p.muk, iters)?;
        println!("PJRT artifact solve (k={k}, ell={ell}, iters={iters}):");
        println!("  E[T]={:.4} E[T1]={:.4} E[Tk]={:.4} E[T^w]={:.4}", m.et, m.et1, m.etk, m.etw);
        println!("  m1={:.4} m23={:.4} m4={:.4} idle={:.4}", m.m1, m.m23, m.m4, m.idle);
        println!("  residual={:.2e} mass={:.6} blocked=({:.1e},{:.1e})", m.residual, m.mass, m.blocked1, m.blockedk);
    } else {
        let n1 = args.u64_or("n1max", 8 * k as u64)? as usize;
        let nk = args.u64_or("nkmax", (2 * k as u64).max(32))? as usize;
        let iters = args.u64_or("iters", 200_000)? as usize;
        let s = MsfqCtmc::new(&p, n1, nk).solve(iters, 1e-11);
        println!("native CTMC solve (k={k}, ell={ell}, {n1}×{nk}):");
        println!("  E[T]={:.4} E[T1]={:.4} E[Tk]={:.4} E[T^w]={:.4}", s.et, s.et1, s.etk, s.etw);
        println!("  m1={:.4} m23={:.4} m4={:.4} idle={:.4}", s.m1, s.m23, s.m4, s.idle);
        println!("  iters={} residual={:.2e} boundary={:.2e}", s.iters, s.residual, s.boundary_mass);
    }
    Ok(())
}

fn cmd_autotune(args: &Args) -> anyhow::Result<()> {
    let k = args.u64_or("k", 32)? as u32;
    let lambda = args.f64_or("lambda", 7.5)?;
    let p1 = args.f64_or("p1", 0.9)?;
    let p = MsfqParams::standard(k, 0, lambda, p1);
    let weighted = args.flag("weighted");
    let (ell, v) = analysis::best_threshold(k, p.lam1, p.lamk, p.mu1, p.muk, weighted)
        .ok_or_else(|| anyhow::anyhow!("no stable threshold (system overloaded?)"))?;
    println!("calculator: best ell = {ell} ({}[T] = {v:.4})", if weighted { "E_w" } else { "E" });
    if args.flag("artifact") {
        let rt = quickswap::runtime::Runtime::new(quickswap::runtime::Runtime::default_dir())?;
        let solver = quickswap::runtime::SolverArtifact::load(&rt, k)?;
        let iters = args.u64_or("iters", 30_000)? as i32;
        let (aell, m) = solver.autotune(p.lam1, p.lamk, p.mu1, p.muk, iters, weighted)?;
        println!("artifact:   best ell = {aell} (E[T] = {:.4})", m.et);
    }
    Ok(())
}

fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    let fig = FigureId::parse(args.required("id")?)?;
    let scale = Scale::from_env();
    match fig {
        FigureId::Fig1 => {
            figures::fig1(scale);
        }
        FigureId::Fig2 => {
            let lambda = args.f64_or("lambda", 7.5)?;
            figures::fig2(scale, lambda, &[0, 1, 2, 4, 8, 16, 24, 31]);
        }
        FigureId::Fig3 => {
            let ls = args.f64_list("lambdas", &[4.0, 5.0, 6.0, 6.75, 7.25, 7.5])?;
            figures::fig3(scale, &ls);
        }
        FigureId::Fig4 => {
            let ls = args.f64_list("lambdas", &[6.0, 6.75, 7.25, 7.5])?;
            figures::fig4(scale, &ls);
        }
        FigureId::Fig5 => {
            let ls = args.f64_list("lambdas", &[2.0, 3.0, 4.0, 4.5, 4.75])?;
            figures::fig5(scale, &ls);
        }
        // Figure 7 is the Jain's-index companion computed from fig6's
        // sweep, so both ids run the pair.
        FigureId::Fig6 | FigureId::Fig7 => {
            let ls = args.f64_list("lambdas", &[2.0, 3.0, 4.0, 4.5])?;
            let pts = figures::fig6(scale, &ls, false);
            figures::fig7(&pts);
        }
        FigureId::Fig8 => {
            let ls = args.f64_list("lambdas", &[2.0, 3.0, 4.0, 4.5])?;
            figures::fig6(scale, &ls, true);
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let wl = workload_from(args)?;
    let policy: quickswap::policy::PolicyId = args.str_or("policy", "msfq").parse()?;
    let pol = quickswap::policy::build(&policy, &wl)?;
    let cfg = CoordinatorConfig {
        time_scale: args.f64_or("time-scale", 1e-3)?,
        autotune_every: args.u64_or("autotune-every", 0)?,
        use_artifact: !args.flag("no-artifact"),
        solver_iters: args.u64_or("iters", 20_000)? as i32,
    };
    let coord = Coordinator::spawn(&wl, pol, cfg);
    let addr = serve_tcp(&args.str_or("addr", "127.0.0.1:7077"), coord.handle())?;
    println!("quickswap coordinator listening on {addr} (policy {policy}, k={})", wl.k);
    println!("protocol: one JSON per line, e.g. {{\"op\":\"submit\",\"class\":0,\"size\":1.0}}");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    // Bare `trace` keeps its historical meaning (generate).
    match args.positional().first().map(|s| s.as_str()) {
        None | Some("generate") => cmd_trace_generate(args),
        Some("convert") => cmd_trace_convert(args),
        Some("stats") => cmd_trace_stats(args),
        Some(other) => anyhow::bail!("unknown trace subcommand '{other}' (generate|convert|stats)"),
    }
}

/// `trace generate`: draw `--n` arrivals from the workload (honouring
/// `--rate-curve`) and write them as CSV, or as `.qst` when `--out`
/// ends in `.qst`.
fn cmd_trace_generate(args: &Args) -> anyhow::Result<()> {
    let wl = workload_from(args)?;
    let n = args.u64_or("n", 100_000)? as usize;
    let seed = args.u64_or("seed", 1)?;
    let out = args.str_or("out", "results/trace.csv");
    let tr = Trace::generate(&wl, n, seed);
    if out.ends_with(".qst") {
        let block = args.u64_or("block", qst::DEFAULT_BLOCK as u64)? as usize;
        let footer = tr.write_qst(&out, wl.num_classes(), block)?;
        println!(
            "wrote {n} arrivals to {out} ({} blocks, t in [{:.3}, {:.3}])",
            footer.blocks.len(),
            footer.t_first,
            footer.t_last
        );
    } else {
        tr.write_csv(&out)?;
        println!("wrote {n} arrivals to {out}");
    }
    Ok(())
}

/// `trace convert`: one-pass CSV → `.qst`. Class count comes from
/// `--classes`, or from the `--workload` family when omitted.
fn cmd_trace_convert(args: &Args) -> anyhow::Result<()> {
    let input = args.required("in")?;
    let out = args.str_or("out", "results/trace.qst");
    let classes = match args.get("classes") {
        Some(_) => args.u64_or("classes", 0)? as usize,
        None => workload_from(args)?.num_classes(),
    };
    let block = args.u64_or("block", qst::DEFAULT_BLOCK as u64)? as usize;
    let footer = qst::convert_csv(input, &out, classes, block)?;
    println!(
        "converted {} arrivals to {out} ({} blocks of <= {block})",
        footer.total,
        footer.blocks.len()
    );
    Ok(())
}

/// `trace stats`: everything printed here comes from the footer — the
/// blocks themselves are never decoded, so this is O(footer) even on a
/// multi-gigabyte trace.
fn cmd_trace_stats(args: &Args) -> anyhow::Result<()> {
    let path = match args.positional().get(1) {
        Some(p) => p.clone(),
        None => args.required("in")?.to_string(),
    };
    let reader = qst::QstReader::open(&path)?;
    let f = reader.footer();
    let span = f.t_last - f.t_first;
    println!("{path}: {} arrivals, {} classes, {} blocks", f.total, f.num_classes, f.blocks.len());
    println!("  time span: [{:.4}, {:.4}] ({span:.4})", f.t_first, f.t_last);
    for (c, &n) in f.class_counts.iter().enumerate() {
        let frac = if f.total > 0 { n as f64 / f.total as f64 } else { 0.0 };
        println!("  class {c:>3}: {n:>12} arrivals ({:>6.2}%)", 100.0 * frac);
    }
    // Empirical λ(t): bucket the span and attribute each block's count
    // to buckets in proportion to its [t_min, t_max] overlap.
    let buckets = args.u64_or("buckets", 10)? as usize;
    if span > 0.0 && f.total > 0 && buckets > 0 {
        let mut mass = vec![0.0f64; buckets];
        let width = span / buckets as f64;
        for b in &f.blocks {
            let (lo, hi) = (b.t_min, b.t_max.max(b.t_min));
            let dur = hi - lo;
            for (i, m) in mass.iter_mut().enumerate() {
                let (w0, w1) = (f.t_first + i as f64 * width, f.t_first + (i + 1) as f64 * width);
                let overlap = (hi.min(w1) - lo.max(w0)).max(0.0);
                // A block narrower than the resolution lands whole in
                // the bucket holding its midpoint.
                if dur > 0.0 {
                    *m += b.n as f64 * overlap / dur;
                } else if (lo + hi) / 2.0 >= w0 && ((lo + hi) / 2.0 < w1 || i + 1 == buckets) {
                    *m += b.n as f64;
                }
            }
        }
        println!("  empirical lambda(t), {buckets} buckets of {width:.4}:");
        for (i, m) in mass.iter().enumerate() {
            println!(
                "    [{:>10.3}, {:>10.3}): lambda = {:>9.4}",
                f.t_first + i as f64 * width,
                f.t_first + (i + 1) as f64 * width,
                m / width
            );
        }
    }
    Ok(())
}
