//! Phase-duration tracking (reproduces Fig 4).
//!
//! Policies expose a [`crate::policy::PhaseLabel`] after every event; this
//! tracker records the duration of each maximal run of a label. Label 0
//! means "untracked" and is ignored.

use crate::util::stats::Welford;

pub const MAX_PHASE: usize = 5; // labels 1..=4 used by MSFQ/MSF

#[derive(Clone, Debug)]
pub struct PhaseStats {
    /// Per-label duration accumulators (index = label).
    pub durations: Vec<Welford>,
    /// Number of completed visits per label.
    pub visits: Vec<u64>,
    /// Total time per label (for time-fraction m_i).
    pub total_time: Vec<f64>,
    current: u8,
    since: f64,
}

impl PhaseStats {
    pub fn new() -> Self {
        Self {
            durations: vec![Welford::new(); MAX_PHASE],
            visits: vec![0; MAX_PHASE],
            total_time: vec![0.0; MAX_PHASE],
            current: 0,
            since: 0.0,
        }
    }

    /// Observe the label at time `now`; closes the previous run on change.
    pub fn observe(&mut self, now: f64, label: u8) {
        if label == self.current {
            return;
        }
        self.close(now);
        self.current = label;
        self.since = now;
    }

    fn close(&mut self, now: f64) {
        let c = self.current as usize;
        if c != 0 && c < MAX_PHASE {
            let d = now - self.since;
            self.durations[c].push(d);
            self.visits[c] += 1;
            self.total_time[c] += d;
        }
    }

    /// Reset at warmup boundary, preserving the in-progress label.
    pub fn reset_at(&mut self, now: f64) {
        let cur = self.current;
        *self = PhaseStats::new();
        self.current = cur;
        self.since = now;
    }

    /// Finalize at simulation end.
    pub fn finish(&mut self, now: f64) {
        self.close(now);
        self.current = 0;
    }

    /// Mean duration of phase `i` (label), NaN if never visited.
    pub fn mean(&self, label: usize) -> f64 {
        self.durations[label].mean()
    }

    /// Fraction of tracked time spent in phase `label` (Lemma 1's m_i,
    /// relative to time covered by labels 1..=4).
    pub fn fraction(&self, label: usize) -> f64 {
        let tot: f64 = self.total_time.iter().sum();
        if tot <= 0.0 {
            f64::NAN
        } else {
            self.total_time[label] / tot
        }
    }
}

impl Default for PhaseStats {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_runs() {
        let mut p = PhaseStats::new();
        p.observe(0.0, 1);
        p.observe(2.0, 2); // phase 1 lasted 2
        p.observe(3.0, 2); // no-op
        p.observe(6.0, 1); // phase 2 lasted 4
        p.finish(7.0); // phase 1 lasted 1
        assert_eq!(p.visits[1], 2);
        assert_eq!(p.visits[2], 1);
        assert!((p.mean(1) - 1.5).abs() < 1e-12);
        assert!((p.mean(2) - 4.0).abs() < 1e-12);
        assert!((p.fraction(2) - 4.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn label_zero_ignored() {
        let mut p = PhaseStats::new();
        p.observe(0.0, 0);
        p.observe(1.0, 1);
        p.observe(2.0, 0);
        p.finish(5.0);
        assert_eq!(p.visits[1], 1);
        assert!((p.mean(1) - 1.0).abs() < 1e-12);
    }
}
