//! Pluggable event-timing structures.
//!
//! The engine's event loop is written against [`EventSchedule`] — the
//! minimal contract a future-event set must honor — with two
//! implementations behind the [`Schedule`] dispatcher:
//!
//! * [`EventQueue`] — the indexed 4-ary min-heap (O(log n) push/pop,
//!   O(log n) cancel-in-place), the reference implementation;
//! * [`LadderQueue`](crate::sim::ladder::LadderQueue) — a two-level
//!   hierarchical calendar ("ladder") queue with O(1) amortized
//!   push/pop/cancel, the default since this structure landed.
//!
//! **Contract.** Both implementations pop in the identical total order
//! on `(t, seq)` — time ascending, equal times in push (FIFO) order via
//! the monotone per-queue sequence number — and both keep an O(1)
//! job-slot → location map so `cancel_departure` / `has_departure` are
//! exact. Because the engine's trajectory is a pure function of pop
//! order, heap and ladder runs are **bit-identical** end to end; the
//! differential replay in `tests/prop_events.rs` enforces this on
//! random interleavings and on full fig5/fig6-shaped engine runs.
//!
//! Selection: [`SimConfig::event_schedule`](crate::sim::SimConfig)
//! (`None` follows the process default) with the `QS_EVENT_SCHEDULE`
//! environment escape hatch (`heap` | `ladder`; unset = ladder).

use crate::policy::JobId;
use crate::sim::events::{Event, EventKind, EventQueue};
use crate::sim::ladder::LadderQueue;

/// The future-event-set contract shared by the heap and the ladder.
///
/// `peek_t` takes `&mut self` because the ladder refills its sorted
/// bottom rung lazily; the heap ignores the mutability.
pub trait EventSchedule {
    fn push(&mut self, t: f64, kind: EventKind);
    /// Time of the earliest event without popping it.
    fn peek_t(&mut self) -> Option<f64>;
    fn pop(&mut self) -> Option<Event>;
    /// Remove `job`'s departure event in place; false if none scheduled.
    fn cancel_departure(&mut self, job: JobId) -> bool;
    /// True iff `job` currently has a scheduled departure.
    fn has_departure(&self, job: JobId) -> bool;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Drop all events and reset the sequence counter (engine reuse).
    fn clear(&mut self);
}

impl EventSchedule for EventQueue {
    #[inline]
    fn push(&mut self, t: f64, kind: EventKind) {
        EventQueue::push(self, t, kind)
    }

    #[inline]
    fn peek_t(&mut self) -> Option<f64> {
        EventQueue::peek_t(self)
    }

    #[inline]
    fn pop(&mut self) -> Option<Event> {
        EventQueue::pop(self)
    }

    fn cancel_departure(&mut self, job: JobId) -> bool {
        EventQueue::cancel_departure(self, job)
    }

    #[inline]
    fn has_departure(&self, job: JobId) -> bool {
        EventQueue::has_departure(self, job)
    }

    fn len(&self) -> usize {
        EventQueue::len(self)
    }

    fn clear(&mut self) {
        EventQueue::clear(self)
    }
}

/// Which timing structure the engine runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventScheduleKind {
    /// Indexed 4-ary min-heap (the reference structure).
    Heap,
    /// Two-level hierarchical calendar queue (the default).
    Ladder,
}

impl EventScheduleKind {
    /// Process-wide default: `QS_EVENT_SCHEDULE=heap|ladder` (unset or
    /// empty = ladder). Any other value panics — a typo must not
    /// silently select a structure.
    pub fn from_env() -> EventScheduleKind {
        match std::env::var("QS_EVENT_SCHEDULE").as_deref() {
            Ok("heap") => EventScheduleKind::Heap,
            Ok("ladder") | Ok("") | Err(_) => EventScheduleKind::Ladder,
            Ok(other) => panic!("QS_EVENT_SCHEDULE must be 'heap' or 'ladder', got '{other}'"),
        }
    }
}

/// Enum dispatcher over the two implementations: one predictable branch
/// per operation instead of a vtable load, and the engine stays a single
/// (non-generic) type.
pub enum Schedule {
    Heap(EventQueue),
    Ladder(LadderQueue),
}

impl Schedule {
    pub fn new(kind: EventScheduleKind) -> Schedule {
        match kind {
            EventScheduleKind::Heap => Schedule::Heap(EventQueue::new()),
            EventScheduleKind::Ladder => Schedule::Ladder(LadderQueue::new()),
        }
    }

    #[inline]
    pub fn push(&mut self, t: f64, kind: EventKind) {
        match self {
            Schedule::Heap(q) => q.push(t, kind),
            Schedule::Ladder(q) => q.push(t, kind),
        }
    }

    #[inline]
    pub fn peek_t(&mut self) -> Option<f64> {
        match self {
            Schedule::Heap(q) => q.peek_t(),
            Schedule::Ladder(q) => q.peek_t(),
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Event> {
        match self {
            Schedule::Heap(q) => q.pop(),
            Schedule::Ladder(q) => q.pop(),
        }
    }

    #[inline]
    pub fn cancel_departure(&mut self, job: JobId) -> bool {
        match self {
            Schedule::Heap(q) => q.cancel_departure(job),
            Schedule::Ladder(q) => q.cancel_departure(job),
        }
    }

    #[inline]
    pub fn has_departure(&self, job: JobId) -> bool {
        match self {
            Schedule::Heap(q) => q.has_departure(job),
            Schedule::Ladder(q) => q.has_departure(job),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Schedule::Heap(q) => q.len(),
            Schedule::Ladder(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&mut self) {
        match self {
            Schedule::Heap(q) => q.clear(),
            Schedule::Ladder(q) => q.clear(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatcher_round_trips_both_kinds() {
        for kind in [EventScheduleKind::Heap, EventScheduleKind::Ladder] {
            let mut s = Schedule::new(kind);
            assert!(s.is_empty());
            s.push(2.0, EventKind::Arrival);
            s.push(1.0, EventKind::Departure { job: 9 });
            assert_eq!(s.len(), 2);
            assert!(s.has_departure(9));
            assert_eq!(s.peek_t(), Some(1.0));
            assert!(s.cancel_departure(9));
            assert!(!s.has_departure(9));
            assert_eq!(s.pop().unwrap().t, 2.0);
            assert!(s.pop().is_none());
            s.push(5.0, EventKind::Arrival);
            s.clear();
            assert!(s.is_empty());
        }
    }
}
