//! Job storage: a **structure-of-arrays** generational slab keyed by
//! `JobId`, plus the intrusive lists the engine's hot path walks.
//!
//! The engine keeps every job in the system (queued or running) here;
//! slots are recycled after departure so memory is O(jobs in system),
//! not O(jobs simulated). Ids are *generational* — a `JobId` packs
//! (generation, slot) so an id that lingers in an index after its job
//! departed can never alias a new job occupying the same slot.
//!
//! Layout: the fields every policy consult touches (state/class/need/
//! remaining) live in their own dense arrays so a scheduling scan pulls
//! only the cache lines it needs; cold bookkeeping (arrival/started/
//! starts/generation/free-list) sits in separate arrays.
//!
//! Two intrusive doubly-linked lists replace the old tombstone deques:
//!
//! * the **arrival-order list** (links owned by `JobTable`, maintained by
//!   insert/remove) contains exactly the live jobs, oldest first — no
//!   tombstone pruning, no compaction heuristics;
//! * the per-class **waiting FIFOs** (`ClassFifos`) give O(1) push
//!   front/back *and O(1) removal at any position*, fixing the former
//!   O(n) `iter().position` scan for out-of-FIFO admissions (MSF-order
//!   and backfilling policies admit from the middle constantly).

use crate::policy::{ClassId, JobId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    /// Slot is free (job departed).
    Free,
}

const NIL: u32 = u32::MAX;

#[inline]
fn pack(gen: u32, slot: u32) -> JobId {
    ((gen as u64) << 32) | slot as u64
}

#[inline]
fn unpack(id: JobId) -> (u32, u32) {
    ((id >> 32) as u32, id as u32)
}

/// By-value copy of one job's fields (for cold paths: tests, the
/// real-time coordinator). Hot paths use the per-field accessors.
#[derive(Clone, Copy, Debug)]
pub struct JobSnapshot {
    pub class: ClassId,
    pub need: u32,
    /// Remaining service requirement (= full size until first run).
    pub remaining: f64,
    /// Absolute arrival time.
    pub arrival: f64,
    /// Time service (re)started; valid while Running.
    pub started: f64,
    pub state: JobState,
    /// Times this job has entered service. The real-time coordinator
    /// uses it to discard stale completion timers after a preemption;
    /// the DES engine needs no such token — it cancels departure events
    /// in place.
    pub starts: u32,
}

/// Generational SoA slab of jobs with O(1) insert/remove, safe id reuse,
/// and an intrusive arrival-order list.
pub struct JobTable {
    state: Vec<JobState>,
    class: Vec<u32>,
    need: Vec<u32>,
    remaining: Vec<f64>,
    arrival: Vec<f64>,
    started: Vec<f64>,
    starts: Vec<u32>,
    gen: Vec<u32>,
    next_free: Vec<u32>,
    ord_prev: Vec<u32>,
    ord_next: Vec<u32>,
    ord_head: u32,
    ord_tail: u32,
    free_head: u32,
    live: usize,
}

impl Default for JobTable {
    fn default() -> Self {
        Self::new()
    }
}

impl JobTable {
    pub fn new() -> Self {
        Self {
            state: Vec::new(),
            class: Vec::new(),
            need: Vec::new(),
            remaining: Vec::new(),
            arrival: Vec::new(),
            started: Vec::new(),
            starts: Vec::new(),
            gen: Vec::new(),
            next_free: Vec::new(),
            ord_prev: Vec::new(),
            ord_next: Vec::new(),
            ord_head: NIL,
            ord_tail: NIL,
            free_head: NIL,
            live: 0,
        }
    }

    /// The slab slot an id refers to (valid whether or not the id is
    /// still live). Pure function of the id.
    #[inline]
    pub fn slot_of(id: JobId) -> u32 {
        id as u32
    }

    /// Panics if the id is stale (generation mismatch).
    #[inline]
    fn slot_checked(&self, id: JobId) -> usize {
        let (gen, slot) = unpack(id);
        let i = slot as usize;
        assert!(self.gen[i] == gen, "stale JobId");
        i
    }

    pub fn insert(&mut self, class: ClassId, need: u32, size: f64, arrival: f64) -> JobId {
        self.live += 1;
        let slot = if self.free_head != NIL {
            let slot = self.free_head;
            let i = slot as usize;
            self.free_head = self.next_free[i];
            self.state[i] = JobState::Queued;
            self.class[i] = class as u32;
            self.need[i] = need;
            self.remaining[i] = size;
            self.arrival[i] = arrival;
            self.started[i] = f64::NAN;
            self.starts[i] = 0;
            self.gen[i] = self.gen[i].wrapping_add(1);
            self.next_free[i] = NIL;
            slot
        } else {
            self.state.push(JobState::Queued);
            self.class.push(class as u32);
            self.need.push(need);
            self.remaining.push(size);
            self.arrival.push(arrival);
            self.started.push(f64::NAN);
            self.starts.push(0);
            self.gen.push(0);
            self.next_free.push(NIL);
            self.ord_prev.push(NIL);
            self.ord_next.push(NIL);
            (self.state.len() - 1) as u32
        };
        // Link at the arrival-order tail.
        let i = slot as usize;
        self.ord_prev[i] = self.ord_tail;
        self.ord_next[i] = NIL;
        if self.ord_tail != NIL {
            self.ord_next[self.ord_tail as usize] = slot;
        } else {
            self.ord_head = slot;
        }
        self.ord_tail = slot;
        pack(self.gen[i], slot)
    }

    pub fn remove(&mut self, id: JobId) {
        let i = self.slot_checked(id);
        debug_assert!(self.state[i] != JobState::Free, "double remove");
        // Unlink from the arrival-order list.
        let (p, n) = (self.ord_prev[i], self.ord_next[i]);
        if p != NIL {
            self.ord_next[p as usize] = n;
        } else {
            self.ord_head = n;
        }
        if n != NIL {
            self.ord_prev[n as usize] = p;
        } else {
            self.ord_tail = p;
        }
        self.ord_prev[i] = NIL;
        self.ord_next[i] = NIL;
        self.state[i] = JobState::Free;
        self.next_free[i] = self.free_head;
        self.free_head = i as u32;
        self.live -= 1;
    }

    // ---- accessors (panic on stale ids, like the former `get`) ----

    #[inline]
    pub fn class(&self, id: JobId) -> ClassId {
        self.class[self.slot_checked(id)] as ClassId
    }

    #[inline]
    pub fn need(&self, id: JobId) -> u32 {
        self.need[self.slot_checked(id)]
    }

    #[inline]
    pub fn remaining(&self, id: JobId) -> f64 {
        self.remaining[self.slot_checked(id)]
    }

    #[inline]
    pub fn arrival(&self, id: JobId) -> f64 {
        self.arrival[self.slot_checked(id)]
    }

    #[inline]
    pub fn started(&self, id: JobId) -> f64 {
        self.started[self.slot_checked(id)]
    }

    #[inline]
    pub fn starts(&self, id: JobId) -> u32 {
        self.starts[self.slot_checked(id)]
    }

    #[inline]
    pub fn state(&self, id: JobId) -> JobState {
        self.state[self.slot_checked(id)]
    }

    /// By-value copy of every field (panics on stale ids).
    pub fn get(&self, id: JobId) -> JobSnapshot {
        let i = self.slot_checked(id);
        JobSnapshot {
            class: self.class[i] as ClassId,
            need: self.need[i],
            remaining: self.remaining[i],
            arrival: self.arrival[i],
            started: self.started[i],
            state: self.state[i],
            starts: self.starts[i],
        }
    }

    /// The live id occupying `slot` (debug-asserts liveness).
    #[inline]
    pub fn id_at(&self, slot: u32) -> JobId {
        debug_assert!(self.state[slot as usize] != JobState::Free);
        pack(self.gen[slot as usize], slot)
    }

    // ---- state transitions ----

    /// Queued → Running at time `now`; returns the new `starts` count.
    pub fn start_service(&mut self, id: JobId, now: f64) -> u32 {
        let i = self.slot_checked(id);
        assert_eq!(self.state[i], JobState::Queued, "starting a non-queued job");
        self.state[i] = JobState::Running;
        self.started[i] = now;
        self.starts[i] += 1;
        self.starts[i]
    }

    /// Running → Queued at time `now`, charging the elapsed service.
    pub fn preempt(&mut self, id: JobId, now: f64) {
        let i = self.slot_checked(id);
        assert_eq!(self.state[i], JobState::Running, "preempting non-running job");
        let rem = self.remaining[i] - (now - self.started[i]);
        debug_assert!(rem >= -1e-9);
        self.remaining[i] = rem.max(0.0);
        self.state[i] = JobState::Queued;
    }

    // ---- liveness queries (stale-safe, no panic) ----

    #[inline]
    fn state_of(&self, id: JobId) -> Option<JobState> {
        let (gen, slot) = unpack(id);
        match self.gen.get(slot as usize) {
            Some(&g) if g == gen => Some(self.state[slot as usize]),
            _ => None,
        }
    }

    #[inline]
    pub fn is_queued(&self, id: JobId) -> bool {
        self.state_of(id) == Some(JobState::Queued)
    }

    #[inline]
    pub fn is_running(&self, id: JobId) -> bool {
        self.state_of(id) == Some(JobState::Running)
    }

    /// True iff the id refers to a live (queued or running) job.
    #[inline]
    pub fn in_system(&self, id: JobId) -> bool {
        matches!(
            self.state_of(id),
            Some(JobState::Queued) | Some(JobState::Running)
        )
    }

    /// Visit live jobs oldest-arrival-first; `f` returns false to stop.
    /// The `bool` argument flags jobs currently in service.
    pub fn for_each_in_order(&self, f: &mut dyn FnMut(JobId, ClassId, bool) -> bool) {
        let mut s = self.ord_head;
        while s != NIL {
            let i = s as usize;
            let next = self.ord_next[i];
            let running = self.state[i] == JobState::Running;
            if !f(pack(self.gen[i], s), self.class[i] as ClassId, running) {
                break;
            }
            s = next;
        }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn capacity(&self) -> usize {
        self.state.len()
    }

    /// Drop every job but retain all allocations (engine reuse). Old ids
    /// become invalid; generation counters restart, so a reset table is
    /// bit-for-bit equivalent to a freshly constructed one.
    pub fn clear(&mut self) {
        self.state.clear();
        self.class.clear();
        self.need.clear();
        self.remaining.clear();
        self.arrival.clear();
        self.started.clear();
        self.starts.clear();
        self.gen.clear();
        self.next_free.clear();
        self.ord_prev.clear();
        self.ord_next.clear();
        self.ord_head = NIL;
        self.ord_tail = NIL;
        self.free_head = NIL;
        self.live = 0;
    }
}

/// Per-class waiting-job FIFOs as intrusive doubly-linked lists over job
/// slots. All of push_front / push_back / remove-anywhere are O(1); the
/// lists contain exactly the queued jobs (no tombstones), so iteration
/// needs no liveness filtering.
pub struct ClassFifos {
    head: Vec<u32>,
    tail: Vec<u32>,
    prev: Vec<u32>,
    next: Vec<u32>,
}

impl ClassFifos {
    pub fn new(num_classes: usize) -> Self {
        Self {
            head: vec![NIL; num_classes],
            tail: vec![NIL; num_classes],
            prev: Vec::new(),
            next: Vec::new(),
        }
    }

    #[inline]
    fn ensure(&mut self, slot: u32) {
        let n = slot as usize + 1;
        if self.prev.len() < n {
            self.prev.resize(n, NIL);
            self.next.resize(n, NIL);
        }
    }

    pub fn push_back(&mut self, class: ClassId, slot: u32) {
        self.ensure(slot);
        let i = slot as usize;
        debug_assert!(self.prev[i] == NIL && self.next[i] == NIL);
        self.prev[i] = self.tail[class];
        self.next[i] = NIL;
        if self.tail[class] != NIL {
            self.next[self.tail[class] as usize] = slot;
        } else {
            self.head[class] = slot;
        }
        self.tail[class] = slot;
    }

    pub fn push_front(&mut self, class: ClassId, slot: u32) {
        self.ensure(slot);
        let i = slot as usize;
        debug_assert!(self.prev[i] == NIL && self.next[i] == NIL);
        self.next[i] = self.head[class];
        self.prev[i] = NIL;
        if self.head[class] != NIL {
            self.prev[self.head[class] as usize] = slot;
        } else {
            self.tail[class] = slot;
        }
        self.head[class] = slot;
    }

    /// Unlink `slot` from its class list — O(1) at any position.
    pub fn remove(&mut self, class: ClassId, slot: u32) {
        let i = slot as usize;
        let (p, n) = (self.prev[i], self.next[i]);
        if p != NIL {
            self.next[p as usize] = n;
        } else {
            debug_assert_eq!(self.head[class], slot, "removing unlinked slot");
            self.head[class] = n;
        }
        if n != NIL {
            self.prev[n as usize] = p;
        } else {
            self.tail[class] = p;
        }
        self.prev[i] = NIL;
        self.next[i] = NIL;
    }

    /// Oldest waiting slot of `class`, if any.
    #[inline]
    pub fn head_slot(&self, class: ClassId) -> Option<u32> {
        let h = self.head[class];
        if h == NIL {
            None
        } else {
            Some(h)
        }
    }

    /// Front-to-back slot iterator for `class`.
    pub fn iter(&self, class: ClassId) -> FifoIter<'_> {
        FifoIter {
            next: &self.next,
            cur: self.head[class],
        }
    }

    /// Empty all lists, retaining allocations.
    pub fn clear(&mut self) {
        for h in &mut self.head {
            *h = NIL;
        }
        for t in &mut self.tail {
            *t = NIL;
        }
        for p in &mut self.prev {
            *p = NIL;
        }
        for n in &mut self.next {
            *n = NIL;
        }
    }
}

pub struct FifoIter<'a> {
    next: &'a [u32],
    cur: u32,
}

impl Iterator for FifoIter<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.cur == NIL {
            return None;
        }
        let s = self.cur;
        self.cur = self.next[s as usize];
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_reuse() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        let b = t.insert(1, 2, 2.0, 0.1);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(b).need, 2);
        t.remove(a);
        assert_eq!(t.len(), 1);
        assert!(!t.in_system(a));
        // Freed slot is reused under a NEW generation.
        let c = t.insert(2, 4, 3.0, 0.2);
        assert_ne!(c, a, "generational ids must not alias");
        assert_eq!(c as u32, a as u32, "slot is reused");
        assert_eq!(t.get(c).class, 2);
        assert_eq!(t.capacity(), 2);
    }

    #[test]
    fn stale_ids_are_dead() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        t.remove(a);
        let _b = t.insert(0, 1, 1.0, 0.5);
        // The stale id must read as not-in-system even though the slot
        // now holds a live job.
        assert!(!t.in_system(a));
        assert!(!t.is_queued(a));
    }

    #[test]
    fn arrival_order_list_tracks_liveness() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        let b = t.insert(1, 1, 1.0, 0.1);
        let c = t.insert(0, 1, 1.0, 0.2);
        t.remove(b);
        let mut seen = Vec::new();
        t.for_each_in_order(&mut |id, _, _| {
            seen.push(id);
            true
        });
        assert_eq!(seen, vec![a, c]);
        // Slot reuse appends at the tail (new arrival = youngest).
        let d = t.insert(2, 1, 1.0, 0.3);
        seen.clear();
        t.for_each_in_order(&mut |id, _, _| {
            seen.push(id);
            true
        });
        assert_eq!(seen, vec![a, c, d]);
    }

    #[test]
    fn service_transitions_track_remaining() {
        let mut t = JobTable::new();
        let a = t.insert(0, 2, 5.0, 0.0);
        assert_eq!(t.start_service(a, 1.0), 1);
        assert_eq!(t.state(a), JobState::Running);
        t.preempt(a, 3.0);
        assert_eq!(t.state(a), JobState::Queued);
        assert!((t.remaining(a) - 3.0).abs() < 1e-12);
        assert_eq!(t.start_service(a, 4.0), 2);
    }

    #[test]
    fn clear_is_like_fresh() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        t.remove(a);
        t.insert(1, 1, 1.0, 0.1);
        t.clear();
        assert!(t.is_empty());
        let b = t.insert(3, 2, 9.0, 0.0);
        let fresh = JobTable::new().insert(3, 2, 9.0, 0.0);
        assert_eq!(b, fresh, "reset table must mint the same ids as a fresh one");
    }

    #[test]
    fn fifo_removal_any_position() {
        let mut f = ClassFifos::new(2);
        for s in 0..5u32 {
            f.push_back(0, s);
        }
        f.remove(0, 2); // middle
        f.remove(0, 0); // head
        f.remove(0, 4); // tail
        let left: Vec<u32> = f.iter(0).collect();
        assert_eq!(left, vec![1, 3]);
        f.push_front(0, 7);
        assert_eq!(f.head_slot(0), Some(7));
        let left: Vec<u32> = f.iter(0).collect();
        assert_eq!(left, vec![7, 1, 3]);
        assert!(f.iter(1).next().is_none());
    }
}
