//! Job storage: a **structure-of-arrays** generational slab keyed by
//! `JobId`, plus the intrusive lists the engine's hot path walks.
//!
//! The engine keeps every job in the system (queued or running) here;
//! slots are recycled after departure so memory is O(jobs in system),
//! not O(jobs simulated). Ids are *generational* — a `JobId` packs
//! (generation, slot) so an id that lingers in an index after its job
//! departed can never alias a new job occupying the same slot.
//!
//! Layout: the fields every policy consult touches (state/class/need/
//! remaining) live in their own dense arrays so a scheduling scan pulls
//! only the cache lines it needs; cold bookkeeping (arrival/started/
//! starts/generation/free-list) sits in separate arrays.
//!
//! Two intrusive doubly-linked lists replace the old tombstone deques:
//!
//! * the **arrival-order list** (links owned by `JobTable`, maintained by
//!   insert/remove) contains exactly the live jobs, oldest first — no
//!   tombstone pruning, no compaction heuristics;
//! * the per-class **waiting FIFOs** (`ClassFifos`) give O(1) push
//!   front/back *and O(1) removal at any position*, fixing the former
//!   O(n) `iter().position` scan for out-of-FIFO admissions (MSF-order
//!   and backfilling policies admit from the middle constantly).

use crate::policy::{ClassId, JobId};
use crate::workload::ResourceVec;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    /// Slot is free (job departed).
    Free,
}

/// Fenwick (binary indexed) tree of u32 counts over class ranks — the
/// O(log C) substrate of [`QueueIndex`]. Internally 1-indexed; the
/// public API is 0-indexed.
#[derive(Debug, Default)]
pub struct Fenwick {
    tree: Vec<u32>,
}

impl Fenwick {
    pub fn new(n: usize) -> Fenwick {
        Fenwick {
            tree: vec![0; n + 1],
        }
    }

    fn len(&self) -> usize {
        self.tree.len() - 1
    }

    pub fn clear(&mut self) {
        self.tree.fill(0);
    }

    #[inline]
    pub fn inc(&mut self, i: usize) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] += 1;
            i += i & i.wrapping_neg();
        }
    }

    #[inline]
    pub fn dec(&mut self, i: usize) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] -= 1;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of the first `n` entries (indices 0..n).
    #[inline]
    pub fn prefix(&self, n: usize) -> u32 {
        let mut i = n.min(self.len());
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Smallest 0-based index `r` with `prefix(r + 1) >= k`; requires
    /// `1 <= k <= prefix(len)`.
    #[inline]
    pub fn select(&self, mut k: u32) -> usize {
        debug_assert!(k >= 1);
        let mut pos = 0usize;
        let mut pw = self.len().next_power_of_two();
        while pw > 0 {
            let npos = pos + pw;
            if npos < self.tree.len() && self.tree[npos] < k {
                k -= self.tree[npos];
                pos = npos;
            }
            pw >>= 1;
        }
        pos
    }
}

/// Fenwick tree of u64 **sums** over class ranks — the need-weighted
/// twin of [`Fenwick`]: where that one counts queued jobs per rank,
/// this one accumulates their total server need, so prefix queries
/// answer "how many servers' worth of queued work fits below this
/// rank" in O(log C). Internally 1-indexed; the public API is
/// 0-indexed.
#[derive(Debug, Default)]
pub struct FenwickSum {
    tree: Vec<u64>,
}

impl FenwickSum {
    pub fn new(n: usize) -> FenwickSum {
        FenwickSum {
            tree: vec![0; n + 1],
        }
    }

    pub fn clear(&mut self) {
        self.tree.fill(0);
    }

    #[inline]
    pub fn add(&mut self, i: usize, w: u64) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] += w;
            i += i & i.wrapping_neg();
        }
    }

    #[inline]
    pub fn sub(&mut self, i: usize, w: u64) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] -= w;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of the first `n` entries (indices 0..n).
    #[inline]
    pub fn prefix(&self, n: usize) -> u64 {
        let mut i = n.min(self.tree.len() - 1);
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Indexed summary of the queue state, maintained by the event driver
/// (engine / harness) in O(log C) per transition and consulted by every
/// policy instead of O(C) scans:
///
/// * classes are ranked by **(need ascending, class id descending)**, so
///   a descending-rank walk visits classes in exactly the MSF admission
///   order (need descending, ties by ascending class id — the stable
///   `sort_by_key(Reverse(need))` order the policies used before);
/// * a [`Fenwick`] tree over ranks holds per-class queued counts, giving
///   "smallest queued need" (the exact fit watermark shared by
///   FCFS / First-Fit / MSF / AdaptiveQS) and "largest queued class
///   fitting in `free` servers" in O(log C);
/// * O(1) counters track totals and the class-status sets behind
///   AdaptiveQS's §4.4 quickswap trigger: `starving` (queued > 0,
///   running = 0) and `backlogged` (queued > 0, running > 0).
///
/// Because the driver applies every state delta to the index *before*
/// the post-event consult, these queries are **exact** at consult time —
/// unlike the former conservative watermarks, they stay exact across
/// admission batches and need no reset on swap epochs.
///
/// **Multiresource (d > 1) generalization — the dominance index.** Under
/// the vector model a job fits iff its whole demand vector is dominated
/// by the free vector. The scalar structures above stay authoritative
/// for dimension 0 (servers), and each extra dimension gets its own
/// rank order + Fenwick count tree. Vector queries then compose:
///
/// * **quick rejection** is exact — if any dimension's fitting count is
///   zero (`prefix` over that dimension's ranks), no queued job can fit,
///   no scan needed;
/// * otherwise the query falls back to an exact O(C) scan over classes
///   with queued jobs (C ≤ 26 in every shipped workload), so every
///   consult-skip predicate stays **exact**, never conservative.
///
/// At d=1 the vector side is empty and every query routes through the
/// unchanged scalar path — d=1 is bit-identical to the scalar model by
/// construction (differential goldens in `tests/prop_dominance.rs`).
#[derive(Debug, Default)]
pub struct QueueIndex {
    /// Class need per class id (dimension-0 projection of `demands`).
    needs: Vec<u32>,
    /// Full per-class demand vectors.
    demands: Vec<ResourceVec>,
    /// Resource dimensions (1 = scalar model).
    dims: usize,
    /// class id -> rank in (need asc, class id desc) order.
    rank_of: Vec<u32>,
    /// rank -> class id.
    class_of_rank: Vec<u32>,
    /// rank -> need (ascending in rank).
    need_of_rank: Vec<u32>,
    /// Queued counts per rank.
    tree: Fenwick,
    /// Queued **need sums** per rank (the need-weighted Fenwick): bounds
    /// First-Fit's arrival-order scan by the total fitting mass.
    wtree: FenwickSum,
    /// Per extra dimension j in 1..dims: class id -> rank in
    /// (demand_j asc, class id desc) order. Empty at d=1.
    dim_rank_of: Vec<Vec<u32>>,
    /// Per extra dimension: rank -> demand_j (ascending in rank).
    dim_need_of_rank: Vec<Vec<u32>>,
    /// Per extra dimension: queued counts per rank.
    dim_tree: Vec<Fenwick>,
    /// Per-class queued / running mirrors (authoritative for the index).
    queued: Vec<u32>,
    running: Vec<u32>,
    total_queued: u32,
    total_running: u32,
    /// Classes with queued > 0 && running == 0.
    starving: u32,
    /// Classes with queued > 0 && running > 0.
    backlogged: u32,
}

impl QueueIndex {
    /// Scalar (servers-only) index — the original model.
    pub fn new(needs: &[u32]) -> QueueIndex {
        let demands: Vec<ResourceVec> = needs.iter().map(|&n| ResourceVec::scalar(n)).collect();
        QueueIndex::with_demands(&demands)
    }

    /// Index over full demand vectors (all classes share a dimension
    /// count). At d=1 this is exactly [`QueueIndex::new`].
    pub fn with_demands(demands: &[ResourceVec]) -> QueueIndex {
        let dims = demands.first().map_or(1, |d| d.dims());
        debug_assert!(demands.iter().all(|d| d.dims() == dims));
        let needs: Vec<u32> = demands.iter().map(|d| d.servers()).collect();
        let mut ranks: Vec<usize> = (0..needs.len()).collect();
        ranks.sort_by_key(|&c| (needs[c], std::cmp::Reverse(c)));
        let mut rank_of = vec![0u32; needs.len()];
        for (r, &c) in ranks.iter().enumerate() {
            rank_of[c] = r as u32;
        }
        // Per extra dimension: the same (demand asc, class id desc)
        // ranking keyed on that dimension's component.
        let mut dim_rank_of = Vec::new();
        let mut dim_need_of_rank = Vec::new();
        let mut dim_tree = Vec::new();
        for j in 1..dims {
            let mut dranks: Vec<usize> = (0..demands.len()).collect();
            dranks.sort_by_key(|&c| (demands[c].get(j), std::cmp::Reverse(c)));
            let mut dr_of = vec![0u32; demands.len()];
            for (r, &c) in dranks.iter().enumerate() {
                dr_of[c] = r as u32;
            }
            dim_rank_of.push(dr_of);
            dim_need_of_rank.push(dranks.iter().map(|&c| demands[c].get(j)).collect());
            dim_tree.push(Fenwick::new(demands.len()));
        }
        QueueIndex {
            needs,
            demands: demands.to_vec(),
            dims,
            rank_of,
            need_of_rank: ranks.iter().map(|&c| demands[c].servers()).collect(),
            class_of_rank: ranks.iter().map(|&c| c as u32).collect(),
            tree: Fenwick::new(demands.len()),
            wtree: FenwickSum::new(demands.len()),
            dim_rank_of,
            dim_need_of_rank,
            dim_tree,
            queued: vec![0; demands.len()],
            running: vec![0; demands.len()],
            total_queued: 0,
            total_running: 0,
            starving: 0,
            backlogged: 0,
        }
    }

    /// Empty the index (all counts zero), retaining the rank tables.
    pub fn clear(&mut self) {
        self.tree.clear();
        self.wtree.clear();
        for t in &mut self.dim_tree {
            t.clear();
        }
        self.queued.fill(0);
        self.running.fill(0);
        self.total_queued = 0;
        self.total_running = 0;
        self.starving = 0;
        self.backlogged = 0;
    }

    #[inline]
    fn status_delta(starving: &mut u32, backlogged: &mut u32, q: u32, r: u32, on: bool) {
        let d: i32 = if on { 1 } else { -1 };
        if q > 0 && r == 0 {
            *starving = starving.wrapping_add_signed(d);
        } else if q > 0 {
            *backlogged = backlogged.wrapping_add_signed(d);
        }
    }

    /// Apply a (queued, running) delta to class `c`, keeping every
    /// derived structure in sync.
    #[inline]
    fn apply(&mut self, c: ClassId, dq: i32, dr: i32) {
        let (q, r) = (self.queued[c], self.running[c]);
        Self::status_delta(&mut self.starving, &mut self.backlogged, q, r, false);
        let (nq, nr) = (q.wrapping_add_signed(dq), r.wrapping_add_signed(dr));
        self.queued[c] = nq;
        self.running[c] = nr;
        Self::status_delta(&mut self.starving, &mut self.backlogged, nq, nr, true);
        match dq {
            1 => {
                self.tree.inc(self.rank_of[c] as usize);
                self.wtree.add(self.rank_of[c] as usize, self.needs[c] as u64);
                for (j, t) in self.dim_tree.iter_mut().enumerate() {
                    t.inc(self.dim_rank_of[j][c] as usize);
                }
                self.total_queued += 1;
            }
            -1 => {
                self.tree.dec(self.rank_of[c] as usize);
                self.wtree.sub(self.rank_of[c] as usize, self.needs[c] as u64);
                for (j, t) in self.dim_tree.iter_mut().enumerate() {
                    t.dec(self.dim_rank_of[j][c] as usize);
                }
                self.total_queued -= 1;
            }
            _ => {}
        }
        self.total_running = self.total_running.wrapping_add_signed(dr);
    }

    /// A job of class `c` joined the waiting queue (arrival).
    pub fn on_enqueue(&mut self, c: ClassId) {
        self.apply(c, 1, 0);
    }

    /// A queued job of class `c` entered service.
    pub fn on_admit(&mut self, c: ClassId) {
        self.apply(c, -1, 1);
    }

    /// A running job of class `c` completed and left the system.
    pub fn on_depart(&mut self, c: ClassId) {
        self.apply(c, 0, -1);
    }

    /// A running job of class `c` was preempted back into the queue.
    pub fn on_preempt(&mut self, c: ClassId) {
        self.apply(c, 1, -1);
    }

    // ---- O(1) / O(log C) queries ----

    pub fn num_ranks(&self) -> usize {
        self.need_of_rank.len()
    }

    #[inline]
    pub fn class_at_rank(&self, r: usize) -> ClassId {
        self.class_of_rank[r] as ClassId
    }

    #[inline]
    pub fn need_at_rank(&self, r: usize) -> u32 {
        self.need_of_rank[r]
    }

    #[inline]
    pub fn queued_of(&self, c: ClassId) -> u32 {
        self.queued[c]
    }

    #[inline]
    pub fn running_of(&self, c: ClassId) -> u32 {
        self.running[c]
    }

    #[inline]
    pub fn queued_total(&self) -> u32 {
        self.total_queued
    }

    #[inline]
    pub fn running_total(&self) -> u32 {
        self.total_running
    }

    /// Jobs in system (queued + running) across classes.
    #[inline]
    pub fn total_live(&self) -> u32 {
        self.total_queued + self.total_running
    }

    /// Smallest need among classes with a queued job (`u32::MAX` when
    /// nothing is queued) — the **exact** admit-possible watermark for
    /// fit-based policies: no consult can admit while `free` is below it.
    #[inline]
    pub fn min_queued_need(&self) -> u32 {
        if self.total_queued == 0 {
            u32::MAX
        } else {
            self.need_of_rank[self.tree.select(1)]
        }
    }

    /// Total server need of queued jobs whose class need fits in `free`
    /// servers — the need-weighted Fenwick prefix, O(log C). Zero iff
    /// nothing queued fits, so it doubles as the exact fit predicate;
    /// its main use is bounding First-Fit's arrival-order scan (the
    /// scan can stop once it has seen this much fitting mass — any job
    /// it has not visited then needs more than `free` servers).
    #[inline]
    pub fn queued_need_fitting(&self, free: u32) -> u64 {
        let hi = self.need_of_rank.partition_point(|&n| n <= free);
        self.wtree.prefix(hi)
    }

    /// Total server need across all queued jobs, O(log C).
    #[inline]
    pub fn queued_need_total(&self) -> u64 {
        self.wtree.prefix(self.num_ranks())
    }

    /// Largest rank `< bound` with a queued job and need ≤ `free`.
    /// Walking `bound` downward through successive answers visits
    /// classes in MSF admission order, skipping empty ones in O(log C).
    #[inline]
    pub fn max_fitting_rank_below(&self, bound: usize, free: u32) -> Option<usize> {
        let hi = self.need_of_rank.partition_point(|&n| n <= free).min(bound);
        let cnt = self.tree.prefix(hi);
        if cnt == 0 {
            None
        } else {
            Some(self.tree.select(cnt))
        }
    }

    /// Largest-need class with a queued job (ties: smallest class id),
    /// irrespective of fit — AdaptiveQS's drain target.
    #[inline]
    pub fn max_queued_class(&self) -> Option<ClassId> {
        self.max_fitting_rank_below(self.num_ranks(), u32::MAX)
            .map(|r| self.class_at_rank(r))
    }

    /// True iff class `c` could start a job right now: something queued
    /// and its need fits in `free` servers.
    #[inline]
    pub fn can_admit(&self, c: ClassId, free: u32) -> bool {
        self.queued[c] > 0 && self.needs[c] <= free
    }

    /// AdaptiveQS's §4.4 quickswap trigger, O(1): some class is starving
    /// (queued with nothing in service) while no in-service class has
    /// backlog.
    #[inline]
    pub fn swap_trigger(&self) -> bool {
        self.starving > 0 && self.backlogged == 0
    }

    // ---- dominance index: vector-fit queries (exact at every d) ----

    /// Resource dimensions this index was built over (1 = scalar).
    #[inline]
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Class `c`'s full demand vector.
    #[inline]
    pub fn demand_of(&self, c: ClassId) -> ResourceVec {
        self.demands[c]
    }

    /// Count of queued jobs whose dimension-`j` demand is ≤ `bound` —
    /// the per-dimension Fenwick prefix, O(log C). Exact for every
    /// dimension; the conjunction over dimensions upper-bounds (but does
    /// not equal) the vector-fitting count, which is what makes it a
    /// *rejection* certificate: any dimension at zero proves no fit.
    #[inline]
    pub fn dim_queued_fitting(&self, j: usize, bound: u32) -> u32 {
        if j == 0 {
            let hi = self.need_of_rank.partition_point(|&n| n <= bound);
            self.tree.prefix(hi)
        } else {
            let hi = self.dim_need_of_rank[j - 1].partition_point(|&n| n <= bound);
            self.dim_tree[j - 1].prefix(hi)
        }
    }

    /// True iff some dimension proves no queued job fits in `free`
    /// (fitting count 0 there). A `false` is inconclusive at d > 1; the
    /// exact scans below resolve it.
    #[inline]
    fn rejected_by_some_dim(&self, free: &ResourceVec) -> bool {
        (0..self.dims).any(|j| self.dim_queued_fitting(j, free.get(j)) == 0)
    }

    /// True iff some queued job's whole demand vector fits in `free` —
    /// the exact admit-possible predicate of the vector model. At d=1
    /// this is exactly `min_queued_need() <= free` (the scalar
    /// watermark); at d > 1 it quick-rejects per dimension, then scans
    /// the ≤ C queued classes.
    #[inline]
    pub fn queued_demand_fits(&self, free: &ResourceVec) -> bool {
        if self.dims == 1 {
            return self.min_queued_need() <= free.servers();
        }
        if self.rejected_by_some_dim(free) {
            return false;
        }
        self.demands
            .iter()
            .zip(&self.queued)
            .any(|(d, &q)| q > 0 && d.fits_in(free))
    }

    /// Smallest server need among queued classes whose whole demand
    /// vector fits in `free` (`None` when nothing fits) — the
    /// min-queued-dominated query generalizing [`Self::min_queued_need`].
    pub fn min_queued_dominated(&self, free: &ResourceVec) -> Option<u32> {
        if self.dims == 1 {
            let min = self.min_queued_need();
            return (min <= free.servers()).then_some(min);
        }
        if self.rejected_by_some_dim(free) {
            return None;
        }
        self.demands
            .iter()
            .zip(&self.queued)
            .filter(|(d, &q)| q > 0 && d.fits_in(free))
            .map(|(d, _)| d.servers())
            .min()
    }

    /// Total **server** need of queued jobs whose whole demand vector
    /// fits in `free` — the fitting mass generalizing
    /// [`Self::queued_need_fitting`], to which it is identical at d=1.
    /// Zero iff nothing queued fits (the exact fit predicate); its main
    /// use is bounding First-Fit's arrival-order scan.
    pub fn queued_mass_fitting(&self, free: &ResourceVec) -> u64 {
        if self.dims == 1 {
            return self.queued_need_fitting(free.servers());
        }
        if self.rejected_by_some_dim(free) {
            return 0;
        }
        self.demands
            .iter()
            .zip(&self.queued)
            .filter(|(d, &q)| q > 0 && d.fits_in(free))
            .map(|(d, &q)| d.servers() as u64 * q as u64)
            .sum()
    }

    /// Largest rank `< bound` with a queued job whose whole demand
    /// vector fits in `free` — the vector twin of
    /// [`Self::max_fitting_rank_below`] (identical at d=1), so the MSF
    /// descending-rank walk survives the vector model unchanged. At
    /// d > 1 the scalar Fenwick supplies dimension-0-fitting candidates
    /// in descending rank order and each is checked for full dominance —
    /// at most C probes of O(log C).
    pub fn max_dominated_rank_below(&self, bound: usize, free: &ResourceVec) -> Option<usize> {
        if self.dims == 1 {
            return self.max_fitting_rank_below(bound, free.servers());
        }
        if self.rejected_by_some_dim(free) {
            return None;
        }
        let mut bound = bound;
        while let Some(r) = self.max_fitting_rank_below(bound, free.servers()) {
            if self.demands[self.class_at_rank(r)].fits_in(free) {
                return Some(r);
            }
            bound = r;
        }
        None
    }

    /// True iff class `c` could start a job right now under the vector
    /// model: something queued and its whole demand fits in `free`.
    /// Identical to [`Self::can_admit`] at d=1.
    #[inline]
    pub fn can_admit_vec(&self, c: ClassId, free: &ResourceVec) -> bool {
        if self.dims == 1 {
            return self.can_admit(c, free.servers());
        }
        self.queued[c] > 0 && self.demands[c].fits_in(free)
    }

    /// Debug-build consistency check against the driver's own counts.
    pub fn assert_consistent(&self, queued: &[u32], running: &[u32]) {
        debug_assert_eq!(self.queued, queued, "index queued counts diverged");
        debug_assert_eq!(self.running, running, "index running counts diverged");
        debug_assert_eq!(
            self.tree.prefix(self.num_ranks()),
            self.total_queued,
            "Fenwick total diverged"
        );
        debug_assert_eq!(
            self.queued_need_total(),
            queued
                .iter()
                .zip(&self.needs)
                .map(|(&q, &n)| q as u64 * n as u64)
                .sum::<u64>(),
            "weighted Fenwick total diverged"
        );
        for (j, t) in self.dim_tree.iter().enumerate() {
            debug_assert_eq!(
                t.prefix(self.num_ranks()),
                self.total_queued,
                "dimension-{} Fenwick total diverged",
                j + 1
            );
        }
    }
}

const NIL: u32 = u32::MAX;

#[inline]
fn pack(gen: u32, slot: u32) -> JobId {
    ((gen as u64) << 32) | slot as u64
}

#[inline]
fn unpack(id: JobId) -> (u32, u32) {
    ((id >> 32) as u32, id as u32)
}

/// By-value copy of one job's fields (for cold paths: tests, the
/// real-time coordinator). Hot paths use the per-field accessors.
#[derive(Clone, Copy, Debug)]
pub struct JobSnapshot {
    pub class: ClassId,
    pub need: u32,
    /// Remaining service requirement (= full size until first run).
    pub remaining: f64,
    /// Absolute arrival time.
    pub arrival: f64,
    /// Time service (re)started; valid while Running.
    pub started: f64,
    pub state: JobState,
    /// Times this job has entered service. The real-time coordinator
    /// uses it to discard stale completion timers after a preemption;
    /// the DES engine needs no such token — it cancels departure events
    /// in place.
    pub starts: u32,
}

/// Generational SoA slab of jobs with O(1) insert/remove, safe id reuse,
/// and an intrusive arrival-order list.
pub struct JobTable {
    state: Vec<JobState>,
    class: Vec<u32>,
    need: Vec<u32>,
    remaining: Vec<f64>,
    arrival: Vec<f64>,
    started: Vec<f64>,
    starts: Vec<u32>,
    gen: Vec<u32>,
    next_free: Vec<u32>,
    ord_prev: Vec<u32>,
    ord_next: Vec<u32>,
    /// Monotone arrival sequence per slot: compares arrival order in
    /// O(1) (slots are recycled, so slot order says nothing).
    ord_seq: Vec<u64>,
    next_ord_seq: u64,
    ord_head: u32,
    ord_tail: u32,
    /// Oldest **queued** job in arrival order — FCFS's head of line —
    /// or NIL when nothing waits. Maintained incrementally: an arrival
    /// into an empty queue sets it, admitting the HoL job advances it
    /// forward past in-service jobs (each slot is walked at most once
    /// per stay absent preemption, so amortized O(1)), and a
    /// preemption rewinds it by arrival-sequence comparison. This is
    /// the arrival-order-aware query the class-ranked [`QueueIndex`]
    /// cannot answer.
    hol: u32,
    free_head: u32,
    live: usize,

    // ---- incremental arrival-order prefix (ServerFilling) ----
    // The minimal prefix of the arrival-order list whose total need
    // reaches `pfx_threshold` (or the whole list while the total is
    // smaller), maintained O(1) amortized across insert/remove: arrivals
    // append to the prefix only while its total is short, and a removal
    // inside the prefix extends the end forward. The prefix end is
    // monotone in arrival order, so a membership flag per slot suffices.
    // `pfx_version` bumps exactly when membership changes — the basis of
    // ServerFilling's exact consult skip (the target service set is a
    // pure function of prefix membership).
    pfx_threshold: u64,
    pfx_total: u64,
    pfx_len: u32,
    pfx_end: u32,
    pfx_version: u64,
    in_pfx: Vec<bool>,
}

impl Default for JobTable {
    fn default() -> Self {
        Self::new()
    }
}

impl JobTable {
    pub fn new() -> Self {
        Self {
            state: Vec::new(),
            class: Vec::new(),
            need: Vec::new(),
            remaining: Vec::new(),
            arrival: Vec::new(),
            started: Vec::new(),
            starts: Vec::new(),
            gen: Vec::new(),
            next_free: Vec::new(),
            ord_prev: Vec::new(),
            ord_next: Vec::new(),
            ord_seq: Vec::new(),
            next_ord_seq: 0,
            ord_head: NIL,
            ord_tail: NIL,
            hol: NIL,
            free_head: NIL,
            live: 0,
            pfx_threshold: u64::MAX,
            pfx_total: 0,
            pfx_len: 0,
            pfx_end: NIL,
            pfx_version: 0,
            in_pfx: Vec::new(),
        }
    }

    /// Configure the arrival-order prefix threshold (the system's server
    /// count `k` for ServerFilling's "minimal prefix with total need
    /// ≥ k"). Must be set before any job is inserted; the default
    /// `u64::MAX` keeps the whole list in the prefix.
    pub fn set_prefix_threshold(&mut self, k: u64) {
        assert!(self.is_empty(), "prefix threshold must be set on an empty table");
        self.pfx_threshold = k;
    }

    /// Monotone counter bumped whenever prefix *membership* changes.
    #[inline]
    pub fn prefix_version(&self) -> u64 {
        self.pfx_version
    }

    /// Number of jobs in the arrival-order prefix.
    #[inline]
    pub fn prefix_len(&self) -> u32 {
        self.pfx_len
    }

    /// Total need of the prefix members.
    #[inline]
    pub fn prefix_total(&self) -> u64 {
        self.pfx_total
    }

    /// The slab slot an id refers to (valid whether or not the id is
    /// still live). Pure function of the id.
    #[inline]
    pub fn slot_of(id: JobId) -> u32 {
        id as u32
    }

    /// Panics if the id is stale (generation mismatch).
    #[inline]
    fn slot_checked(&self, id: JobId) -> usize {
        let (gen, slot) = unpack(id);
        let i = slot as usize;
        assert!(self.gen[i] == gen, "stale JobId");
        i
    }

    pub fn insert(&mut self, class: ClassId, need: u32, size: f64, arrival: f64) -> JobId {
        self.live += 1;
        let slot = if self.free_head != NIL {
            let slot = self.free_head;
            let i = slot as usize;
            self.free_head = self.next_free[i];
            self.state[i] = JobState::Queued;
            self.class[i] = class as u32;
            self.need[i] = need;
            self.remaining[i] = size;
            self.arrival[i] = arrival;
            self.started[i] = f64::NAN;
            self.starts[i] = 0;
            self.gen[i] = self.gen[i].wrapping_add(1);
            self.next_free[i] = NIL;
            slot
        } else {
            self.state.push(JobState::Queued);
            self.class.push(class as u32);
            self.need.push(need);
            self.remaining.push(size);
            self.arrival.push(arrival);
            self.started.push(f64::NAN);
            self.starts.push(0);
            self.gen.push(0);
            self.next_free.push(NIL);
            self.ord_prev.push(NIL);
            self.ord_next.push(NIL);
            self.ord_seq.push(0);
            self.in_pfx.push(false);
            (self.state.len() - 1) as u32
        };
        // Link at the arrival-order tail.
        let i = slot as usize;
        self.ord_seq[i] = self.next_ord_seq;
        self.next_ord_seq += 1;
        self.ord_prev[i] = self.ord_tail;
        self.ord_next[i] = NIL;
        if self.ord_tail != NIL {
            self.ord_next[self.ord_tail as usize] = slot;
        } else {
            self.ord_head = slot;
        }
        self.ord_tail = slot;
        // A new (queued) tail is HoL only when nothing else waits.
        if self.hol == NIL {
            self.hol = slot;
        }
        // A new tail job joins the prefix only while the prefix is short
        // of the threshold (it then is the minimal crossing element).
        if self.pfx_total < self.pfx_threshold {
            self.in_pfx[i] = true;
            self.pfx_total += need as u64;
            self.pfx_len += 1;
            self.pfx_end = slot;
            self.pfx_version += 1;
        }
        pack(self.gen[i], slot)
    }

    pub fn remove(&mut self, id: JobId) {
        let i = self.slot_checked(id);
        debug_assert!(self.state[i] != JobState::Free, "double remove");
        // HoL maintenance (engine removals target running jobs, which
        // are never HoL; be correct for direct queued removals anyway).
        if self.hol == i as u32 {
            self.advance_hol(self.ord_next[i]);
        }
        // Prefix bookkeeping, phase 1 (needs the links still intact):
        // drop the job from the prefix and back the end pointer off it.
        let was_pfx = self.in_pfx[i];
        if was_pfx {
            self.in_pfx[i] = false;
            self.pfx_total -= self.need[i] as u64;
            self.pfx_len -= 1;
            self.pfx_version += 1;
            if self.pfx_end == i as u32 {
                self.pfx_end = self.ord_prev[i];
            }
        }
        // Unlink from the arrival-order list.
        let (p, n) = (self.ord_prev[i], self.ord_next[i]);
        if p != NIL {
            self.ord_next[p as usize] = n;
        } else {
            self.ord_head = n;
        }
        if n != NIL {
            self.ord_prev[n as usize] = p;
        } else {
            self.ord_tail = p;
        }
        self.ord_prev[i] = NIL;
        self.ord_next[i] = NIL;
        self.state[i] = JobState::Free;
        self.next_free[i] = self.free_head;
        self.free_head = i as u32;
        self.live -= 1;
        // Phase 2: extend the prefix end forward until the total crosses
        // the threshold again (amortized O(1): every job enters the
        // prefix at most once per stay in the system).
        if was_pfx {
            while self.pfx_total < self.pfx_threshold {
                let next = if self.pfx_end == NIL {
                    self.ord_head
                } else {
                    self.ord_next[self.pfx_end as usize]
                };
                if next == NIL {
                    break;
                }
                let j = next as usize;
                self.in_pfx[j] = true;
                self.pfx_total += self.need[j] as u64;
                self.pfx_len += 1;
                self.pfx_end = next;
            }
        }
    }

    // ---- accessors (panic on stale ids, like the former `get`) ----

    #[inline]
    pub fn class(&self, id: JobId) -> ClassId {
        self.class[self.slot_checked(id)] as ClassId
    }

    #[inline]
    pub fn need(&self, id: JobId) -> u32 {
        self.need[self.slot_checked(id)]
    }

    #[inline]
    pub fn remaining(&self, id: JobId) -> f64 {
        self.remaining[self.slot_checked(id)]
    }

    #[inline]
    pub fn arrival(&self, id: JobId) -> f64 {
        self.arrival[self.slot_checked(id)]
    }

    #[inline]
    pub fn started(&self, id: JobId) -> f64 {
        self.started[self.slot_checked(id)]
    }

    #[inline]
    pub fn starts(&self, id: JobId) -> u32 {
        self.starts[self.slot_checked(id)]
    }

    #[inline]
    pub fn state(&self, id: JobId) -> JobState {
        self.state[self.slot_checked(id)]
    }

    /// By-value copy of every field (panics on stale ids).
    pub fn get(&self, id: JobId) -> JobSnapshot {
        let i = self.slot_checked(id);
        JobSnapshot {
            class: self.class[i] as ClassId,
            need: self.need[i],
            remaining: self.remaining[i],
            arrival: self.arrival[i],
            started: self.started[i],
            state: self.state[i],
            starts: self.starts[i],
        }
    }

    /// The live id occupying `slot` (debug-asserts liveness).
    #[inline]
    pub fn id_at(&self, slot: u32) -> JobId {
        debug_assert!(self.state[slot as usize] != JobState::Free);
        pack(self.gen[slot as usize], slot)
    }

    // ---- state transitions ----

    /// Queued → Running at time `now`; returns the new `starts` count.
    pub fn start_service(&mut self, id: JobId, now: f64) -> u32 {
        let i = self.slot_checked(id);
        assert_eq!(self.state[i], JobState::Queued, "starting a non-queued job");
        self.state[i] = JobState::Running;
        self.started[i] = now;
        self.starts[i] += 1;
        if self.hol == i as u32 {
            self.advance_hol(self.ord_next[i]);
        }
        self.starts[i]
    }

    /// Running → Queued at time `now`, charging the elapsed service.
    pub fn preempt(&mut self, id: JobId, now: f64) {
        let i = self.slot_checked(id);
        assert_eq!(self.state[i], JobState::Running, "preempting non-running job");
        let rem = self.remaining[i] - (now - self.started[i]);
        debug_assert!(rem >= -1e-9);
        self.remaining[i] = rem.max(0.0);
        self.state[i] = JobState::Queued;
        // A preempted job re-queues at its original arrival position,
        // which may precede the current HoL.
        if self.hol == NIL || self.ord_seq[i] < self.ord_seq[self.hol as usize] {
            self.hol = i as u32;
        }
    }

    /// Advance the HoL cursor forward from `s` to the next queued slot.
    fn advance_hol(&mut self, mut s: u32) {
        while s != NIL && self.state[s as usize] != JobState::Queued {
            s = self.ord_next[s as usize];
        }
        self.hol = s;
    }

    /// Oldest queued job in arrival order (FCFS's head of line), O(1).
    #[inline]
    pub fn hol_queued_slot(&self) -> Option<u32> {
        if self.hol == NIL {
            None
        } else {
            debug_assert_eq!(self.state[self.hol as usize], JobState::Queued);
            Some(self.hol)
        }
    }

    /// Visit **queued** jobs in arrival order, starting at the head of
    /// line; `f` returns false to stop. Skips the in-service prefix
    /// entirely (every job before the HoL is running by definition),
    /// which is what makes the FCFS / First-Fit admission scans
    /// O(queued visited) instead of O(jobs in system).
    pub fn for_each_queued_from_hol(&self, f: &mut dyn FnMut(JobId, ClassId) -> bool) {
        let mut s = self.hol;
        while s != NIL {
            let i = s as usize;
            let next = self.ord_next[i];
            if self.state[i] == JobState::Queued
                && !f(pack(self.gen[i], s), self.class[i] as ClassId)
            {
                break;
            }
            s = next;
        }
    }

    // ---- liveness queries (stale-safe, no panic) ----

    #[inline]
    fn state_of(&self, id: JobId) -> Option<JobState> {
        let (gen, slot) = unpack(id);
        match self.gen.get(slot as usize) {
            Some(&g) if g == gen => Some(self.state[slot as usize]),
            _ => None,
        }
    }

    #[inline]
    pub fn is_queued(&self, id: JobId) -> bool {
        self.state_of(id) == Some(JobState::Queued)
    }

    #[inline]
    pub fn is_running(&self, id: JobId) -> bool {
        self.state_of(id) == Some(JobState::Running)
    }

    /// True iff the id refers to a live (queued or running) job.
    #[inline]
    pub fn in_system(&self, id: JobId) -> bool {
        matches!(
            self.state_of(id),
            Some(JobState::Queued) | Some(JobState::Running)
        )
    }

    /// Visit live jobs oldest-arrival-first; `f` returns false to stop.
    /// The `bool` argument flags jobs currently in service.
    pub fn for_each_in_order(&self, f: &mut dyn FnMut(JobId, ClassId, bool) -> bool) {
        let mut s = self.ord_head;
        while s != NIL {
            let i = s as usize;
            let next = self.ord_next[i];
            let running = self.state[i] == JobState::Running;
            if !f(pack(self.gen[i], s), self.class[i] as ClassId, running) {
                break;
            }
            s = next;
        }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn capacity(&self) -> usize {
        self.state.len()
    }

    /// Drop every job but retain all allocations (engine reuse). Old ids
    /// become invalid; generation counters restart, so a reset table is
    /// bit-for-bit equivalent to a freshly constructed one.
    pub fn clear(&mut self) {
        self.state.clear();
        self.class.clear();
        self.need.clear();
        self.remaining.clear();
        self.arrival.clear();
        self.started.clear();
        self.starts.clear();
        self.gen.clear();
        self.next_free.clear();
        self.ord_prev.clear();
        self.ord_next.clear();
        self.ord_seq.clear();
        self.next_ord_seq = 0;
        self.ord_head = NIL;
        self.ord_tail = NIL;
        self.hol = NIL;
        self.free_head = NIL;
        self.live = 0;
        // Prefix state resets to fresh-construction values; the
        // configured threshold survives (an engine reset keeps its k).
        self.pfx_total = 0;
        self.pfx_len = 0;
        self.pfx_end = NIL;
        self.pfx_version = 0;
        self.in_pfx.clear();
    }
}

/// Per-class waiting-job FIFOs as intrusive doubly-linked lists over job
/// slots. All of push_front / push_back / remove-anywhere are O(1); the
/// lists contain exactly the queued jobs (no tombstones), so iteration
/// needs no liveness filtering.
pub struct ClassFifos {
    head: Vec<u32>,
    tail: Vec<u32>,
    prev: Vec<u32>,
    next: Vec<u32>,
}

impl ClassFifos {
    pub fn new(num_classes: usize) -> Self {
        Self {
            head: vec![NIL; num_classes],
            tail: vec![NIL; num_classes],
            prev: Vec::new(),
            next: Vec::new(),
        }
    }

    #[inline]
    fn ensure(&mut self, slot: u32) {
        let n = slot as usize + 1;
        if self.prev.len() < n {
            self.prev.resize(n, NIL);
            self.next.resize(n, NIL);
        }
    }

    pub fn push_back(&mut self, class: ClassId, slot: u32) {
        self.ensure(slot);
        let i = slot as usize;
        debug_assert!(self.prev[i] == NIL && self.next[i] == NIL);
        self.prev[i] = self.tail[class];
        self.next[i] = NIL;
        if self.tail[class] != NIL {
            self.next[self.tail[class] as usize] = slot;
        } else {
            self.head[class] = slot;
        }
        self.tail[class] = slot;
    }

    pub fn push_front(&mut self, class: ClassId, slot: u32) {
        self.ensure(slot);
        let i = slot as usize;
        debug_assert!(self.prev[i] == NIL && self.next[i] == NIL);
        self.next[i] = self.head[class];
        self.prev[i] = NIL;
        if self.head[class] != NIL {
            self.prev[self.head[class] as usize] = slot;
        } else {
            self.tail[class] = slot;
        }
        self.head[class] = slot;
    }

    /// Unlink `slot` from its class list — O(1) at any position.
    pub fn remove(&mut self, class: ClassId, slot: u32) {
        let i = slot as usize;
        let (p, n) = (self.prev[i], self.next[i]);
        if p != NIL {
            self.next[p as usize] = n;
        } else {
            debug_assert_eq!(self.head[class], slot, "removing unlinked slot");
            self.head[class] = n;
        }
        if n != NIL {
            self.prev[n as usize] = p;
        } else {
            self.tail[class] = p;
        }
        self.prev[i] = NIL;
        self.next[i] = NIL;
    }

    /// Oldest waiting slot of `class`, if any.
    #[inline]
    pub fn head_slot(&self, class: ClassId) -> Option<u32> {
        let h = self.head[class];
        if h == NIL {
            None
        } else {
            Some(h)
        }
    }

    /// Front-to-back slot iterator for `class`.
    pub fn iter(&self, class: ClassId) -> FifoIter<'_> {
        FifoIter {
            next: &self.next,
            cur: self.head[class],
        }
    }

    /// Empty all lists, retaining allocations.
    pub fn clear(&mut self) {
        for h in &mut self.head {
            *h = NIL;
        }
        for t in &mut self.tail {
            *t = NIL;
        }
        for p in &mut self.prev {
            *p = NIL;
        }
        for n in &mut self.next {
            *n = NIL;
        }
    }
}

pub struct FifoIter<'a> {
    next: &'a [u32],
    cur: u32,
}

impl Iterator for FifoIter<'_> {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.cur == NIL {
            return None;
        }
        let s = self.cur;
        self.cur = self.next[s as usize];
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_reuse() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        let b = t.insert(1, 2, 2.0, 0.1);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(b).need, 2);
        t.remove(a);
        assert_eq!(t.len(), 1);
        assert!(!t.in_system(a));
        // Freed slot is reused under a NEW generation.
        let c = t.insert(2, 4, 3.0, 0.2);
        assert_ne!(c, a, "generational ids must not alias");
        assert_eq!(c as u32, a as u32, "slot is reused");
        assert_eq!(t.get(c).class, 2);
        assert_eq!(t.capacity(), 2);
    }

    #[test]
    fn stale_ids_are_dead() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        t.remove(a);
        let _b = t.insert(0, 1, 1.0, 0.5);
        // The stale id must read as not-in-system even though the slot
        // now holds a live job.
        assert!(!t.in_system(a));
        assert!(!t.is_queued(a));
    }

    #[test]
    fn arrival_order_list_tracks_liveness() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        let b = t.insert(1, 1, 1.0, 0.1);
        let c = t.insert(0, 1, 1.0, 0.2);
        t.remove(b);
        let mut seen = Vec::new();
        t.for_each_in_order(&mut |id, _, _| {
            seen.push(id);
            true
        });
        assert_eq!(seen, vec![a, c]);
        // Slot reuse appends at the tail (new arrival = youngest).
        let d = t.insert(2, 1, 1.0, 0.3);
        seen.clear();
        t.for_each_in_order(&mut |id, _, _| {
            seen.push(id);
            true
        });
        assert_eq!(seen, vec![a, c, d]);
    }

    #[test]
    fn service_transitions_track_remaining() {
        let mut t = JobTable::new();
        let a = t.insert(0, 2, 5.0, 0.0);
        assert_eq!(t.start_service(a, 1.0), 1);
        assert_eq!(t.state(a), JobState::Running);
        t.preempt(a, 3.0);
        assert_eq!(t.state(a), JobState::Queued);
        assert!((t.remaining(a) - 3.0).abs() < 1e-12);
        assert_eq!(t.start_service(a, 4.0), 2);
    }

    #[test]
    fn clear_is_like_fresh() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        t.remove(a);
        t.insert(1, 1, 1.0, 0.1);
        t.clear();
        assert!(t.is_empty());
        let b = t.insert(3, 2, 9.0, 0.0);
        let fresh = JobTable::new().insert(3, 2, 9.0, 0.0);
        assert_eq!(b, fresh, "reset table must mint the same ids as a fresh one");
    }

    /// Brute-force twin of every QueueIndex query.
    struct Brute {
        needs: Vec<u32>,
        queued: Vec<u32>,
        running: Vec<u32>,
    }

    impl Brute {
        fn min_queued_need(&self) -> u32 {
            (0..self.needs.len())
                .filter(|&c| self.queued[c] > 0)
                .map(|c| self.needs[c])
                .min()
                .unwrap_or(u32::MAX)
        }

        fn max_fitting(&self, free: u32) -> Option<usize> {
            (0..self.needs.len())
                .filter(|&c| self.queued[c] > 0 && self.needs[c] <= free)
                .max_by_key(|&c| (self.needs[c], std::cmp::Reverse(c)))
        }

        fn trigger(&self) -> bool {
            let starving =
                (0..self.needs.len()).any(|c| self.queued[c] > 0 && self.running[c] == 0);
            let backlogged =
                (0..self.needs.len()).any(|c| self.queued[c] > 0 && self.running[c] > 0);
            starving && !backlogged
        }
    }

    /// Random transition sequences: every index query must match the
    /// brute-force recompute after every step.
    #[test]
    fn queue_index_matches_brute_force() {
        let mut rng = crate::util::rng::Rng::new(0x51eed);
        for _ in 0..200 {
            let k = 2 + rng.below(30) as u32;
            let nc = 1 + rng.index(6);
            let needs: Vec<u32> = (0..nc).map(|_| 1 + rng.below(k as u64) as u32).collect();
            let mut idx = QueueIndex::new(&needs);
            let mut brute = Brute {
                needs: needs.clone(),
                queued: vec![0; nc],
                running: vec![0; nc],
            };
            for _ in 0..120 {
                let c = rng.index(nc);
                match rng.index(4) {
                    0 => {
                        idx.on_enqueue(c);
                        brute.queued[c] += 1;
                    }
                    1 if brute.queued[c] > 0 => {
                        idx.on_admit(c);
                        brute.queued[c] -= 1;
                        brute.running[c] += 1;
                    }
                    2 if brute.running[c] > 0 => {
                        idx.on_depart(c);
                        brute.running[c] -= 1;
                    }
                    3 if brute.running[c] > 0 => {
                        idx.on_preempt(c);
                        brute.running[c] -= 1;
                        brute.queued[c] += 1;
                    }
                    _ => continue,
                }
                idx.assert_consistent(&brute.queued, &brute.running);
                assert_eq!(idx.min_queued_need(), brute.min_queued_need());
                assert_eq!(idx.swap_trigger(), brute.trigger());
                let brute_w: u64 = (0..nc)
                    .map(|c| brute.queued[c] as u64 * needs[c] as u64)
                    .sum();
                assert_eq!(idx.queued_need_total(), brute_w);
                let wfree = rng.below(k as u64 + 1) as u32;
                let brute_wfit: u64 = (0..nc)
                    .filter(|&c| needs[c] <= wfree)
                    .map(|c| brute.queued[c] as u64 * needs[c] as u64)
                    .sum();
                assert_eq!(
                    idx.queued_need_fitting(wfree),
                    brute_wfit,
                    "free={wfree} needs={needs:?} queued={:?}",
                    brute.queued
                );
                assert_eq!(
                    idx.total_live(),
                    brute.queued.iter().sum::<u32>() + brute.running.iter().sum::<u32>()
                );
                let free = rng.below(k as u64 + 1) as u32;
                assert_eq!(
                    idx.max_fitting_rank_below(idx.num_ranks(), free)
                        .map(|r| idx.class_at_rank(r)),
                    brute.max_fitting(free),
                    "free={free} needs={needs:?} queued={:?}",
                    brute.queued
                );
            }
        }
    }

    /// The descending-rank walk visits classes in MSF order: need
    /// descending, ties by ascending class id.
    #[test]
    fn queue_index_rank_walk_is_msf_order() {
        // Classes: needs 4, 2, 4, 1 — two classes tie at need 4.
        let needs = [4u32, 2, 4, 1];
        let mut idx = QueueIndex::new(&needs);
        for c in 0..needs.len() {
            idx.on_enqueue(c);
        }
        let mut seen = Vec::new();
        let mut bound = idx.num_ranks();
        while let Some(r) = idx.max_fitting_rank_below(bound, u32::MAX) {
            seen.push(idx.class_at_rank(r));
            bound = r;
        }
        assert_eq!(seen, vec![0, 2, 1, 3]);
    }

    /// The arrival-order prefix tracks the minimal crossing prefix
    /// through inserts and removals at every position.
    #[test]
    fn prefix_cursor_is_minimal_crossing() {
        let mut t = JobTable::new();
        t.set_prefix_threshold(10);
        let v0 = t.prefix_version();
        let a = t.insert(0, 5, 1.0, 0.0); // cum 5  -> in prefix
        let b = t.insert(0, 2, 1.0, 0.1); // cum 7  -> in prefix
        let c = t.insert(0, 4, 1.0, 0.2); // cum 11 -> crossing member
        assert_eq!(t.prefix_len(), 3);
        assert_eq!(t.prefix_total(), 11);
        // A tail arrival beyond the crossing point changes nothing.
        let d = t.insert(0, 3, 1.0, 0.3);
        let v1 = t.prefix_version();
        let e = t.insert(0, 8, 1.0, 0.4);
        assert_eq!(t.prefix_version(), v1, "beyond-prefix arrival must not bump");
        assert_eq!(t.prefix_len(), 3);
        assert!(t.prefix_version() > v0);
        // Removing a mid-prefix member extends the end forward.
        t.remove(b); // cum: 5, 9 -> extends over d: 12
        assert_eq!(t.prefix_len(), 3);
        assert_eq!(t.prefix_total(), 12);
        // Removing a non-member leaves the prefix alone.
        let v2 = t.prefix_version();
        t.remove(e);
        assert_eq!(t.prefix_version(), v2);
        // Draining below the threshold keeps the whole list in.
        t.remove(a);
        t.remove(c);
        assert_eq!(t.prefix_len(), 1);
        assert_eq!(t.prefix_total(), 3);
        t.remove(d);
        assert_eq!(t.prefix_len(), 0);
        assert_eq!(t.prefix_total(), 0);
        // New arrivals re-enter the (short) prefix.
        t.insert(0, 1, 1.0, 1.0);
        assert_eq!(t.prefix_len(), 1);
    }

    /// The HoL cursor always points at the oldest queued job, through
    /// admissions (advance), departures, and preemptions (rewind) —
    /// random transition sequences checked against a brute-force walk.
    #[test]
    fn hol_cursor_matches_brute_force() {
        let mut rng = crate::util::rng::Rng::new(0x601_4ead);
        for _ in 0..150 {
            let mut t = JobTable::new();
            let mut live: Vec<JobId> = Vec::new();
            for step in 0..200 {
                match rng.index(4) {
                    0 => live.push(t.insert(rng.index(3), 1 + rng.below(4) as u32, 1.0, 0.0)),
                    1 if !live.is_empty() => {
                        let id = live[rng.index(live.len())];
                        if t.is_queued(id) {
                            t.start_service(id, 1.0);
                        }
                    }
                    2 if !live.is_empty() => {
                        let id = live[rng.index(live.len())];
                        if t.is_running(id) {
                            t.preempt(id, 1.0);
                        }
                    }
                    3 if !live.is_empty() => {
                        let i = rng.index(live.len());
                        let id = live.swap_remove(i);
                        if t.is_running(id) {
                            t.remove(id);
                        } else {
                            live.push(id); // only complete running jobs
                        }
                    }
                    _ => continue,
                }
                // Brute force: first queued job in arrival order.
                let mut brute = None;
                t.for_each_in_order(&mut |id, _, running| {
                    if !running {
                        brute = Some(JobTable::slot_of(id));
                        return false;
                    }
                    true
                });
                assert_eq!(t.hol_queued_slot(), brute, "step {step}");
                // The queued-from-HoL walk sees exactly the queued jobs
                // of the full arrival-order walk, in the same order.
                let mut fast = Vec::new();
                t.for_each_queued_from_hol(&mut |id, _| {
                    fast.push(id);
                    true
                });
                let mut slow = Vec::new();
                t.for_each_in_order(&mut |id, _, running| {
                    if !running {
                        slow.push(id);
                    }
                    true
                });
                assert_eq!(fast, slow, "step {step}");
            }
        }
    }

    #[test]
    fn fifo_removal_any_position() {
        let mut f = ClassFifos::new(2);
        for s in 0..5u32 {
            f.push_back(0, s);
        }
        f.remove(0, 2); // middle
        f.remove(0, 0); // head
        f.remove(0, 4); // tail
        let left: Vec<u32> = f.iter(0).collect();
        assert_eq!(left, vec![1, 3]);
        f.push_front(0, 7);
        assert_eq!(f.head_slot(0), Some(7));
        let left: Vec<u32> = f.iter(0).collect();
        assert_eq!(left, vec![7, 1, 3]);
        assert!(f.iter(1).next().is_none());
    }
}
