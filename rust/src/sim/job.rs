//! Job storage: a generational slab keyed by `JobId`.
//!
//! The engine keeps every job in the system (queued or running) in this
//! table; slots are recycled after departure so memory is O(jobs in
//! system), not O(jobs simulated). Ids are *generational* — a `JobId`
//! packs (generation, slot) so an id that lingers in an index (e.g. the
//! arrival-order deque) after its job departed can never alias a new job
//! occupying the same slot.

use crate::policy::{ClassId, JobId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    /// Slot is free (job departed); `next_free` threads the free list.
    Free,
}

#[derive(Clone, Debug)]
pub struct Job {
    pub class: ClassId,
    pub need: u32,
    /// Remaining service requirement (= full size until first run).
    pub remaining: f64,
    /// Absolute arrival time.
    pub arrival: f64,
    /// Time service (re)started; valid while Running.
    pub started: f64,
    pub state: JobState,
    /// Incremented on every (re)start/preemption; stale departure events
    /// carry an old epoch and are discarded.
    pub epoch: u32,
    /// Slot generation; must match the id's generation half.
    gen: u32,
    next_free: u32,
}

const NIL: u32 = u32::MAX;

#[inline]
fn pack(gen: u32, slot: u32) -> JobId {
    ((gen as u64) << 32) | slot as u64
}

#[inline]
fn unpack(id: JobId) -> (u32, u32) {
    ((id >> 32) as u32, id as u32)
}

/// Generational slab of jobs with O(1) insert/remove and safe id reuse.
#[derive(Default)]
pub struct JobTable {
    slots: Vec<Job>,
    free_head: u32,
    live: usize,
}

impl JobTable {
    pub fn new() -> Self {
        Self {
            slots: Vec::new(),
            free_head: NIL,
            live: 0,
        }
    }

    pub fn insert(&mut self, class: ClassId, need: u32, size: f64, arrival: f64) -> JobId {
        self.live += 1;
        let job = Job {
            class,
            need,
            remaining: size,
            arrival,
            started: f64::NAN,
            state: JobState::Queued,
            epoch: 0,
            gen: 0,
            next_free: NIL,
        };
        if self.free_head != NIL {
            let slot = self.free_head;
            let s = &mut self.slots[slot as usize];
            self.free_head = s.next_free;
            let gen = s.gen.wrapping_add(1);
            *s = job;
            s.gen = gen;
            pack(gen, slot)
        } else {
            self.slots.push(job);
            pack(0, (self.slots.len() - 1) as u32)
        }
    }

    pub fn remove(&mut self, id: JobId) {
        let (gen, slot) = unpack(id);
        let s = &mut self.slots[slot as usize];
        debug_assert!(s.gen == gen && s.state != JobState::Free);
        s.state = JobState::Free;
        s.next_free = self.free_head;
        self.free_head = slot;
        self.live -= 1;
    }

    /// Panics if the id is stale (generation mismatch).
    #[inline]
    pub fn get(&self, id: JobId) -> &Job {
        let (gen, slot) = unpack(id);
        let j = &self.slots[slot as usize];
        assert!(j.gen == gen, "stale JobId");
        j
    }

    #[inline]
    pub fn get_mut(&mut self, id: JobId) -> &mut Job {
        let (gen, slot) = unpack(id);
        let j = &mut self.slots[slot as usize];
        assert!(j.gen == gen, "stale JobId");
        j
    }

    #[inline]
    fn state_of(&self, id: JobId) -> Option<JobState> {
        let (gen, slot) = unpack(id);
        match self.slots.get(slot as usize) {
            Some(j) if j.gen == gen => Some(j.state),
            _ => None,
        }
    }

    #[inline]
    pub fn is_queued(&self, id: JobId) -> bool {
        self.state_of(id) == Some(JobState::Queued)
    }

    #[inline]
    pub fn is_running(&self, id: JobId) -> bool {
        self.state_of(id) == Some(JobState::Running)
    }

    /// True iff the id refers to a live (queued or running) job.
    #[inline]
    pub fn in_system(&self, id: JobId) -> bool {
        matches!(
            self.state_of(id),
            Some(JobState::Queued) | Some(JobState::Running)
        )
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_reuse() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        let b = t.insert(1, 2, 2.0, 0.1);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(b).need, 2);
        t.remove(a);
        assert_eq!(t.len(), 1);
        assert!(!t.in_system(a));
        // Freed slot is reused under a NEW generation.
        let c = t.insert(2, 4, 3.0, 0.2);
        assert_ne!(c, a, "generational ids must not alias");
        assert_eq!(c as u32, a as u32, "slot is reused");
        assert_eq!(t.get(c).class, 2);
        assert_eq!(t.capacity(), 2);
    }

    #[test]
    fn stale_ids_are_dead() {
        let mut t = JobTable::new();
        let a = t.insert(0, 1, 1.0, 0.0);
        t.remove(a);
        let _b = t.insert(0, 1, 1.0, 0.5);
        // The stale id must read as not-in-system even though the slot
        // now holds a live job.
        assert!(!t.in_system(a));
        assert!(!t.is_queued(a));
    }
}
