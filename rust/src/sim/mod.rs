//! Discrete-event simulation of multiserver-job systems.

pub mod engine;
pub mod events;
pub mod job;
pub mod ladder;
pub mod metrics;
pub mod phase;
pub mod schedule;
pub mod timeseries;

pub use engine::{Engine, SimConfig};
pub use job::QueueIndex;
pub use ladder::LadderQueue;
pub use metrics::{Metrics, ReplicationPool, SimResult, UnitStats};
pub use phase::PhaseStats;
pub use schedule::{EventSchedule, EventScheduleKind, Schedule};
pub use timeseries::{Timeseries, TimeseriesSpec};

use crate::policy::Policy;
use crate::util::rng::Rng;
use crate::workload::{SyntheticSource, Workload};

/// Convenience: simulate `policy` on `wl` with default config and a seed.
pub fn run(
    wl: &Workload,
    policy: &mut dyn Policy,
    cfg: &SimConfig,
    seed: u64,
) -> SimResult {
    let mut src = SyntheticSource::new(wl.clone());
    let mut rng = Rng::new(seed);
    let mut engine = Engine::new(wl, cfg.clone());
    engine.run(&mut src, policy, &mut rng)
}

/// Convenience: simulate the policy identified by a typed
/// [`PolicyId`](crate::policy::PolicyId) (the replacement for the former
/// stringly `run_named`).
pub fn run_policy(
    wl: &Workload,
    policy: &crate::policy::PolicyId,
    cfg: &SimConfig,
    seed: u64,
) -> crate::Result<SimResult> {
    let mut p = crate::policy::build(policy, wl)?;
    Ok(run(wl, p.as_mut(), cfg, seed))
}
