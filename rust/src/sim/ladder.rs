//! A two-level hierarchical calendar ("ladder") event queue with O(1)
//! amortized push/pop/cancel — the default timing structure behind
//! [`EventSchedule`](crate::sim::schedule::EventSchedule).
//!
//! ## Layout
//!
//! Events flow through three tiers, earliest-first:
//!
//! * **bottom** — a small vector sorted ascending by `(t, seq)`, popped
//!   through a head cursor (no memmove per pop). Everything with
//!   `t < bot_hi` lives here.
//! * **rungs** — a stack of bucket arrays. The base rung spans the
//!   events observed at the last re-seed; each bucket covers a `width`
//!   slice of time and holds its events unsorted. When the next
//!   non-empty bucket comes due, its events are sorted once into the
//!   bottom (and `bot_hi` advances past the bucket). A bucket holding
//!   more than [`SPILL_THRESHOLD`] events is **spilled** instead: its
//!   span is re-bucketed at finer width onto a child rung (the
//!   "ladder" step), so heavy-tailed clusters never degenerate into one
//!   giant sort — rung depth is capped at [`MAX_RUNGS`], beyond which a
//!   dense bucket is simply sorted.
//! * **overflow** — an unsorted catch-all for events beyond the last
//!   rung's limit. When every rung is exhausted, the overflow is
//!   re-seeded into a fresh base rung whose bucket count derives from
//!   the **observed event span** (an EWMA of span/count across
//!   re-seeds estimates the typical gap, targeting ~1 event per
//!   bucket — the auto-tuning knob for workloads whose departure spans
//!   drift, e.g. the Borg trace's heavy-tailed service times).
//!
//! Each event is touched O(1) times on its way down (overflow → rung
//! bucket → bottom, plus at most [`MAX_RUNGS`] re-bucketings), giving
//! O(1) amortized push/pop against the heap's O(log n) sifts.
//!
//! ## Bit-identical pop order
//!
//! Pops leave exclusively through the bottom, which is sorted by the
//! same `(t, seq)` total order (`f64::total_cmp`, FIFO tie-break on the
//! monotone push sequence) the indexed 4-ary heap uses. Region
//! boundaries partition the time axis exactly: bottom `< bot_hi` ≤
//! rung buckets (in bucket order) ≤ overflow, and bucket membership is
//! decided against the *same* canonical boundary expression
//! (`start + i·width`) used when draining, with an explicit fix-up
//! after the float division so rounding can never place an event on
//! the wrong side of a boundary. Pop order is therefore the global
//! `(t, seq)` ascending order — bit-identical to the heap by
//! construction, and enforced by the differential replay in
//! `tests/prop_events.rs`.
//!
//! ## O(1) cancel
//!
//! A job-slot → location map (`Loc`) tracks which tier/bucket/index a
//! departure occupies, maintained across every internal move, so
//! `cancel_departure` / `has_departure` stay O(1) amortized exactly
//! like the heap's position map (bucket/overflow removal is a
//! swap-remove; a bottom removal shifts the sorted tail, which is short
//! because the bottom holds at most one drained bucket). All-ties
//! clusters — which no time width can subdivide — get **seq-keyed
//! sub-buckets**: a tie rung partitions the cluster by push sequence
//! into [`TIE_BUCKET`]-sized slices (see [`Rung::seq_key`]), so the
//! cluster reaches the bottom one bounded slice at a time and a cancel
//! inside it is a bucket swap-remove (or a short bottom shift) instead
//! of O(cluster). The `QS_EVENT_SCHEDULE=heap` escape hatch remains.

use crate::policy::JobId;
use crate::sim::events::{Event, EventKind};
use crate::sim::job::JobTable;

/// Buckets denser than this are re-bucketed onto a child rung.
const SPILL_THRESHOLD: usize = 64;
/// Maximum rung-stack depth; denser buckets are sorted directly.
const MAX_RUNGS: usize = 8;
/// Re-seeds at or below this size skip the rung and sort directly.
const DIRECT_TO_BOTTOM: usize = 8;
/// Bucket-count bounds for rung construction.
const MIN_BUCKETS: usize = 8;
const MAX_BUCKETS: usize = 4096;
/// Target events per seq-keyed sub-bucket when an all-ties cluster is
/// split (see [`Rung::seq_key`]): each drained slice costs one bounded
/// sort, and a cancel shifts at most one slice.
const TIE_BUCKET: u64 = SPILL_THRESHOLD as u64;

/// Where a scheduled departure currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Loc {
    None,
    Bottom(u32),
    Rung { rung: u32, bucket: u32, idx: u32 },
    Overflow(u32),
}

/// One bucket array of the ladder.
struct Rung {
    /// Time of bucket 0's lower boundary.
    start: f64,
    /// Bucket width; boundary `i` is canonically `start + i·width`.
    width: f64,
    /// Upper bound of the rung's span (exclusive): push eligibility is
    /// `t < limit`, and the last bucket's end is `limit` exactly.
    limit: f64,
    /// Next bucket to drain; buckets below hold nothing (their span has
    /// been handed to the bottom or to a child rung).
    cur: usize,
    buckets: Vec<Vec<Event>>,
    /// `Some((s0, w))` marks a **tie rung**: every event shares one
    /// time (`start`), so buckets slice the cluster by push sequence
    /// instead — bucket `i` holds seqs `[s0 + i·w, s0 + (i+1)·w)`, the
    /// last bucket open-ended. `width` is 0, which makes the canonical
    /// boundary `start + (b+1)·width` degenerate to `start`: exactly
    /// right, because `bot_hi` must park at the tie time until the last
    /// slice drains so that later pushes at that time route back into
    /// the rung (by seq, hence after every older tie).
    seq_key: Option<(u64, u64)>,
}

impl Rung {
    #[inline]
    fn bucket_end(&self, b: usize) -> f64 {
        if b + 1 == self.buckets.len() {
            self.limit
        } else {
            self.start + (b as f64 + 1.0) * self.width
        }
    }

    /// Bucket index for `t`, agreeing *exactly* with the canonical
    /// boundaries: float division only seeds the guess, then the
    /// fix-up walks (at most a step or two) so that
    /// `start + i·width ≤ t < bucket_end(i)` holds by the same
    /// arithmetic the drain path uses.
    #[inline]
    fn bucket_index(&self, t: f64) -> usize {
        let nb = self.buckets.len();
        // Negative offsets (events clamped in from below) saturate to 0.
        let mut i = (((t - self.start) / self.width) as usize).min(nb - 1);
        while i > 0 && t < self.start + i as f64 * self.width {
            i -= 1;
        }
        while i + 1 < nb && t >= self.start + (i as f64 + 1.0) * self.width {
            i += 1;
        }
        i
    }

    /// Destination bucket for `e`: by time on a normal rung, by push
    /// sequence on a tie rung (one shared time — only the FIFO order
    /// can subdivide the cluster). Events clamped in from before the
    /// tie time go to the front bucket, which drains (and sorts) first.
    #[inline]
    fn bucket_of(&self, e: &Event) -> usize {
        match self.seq_key {
            Some((s0, w)) => {
                if e.t < self.start {
                    0
                } else {
                    ((e.seq.saturating_sub(s0) / w) as usize).min(self.buckets.len() - 1)
                }
            }
            None => self.bucket_index(e.t),
        }
    }

    fn reset(&mut self) {
        self.cur = 0;
        self.seq_key = None;
        for b in &mut self.buckets {
            debug_assert!(b.is_empty(), "recycling a rung with live events");
            b.clear();
        }
    }
}

/// Smallest f64 strictly greater than finite `x` (rung limits must sit
/// strictly above the largest event they admit). Hand-rolled rather
/// than `f64::next_up` (stable only since 1.86) to hold the crate's
/// documented MSRV of 1.73 — see rust-toolchain.toml.
#[inline]
fn next_up(x: f64) -> f64 {
    debug_assert!(x.is_finite());
    if x == 0.0 {
        f64::from_bits(1)
    } else if x > 0.0 {
        f64::from_bits(x.to_bits() + 1)
    } else {
        f64::from_bits(x.to_bits() - 1)
    }
}

#[inline]
fn by_t_seq(a: &Event, b: &Event) -> std::cmp::Ordering {
    a.t.total_cmp(&b.t).then(a.seq.cmp(&b.seq))
}

/// Min seq and seq span (max − min + 1) of an all-ties event set, or
/// `None` when the span fits a single [`TIE_BUCKET`] slice (including
/// the empty case) and seq-keyed splitting would buy nothing.
fn seq_span(events: &[Event]) -> Option<(u64, u64)> {
    let (mut s0, mut s1) = (u64::MAX, 0u64);
    for e in events {
        s0 = s0.min(e.seq);
        s1 = s1.max(e.seq);
    }
    let span = s1.checked_sub(s0)? + 1;
    if span <= TIE_BUCKET {
        return None;
    }
    Some((s0, span))
}

/// Record `e`'s location if it is a departure. Free function over the
/// map so callers can update locations while other fields of the queue
/// are borrowed (disjoint-field borrows).
#[inline]
fn note_loc(map: &mut [Loc], e: &Event, loc: Loc) {
    if let EventKind::Departure { job } = e.kind {
        map[JobTable::slot_of(job) as usize] = loc;
    }
}

/// The ladder queue. See the module docs for layout and invariants.
pub struct LadderQueue {
    /// Sorted ascending by `(t, seq)`; `[head..]` is the live region.
    bottom: Vec<Event>,
    head: usize,
    /// Bottom region boundary: every event with `t < bot_hi` is in the
    /// bottom, and everything outside the bottom has `t ≥ bot_hi`.
    bot_hi: f64,
    /// Base rung first; deeper rungs cover earlier sub-spans.
    rungs: Vec<Rung>,
    /// Recycled rung allocations.
    spare: Vec<Rung>,
    overflow: Vec<Event>,
    /// Scratch buffer for spill redistribution.
    scratch: Vec<Event>,
    /// loc[job_slot] — O(1) cancel/has-departure, like the heap's map.
    loc: Vec<Loc>,
    next_seq: u64,
    len: usize,
    /// EWMA of (span / count) across re-seeds: the observed mean event
    /// gap driving bucket-count auto-tuning.
    gap_ewma: f64,
    spills: u64,
    tie_spills: u64,
    reseeds: u64,
}

impl Default for LadderQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl LadderQueue {
    pub fn new() -> LadderQueue {
        LadderQueue {
            bottom: Vec::new(),
            head: 0,
            bot_hi: f64::NEG_INFINITY,
            rungs: Vec::new(),
            spare: Vec::new(),
            overflow: Vec::new(),
            scratch: Vec::new(),
            loc: Vec::new(),
            next_seq: 0,
            len: 0,
            gap_ewma: 0.0,
            spills: 0,
            tie_spills: 0,
            reseeds: 0,
        }
    }

    #[inline]
    fn job_slot(job: JobId) -> usize {
        JobTable::slot_of(job) as usize
    }

    /// Record `e`'s location if it is a departure.
    #[inline]
    fn note(&mut self, e: &Event, loc: Loc) {
        note_loc(&mut self.loc, e, loc);
    }

    pub fn push(&mut self, t: f64, kind: EventKind) {
        debug_assert!(t.is_finite(), "event time must be finite, got {t}");
        if let EventKind::Departure { job } = kind {
            let slot = Self::job_slot(job);
            if slot >= self.loc.len() {
                self.loc.resize(slot + 1, Loc::None);
            }
            debug_assert!(
                self.loc[slot] == Loc::None,
                "job already has a scheduled departure"
            );
        }
        let e = Event {
            t,
            seq: self.next_seq,
            kind,
        };
        self.next_seq += 1;
        self.len += 1;
        if t < self.bot_hi {
            self.bottom_insert(e);
            return;
        }
        // Deepest rung covers the earliest region beyond the bottom.
        // Exhausted rungs (cur == buckets, i.e. empty and awaiting pop)
        // are skipped: an event falling in their span clamps into the
        // next live rung's current bucket, which drains first and is
        // sorted — order is preserved (see module docs).
        for r in (0..self.rungs.len()).rev() {
            let nb = self.rungs[r].buckets.len();
            if self.rungs[r].cur == nb || t >= self.rungs[r].limit {
                continue;
            }
            let b = self.rungs[r].bucket_of(&e).max(self.rungs[r].cur);
            let idx = self.rungs[r].buckets[b].len();
            self.rungs[r].buckets[b].push(e);
            self.note(
                &e,
                Loc::Rung {
                    rung: r as u32,
                    bucket: b as u32,
                    idx: idx as u32,
                },
            );
            return;
        }
        let idx = self.overflow.len();
        self.overflow.push(e);
        self.note(&e, Loc::Overflow(idx as u32));
    }

    /// Sorted insert into the live bottom region, keeping locations of
    /// the shifted tail correct. The tail is short in the common case —
    /// the bottom holds one drained bucket (or an undivisible tie run).
    fn bottom_insert(&mut self, e: Event) {
        let live = &self.bottom[self.head..];
        let pos = self.head + live.partition_point(|x| by_t_seq(x, &e).is_lt());
        self.bottom.insert(pos, e);
        for (i, ev) in self.bottom.iter().enumerate().skip(pos) {
            note_loc(&mut self.loc, ev, Loc::Bottom(i as u32));
        }
    }

    /// Time of the earliest event. `&mut`: refills the bottom lazily.
    #[inline]
    pub fn peek_t(&mut self) -> Option<f64> {
        if self.head == self.bottom.len() && !self.refill_bottom() {
            return None;
        }
        Some(self.bottom[self.head].t)
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Event> {
        if self.head == self.bottom.len() && !self.refill_bottom() {
            return None;
        }
        let e = self.bottom[self.head];
        self.head += 1;
        self.note(&e, Loc::None);
        self.len -= 1;
        Some(e)
    }

    /// Refill the (fully consumed) bottom from the rungs/overflow.
    /// Returns false iff the queue is empty.
    fn refill_bottom(&mut self) -> bool {
        debug_assert_eq!(self.head, self.bottom.len(), "bottom not consumed");
        self.bottom.clear();
        self.head = 0;
        loop {
            let Some(r) = self.rungs.len().checked_sub(1) else {
                if self.overflow.is_empty() {
                    return false;
                }
                self.reseed();
                if self.head < self.bottom.len() {
                    return true; // tiny/degenerate overflow went straight in
                }
                continue;
            };
            let nb = self.rungs[r].buckets.len();
            while self.rungs[r].cur < nb && self.rungs[r].buckets[self.rungs[r].cur].is_empty() {
                self.rungs[r].cur += 1;
            }
            if self.rungs[r].cur == nb {
                let mut dead = self.rungs.pop().expect("rung exists");
                dead.reset();
                self.spare.push(dead);
                continue;
            }
            let b = self.rungs[r].cur;
            self.rungs[r].cur += 1;
            let be = self.rungs[r].bucket_end(b);
            if self.rungs[r].buckets[b].len() > SPILL_THRESHOLD
                && self.rungs.len() < MAX_RUNGS
                && self.try_spill(r, b)
            {
                continue;
            }
            // Drain the bucket into the bottom: swap allocations, sort
            // once, advance the boundary past the bucket.
            std::mem::swap(&mut self.bottom, &mut self.rungs[r].buckets[b]);
            self.bottom.sort_unstable_by(by_t_seq);
            for (i, ev) in self.bottom.iter().enumerate() {
                note_loc(&mut self.loc, ev, Loc::Bottom(i as u32));
            }
            self.bot_hi = be;
            return true;
        }
    }

    /// Re-bucket rung `r`'s bucket `b` onto a finer child rung. Returns
    /// false (leaving the bucket untouched) when the events carry no
    /// usable time spread — the caller sorts them directly instead.
    fn try_spill(&mut self, r: usize, b: usize) -> bool {
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for e in &self.rungs[r].buckets[b] {
            mn = mn.min(e.t);
            mx = mx.max(e.t);
        }
        if mx <= mn {
            // All ties (or one time): no width subdivides them, but the
            // push sequence does.
            return self.try_spill_ties(r, b, mn);
        }
        let start = mn;
        let limit = next_up(mx);
        let n = self.rungs[r].buckets[b].len();
        let nb = n.clamp(MIN_BUCKETS, MAX_BUCKETS);
        let width = (limit - start) / nb as f64;
        if width <= 0.0 || !width.is_finite() {
            return false;
        }
        debug_assert!(self.scratch.is_empty());
        std::mem::swap(&mut self.scratch, &mut self.rungs[r].buckets[b]);
        let child = self.make_rung(start, width, limit, nb);
        let c = self.rungs.len();
        self.rungs.push(child);
        let events = std::mem::take(&mut self.scratch);
        for e in &events {
            let cb = self.rungs[c].bucket_index(e.t);
            let idx = self.rungs[c].buckets[cb].len();
            self.rungs[c].buckets[cb].push(*e);
            self.note(
                e,
                Loc::Rung {
                    rung: c as u32,
                    bucket: cb as u32,
                    idx: idx as u32,
                },
            );
        }
        self.scratch = events;
        self.scratch.clear();
        self.spills += 1;
        true
    }

    /// Re-bucket an all-ties bucket onto a seq-keyed child rung (see
    /// [`Rung::seq_key`]). Returns false when the cluster's seq span
    /// fits one [`TIE_BUCKET`] slice — the caller sorts it directly.
    fn try_spill_ties(&mut self, r: usize, b: usize, t0: f64) -> bool {
        let Some((s0, span)) = seq_span(&self.rungs[r].buckets[b]) else {
            return false;
        };
        let nb = (span.div_ceil(TIE_BUCKET) as usize).min(MAX_BUCKETS);
        let w = span.div_ceil(nb as u64);
        debug_assert!(self.scratch.is_empty());
        std::mem::swap(&mut self.scratch, &mut self.rungs[r].buckets[b]);
        let mut child = self.make_rung(t0, 0.0, next_up(t0), nb);
        child.seq_key = Some((s0, w));
        let c = self.rungs.len();
        self.rungs.push(child);
        let events = std::mem::take(&mut self.scratch);
        for e in &events {
            let cb = self.rungs[c].bucket_of(e);
            let idx = self.rungs[c].buckets[cb].len();
            self.rungs[c].buckets[cb].push(*e);
            self.note(
                e,
                Loc::Rung {
                    rung: c as u32,
                    bucket: cb as u32,
                    idx: idx as u32,
                },
            );
        }
        self.scratch = events;
        self.scratch.clear();
        self.tie_spills += 1;
        true
    }

    /// Build a seq-keyed base rung from an all-ties overflow. Returns
    /// false when the seq span fits one slice (direct sort is cheap).
    fn reseed_ties(&mut self, t0: f64) -> bool {
        let Some((s0, span)) = seq_span(&self.overflow) else {
            return false;
        };
        let nb = (span.div_ceil(TIE_BUCKET) as usize).min(MAX_BUCKETS);
        let w = span.div_ceil(nb as u64);
        let mut rung = self.make_rung(t0, 0.0, next_up(t0), nb);
        rung.seq_key = Some((s0, w));
        let rr = self.rungs.len();
        self.rungs.push(rung);
        let events = std::mem::take(&mut self.overflow);
        for e in &events {
            let b = self.rungs[rr].bucket_of(e);
            let idx = self.rungs[rr].buckets[b].len();
            self.rungs[rr].buckets[b].push(*e);
            self.note(
                e,
                Loc::Rung {
                    rung: rr as u32,
                    bucket: b as u32,
                    idx: idx as u32,
                },
            );
        }
        self.overflow = events;
        self.overflow.clear();
        // Same gap-closing rule as a normal re-seed: later pushes in
        // [old bot_hi, t0) belong to the (empty) bottom, which pops
        // first.
        self.bot_hi = t0;
        self.tie_spills += 1;
        true
    }

    /// Build the base rung from the accumulated overflow (or sort a
    /// tiny / zero-spread overflow straight into the bottom).
    fn reseed(&mut self) {
        self.reseeds += 1;
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for e in &self.overflow {
            mn = mn.min(e.t);
            mx = mx.max(e.t);
        }
        let n = self.overflow.len();
        // A large all-ties overflow takes the seq-keyed path instead of
        // being sorted (and later cancel-shifted) as one block.
        if n > DIRECT_TO_BOTTOM && mx <= mn && self.reseed_ties(mn) {
            return;
        }
        let span = next_up(mx) - mn;
        let direct = n <= DIRECT_TO_BOTTOM || mx <= mn || span <= 0.0 || !span.is_finite();
        if !direct {
            // Auto-tune the bucket count toward ~1 event per bucket at
            // the observed mean gap (EWMA across re-seeds).
            let gap_obs = span / n as f64;
            self.gap_ewma = if self.gap_ewma > 0.0 {
                0.75 * self.gap_ewma + 0.25 * gap_obs
            } else {
                gap_obs
            };
            let nb = ((span / self.gap_ewma).ceil() as usize).clamp(MIN_BUCKETS, MAX_BUCKETS);
            let width = span / nb as f64;
            if width > 0.0 && width.is_finite() {
                let rung = self.make_rung(mn, width, next_up(mx), nb);
                let rr = self.rungs.len();
                self.rungs.push(rung);
                let events = std::mem::take(&mut self.overflow);
                for e in &events {
                    let b = self.rungs[rr].bucket_index(e.t);
                    let idx = self.rungs[rr].buckets[b].len();
                    self.rungs[rr].buckets[b].push(*e);
                    self.note(
                        e,
                        Loc::Rung {
                            rung: rr as u32,
                            bucket: b as u32,
                            idx: idx as u32,
                        },
                    );
                }
                self.overflow = events;
                self.overflow.clear();
                // Close the [old bot_hi, rung.start) gap: later pushes in
                // it belong to the (empty) bottom, which pops first.
                self.bot_hi = mn;
                return;
            }
        }
        // Degenerate or tiny: straight into the bottom.
        std::mem::swap(&mut self.bottom, &mut self.overflow);
        self.overflow.clear();
        self.head = 0;
        self.bottom.sort_unstable_by(by_t_seq);
        for (i, ev) in self.bottom.iter().enumerate() {
            note_loc(&mut self.loc, ev, Loc::Bottom(i as u32));
        }
        self.bot_hi = next_up(mx);
    }

    fn make_rung(&mut self, start: f64, width: f64, limit: f64, nb: usize) -> Rung {
        let mut rung = self.spare.pop().unwrap_or_else(|| Rung {
            start: 0.0,
            width: 0.0,
            limit: 0.0,
            cur: 0,
            buckets: Vec::new(),
            seq_key: None,
        });
        rung.start = start;
        rung.width = width;
        rung.limit = limit;
        rung.cur = 0;
        rung.seq_key = None;
        if rung.buckets.len() < nb {
            rung.buckets.resize_with(nb, Vec::new);
        } else {
            rung.buckets.truncate(nb);
        }
        rung
    }

    /// Remove `job`'s departure event in place. Returns false if no
    /// departure is scheduled for this job.
    pub fn cancel_departure(&mut self, job: JobId) -> bool {
        let slot = Self::job_slot(job);
        let Some(&loc) = self.loc.get(slot) else {
            return false;
        };
        match loc {
            Loc::None => return false,
            Loc::Bottom(i) => {
                let i = i as usize;
                debug_assert!(i >= self.head, "cancelling an already-popped event");
                debug_assert!(
                    matches!(self.bottom[i].kind, EventKind::Departure { job: j } if j == job),
                    "ladder bottom location out of sync"
                );
                self.bottom.remove(i);
                for (j, ev) in self.bottom.iter().enumerate().skip(i) {
                    note_loc(&mut self.loc, ev, Loc::Bottom(j as u32));
                }
            }
            Loc::Rung { rung, bucket, idx } => {
                let (r, b, i) = (rung as usize, bucket as usize, idx as usize);
                debug_assert!(
                    matches!(self.rungs[r].buckets[b][i].kind,
                             EventKind::Departure { job: j } if j == job),
                    "ladder rung location out of sync"
                );
                self.rungs[r].buckets[b].swap_remove(i);
                if i < self.rungs[r].buckets[b].len() {
                    let moved = self.rungs[r].buckets[b][i];
                    self.note(&moved, Loc::Rung { rung, bucket, idx });
                }
            }
            Loc::Overflow(i) => {
                let i = i as usize;
                debug_assert!(
                    matches!(self.overflow[i].kind, EventKind::Departure { job: j } if j == job),
                    "ladder overflow location out of sync"
                );
                self.overflow.swap_remove(i);
                if i < self.overflow.len() {
                    let moved = self.overflow[i];
                    self.note(&moved, Loc::Overflow(i as u32));
                }
            }
        }
        self.loc[slot] = Loc::None;
        self.len -= 1;
        true
    }

    /// True iff `job` currently has a scheduled departure.
    #[inline]
    pub fn has_departure(&self, job: JobId) -> bool {
        self.loc
            .get(Self::job_slot(job))
            .map(|&l| l != Loc::None)
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop all events and reset the sequence counter (engine reuse).
    /// Bucket/rung allocations are retained; tuning state resets so a
    /// cleared queue behaves exactly like a fresh one.
    pub fn clear(&mut self) {
        self.bottom.clear();
        self.head = 0;
        self.bot_hi = f64::NEG_INFINITY;
        while let Some(mut r) = self.rungs.pop() {
            for b in &mut r.buckets {
                b.clear();
            }
            r.cur = 0;
            self.spare.push(r);
        }
        self.overflow.clear();
        for l in &mut self.loc {
            *l = Loc::None;
        }
        self.next_seq = 0;
        self.len = 0;
        self.gap_ewma = 0.0;
        self.spills = 0;
        self.tie_spills = 0;
        self.reseeds = 0;
    }

    /// Rung spills performed so far (observability; tests use it to
    /// prove heavy-tailed inputs actually exercised the spill path).
    pub fn spills(&self) -> u64 {
        self.spills
    }

    /// Seq-keyed tie-rung constructions so far (all-ties clusters that
    /// would otherwise drain — and cancel — as one O(cluster) block).
    pub fn tie_spills(&self) -> u64 {
        self.tie_spills
    }

    /// Overflow re-seeds performed so far.
    pub fn reseeds(&self) -> u64 {
        self.reseeds
    }

    /// Current rung-stack depth.
    pub fn rung_depth(&self) -> usize {
        self.rungs.len()
    }
}

impl crate::sim::schedule::EventSchedule for LadderQueue {
    #[inline]
    fn push(&mut self, t: f64, kind: EventKind) {
        LadderQueue::push(self, t, kind)
    }

    #[inline]
    fn peek_t(&mut self) -> Option<f64> {
        LadderQueue::peek_t(self)
    }

    #[inline]
    fn pop(&mut self) -> Option<Event> {
        LadderQueue::pop(self)
    }

    fn cancel_departure(&mut self, job: JobId) -> bool {
        LadderQueue::cancel_departure(self, job)
    }

    #[inline]
    fn has_departure(&self, job: JobId) -> bool {
        LadderQueue::has_departure(self, job)
    }

    fn len(&self) -> usize {
        LadderQueue::len(self)
    }

    fn clear(&mut self) {
        LadderQueue::clear(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = LadderQueue::new();
        q.push(3.0, EventKind::Arrival);
        q.push(1.0, EventKind::Arrival);
        q.push(2.0, EventKind::PolicyTimer { seq: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.t).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_pop_fifo() {
        let mut q = LadderQueue::new();
        for i in 0..10u64 {
            q.push(1.0, EventKind::Departure { job: i });
        }
        let mut expect = 0u64;
        while let Some(e) = q.pop() {
            assert_eq!(e.t, 1.0);
            match e.kind {
                EventKind::Departure { job } => {
                    assert_eq!(job, expect, "equal-time events must pop in push order");
                    expect += 1;
                }
                _ => panic!("wrong kind"),
            }
        }
        assert_eq!(expect, 10);
    }

    #[test]
    fn cancel_works_in_every_tier() {
        let mut q = LadderQueue::new();
        for i in 0..40u64 {
            q.push(i as f64 * 0.5, EventKind::Departure { job: i });
        }
        // Force a partial drain so events sit in bottom AND rungs.
        assert_eq!(q.pop().unwrap().t, 0.0);
        assert!(q.rung_depth() > 0 || q.head < q.bottom.len());
        // Overflow tier: push beyond the current base rung's limit.
        q.push(1.0e6, EventKind::Departure { job: 99 });
        for job in [1u64, 20, 39, 99] {
            assert!(q.has_departure(job), "job {job}");
            assert!(q.cancel_departure(job), "job {job}");
            assert!(!q.cancel_departure(job), "double cancel must fail");
        }
        assert!(!q.cancel_departure(7_000), "unknown job must fail");
        let mut last = (f64::NEG_INFINITY, 0u64);
        let mut n = 0;
        while let Some(e) = q.pop() {
            assert!((e.t, e.seq) > last, "order violated");
            last = (e.t, e.seq);
            if let EventKind::Departure { job } = e.kind {
                assert!(![1u64, 20, 39, 99].contains(&job), "cancelled {job} popped");
            }
            n += 1;
        }
        assert_eq!(n, 40 - 1 - 4);
    }

    #[test]
    fn cancel_then_reschedule() {
        let mut q = LadderQueue::new();
        q.push(5.0, EventKind::Departure { job: 3 });
        q.push(1.0, EventKind::Arrival);
        assert!(q.cancel_departure(3));
        q.push(2.0, EventKind::Departure { job: 3 });
        assert_eq!(q.pop().unwrap().t, 1.0);
        let e = q.pop().unwrap();
        assert_eq!(e.t, 2.0);
        assert!(matches!(e.kind, EventKind::Departure { job: 3 }));
        assert!(q.is_empty());
    }

    #[test]
    fn clear_resets_sequence_for_reuse() {
        let mut q = LadderQueue::new();
        for i in 0..100u64 {
            q.push((i % 13) as f64, EventKind::Departure { job: i });
        }
        q.pop();
        q.clear();
        assert!(q.is_empty());
        assert!(!q.has_departure(0));
        q.push(4.0, EventKind::Arrival);
        assert_eq!(q.pop().unwrap().seq, 0, "sequence restarts after clear");
    }

    #[test]
    fn dense_bucket_spills_to_child_rung() {
        let mut q = LadderQueue::new();
        // A tight cluster plus a far tail: the re-seeded base rung puts
        // the cluster into few buckets, which must spill.
        for i in 0..600u64 {
            q.push(10.0 + (i as f64) * 1e-6, EventKind::Departure { job: i });
        }
        q.push(1.0e9, EventKind::Arrival);
        let first = q.pop().unwrap();
        assert_eq!(first.t, 10.0);
        assert!(q.spills() > 0, "cluster+tail input must exercise the spill path");
        let mut last = (first.t, first.seq);
        while let Some(e) = q.pop() {
            assert!((e.t, e.seq) > last);
            last = (e.t, e.seq);
        }
    }

    #[test]
    fn all_equal_times_do_not_spill_forever() {
        let mut q = LadderQueue::new();
        for i in 0..500u64 {
            q.push(7.0, EventKind::Departure { job: i });
        }
        let mut expect = 0u64;
        while let Some(e) = q.pop() {
            match e.kind {
                EventKind::Departure { job } => {
                    assert_eq!(job, expect);
                    expect += 1;
                }
                _ => unreachable!(),
            }
        }
        assert_eq!(expect, 500);
    }

    #[test]
    fn giant_tie_cluster_splits_by_seq_and_cancels_cheaply() {
        let mut q = LadderQueue::new();
        for i in 0..1000u64 {
            q.push(7.0, EventKind::Departure { job: i });
        }
        assert_eq!(q.pop().unwrap().seq, 0);
        assert!(q.tie_spills() > 0, "tie cluster must take the seq-keyed path");
        // Cancels landing in undrained sub-buckets are swap-removes;
        // FIFO pop order must survive them.
        let cancelled: Vec<u64> = (100..900).step_by(50).collect();
        for &job in &cancelled {
            assert!(q.cancel_departure(job), "job {job}");
        }
        let mut expect = 1u64;
        while let Some(e) = q.pop() {
            assert_eq!(e.t, 7.0);
            let EventKind::Departure { job } = e.kind else {
                panic!("wrong kind")
            };
            while cancelled.contains(&expect) {
                expect += 1;
            }
            assert_eq!(job, expect);
            expect += 1;
        }
        assert_eq!(expect, 1000);
    }

    #[test]
    fn tie_rung_accepts_pushes_at_and_before_the_tie_time() {
        let mut q = LadderQueue::new();
        for i in 0..300u64 {
            q.push(5.0, EventKind::Departure { job: i });
        }
        assert_eq!(q.pop().unwrap().seq, 0); // tie rung is live
        assert!(q.tie_spills() > 0);
        // A new same-time departure must pop after every older tie; an
        // earlier-time push must pop before all remaining ties.
        q.push(5.0, EventKind::Departure { job: 300 });
        q.push(4.5, EventKind::Departure { job: 301 });
        let e = q.pop().unwrap();
        assert!(matches!(e.kind, EventKind::Departure { job: 301 }));
        let (mut last_seq, mut saw) = (0u64, 0u32);
        while let Some(e) = q.pop() {
            assert_eq!(e.t, 5.0);
            assert!(e.seq > last_seq, "FIFO order violated at seq {}", e.seq);
            last_seq = e.seq;
            saw += 1;
        }
        assert_eq!(saw, 300, "ties 1..=299 plus the late same-time push");
    }

    #[test]
    fn tie_cluster_inside_a_spread_rung_spills_by_seq() {
        let mut q = LadderQueue::new();
        // Spread events force a normal time-keyed base rung; the tie
        // cluster then lands in one of its buckets and must spill via
        // the seq-keyed arm of try_spill (not reseed_ties).
        for i in 0..200u64 {
            q.push(1.0 + i as f64, EventKind::Departure { job: i });
        }
        for i in 200..600u64 {
            q.push(50.0, EventKind::Departure { job: i });
        }
        let first = q.pop().unwrap();
        assert_eq!(first.t, 1.0);
        let mut last = (first.t, first.seq);
        while let Some(e) = q.pop() {
            assert!((e.t, e.seq) > last, "order violated");
            last = (e.t, e.seq);
        }
        assert!(q.tie_spills() > 0, "embedded tie cluster must split by seq");
    }
}
