//! Simulation output metrics: per-class response times, time-averaged
//! occupancy and utilization, Jain fairness, weighted mean response time.

use crate::util::stats::{jain_index, BatchMeans, TimeAverage, Welford};
use crate::workload::Workload;

/// Collects per-class and aggregate statistics; `reset` is called at the
/// end of warmup so reported numbers cover only the measurement window.
pub struct Metrics {
    /// Response-time accumulators per class.
    pub resp: Vec<Welford>,
    /// Batch-means accumulator for the overall response time CI.
    pub resp_all: BatchMeans,
    /// Time-average of jobs-in-system per class.
    pub n_avg: Vec<TimeAverage>,
    /// Time-average of busy servers.
    pub busy_avg: TimeAverage,
    /// Completions counted (post-warmup).
    pub completed: u64,
    /// Measurement window start.
    pub window_start: f64,
    batch: u64,
}

impl Metrics {
    pub fn new(num_classes: usize, batch: u64) -> Self {
        Self {
            resp: vec![Welford::new(); num_classes],
            resp_all: BatchMeans::new(batch),
            n_avg: vec![TimeAverage::new(); num_classes],
            busy_avg: TimeAverage::new(),
            completed: 0,
            window_start: 0.0,
            batch,
        }
    }

    pub fn record_response(&mut self, class: usize, t: f64) {
        self.resp[class].push(t);
        self.resp_all.push(t);
        self.completed += 1;
    }

    pub fn occupancy_changed(&mut self, now: f64, class: usize, n: u32) {
        self.n_avg[class].update(now, n as f64);
    }

    pub fn busy_changed(&mut self, now: f64, busy: u32) {
        self.busy_avg.update(now, busy as f64);
    }

    /// Drop warmup samples: zero all accumulators but re-seed the
    /// time-averages at the current occupancy.
    pub fn reset_at(&mut self, now: f64, n_by_class: &[u32], busy: u32) {
        for w in &mut self.resp {
            *w = Welford::new();
        }
        self.resp_all = BatchMeans::new(self.batch);
        for (c, ta) in self.n_avg.iter_mut().enumerate() {
            *ta = TimeAverage::new();
            ta.update(now, n_by_class[c] as f64);
        }
        self.busy_avg = TimeAverage::new();
        self.busy_avg.update(now, busy as f64);
        self.completed = 0;
        self.window_start = now;
    }
}

/// Final, immutable result of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub policy: String,
    /// Mean response time per class (NaN if no completions).
    pub mean_t: Vec<f64>,
    /// Completions per class.
    pub count: Vec<u64>,
    /// Time-average number in system per class.
    pub mean_n: Vec<f64>,
    /// Overall mean response time.
    pub mean_t_all: f64,
    /// 95% CI half-width for the overall mean (batch means).
    pub ci95: f64,
    /// Load-weighted mean response time E[T^w] (§6.1).
    pub weighted_t: f64,
    /// Jain fairness index over per-class means (Eq. C.1).
    pub jain: f64,
    /// Time-average busy servers / k.
    pub utilization: f64,
    /// Simulated (virtual) measurement time.
    pub sim_time: f64,
    /// Total events processed (incl. warmup).
    pub events: u64,
    /// Completions in the measurement window.
    pub completed: u64,
    /// Wall-clock seconds.
    pub wall_s: f64,
    /// Phase-duration statistics (when tracked).
    pub phases: Option<crate::sim::phase::PhaseStats>,
    /// Occupancy time-series (when recorded).
    pub timeseries: Option<crate::sim::timeseries::Timeseries>,
}

impl SimResult {
    pub fn from_metrics(
        policy: &str,
        m: &Metrics,
        wl: &Workload,
        now: f64,
        events: u64,
        wall_s: f64,
    ) -> SimResult {
        let nc = m.resp.len();
        let mean_t: Vec<f64> = m.resp.iter().map(|w| w.mean()).collect();
        let count: Vec<u64> = m.resp.iter().map(|w| w.count()).collect();
        let mean_n: Vec<f64> = m.n_avg.iter().map(|ta| ta.average(now)).collect();
        let mean_t_all = m.resp_all.mean();
        // Load weights ρ_j = need_j · λ_j / μ_j from the workload spec.
        let rho: Vec<f64> = (0..nc).map(|c| wl.rho_class(c)).collect();
        let rho_tot: f64 = rho.iter().sum();
        let weighted_t = if rho_tot > 0.0 {
            (0..nc)
                .map(|c| {
                    if count[c] > 0 {
                        rho[c] / rho_tot * mean_t[c]
                    } else {
                        0.0
                    }
                })
                .sum()
        } else {
            f64::NAN
        };
        SimResult {
            policy: policy.to_string(),
            jain: jain_index(&mean_t),
            mean_t,
            count,
            mean_n,
            mean_t_all,
            ci95: m.resp_all.ci95_half_width(),
            weighted_t,
            utilization: m.busy_avg.average(now) / wl.k as f64,
            sim_time: now - m.window_start,
            events,
            completed: m.completed,
            wall_s,
            phases: None,
            timeseries: None,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<14} E[T]={:>9.3} ±{:<8.3} E[T^w]={:>10.3} util={:.3} jain={:.3} (n={})",
            self.policy, self.mean_t_all, self.ci95, self.weighted_t, self.utilization, self.jain,
            self.completed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Dist;
    use crate::workload::{ClassSpec, Workload};

    fn wl2() -> Workload {
        Workload::new(
            4,
            vec![
                ClassSpec::new(1, 1.0, Dist::exp_mean(1.0)),
                ClassSpec::new(4, 0.25, Dist::exp_mean(1.0)),
            ],
        )
    }

    #[test]
    fn weighted_mean_uses_load_shares() {
        let wl = wl2();
        let mut m = Metrics::new(2, 10);
        for _ in 0..100 {
            m.record_response(0, 1.0);
            m.record_response(1, 3.0);
        }
        m.n_avg[0].update(0.0, 1.0);
        m.n_avg[1].update(0.0, 1.0);
        m.busy_avg.update(0.0, 2.0);
        let r = SimResult::from_metrics("t", &m, &wl, 10.0, 200, 0.1);
        // ρ_1 = 1·1/1 = 1, ρ_2 = 4·0.25/1 = 1 → weights 1/2, 1/2.
        assert!((r.weighted_t - 2.0).abs() < 1e-12);
        assert!((r.mean_t_all - 2.0).abs() < 1e-12);
        assert!((r.utilization - 0.5).abs() < 1e-12);
    }
}
